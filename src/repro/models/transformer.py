"""Model assembly for all assigned architectures.

One functional `Model` facade with three entry points:

  * ``loss(params, batch)``            — training forward (next-token CE)
  * ``prefill(params, batch)``         — full forward returning logits
  * ``decode_step(params, cache, tok, t)`` — one token with KV/state cache

Layer stacks are scanned (``lax.scan`` over stacked params) for compact HLO;
heterogeneous patterns (DeepSeek first dense layer, Zamba2 shared-attention
interleave, Whisper enc/dec) are composed from scanned homogeneous chunks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import ffn as F
from . import ssm as S
from .common import (apply_norm, cross_entropy, dense_init, embed_init,
                     norm_params, sinusoidal_pos, sinusoidal_pos_at)
from repro.runtime.shard_ctx import constrain


# ---------------------------------------------------------------------------
# Block-level init/apply
# ---------------------------------------------------------------------------

def _init_dense_block(cfg, key) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    attn = A.init_mla(cfg, k1) if cfg.mla else A.init_attn(cfg, k1)
    return {"ln1": norm_params(cfg, k3, cfg.d_model, jnp.dtype(cfg.dtype)),
            "attn": attn,
            "ln2": norm_params(cfg, k4, cfg.d_model, jnp.dtype(cfg.dtype)),
            "mlp": F.init_mlp(cfg, k2)}


def _init_moe_block(cfg, key) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    attn = A.init_mla(cfg, k1) if cfg.mla else A.init_attn(cfg, k1)
    return {"ln1": norm_params(cfg, k3, cfg.d_model, jnp.dtype(cfg.dtype)),
            "attn": attn,
            "ln2": norm_params(cfg, k4, cfg.d_model, jnp.dtype(cfg.dtype)),
            "moe": F.init_moe(cfg, k2)}


def _init_mamba_block(cfg, key) -> dict:
    k1, k2 = jax.random.split(key)
    return {"ln": norm_params(cfg, k2, cfg.d_model, jnp.dtype(cfg.dtype)),
            "mamba": S.init_mamba2(cfg, k1)}


def _init_rwkv_block(cfg, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": norm_params(cfg, k2, cfg.d_model, jnp.dtype(cfg.dtype)),
            "ln2": norm_params(cfg, k3, cfg.d_model, jnp.dtype(cfg.dtype)),
            "tmix": S.init_rwkv6(cfg, k1)}


def _init_enc_block(cfg, key) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"ln1": norm_params(cfg, k3, cfg.d_model, jnp.dtype(cfg.dtype)),
            "attn": A.init_attn(cfg, k1),
            "ln2": norm_params(cfg, k4, cfg.d_model, jnp.dtype(cfg.dtype)),
            "mlp": F.init_mlp(cfg, k2)}


def _init_dec_block(cfg, key) -> dict:
    ks = jax.random.split(key, 6)
    return {"ln1": norm_params(cfg, ks[0], cfg.d_model, jnp.dtype(cfg.dtype)),
            "attn": A.init_attn(cfg, ks[1]),
            "ln_x": norm_params(cfg, ks[2], cfg.d_model, jnp.dtype(cfg.dtype)),
            "xattn": A.init_cross_attn(cfg, ks[3]),
            "ln2": norm_params(cfg, ks[4], cfg.d_model, jnp.dtype(cfg.dtype)),
            "mlp": F.init_mlp(cfg, ks[5])}


def _attn_full(cfg, p, h, pos, pos3, window):
    if cfg.mla:
        return A.mla_full(cfg, p, h, pos=pos, window=window)
    return A.gqa_full(cfg, p, h, causal=True, pos=pos, pos3=pos3,
                      window=window)


def _dense_block(cfg, p, x, pos, pos3, window):
    h = apply_norm(cfg, x, p["ln1"])
    x = x + _attn_full(cfg, p["attn"], h, pos, pos3, window)
    h = apply_norm(cfg, x, p["ln2"])
    return x + F.mlp(cfg, p["mlp"], h)


def _moe_block(cfg, p, x, pos, pos3, window):
    h = apply_norm(cfg, x, p["ln1"])
    x = x + _attn_full(cfg, p["attn"], h, pos, pos3, window)
    h = apply_norm(cfg, x, p["ln2"])
    out, aux = F.moe(cfg, p["moe"], h)
    return x + out, aux


def _mamba_block(cfg, p, x):
    return x + S.mamba2_full(cfg, p["mamba"], apply_norm(cfg, x, p["ln"]))


def _rwkv_block(cfg, p, x):
    x = x + S.rwkv6_time_mix(cfg, p["tmix"], apply_norm(cfg, x, p["ln1"]))
    return x + S.rwkv6_channel_mix(cfg, p["tmix"],
                                   apply_norm(cfg, x, p["ln2"]))


# ---------------------------------------------------------------------------
# Stacking helpers
# ---------------------------------------------------------------------------

def _stack_init(init_fn, cfg, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(cfg, k))(keys)


def _scan_blocks(body, x, stacked, remat: bool):
    def wrapped(c, p):
        c = constrain(c)          # FCO T-boundary: activation re-layout point
        return body(c, p)
    fn = jax.checkpoint(wrapped) if remat else wrapped
    x, aux = jax.lax.scan(lambda c, p: fn(c, p), x, stacked)
    return constrain(x), aux


# ---------------------------------------------------------------------------
# Model facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any

    # ---------------- init ----------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "tok_emb": embed_init(ks[0], cfg.vocab, cfg.d_model, dt),
            "final_norm": norm_params(cfg, ks[1], cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab, dt)

        fam = cfg.family
        if fam in ("dense", "vlm"):
            params["blocks"] = _stack_init(_init_dense_block, cfg, ks[3],
                                           cfg.n_layers)
        elif fam == "moe":
            m = cfg.moe
            if m.first_dense:
                dense_cfg = dataclasses.replace(cfg, d_ff=m.d_ff_dense
                                                or cfg.d_ff)
                params["first_blocks"] = _stack_init(
                    _init_dense_block, dense_cfg, ks[4], m.first_dense)
            params["blocks"] = _stack_init(_init_moe_block, cfg, ks[3],
                                           cfg.n_layers - m.first_dense)
        elif fam == "ssm":
            params["blocks"] = _stack_init(_init_rwkv_block, cfg, ks[3],
                                           cfg.n_layers)
        elif fam == "hybrid":
            params["blocks"] = _stack_init(_init_mamba_block, cfg, ks[3],
                                           cfg.n_layers)
            params["shared_attn"] = _init_dense_block(cfg, ks[5])
        elif fam == "encdec":
            params["enc_blocks"] = _stack_init(_init_enc_block, cfg, ks[3],
                                               cfg.n_enc_layers)
            params["blocks"] = _stack_init(_init_dec_block, cfg, ks[4],
                                           cfg.n_layers)
            params["enc_norm"] = norm_params(cfg, ks[6], cfg.d_model, dt)
        else:
            raise ValueError(fam)
        return params

    # ---------------- shared pieces ----------------
    def _embed(self, params, tokens):
        return params["tok_emb"][tokens]

    def _logits(self, params, x):
        x = apply_norm(self.cfg, x, params["final_norm"])
        if self.cfg.tie_embeddings:
            return x @ params["tok_emb"].T
        return x @ params["lm_head"]

    def _positions(self, batch) -> Tuple[Optional[jnp.ndarray],
                                         Optional[jnp.ndarray]]:
        """(pos [B,S], pos3 [B,3,S]) for the decoder stream."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, Stx = tokens.shape
        if cfg.family == "vlm":
            nv = cfg.vision_tokens
            side = max(1, int(math.sqrt(nv)))
            t_v = jnp.zeros((nv,), jnp.int32)
            hcoord = (jnp.arange(nv) // side).astype(jnp.int32)
            wcoord = (jnp.arange(nv) % side).astype(jnp.int32)
            t_t = jnp.arange(Stx, dtype=jnp.int32) + 1
            pos3 = jnp.stack([
                jnp.concatenate([t_v, t_t]),
                jnp.concatenate([hcoord, t_t]),
                jnp.concatenate([wcoord, t_t]),
            ])                                            # [3, nv+Stx]
            pos3 = jnp.broadcast_to(pos3[None], (B, 3, nv + Stx))
            return None, pos3
        pos = jnp.broadcast_to(jnp.arange(Stx, dtype=jnp.int32)[None],
                               (B, Stx))
        return pos, None

    # ---------------- full forward ----------------
    def forward(self, params, batch, *, remat: bool = False) -> Tuple[
            jnp.ndarray, jnp.ndarray]:
        """Returns (logits over the decoder stream, aux loss)."""
        cfg = self.cfg
        window = cfg.attn_window
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        aux = jnp.zeros((), jnp.float32)

        if cfg.family == "vlm":
            x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x],
                                axis=1)
        pos, pos3 = self._positions(batch)

        fam = cfg.family
        if fam in ("dense", "vlm"):
            def body(h, p):
                return _dense_block(cfg, p, h, pos, pos3, window), 0.0
            x, _ = _scan_blocks(body, x, params["blocks"], remat)
        elif fam == "moe":
            if "first_blocks" in params:
                dcfg = dataclasses.replace(cfg, d_ff=cfg.moe.d_ff_dense
                                           or cfg.d_ff)
                def dbody(h, p):
                    return _dense_block(dcfg, p, h, pos, pos3, window), 0.0
                x, _ = _scan_blocks(dbody, x, params["first_blocks"], remat)
            def mbody(h, p):
                h, a = _moe_block(cfg, p, h, pos, pos3, window)
                return h, a
            x, auxs = _scan_blocks(mbody, x, params["blocks"], remat)
            aux = aux + auxs.sum()
        elif fam == "ssm":
            def body(h, p):
                return _rwkv_block(cfg, p, h), 0.0
            x, _ = _scan_blocks(body, x, params["blocks"], remat)
        elif fam == "hybrid":
            x = self._hybrid_forward(params, x, pos, window, remat)
        elif fam == "encdec":
            x = self._encdec_forward(params, batch, x, pos, window, remat)
        else:
            raise ValueError(fam)

        logits = self._logits(params, x)
        if fam == "vlm":
            logits = logits[:, cfg.vision_tokens:, :]
        return logits, aux

    def _hybrid_forward(self, params, x, pos, window, remat):
        """Zamba2: scan chunks of mamba blocks, shared attn block between."""
        cfg = self.cfg
        every = cfg.hybrid_attn_every or cfg.n_layers
        n = cfg.n_layers
        off = 0
        while off < n:
            size = min(every, n - off)
            chunk = jax.tree.map(lambda a: a[off:off + size], params["blocks"])
            def body(h, p):
                return _mamba_block(cfg, p, h), 0.0
            x, _ = _scan_blocks(body, x, chunk, remat)
            x = _dense_block(cfg, params["shared_attn"], x, pos, None, window)
            off += size
        return x

    def encode(self, params, audio_embeds, *, remat: bool = False):
        """Whisper encoder over stub frame embeddings -> [B, enc_seq, d]."""
        cfg = self.cfg
        enc = audio_embeds.astype(jnp.dtype(cfg.dtype))
        enc = enc + sinusoidal_pos(enc.shape[1], cfg.d_model).astype(enc.dtype)

        def ebody(h, p):
            hh = apply_norm(cfg, h, p["ln1"])
            h = h + A.gqa_full(cfg, p["attn"], hh, causal=False)
            hh = apply_norm(cfg, h, p["ln2"])
            return h + F.mlp(cfg, p["mlp"], hh), 0.0
        enc, _ = _scan_blocks(ebody, enc, params["enc_blocks"], remat)
        return apply_norm(cfg, enc, params["enc_norm"])

    def _encdec_forward(self, params, batch, x, pos, window, remat):
        cfg = self.cfg
        enc = self.encode(params, batch["audio_embeds"], remat=remat)
        x = x + sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)

        def dbody(h, p):
            hh = apply_norm(cfg, h, p["ln1"])
            h = h + A.gqa_full(cfg, p["attn"], hh, causal=True, window=window)
            hh = apply_norm(cfg, h, p["ln_x"])
            h = h + A.gqa_full(cfg, p["xattn"], hh, causal=False, kv_x=enc)
            hh = apply_norm(cfg, h, p["ln2"])
            return h + F.mlp(cfg, p["mlp"], hh), 0.0
        x, _ = _scan_blocks(dbody, x, params["blocks"], remat)
        return x

    # ---------------- loss ----------------
    def loss(self, params, batch, *, remat: bool = True) -> jnp.ndarray:
        logits, aux = self.forward(params, batch, remat=remat)
        labels = batch["labels"]
        return cross_entropy(logits, labels) + aux

    # ---------------- decode ----------------
    def cache_init(self, batch: int, capacity: int) -> Dict[str, Any]:
        """Per-layer cache pages (a list, not a stacked array): the decode
        loop is unrolled so each layer performs exactly one in-place
        dynamic-update-slice — scanned stacks would copy the whole cache in
        and out of the loop carry every layer."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        fam = cfg.family

        def pages(fn, n):
            return [fn() for _ in range(n)]

        if fam in ("dense", "vlm", "moe"):
            mk = (lambda: A.mla_cache_init(cfg, batch, capacity, dt)) \
                if cfg.mla else \
                (lambda: A.gqa_cache_init(cfg, batch, capacity, dt))
            out = {"layers": pages(mk, cfg.n_layers - (
                cfg.moe.first_dense if cfg.moe else 0))}
            if cfg.moe and cfg.moe.first_dense:
                out["first_layers"] = pages(mk, cfg.moe.first_dense)
            return out
        if fam == "ssm":
            return {"layers": pages(
                lambda: S.rwkv6_state_init(cfg, batch), cfg.n_layers)}
        if fam == "hybrid":
            n_attn = -(-cfg.n_layers // (cfg.hybrid_attn_every
                                         or cfg.n_layers))
            return {
                "layers": pages(lambda: S.mamba2_state_init(cfg, batch),
                                cfg.n_layers),
                "attn_layers": pages(
                    lambda: A.gqa_cache_init(cfg, batch, capacity, dt),
                    n_attn),
            }
        if fam == "encdec":
            return {
                "layers": pages(
                    lambda: A.gqa_cache_init(cfg, batch, capacity, dt),
                    cfg.n_layers),
                # cross-attn K/V cached once at prefill (recomputing them
                # from enc_out per decode token dominated whisper's memory
                # roofline term — §Perf E)
                "xlayers": pages(
                    lambda: {"xk": jnp.zeros((batch, cfg.n_kv, cfg.enc_seq,
                                              cfg.hd), dt),
                             "xv": jnp.zeros((batch, cfg.n_kv, cfg.enc_seq,
                                              cfg.hd), dt)},
                    cfg.n_layers),
            }
        raise ValueError(fam)

    def encode_cross(self, params, audio_embeds):
        """Whisper serve-time prefill: encoder forward + per-layer cross
        K/V cache pages (fills ``cache['xlayers']``)."""
        enc = self.encode(params, audio_embeds)
        out = []
        for i in range(self.cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["blocks"])
            xk, xv = A.cross_kv(self.cfg, p["xattn"], enc)
            out.append({"xk": xk, "xv": xv})
        return out

    def decode_step(self, params, cache, tok, t) -> Tuple[jnp.ndarray,
                                                          Dict[str, Any]]:
        """tok [B,1] int32; t scalar int32 position.  Returns (logits [B,1,V],
        new cache)."""
        cfg = self.cfg
        window = cfg.attn_window
        x = self._embed(params, tok)
        fam = cfg.family
        new_cache = dict(cache)

        if fam in ("dense", "vlm", "moe"):
            # decode MoE is drop-free: groups = batch rows with one token
            # each, so per-(group, expert) capacity 1 suffices exactly
            decode_cap = 1
            rope_pos = t + 1 if fam == "vlm" else t

            def body(h, p, c):
                hh = apply_norm(cfg, h, p["ln1"])
                if cfg.mla:
                    a, c2 = A.mla_decode(cfg, p["attn"], hh, c, t,
                                         rope_pos=rope_pos)
                else:
                    a, c2 = A.gqa_decode(cfg, p["attn"], hh, c, t,
                                         rope_pos=rope_pos)
                h = h + a
                hh = apply_norm(cfg, h, p["ln2"])
                if "moe" in p:
                    out, _ = F.moe(cfg, p["moe"], hh, capacity=decode_cap)
                    h = h + out
                else:
                    h = h + F.mlp(cfg, p["mlp"], hh)
                return h, c2
            if fam == "moe" and "first_blocks" in params:
                fcs = []
                for i, c in enumerate(cache["first_layers"]):
                    p = jax.tree.map(lambda a: a[i], params["first_blocks"])
                    x, c2 = body(x, p, c)
                    fcs.append(c2)
                new_cache["first_layers"] = fcs
            lcs = []
            for i, c in enumerate(cache["layers"]):
                p = jax.tree.map(lambda a: a[i], params["blocks"])
                x, c2 = body(x, p, c)
                lcs.append(c2)
            new_cache["layers"] = lcs
        elif fam == "ssm":
            lcs = []
            for i, c in enumerate(cache["layers"]):
                p = jax.tree.map(lambda a: a[i], params["blocks"])
                a, c2 = S.rwkv6_decode(cfg, p["tmix"],
                                       apply_norm(cfg, x, p["ln1"]), c)
                x = x + a
                x = x + S.rwkv6_channel_mix(
                    cfg, p["tmix"], apply_norm(cfg, x, p["ln2"]))
                lcs.append(c2)
            new_cache["layers"] = lcs
        elif fam == "hybrid":
            x, new_cache = self._hybrid_decode(params, cache, x, t)
        elif fam == "encdec":
            x, new_cache = self._encdec_decode(params, cache, x, t)
        else:
            raise ValueError(fam)
        return self._logits(params, x), new_cache

    def _hybrid_decode(self, params, cache, x, t):
        cfg = self.cfg
        every = cfg.hybrid_attn_every or cfg.n_layers
        n = cfg.n_layers
        new_cache = dict(cache)
        new_m, new_a = [], []
        ai = 0
        for i in range(n):
            p = jax.tree.map(lambda a: a[i], params["blocks"])
            a_out, c2 = S.mamba2_decode(cfg, p["mamba"],
                                        apply_norm(cfg, x, p["ln"]),
                                        cache["layers"][i])
            x = x + a_out
            new_m.append(c2)
            if (i + 1) % every == 0 or i == n - 1:
                pa = params["shared_attn"]
                hh = apply_norm(cfg, x, pa["ln1"])
                a2, ac2 = A.gqa_decode(cfg, pa["attn"], hh,
                                       cache["attn_layers"][ai], t)
                x = x + a2
                hh = apply_norm(cfg, x, pa["ln2"])
                x = x + F.mlp(cfg, pa["mlp"], hh)
                new_a.append(ac2)
                ai += 1
        new_cache["layers"] = new_m
        new_cache["attn_layers"] = new_a
        return x, new_cache

    def _encdec_decode(self, params, cache, x, t):
        cfg = self.cfg
        x = x + sinusoidal_pos_at(t, cfg.d_model).astype(x.dtype)[None, None]
        lcs = []
        for i, c in enumerate(cache["layers"]):
            p = jax.tree.map(lambda a: a[i], params["blocks"])
            xc = cache["xlayers"][i]
            hh = apply_norm(cfg, x, p["ln1"])
            a, c2 = A.gqa_decode(cfg, p["attn"], hh, c, t)
            x = x + a
            hh = apply_norm(cfg, x, p["ln_x"])
            x = x + A.gqa_cross_cached(cfg, p["xattn"], hh, xc["xk"],
                                       xc["xv"])
            hh = apply_norm(cfg, x, p["ln2"])
            x = x + F.mlp(cfg, p["mlp"], hh)
            lcs.append(c2)
        new_cache = dict(cache)
        new_cache["layers"] = lcs
        return x, new_cache

    # prefill = forward returning logits (cache prefill is exercised via
    # decode-from-scratch in tests; production serving lowers decode_step)
    def prefill(self, params, batch):
        logits, _ = self.forward(params, batch)
        return logits
