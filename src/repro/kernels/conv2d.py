"""Pallas TPU conv2d with output-row tiling — the FlexPie compute hot spot.

The edge engine's partitioned inference runs conv shards with halo rows
(§2.3 of the paper).  This kernel is the TPU-native version of one shard's
compute: the (pre-padded) input lives in VMEM, the output is tiled by rows,
and each (kh, kw) kernel tap is an MXU matmul ``[tile_h*W, Cin] @
[Cin, Cout]`` accumulated in f32 — im2col without materializing the im2col
matrix.  The halo handling mirrors NT-mode: a tile reads ``K-1`` rows past
its own range, exactly the redundant-compute region the planner accounts
for.

Stride-1 convs only (the edge models' 3x3/1x1 layers); strided layers fall
back to the jnp reference in ops.py.  Validated with interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, k: int, tile_h: int, out_w: int,
                 cin: int, cout: int):
    i = pl.program_id(0)
    acc = jnp.zeros((tile_h * out_w, cout), jnp.float32)
    for kh in range(k):
        for kw in range(k):
            # rows [i*tile_h + kh, ...), cols [kw, kw+out_w)
            xs = x_ref[pl.dslice(i * tile_h + kh, tile_h),
                       pl.dslice(kw, out_w), :]
            xm = xs.reshape(tile_h * out_w, cin).astype(jnp.float32)
            wm = w_ref[kh, kw].astype(jnp.float32)      # [cin, cout]
            acc = acc + jax.lax.dot_general(
                xm, wm, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    o_ref[...] = acc.reshape(tile_h, out_w, cout).astype(o_ref.dtype)


def conv2d_tiled(x: jnp.ndarray, w: jnp.ndarray, *, padding: int = 0,
                 tile_h: int = 8, interpret: bool = True) -> jnp.ndarray:
    """x: [H, W, Cin] (unpadded); w: [K, K, Cin, Cout]; stride 1."""
    K = w.shape[0]
    cin, cout = w.shape[2], w.shape[3]
    xp = jnp.pad(x, ((padding, padding), (padding, padding), (0, 0)))
    Hp, Wp, _ = xp.shape
    out_h = Hp - K + 1
    out_w = Wp - K + 1
    # pad output rows to a tile multiple (extra rows computed then dropped)
    nt = -(-out_h // tile_h)
    pad_rows = nt * tile_h - out_h
    if pad_rows:
        xp = jnp.pad(xp, ((0, pad_rows), (0, 0), (0, 0)))
    kernel = functools.partial(_conv_kernel, k=K, tile_h=tile_h, out_w=out_w,
                               cin=cin, cout=cout)
    out = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),     # input in VMEM
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_h, out_w, cout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nt * tile_h, out_w, cout), x.dtype),
        interpret=interpret,
    )(xp, w)
    return out[:out_h]
