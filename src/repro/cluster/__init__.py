"""Heterogeneous cluster subsystem: specs, weighted costing, simulator,
serving objectives.

Quick start::

    from repro.cluster import mixed_fast_slow, cluster_plan_search, simulate
    cluster = mixed_fast_slow(6)            # 2 fast + 4 slow devices
    res = cluster_plan_search(graph, cluster)
    rep = simulate(graph, res.plan, cluster, n_requests=32)

Serving::

    from repro.core import Objective
    thr = cluster_plan_search(graph, cluster,
                              objective=Objective.THROUGHPUT)
    best, pts = choose_batch(graph, thr.plan, cluster,
                             arrival_rate_rps=50.0, p99_bound_s=0.2)
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.dpp import (Objective, PlanFrontier, SearchResult,
                            pipeline_frontier, plan_search)
from repro.core.graph import ModelGraph
from repro.core.partition import ALL_SCHEMES, Scheme

from .churn import (CHURN_SCENARIOS, STRATEGIES, ChurnEvent, ChurnRunResult,
                    ChurnScenario, compare_strategies, random_scenario,
                    run_churn)
from .elastic import (CapacityError, DeviceRegistry, DeviceState,
                      ElasticPlanner, Member, MembershipError, MigrationCost,
                      ReplanDecision, migration_cost_s, plan_device_bytes,
                      plan_memory_ok)
from .calibrate import (CalibrationSample, OnlineCalibrator,
                        fold_queueing_delay)
from .estimator import ClusterAnalyticEstimator, ClusterGBDTEstimator
from .refine import (RefineOscillationError, RefineResult, RefineStep,
                     refine_with_simulator)
from .serving import (DecodeServingReport, ServingPoint, choose_batch,
                      max_goodput, plan_decode_serving, serve_decode,
                      serve_point, sweep_serving)
from .simsched import (SimReport, Stage, build_stages, export_sim_trace,
                       simulate, simulate_trace)
from .spec import (CLUSTER_PRESETS, ClusterSpec, DeviceSpec, LinkSpec,
                   asym_uplink, homogeneous, mixed_fast_slow, stepped,
                   topology_edges)


def cluster_plan_search(graph: ModelGraph, cluster: ClusterSpec,
                        weighted: bool = True,
                        schemes: Sequence[Scheme] = ALL_SCHEMES,
                        max_segment: int = 32,
                        allow_fusion: bool = True,
                        objective: Objective = Objective.LATENCY,
                        latency_bound_s: Optional[float] = None,
                        estimator=None) -> SearchResult:
    """DPP over a cluster: batched tables throughout (the cluster estimator
    implements the full batched protocol, so heterogeneous layouts never
    fall back to scalar calls).  ``weighted=False`` plans with even shard
    fractions on the same silicon — the homogeneous-assumption baseline.
    ``objective`` selects the serving objective (single-shot latency,
    pipelined throughput, or p99-bounded throughput).  ``estimator``
    overrides the analytic cluster estimator — pass a
    :class:`ClusterGBDTEstimator` bound to this cluster to plan on
    learned costs (it must be bound to the same cluster; the testbed
    check enforces the projection)."""
    est = estimator if estimator is not None else \
        ClusterAnalyticEstimator(cluster, weighted=weighted)
    return plan_search(graph, est, cluster.compat_testbed(), schemes=schemes,
                       max_segment=max_segment, allow_fusion=allow_fusion,
                       objective=objective, latency_bound_s=latency_bound_s)


def cluster_pipeline_frontier(graph: ModelGraph, cluster: ClusterSpec,
                              weighted: bool = True,
                              schemes: Sequence[Scheme] = ALL_SCHEMES,
                              max_segment: int = 32,
                              allow_fusion: bool = True,
                              ub_cost: Optional[float] = None,
                              prune_ub: bool = True,
                              estimator=None) -> PlanFrontier:
    """The (compute, sync) Pareto frontier of all plans on this cluster —
    one build serves every objective selection and the simulator-in-the-
    loop refinement.  Pass ``prune_ub=False`` when the frontier will be
    re-weighted (``refine_with_simulator``), ``ub_cost`` to reuse an
    already-computed latency optimum (see ``core.pipeline_frontier``),
    ``estimator`` to build the frontier on learned costs
    (:class:`ClusterGBDTEstimator`) instead of the analytic model."""
    est = estimator if estimator is not None else \
        ClusterAnalyticEstimator(cluster, weighted=weighted)
    return pipeline_frontier(graph, est, cluster.compat_testbed(),
                             schemes=schemes, max_segment=max_segment,
                             allow_fusion=allow_fusion, ub_cost=ub_cost,
                             prune_ub=prune_ub)


__all__ = [
    "CHURN_SCENARIOS", "CLUSTER_PRESETS", "CalibrationSample",
    "CapacityError", "ChurnEvent", "ChurnRunResult", "ChurnScenario",
    "ClusterAnalyticEstimator", "ClusterGBDTEstimator", "ClusterSpec",
    "DeviceRegistry",
    "DeviceSpec", "DeviceState", "ElasticPlanner", "LinkSpec", "Member",
    "MembershipError", "MigrationCost", "Objective", "OnlineCalibrator",
    "PlanFrontier",
    "RefineOscillationError", "RefineResult", "RefineStep",
    "ReplanDecision", "STRATEGIES", "ServingPoint", "SimReport", "Stage",
    "asym_uplink", "build_stages", "choose_batch",
    "cluster_pipeline_frontier", "cluster_plan_search",
    "compare_strategies", "export_sim_trace", "fold_queueing_delay",
    "homogeneous",
    "max_goodput", "migration_cost_s", "mixed_fast_slow",
    "DecodeServingReport", "plan_decode_serving", "serve_decode",
    "plan_device_bytes", "plan_memory_ok", "random_scenario",
    "refine_with_simulator", "run_churn", "serve_point", "simulate",
    "simulate_trace", "stepped", "sweep_serving", "topology_edges",
]
