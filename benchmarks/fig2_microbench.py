"""Fig. 2 — micro-bench: per-layer optimal scheme varies with layer and
testbed (MobileNet L2/L5/L13, 4-node vs 3-node)."""
from __future__ import annotations

from repro.core import Testbed
from repro.core.cost import compute_time_s, sync_time_s
from repro.core.partition import ALL_SCHEMES
from repro.configs.edge_models import mobilenet_v1

from .common import emit, time_call

LAYERS = {"L2": 2, "L5": 5, "L13": 13}


def run() -> None:
    g = mobilenet_v1()
    for nodes in (4, 3):
        tb = Testbed(nodes=nodes, bandwidth_gbps=5.0)
        for lname, li in LAYERS.items():
            layer = g.layers[li]
            nxt = g.layers[li + 1] if li + 1 < len(g) else None
            times = {}
            for s in ALL_SCHEMES:
                us, t = time_call(lambda s=s: (
                    compute_time_s(layer, s, tb)
                    + sync_time_s(layer, nxt, s, s, tb)))
                times[s.name] = t
            best = min(times, key=times.get)
            derived = ";".join(f"{k}={v * 1e3:.3f}ms"
                               for k, v in times.items())
            emit(f"fig2/{nodes}n-{lname}", us, f"best={best};{derived}")


if __name__ == "__main__":
    run()
