import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST run before any other import (jax locks the device
count on first init).  512 placeholder CPU devices back both the single-pod
(16, 16) mesh and the multi-pod (2, 16, 16) mesh.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k [--multi-pod] [--strategy auto] [--out out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import make_batch_specs
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, model_flops_estimate
from repro.models.transformer import Model
from repro.optim import adamw_init
from repro.runtime.planner import choose_strategy
from repro.runtime.shard_ctx import (activation_sharding, batch_shard_fn,
                                     seq_shard_fn)
from repro.runtime.shard_plan import (Strategy, batch_specs, cache_specs,
                                      data_axes, named, opt_specs,
                                      param_specs)
from repro.runtime.steps import (make_decode_step, make_prefill_step,
                                 make_train_step)

# (seq_len, global_batch, mode)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

LONG_WINDOW = 4_096   # sliding window used by all archs at 500k context


def arch_for_shape(arch: str, shape: str):
    cfg = get_config(arch)
    if shape == "long_500k" and cfg.family in ("dense", "vlm", "moe",
                                               "encdec"):
        # sub-quadratic requirement: sliding-window attention variant
        cfg = dataclasses.replace(cfg, attn_window=LONG_WINDOW)
    return cfg


def build_inputs(cfg, model: Model, shape: str, mesh, st: Strategy,
                 accum: int = 1):
    """(arg shapes, in_shardings, out_shardings, step_fn, meta)."""
    seq, batch, mode = SHAPES[shape]
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda: model.init(key))
    p_spec = param_specs(params_shape, mesh, st, mode)
    p_sh = named(p_spec, mesh)
    dp = data_axes(mesh)

    if mode == "train":
        opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
        o_spec = opt_specs(p_spec, params_shape)
        o_sh = named(o_spec, mesh)
        b_shape = make_batch_specs(cfg, seq, batch, mode="train")
        b_sh = named(batch_specs(b_shape, mesh), mesh)
        step = make_train_step(model, accum=accum)
        args = (params_shape, opt_shape, b_shape)
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh, NamedSharding(mesh, P()))
        return args, in_sh, out_sh, step, {"mode": mode, "seq": seq,
                                           "batch": batch}

    if mode == "prefill":
        b_shape = make_batch_specs(cfg, seq, batch, mode="prefill")
        b_sh = named(batch_specs(b_shape, mesh), mesh)
        base = make_prefill_step(model)

        def step(params, b):
            return base(params, b)[:, -1, :]
        v_ok = cfg.vocab % mesh.shape["model"] == 0
        out_sh = NamedSharding(mesh, P(dp, "model") if v_ok else P(dp, None))
        return (params_shape, b_shape), (p_sh, b_sh), out_sh, step, \
            {"mode": mode, "seq": seq, "batch": batch}

    # decode
    cap = min(seq, cfg.attn_window or seq)
    cache_shape = jax.eval_shape(lambda: model.cache_init(batch, cap))
    c_spec = cache_specs(cache_shape, mesh, st)
    c_sh = named(c_spec, mesh)
    tok = ShapeDtypeStruct((batch, 1), jnp.int32)
    t = ShapeDtypeStruct((), jnp.int32)
    dpn = _dpn(mesh)
    b_sharded = batch % dpn == 0 and batch > 1
    tok_sh = NamedSharding(mesh, P(dp, None) if b_sharded else P(None, None))
    t_sh = NamedSharding(mesh, P())
    step = make_decode_step(model)
    v_ok = cfg.vocab % mesh.shape["model"] == 0
    logit_sh = NamedSharding(
        mesh, P(dp if b_sharded else None, None,
                "model" if v_ok else None))
    args = (params_shape, cache_shape, tok, t)
    in_sh = (p_sh, c_sh, tok_sh, t_sh)
    out_sh = (logit_sh, c_sh)
    return args, in_sh, out_sh, step, {"mode": mode, "seq": seq,
                                       "batch": batch, "capacity": cap}


def _dpn(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def run_one(arch: str, shape: str, *, multi_pod: bool = False,
            strategy: Optional[Strategy] = None,
            cfg_transform=None, accum: int = 1,
            verbose: bool = True) -> dict:
    cfg = arch_for_shape(arch, shape)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    seq, batch, mode = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    st = strategy or choose_strategy(cfg, mesh, mode)
    model = Model(cfg)
    t0 = time.time()
    args, in_sh, out_sh, step, meta = build_inputs(cfg, model, shape, mesh,
                                                   st, accum=accum)
    # activation constraint = the planner's scheme choice made concrete.
    # SSM/hybrid time-scans cannot shard the sequence axis (recurrence);
    # decode steps have S=1 — both fall back to batch-only sharding.
    sp_ok = (mode != "decode" and cfg.family not in ("ssm", "hybrid")
             and (st.attn == "sp" or st.ffn == "sp"))
    act_fn = (seq_shard_fn(mesh, data_axes(mesh)) if sp_ok
              else batch_shard_fn(mesh, data_axes(mesh)))
    with mesh, activation_sharding(act_fn):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256

    # loop-aware accounting via the in-repo HLO analyzer (XLA cost_analysis
    # counts while bodies once — see launch/hlo_cost.py)
    tot = analyze_hlo(compiled.as_text())
    coll = {k.split(":", 1)[1]: v for k, v in tot.items()
            if k.startswith("coll:")}
    roof = Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=tot["flops"], hlo_bytes=tot["bytes"],
                    coll_bytes=coll,
                    model_flops=model_flops_estimate(cfg, seq, batch, mode))
    rec = roof.row()
    rec.update({
        "strategy": dataclasses.asdict(st),
        "compile_s": round(time.time() - t0, 1),
        "mem_per_device": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        **meta,
    })
    if verbose:
        print(json.dumps(rec, indent=1, default=str))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    # explicit FCO decision variables (default: the planner decides)
    ap.add_argument("--attn", choices=("tp", "sp"))
    ap.add_argument("--ffn", choices=("tp", "sp"))
    ap.add_argument("--moe", choices=("ep", "tp"))
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--resident", action="store_true",
                    help="decode: TP-resident weights (no data-axis shard)")
    ap.add_argument("--ssm-chunk", type=int, default=0,
                    help="chunk-parallel SSM scan width (0 = recurrent)")
    args = ap.parse_args(argv)

    strategy = None
    if args.attn or args.ffn or args.moe or args.no_fsdp or args.resident:
        strategy = Strategy(attn=args.attn or "sp", ffn=args.ffn or "tp",
                            moe=args.moe or "ep", fsdp=not args.no_fsdp,
                            decode_resident=args.resident)
    cfg_transform = None
    if args.ssm_chunk:
        def cfg_transform(cfg, _n=args.ssm_chunk):
            if cfg.ssm is None:
                return cfg
            return dataclasses.replace(
                cfg, ssm=dataclasses.replace(cfg.ssm, chunk=_n))

    records = []
    if args.all:
        combos = [(a, s, mp) for a in ARCH_IDS for s in SHAPES
                  for mp in (False, True)]
    else:
        combos = [(args.arch, args.shape, args.multi_pod)]
    for arch, shape, mp in combos:
        print(f"== dryrun {arch} {shape} mesh={'2x16x16' if mp else '16x16'}",
              flush=True)
        records.append(run_one(arch, shape, multi_pod=mp, strategy=strategy,
                               cfg_transform=cfg_transform))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1, default=str)
    return 0


if __name__ == "__main__":
    sys.exit(main())
