"""Real multi-device execution (subprocess: 8 fake CPU devices).

The main test process keeps jax at 1 device (per the dry-run rule), so the
sharded numeric checks run in a subprocess with
``--xla_force_host_platform_device_count=8``:

  * a reduced llama3 train step under a (4, 2) mesh with the production
    sharding rules must match the single-device step numerically;
  * the production-mesh dry-run lowering path (scaled mesh) compiles.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    r = _run("""
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import get_config
        from repro.models.transformer import Model
        from repro.optim import adamw_init
        from repro.runtime.shard_plan import (Strategy, batch_specs, named,
                                              opt_specs, param_specs)
        from repro.runtime.steps import make_train_step

        assert len(jax.devices()) == 8
        cfg = dataclasses.replace(get_config('llama3-8b').reduced(),
                                  dtype='float32')
        model = Model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        opt = adamw_init(params)
        toks = jax.random.randint(key, (8, 16), 0, cfg.vocab)
        batch = {'tokens': toks, 'labels': toks}
        step = make_train_step(model)

        # single device reference
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        st = Strategy(attn='tp', ffn='tp')
        p_spec = param_specs(jax.eval_shape(lambda: params), mesh, st,
                             'train')
        p_sh = named(p_spec, mesh)
        o_sh = named(opt_specs(p_spec, None), mesh)
        b_sh = named(batch_specs(jax.eval_shape(lambda: batch), mesh), mesh)
        with mesh:
            p2, o2, m2 = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                                 out_shardings=(p_sh, o_sh,
                                                NamedSharding(mesh, P()))
                                 )(params, opt, batch)
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-4, (
            float(m1['loss']), float(m2['loss']))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4)
        print('SHARDED_MATCH_OK')
    """)
    assert "SHARDED_MATCH_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_decode_step_sharded_compiles_and_runs():
    r = _run("""
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs.registry import get_config
        from repro.models.transformer import Model
        from repro.runtime.shard_plan import (Strategy, cache_specs, named,
                                              param_specs)
        cfg = dataclasses.replace(get_config('zamba2-1.2b').reduced(),
                                  dtype='float32')
        model = Model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        cache = model.cache_init(8, 16)
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        st = Strategy()
        p_sh = named(param_specs(jax.eval_shape(lambda: params), mesh, st,
                                 'decode'), mesh)
        c_sh = named(cache_specs(jax.eval_shape(lambda: cache), mesh, st),
                     mesh)
        tok = jnp.zeros((8, 1), jnp.int32)
        with mesh:
            fn = jax.jit(model.decode_step, in_shardings=(p_sh, c_sh, None,
                                                          None))
            logits, cache2 = fn(params, cache, tok, jnp.int32(0))
        assert logits.shape == (8, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        print('DECODE_SHARDED_OK')
    """)
    assert "DECODE_SHARDED_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_shard_map_cnn_halo_exchange():
    """FlexPie InH partition as a REAL shard_map program: per-device conv
    shards with explicit collective_permute halo exchange reproduce the
    full conv."""
    r = _run("""
        import jax, jax.numpy as jnp
        import numpy as np
        from functools import partial
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        devs = jax.devices()[:4]
        mesh = jax.make_mesh((4,), ('rows',), devices=devs)
        H, W, C = 32, 16, 8
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (H, W, C))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, C, C)) * 0.1

        def ref(x):
            return jax.lax.conv_general_dilated(
                x[None], w, (1, 1), [(1, 1), (1, 1)],
                dimension_numbers=('NHWC', 'HWIO', 'NHWC'))[0]

        @partial(shard_map, mesh=mesh, in_specs=(P('rows', None, None),),
                 out_specs=P('rows', None, None))
        def sharded_conv(xs):
            # halo exchange: one boundary row from each neighbour
            up = jax.lax.ppermute(xs[-1:], 'rows',
                                  [(i, (i + 1) % 4) for i in range(4)])
            dn = jax.lax.ppermute(xs[:1], 'rows',
                                  [(i, (i - 1) % 4) for i in range(4)])
            idx = jax.lax.axis_index('rows')
            up = jnp.where(idx == 0, 0.0, up)      # top border: zero pad
            dn = jnp.where(idx == 3, 0.0, dn)
            xh = jnp.concatenate([up, xs, dn], axis=0)
            out = jax.lax.conv_general_dilated(
                xh[None], w, (1, 1), [(0, 0), (1, 1)],
                dimension_numbers=('NHWC', 'HWIO', 'NHWC'))[0]
            return out

        out = sharded_conv(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref(x)),
                                   atol=1e-4)
        print('SHARD_MAP_HALO_OK')
    """)
    assert "SHARD_MAP_HALO_OK" in r.stdout, r.stdout + r.stderr
