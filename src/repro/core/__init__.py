"""FlexPie core: flexible combinatorial optimization for model partition."""
from .graph import (GRAPH_INPUT, Branch, ConvT, LayerSpec, ModelGraph, chain,
                    halo_growth)
from .partition import (ALL_SCHEMES, Mode, Scheme, hetero_shard_work,
                        weighted_split_sizes)
from .cost import (Testbed, Topology, hetero_compute_time_batch_s,
                   hetero_compute_time_s, hetero_device_times_s,
                   sync_bytes_messages)
from .estimator import (HETERO_FEATURE_NAMES, I_FEATURE_NAMES,
                        I_FEATURE_NAMES_HETERO, N_HETERO_FEATURES,
                        S_FEATURE_NAMES, S_FEATURE_NAMES_HETERO,
                        AnalyticEstimator, BatchedCostEstimator,
                        CostEstimator, GBDTEstimator, hetero_summary,
                        testbed_summary)
from .cost_tables import (ChainTables, CostTableBuilder, PrefetchedEstimator,
                          build_chain_tables)
from .plan import (Plan, PipelineCost, dag_plan_cost, fixed_plan, plan_cost,
                   plan_feasible, plan_pipeline_cost, plan_stage_counts,
                   steps_segments)
from .dpp import (Objective, PlanFrontier, SearchResult,
                  pipeline_frontier, pipeline_objective_key, plan_search,
                  plan_search_reference)
from .exhaustive import enumerate_dag_plans, exhaustive_search
from . import baselines

__all__ = [
    "GRAPH_INPUT", "Branch", "ConvT", "LayerSpec", "ModelGraph", "chain",
    "halo_growth", "ALL_SCHEMES", "Mode", "Scheme", "Testbed", "Topology",
    "hetero_compute_time_batch_s", "hetero_compute_time_s",
    "hetero_device_times_s", "hetero_shard_work", "sync_bytes_messages",
    "weighted_split_sizes",
    "AnalyticEstimator", "BatchedCostEstimator", "CostEstimator",
    "GBDTEstimator", "HETERO_FEATURE_NAMES", "I_FEATURE_NAMES",
    "I_FEATURE_NAMES_HETERO", "N_HETERO_FEATURES", "S_FEATURE_NAMES",
    "S_FEATURE_NAMES_HETERO", "hetero_summary", "testbed_summary",
    "ChainTables", "CostTableBuilder",
    "PrefetchedEstimator", "build_chain_tables", "Plan", "PipelineCost",
    "dag_plan_cost", "fixed_plan", "plan_cost", "plan_feasible",
    "plan_pipeline_cost", "plan_stage_counts", "steps_segments",
    "Objective", "PlanFrontier", "SearchResult", "pipeline_frontier",
    "pipeline_objective_key", "plan_search", "plan_search_reference",
    "enumerate_dag_plans", "exhaustive_search", "baselines",
]
