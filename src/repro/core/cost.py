"""Analytic cost model — the simulated testbed "physics".

On real hardware these times would be measured; here (no SRIO DSP cluster)
the analytic model is both (a) the ground truth the trace generator samples
from when training the GBDT estimators and (b) the oracle the Theorem-1
property tests compare DPP against.  The model captures the effects the paper
measures: straggler imbalance, scheme-dependent efficiency, per-message
latency, topology (ring / PS / mesh) and bandwidth.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence, Tuple

import numpy as np

from .graph import ConvT, LayerSpec
from .partition import (Scheme, boundary_bytes_same_scheme,
                        boundary_bytes_same_scheme_batch,
                        conv_flops_per_elem_batch, hetero_flops_batch,
                        hetero_shard_work, relayout_bytes,
                        relayout_bytes_batch, shard_work,
                        straggler_flops_batch)


class Topology(enum.IntEnum):
    RING = 0
    PS = 1     # parameter-server (star)
    MESH = 2   # full bisection, direct point-to-point


#: multiplier on bytes-on-busiest-link per topology (single source for the
#: scalar and batched paths)
_TOPO_FACTOR = {Topology.RING: 1.0, Topology.PS: 2.0, Topology.MESH: 0.7}

#: kernel-efficiency derate per layer category (low arithmetic intensity)
_CONV_T_DERATE = {ConvT.DWCONV: 0.45, ConvT.POOL: 0.60,
                  ConvT.ADD: 0.30, ConvT.CONCAT: 0.30}


@dataclasses.dataclass(frozen=True)
class Testbed:
    """Edge cluster description (Fig. 4 features 11-12 + node count)."""

    nodes: int = 4
    bandwidth_gbps: float = 5.0          # per-link, SRIO in the paper
    topology: Topology = Topology.RING
    device_gflops: float = 16.0          # TMS320C6678 ~16 GFLOP/s fp32
    link_latency_us: float = 10.0        # per message
    # scheme-dependent kernel efficiency: contiguous row splits vectorize
    # better on the DSP than column or channel splits.
    eff_inh: float = 0.90
    eff_inw: float = 0.80
    eff_outc: float = 0.85
    eff_grid: float = 0.82

    def efficiency(self, scheme: Scheme) -> float:
        return {Scheme.INH: self.eff_inh, Scheme.INW: self.eff_inw,
                Scheme.OUTC: self.eff_outc, Scheme.GRID2D: self.eff_grid}[scheme]

    def topo_factor(self) -> float:
        """Multiplier on bytes-on-busiest-link."""
        return _TOPO_FACTOR[self.topology]

    def comm_time_s(self, bytes_busiest: float, n_messages: int = 2) -> float:
        if bytes_busiest <= 0.0:
            return 0.0
        bw = self.bandwidth_gbps * 1e9 / 8.0  # bytes/s
        return (bytes_busiest * self.topo_factor() / bw
                + n_messages * self.link_latency_us * 1e-6)


def compute_time_s(layer: LayerSpec, scheme: Scheme, tb: Testbed,
                   extra_halo: int = 0) -> float:
    """i-Estimator ground truth: straggler compute time of one layer."""
    work = shard_work(layer, scheme, tb.nodes, extra_halo=extra_halo)
    eff = tb.efficiency(scheme)
    derate = _CONV_T_DERATE.get(layer.conv_t)
    if derate is not None:
        eff *= derate
    return work.straggler_flops / (tb.device_gflops * 1e9 * eff)


def sync_bytes_messages(layer: LayerSpec, nxt: Optional[LayerSpec],
                        src: Scheme, dst: Optional[Scheme],
                        nodes: int) -> Tuple[float, int]:
    """Busiest-node byte volume and message count of one T-mode boundary —
    the topology-independent half of :func:`sync_time_s`, shared with the
    cluster simulator's per-link transfer accounting.

    ``nxt=None``/``dst=None`` means final layer: gather to node 0.
    """
    if nxt is None or dst is None:
        total = layer.out_elems() * 4.0
        return total * (nodes - 1) / nodes, nodes - 1
    if nxt.conv_t == ConvT.ATTN and dst.spatial:
        # attention reads the whole sequence (every position is KV for every
        # query), so a sequence-sharded successor still needs the full input:
        # all-gather, regardless of how src and dst layouts relate.
        total = layer.out_elems() * 4.0
        return total * (nodes - 1) / nodes, 2 * (nodes - 1)
    if src == dst and src.spatial:
        b = boundary_bytes_same_scheme(layer, nxt, src, nodes)
        return b, 2 if b else 0
    b = relayout_bytes(layer, src, dst, nodes)
    halo = 0.0
    if dst.spatial:
        halo = boundary_bytes_same_scheme(layer, nxt, dst, nodes)
    return b + halo, 2 * (nodes - 1)


def sync_time_s(layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
                dst: Optional[Scheme], tb: Testbed) -> float:
    """s-Estimator ground truth: time to make ``layer``'s output available in
    the layout the next layer's scheme requires (T-mode boundary).

    ``nxt=None`` means final layer: outputs are gathered to node 0.
    """
    b, msgs = sync_bytes_messages(layer, nxt, src, dst, tb.nodes)
    return tb.comm_time_s(b, n_messages=msgs)


# ---------------------------------------------------------------------------
# Heterogeneous-cluster compute times (capability-weighted shard fractions).
#
# The per-device capability arrays come from ``repro.cluster.ClusterSpec``
# (kept as plain sequences here so core stays import-cycle free).  ``tb``
# supplies the scheme efficiencies and node count exactly as in the
# homogeneous path; per-device speed enters as ``gflops_d`` and a
# kernel-efficiency derate ``e_d``.  Straggler time = max over per-device
# compute — with uniform devices and weights every expression reduces
# bit-identically to :func:`compute_time_s`.
# ---------------------------------------------------------------------------

def hetero_device_times_s(layer: LayerSpec, scheme: Scheme, tb: Testbed,
                          speeds_gflops: Sequence[float],
                          dev_derates: Sequence[float],
                          weights: Sequence[float],
                          extra_halo: int = 0) -> np.ndarray:
    """Per-device compute seconds of one layer on a heterogeneous cluster
    (the straggler max of this vector is :func:`hetero_compute_time_s`; the
    full vector feeds the discrete-event simulator's device queues)."""
    work = hetero_shard_work(layer, scheme, weights, extra_halo=extra_halo)
    eff = tb.efficiency(scheme)
    derate = _CONV_T_DERATE.get(layer.conv_t)
    if derate is not None:
        eff *= derate
    return np.asarray([f / (g * 1e9 * (eff * e))
                       for f, g, e in zip(work.flops_per_node, speeds_gflops,
                                          dev_derates)], np.float64)


def hetero_compute_time_s(layer: LayerSpec, scheme: Scheme, tb: Testbed,
                          speeds_gflops: Sequence[float],
                          dev_derates: Sequence[float],
                          weights: Sequence[float],
                          extra_halo: int = 0) -> float:
    """i-Estimator ground truth on a heterogeneous cluster: straggler time
    = max over per-device compute under capability-weighted shards."""
    return float(np.max(hetero_device_times_s(
        layer, scheme, tb, speeds_gflops, dev_derates, weights,
        extra_halo=extra_halo)))


def hetero_compute_time_batch_s(X: np.ndarray, tb: Testbed,
                                speeds_gflops: np.ndarray,
                                dev_derates: np.ndarray,
                                weights: np.ndarray,
                                flop_factor: Optional[np.ndarray] = None
                                ) -> np.ndarray:
    """Vector form of :func:`hetero_compute_time_s` over an ``(n, 17)``
    i-feature matrix with one fixed cluster.  Float expressions mirror the
    scalar op order, so any row bit-matches the scalar call."""
    X = np.asarray(X, np.float64)
    conv_t = X[:, _F_CONV_T].astype(np.int64)
    scheme = X[:, _F_SCHEME].astype(np.int64)
    oh = X[:, _F_OUT_H].astype(np.int64)
    ow = X[:, _F_OUT_W].astype(np.int64)
    oc = X[:, _F_OUT_C].astype(np.int64)
    halo = X[:, _F_HALO].astype(np.int64)
    factor = (np.ones(len(X), np.float64) if flop_factor is None
              else np.asarray(flop_factor, np.float64))
    per = conv_flops_per_elem_batch(conv_t, X[:, _F_IN_C], X[:, _F_K],
                                    X[:, _F_FAN_IN])
    flops = hetero_flops_batch(per, oh, ow, oc, scheme, halo, factor,
                               np.asarray(weights, np.float64),
                               heads=X[:, _F_HEADS].astype(np.int64))
    eff = np.asarray([tb.eff_inh, tb.eff_inw, tb.eff_outc,
                      tb.eff_grid])[scheme]
    for ct, derate in _CONV_T_DERATE.items():
        eff = np.where(conv_t == ct, eff * derate, eff)
    denom = np.asarray(speeds_gflops, np.float64)[None, :] * 1e9 \
        * (eff[:, None] * np.asarray(dev_derates, np.float64)[None, :])
    return (flops / denom).max(axis=1)


# ---------------------------------------------------------------------------
# Batched forms over stacked feature matrices.
#
# Row layout matches ``estimator.i_features`` / ``estimator.s_features``
# (asserted against I_FEATURE_NAMES / S_FEATURE_NAMES there).  Per-sample
# testbed variation travels in the BW / Topo / Nodes columns; the remaining
# physics constants (device_gflops, link latency, kernel efficiencies) come
# from the ``tb`` argument.  Float expressions mirror the scalar op order,
# so for any row the batched time is bit-identical to the scalar one.
# ---------------------------------------------------------------------------

# shared leading columns of both feature layouts
(_F_IN_H, _F_IN_W, _F_IN_C, _F_OUT_H, _F_OUT_W, _F_OUT_C, _F_K, _F_S, _F_P,
 _F_CONV_T, _F_FAN_IN, _F_HEADS, _F_BW, _F_TOPO, _F_NODES) = range(15)
# i-feature tail
_F_SCHEME, _F_HALO = 15, 16
# s-feature tail
_F_SRC, _F_DST, _F_NEXT_K, _F_NEXT_FAN, _F_NEXT_CONV_T = 15, 16, 17, 18, 19

_TOPO_FACTORS = np.asarray([_TOPO_FACTOR[t] for t in Topology])


def _comm_time_batch(tb: Testbed, bytes_busiest: np.ndarray,
                     n_messages: np.ndarray, bw_gbps: np.ndarray,
                     topo: np.ndarray) -> np.ndarray:
    """Vector form of :meth:`Testbed.comm_time_s` with per-row BW/topology."""
    bw = bw_gbps * 1e9 / 8.0
    t = (bytes_busiest * _TOPO_FACTORS[topo] / bw
         + n_messages * tb.link_latency_us * 1e-6)
    return np.where(bytes_busiest <= 0.0, 0.0, t)


def compute_time_batch_s(X: np.ndarray, tb: Testbed,
                         flop_factor: Optional[np.ndarray] = None
                         ) -> np.ndarray:
    """Vector form of :func:`compute_time_s` over an ``(n, 17)`` i-feature
    matrix.  ``flop_factor`` carries ``LayerSpec.extra_flop_factor`` (not
    part of the learned feature expression; defaults to 1)."""
    X = np.asarray(X, np.float64)
    conv_t = X[:, _F_CONV_T].astype(np.int64)
    scheme = X[:, _F_SCHEME].astype(np.int64)
    oh = X[:, _F_OUT_H].astype(np.int64)
    ow = X[:, _F_OUT_W].astype(np.int64)
    oc = X[:, _F_OUT_C].astype(np.int64)
    nodes = X[:, _F_NODES].astype(np.int64)
    halo = X[:, _F_HALO].astype(np.int64)
    factor = (np.ones(len(X), np.float64) if flop_factor is None
              else np.asarray(flop_factor, np.float64))
    per = conv_flops_per_elem_batch(conv_t, X[:, _F_IN_C], X[:, _F_K],
                                    X[:, _F_FAN_IN])
    work = straggler_flops_batch(per, oh, ow, oc, scheme, nodes, halo,
                                 factor,
                                 heads=X[:, _F_HEADS].astype(np.int64))
    eff = np.asarray([tb.eff_inh, tb.eff_inw, tb.eff_outc,
                      tb.eff_grid])[scheme]
    for ct, derate in _CONV_T_DERATE.items():
        eff = np.where(conv_t == ct, eff * derate, eff)
    return work / (tb.device_gflops * 1e9 * eff)


def sync_time_batch_s(X: np.ndarray, tb: Testbed) -> np.ndarray:
    """Vector form of :func:`sync_time_s` over an ``(n, 20)`` s-feature
    matrix (``Dst = -1`` encodes the final gather-to-root)."""
    X = np.asarray(X, np.float64)
    oh = X[:, _F_OUT_H].astype(np.int64)
    ow = X[:, _F_OUT_W].astype(np.int64)
    oc = X[:, _F_OUT_C].astype(np.int64)
    nodes = X[:, _F_NODES].astype(np.int64)
    src = X[:, _F_SRC].astype(np.int64)
    dst = X[:, _F_DST].astype(np.int64)
    next_k = X[:, _F_NEXT_K].astype(np.int64)
    next_conv_t = X[:, _F_NEXT_CONV_T].astype(np.int64)
    topo = X[:, _F_TOPO].astype(np.int64)
    bw = X[:, _F_BW]

    final = dst < 0
    src_spatial = src != Scheme.OUTC
    dst_spatial = (dst != Scheme.OUTC) & ~final
    same_spatial = (src == dst) & src_spatial
    next_attn = (next_conv_t == ConvT.ATTN) & dst_spatial

    total = (oh * ow * oc) * 4.0
    gather_b = total * (nodes - 1) / nodes

    halo_src = boundary_bytes_same_scheme_batch(src, oh, ow, oc, nodes,
                                                next_k)
    halo_dst = boundary_bytes_same_scheme_batch(dst, oh, ow, oc, nodes,
                                                next_k)
    relay_b = relayout_bytes_batch(oh, ow, oc, src, dst, nodes) \
        + np.where(dst_spatial, halo_dst, 0.0)

    bytes_b = np.where(final, gather_b,
                       np.where(next_attn, gather_b,
                                np.where(same_spatial, halo_src, relay_b)))
    msgs = np.where(final, nodes - 1,
                    np.where(next_attn, 2 * (nodes - 1),
                             np.where(same_spatial,
                                      np.where(halo_src != 0.0, 2, 0),
                                      2 * (nodes - 1))))
    return _comm_time_batch(tb, bytes_b, msgs, bw, topo)
