"""Consolidated execution API: :class:`ExecConfig` + :class:`Session`.

``run_partitioned`` grew ten orthogonal keyword arguments (backend,
executor, mesh, instrumentation, overlap, jit caching, fault policy) that
every caller had to re-thread on every call — untenable for decode loops
that execute one plan hundreds of times.  The consolidation splits the
sprawl into its two actual lifetimes:

* :class:`ExecConfig` — frozen, hashable *policy*: which backend/executor,
  how to instrument, how to fail.  Build it once, share it anywhere.
* :class:`Session` — *bound state*: one (graph, weights, plan, nodes)
  binding plus the device mesh and compiled-program reuse across ``run``
  calls.  Step programs are cached process-wide keyed by segment geometry
  (``engine._compiled_segment``) and mesh program signature
  (``mesh_exec._PROG_CACHE``), so a Session's second ``run`` skips
  retracing entirely; the Session additionally pins the mesh object so
  repeated mesh runs don't rebuild device layouts.

``run_partitioned(**kwargs)`` survives as a thin back-compat shim over
``Session`` and warns ``DeprecationWarning``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ExecConfig", "Session"]

BACKENDS = ("xla", "pallas")
EXECUTORS = ("local", "mesh")
FALLBACKS = ("raise", "local")


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Execution policy — everything about *how* to run that is not the
    model, the plan, or the data.

    Fields mirror the historical ``run_partitioned`` kwargs:

    * ``backend``: segment lowering, ``"xla"`` or ``"pallas"`` (shard
      kernels with per-record XLA fallback).
    * ``executor``: ``"local"`` single-process reference executor or
      ``"mesh"`` (one JAX device per planned node, collective exchanges).
    * ``jit_segments``: route local-executor segments through the
      compiled-program cache (mesh is always compiled).
    * ``instrument``: record measured per-stage times into ``ExecStats``.
    * ``overlap``: fuse halo exchanges into the consuming compute stage
      (mesh executor).
    * ``stage_timeout_s`` / ``stage_retries`` / ``fallback``: mesh fault
      policy (watchdog, bounded dispatch retries, degrade-to-local).
    """

    backend: str = "xla"
    executor: str = "local"
    jit_segments: bool = True
    instrument: bool = False
    overlap: bool = True
    stage_timeout_s: Optional[float] = None
    stage_retries: int = 0
    fallback: str = "raise"

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        if self.executor not in EXECUTORS:
            raise ValueError(f"executor {self.executor!r} not in "
                             f"{EXECUTORS}")
        if self.fallback not in FALLBACKS:
            raise ValueError(f"fallback {self.fallback!r} not in "
                             f"{FALLBACKS}")
        if self.stage_retries < 0:
            raise ValueError(f"stage_retries must be >= 0, got "
                             f"{self.stage_retries}")
        if self.stage_timeout_s is not None and self.stage_timeout_s <= 0:
            raise ValueError(f"stage_timeout_s must be positive, got "
                             f"{self.stage_timeout_s}")


class Session:
    """One plan bound to one executor, reusable across many inputs.

    ``Session(graph, weights, plan, nodes, config).run(x)`` replaces
    ``run_partitioned(graph, weights, x, plan, nodes, **ten_kwargs)``.
    The Session validates the plan/config once, builds (or adopts) the
    device mesh once, and leans on the process-wide compiled-program
    caches so repeated ``run`` calls — a decode loop, a benchmark's warm
    iterations — skip retracing.

    ``mesh`` optionally passes a prebuilt 1-D ``nodes`` mesh (it is
    unhashable, hence not an :class:`ExecConfig` field); ``fault_hook``
    is the mesh executor's fault-injection test hook.
    """

    def __init__(self, graph, weights, plan, nodes: int,
                 config: ExecConfig = ExecConfig(), *, mesh=None,
                 fault_hook=None):
        if nodes < 1:
            raise ValueError(f"nodes must be >= 1, got {nodes}")
        self.graph = graph
        self.weights = weights
        self.plan = plan
        self.nodes = nodes
        self.config = config
        self.fault_hook = fault_hook
        if graph.is_chain:
            plan.validate()
            if len(plan) != len(graph):
                raise ValueError("plan/graph length mismatch")
        else:
            plan.validate_for(graph)
        self._mesh = mesh
        if config.executor == "mesh" and mesh is None and nodes > 1:
            from repro.launch.mesh import make_nodes_mesh
            try:
                self._mesh = make_nodes_mesh(nodes)
            except RuntimeError:
                # too few devices: leave the mesh unset so the executor's
                # fallback policy decides (degrade-to-local vs raise)
                self._mesh = None

    @property
    def mesh(self):
        """The bound device mesh (``None`` for the local executor)."""
        return self._mesh

    def run(self, x) -> Tuple[object, object]:
        """Execute the bound plan on ``x`` → ``(output, ExecStats)``."""
        cfg = self.config
        if cfg.executor == "mesh":
            from repro.runtime.mesh_exec import run_partitioned_mesh
            return run_partitioned_mesh(
                self.graph, self.weights, x, self.plan, self.nodes,
                backend=cfg.backend, mesh=self._mesh,
                instrument=cfg.instrument, overlap=cfg.overlap,
                stage_timeout_s=cfg.stage_timeout_s,
                stage_retries=cfg.stage_retries, fallback=cfg.fallback,
                fault_hook=self.fault_hook)
        from repro.runtime.engine import _run_partitioned_local
        return _run_partitioned_local(
            self.graph, self.weights, x, self.plan, self.nodes,
            jit_segments=cfg.jit_segments, backend=cfg.backend)

    def __call__(self, x):
        """Convenience: ``session(x)`` → output only (stats dropped)."""
        return self.run(x)[0]
