"""Gradient-boosted decision trees (squared error) — XGBoost stand-in.

Inference stacks every tree's flat node arrays into padded ``(T, M)``
matrices and advances all trees over all samples in lockstep: one fancy
gather + one compare per tree-depth level for the whole forest, instead of
a Python loop over trees.  ``predict_reference`` retains the per-tree
accumulation as the parity oracle (``predict`` reproduces its float
accumulation order exactly, so the two are bit-identical).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .tree import RegressionTree


class GBDTRegressor:
    def __init__(self, n_estimators: int = 120, learning_rate: float = 0.15,
                 max_depth: int = 6, min_child_weight: float = 2.0,
                 reg_lambda: float = 1.0, n_bins: int = 64,
                 subsample: float = 0.9, seed: int = 0):
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_depth = max_depth
        self.min_child_weight = min_child_weight
        self.reg_lambda = reg_lambda
        self.n_bins = n_bins
        self.subsample = subsample
        self.seed = seed
        self.base_: float = 0.0
        self.n_features_: Optional[int] = None
        self.trees_: List[RegressionTree] = []
        self._forest: Optional[Tuple[np.ndarray, ...]] = None

    # ---- binning ----------------------------------------------------------
    def _make_bins(self, x: np.ndarray) -> List[np.ndarray]:
        edges = []
        qs = np.linspace(0, 1, self.n_bins + 1)[1:-1]
        for f in range(x.shape[1]):
            e = np.unique(np.quantile(x[:, f], qs))
            edges.append(e)
        return edges

    @staticmethod
    def _bin(x: np.ndarray, edges: List[np.ndarray]) -> np.ndarray:
        out = np.empty(x.shape, dtype=np.int32)
        for f, e in enumerate(edges):
            out[:, f] = np.searchsorted(e, x[:, f], side="left")
        return out

    # ---- fit / predict ----------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray,
            eval_set=None, verbose_every: int = 0) -> "GBDTRegressor":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        rng = np.random.default_rng(self.seed)
        self.n_features_ = int(x.shape[1])
        edges = self._make_bins(x)
        binned = self._bin(x, edges)
        self.base_ = float(y.mean())
        pred = np.full_like(y, self.base_)
        self.trees_ = []
        self._forest = None
        hess = np.ones_like(y)
        for t in range(self.n_estimators):
            grad = pred - y
            if self.subsample < 1.0:
                m = rng.random(len(y)) < self.subsample
                tree = RegressionTree(self.max_depth, self.min_child_weight,
                                      self.reg_lambda).fit(
                    binned[m], edges, grad[m], hess[m])
            else:
                tree = RegressionTree(self.max_depth, self.min_child_weight,
                                      self.reg_lambda).fit(
                    binned, edges, grad, hess)
            upd = tree.predict(x)
            pred += self.learning_rate * upd
            self.trees_.append(tree)
            if verbose_every and (t + 1) % verbose_every == 0:
                from repro.obs.log import log
                fields = {"tree": t + 1,
                          "train_rmse": float(np.sqrt(np.mean((pred - y)**2)))}
                if eval_set is not None:
                    ex, ey = eval_set
                    ep = self.predict(ex)
                    fields["eval_rmse"] = float(np.sqrt(np.mean((ep - ey)**2)))
                log("gbdt.fit", **fields)
        return self

    # ---- batched forest inference -----------------------------------------
    def _packed_forest(self) -> Tuple[np.ndarray, ...]:
        """Pad every tree's flat arrays into ``(T, M)`` matrices (cached).
        Padding slots are leaves pointing at themselves with value 0, so a
        finished tree idles harmlessly while deeper trees keep descending."""
        if self._forest is not None and self._forest[0].shape[0] == \
                len(self.trees_):
            return self._forest
        flats = [tr.flat() for tr in self.trees_]
        T = len(flats)
        M = max(len(f[0]) for f in flats)
        feature = np.zeros((T, M), np.int32)
        threshold = np.zeros((T, M), np.float64)
        left = np.zeros((T, M), np.int32)
        right = np.zeros((T, M), np.int32)
        value = np.zeros((T, M), np.float64)
        is_leaf = np.ones((T, M), np.bool_)
        for t, (f, thr, l, r, v, leaf) in enumerate(flats):
            m = len(f)
            feature[t, :m] = np.maximum(f, 0)   # leaf sentinel -1 -> 0
            threshold[t, :m] = thr
            left[t, :m] = l
            right[t, :m] = r
            value[t, :m] = v
            is_leaf[t, :m] = leaf
        self._forest = (feature, threshold, left, right, value, is_leaf)
        return self._forest

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        n = x.shape[0]
        if not self.trees_ or n == 0:
            return np.full(n, self.base_)
        feature, threshold, left, right, value, is_leaf = \
            self._packed_forest()
        T = len(self.trees_)
        # flat (tree, sample) state; only still-descending pairs do work,
        # so the active set shrinks as shallow branches bottom out
        cur = np.zeros((T, n), np.int32)
        roots = np.flatnonzero(~is_leaf[:, 0])
        t_id = roots.repeat(n)
        col = np.tile(np.arange(n), roots.size)
        c = cur[t_id, col]
        while t_id.size:
            f = feature[t_id, c]
            go_left = x[col, f] <= threshold[t_id, c]
            nxt = np.where(go_left, left[t_id, c], right[t_id, c])
            cur[t_id, col] = nxt
            keep = ~is_leaf[t_id, nxt]
            t_id, col, c = t_id[keep], col[keep], nxt[keep]
        leaf_vals = value[np.arange(T)[:, None], cur]     # (T, n)
        # accumulate per tree in fit order — bit-identical to the scalar
        # reference (sum-then-scale would round differently)
        out = np.full(n, self.base_)
        for t in range(T):
            out += self.learning_rate * leaf_vals[t]
        return out

    def predict_reference(self, x: np.ndarray) -> np.ndarray:
        """Per-tree scalar-walk prediction — the parity oracle."""
        x = np.asarray(x, dtype=np.float64)
        out = np.full(x.shape[0], self.base_)
        for tree in self.trees_:
            out += self.learning_rate * tree.predict_reference(x)
        return out

    # ---- persistence (npz) -------------------------------------------------
    def save(self, path: str) -> None:
        flat = {"base": np.array([self.base_]),
                "lr": np.array([self.learning_rate]),
                "n_trees": np.array([len(self.trees_)]),
                "n_features": np.array([-1 if self.n_features_ is None
                                        else self.n_features_])}
        for i, tr in enumerate(self.trees_):
            arr = np.array([[n.feature, n.threshold, n.left, n.right, n.value,
                             1.0 if n.is_leaf else 0.0] for n in tr.nodes])
            flat[f"tree_{i}"] = arr
        np.savez_compressed(path, **flat)

    @classmethod
    def load(cls, path: str) -> "GBDTRegressor":
        data = np.load(path)
        obj = cls(n_estimators=int(data["n_trees"][0]),
                  learning_rate=float(data["lr"][0]))
        obj.base_ = float(data["base"][0])
        if "n_features" in data:        # absent in pre-width checkpoints
            nf = int(data["n_features"][0])
            obj.n_features_ = None if nf < 0 else nf
        obj.trees_ = []
        from .tree import _Node
        for i in range(int(data["n_trees"][0])):
            arr = data[f"tree_{i}"]
            tr = RegressionTree()
            tr.nodes = [
                _Node(feature=int(r[0]), threshold=float(r[1]), left=int(r[2]),
                      right=int(r[3]), value=float(r[4]), is_leaf=r[5] > 0.5)
                for r in arr]
            obj.trees_.append(tr)
        return obj
