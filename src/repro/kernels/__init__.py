"""Pallas shard kernels for the engine hot path (+ flash attention).

Public surface: the jit'd wrappers in :mod:`repro.kernels.ops` (automatic
XLA fallback on unsupported geometries), the raw shard kernel
:func:`repro.kernels.conv2d.conv2d_shard` consumed by the engine's
``backend="pallas"`` path, and the jnp oracles in :mod:`repro.kernels.ref`.
"""
from .conv2d import UnsupportedGeometry, conv2d_shard, conv2d_tiled
from .flash_attention import flash_decode_paged
from .ops import conv2d, dwconv2d, flash_attention, matmul, matmul_tiled

__all__ = [
    "UnsupportedGeometry", "conv2d", "conv2d_shard", "conv2d_tiled",
    "dwconv2d", "flash_attention", "flash_decode_paged", "matmul",
    "matmul_tiled",
]
