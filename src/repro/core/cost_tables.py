"""Batched cost tables — the planner's scalar-call hot path, precomputed.

DPP's inner loops used to call ``est.i_cost`` / ``est.s_cost`` one sample
at a time, so search time was dominated by Python call overhead (and, for
the GBDT estimator, by thousands of single-row forest walks).  This module
turns cost evaluation inside-out: every (layer, scheme, halo) compute query
and every (boundary, src-scheme, dst-scheme) sync query a search could
touch is enumerated up front, deduplicated, evaluated in **one**
``i_cost_batch`` / ``s_cost_batch`` call each, and served back as numpy
tables.  The tables hold exactly the values the scalar protocol would have
returned (both estimators guarantee bit-parity between their scalar and
batched paths), so any search driven from them reproduces the scalar
reference bit for bit.

Three consumers:

* ``repro.core.dpp.plan_search`` — chain DP over the ``seg`` tensor and
  per-branch tables for DAG composition;
* ``PrefetchedEstimator`` — a ``CostEstimator`` view for code that still
  walks plans scalar-wise (the exhaustive oracle, fixed-plan baselines);
* ``repro.sim.trace`` — trace generation uses the same batched estimator
  entry points directly.

Heterogeneous clusters ride the same pipeline: a
``repro.cluster.ClusterAnalyticEstimator`` implements the full batched
protocol (capability-weighted straggler i-costs, busiest-link s-costs), so
table building, the DP, and the prefetched oracle all run batched on
heterogeneous layouts — no scalar fallback.  Pass
``cluster.compat_testbed()`` as ``tb``; its node count / topology /
bottleneck link populate the feature columns.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost import Testbed
from repro.obs import metrics as _obs_metrics

from .estimator import CostEstimator, i_features, s_features
from .graph import LayerSpec, ModelGraph, halo_growth
from .partition import ALL_SCHEMES, Scheme, min_shard_extent

_INF = float("inf")


def _i_key(layer: LayerSpec, scheme: Scheme, halo: int) -> tuple:
    """Cache key of one scalar i-query (shared by prefetch fill + lookup)."""
    return (layer, scheme, halo)


def _s_key(layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
           dst: Optional[Scheme]) -> tuple:
    """Cache key of one scalar s-query: ``nxt`` enters only through
    ``(k, fan_in, conv_t)`` — all the feature expression reads from it."""
    return (layer, None if nxt is None else (nxt.k, nxt.fan_in, nxt.conv_t),
            src, dst)


class CostTableBuilder:
    """Two-phase batched evaluation: register unique queries, then resolve
    them all with one ``i_cost_batch`` and one ``s_cost_batch`` call.

    Deduplication uses the same keys as ``GBDTEstimator``'s scalar caches,
    which is exactly the information either estimator reads — repeated
    blocks (e.g. resnet101's 23 identical bottlenecks) collapse to one row.
    """

    def __init__(self, est: CostEstimator, tb: Testbed):
        self._est = est
        self._tb = tb
        self._i_keys: Dict[tuple, int] = {}
        self._i_rows: List[List[float]] = []
        self._i_factors: List[float] = []
        self._s_keys: Dict[tuple, int] = {}
        self._s_rows: List[List[float]] = []
        # dedup accounting: a hit is a registered query that collapsed
        # onto an existing row (plain ints here; pushed to the metrics
        # registry in one batch by evaluate() — see obs.metrics)
        self.i_hits = 0
        self.i_misses = 0
        self.s_hits = 0
        self.s_misses = 0
        self._pushed = {"i_hits": 0, "i_misses": 0,
                        "s_hits": 0, "s_misses": 0}
        # geometric identity per layer *object* (pinned so ids stay unique):
        # both estimators read only feature_vector() (+ extra_flop_factor),
        # so name-blind keys make repeated blocks share one row
        self._layer_memo: Dict[int, tuple] = {}
        self._pinned: List[LayerSpec] = []

    def layer_key(self, layer: LayerSpec) -> tuple:
        """Name-blind geometric identity of ``layer`` — everything the
        estimators can read.  Layers (and whole branches) with equal keys
        have equal costs and can share rows and DP tables."""
        key = self._layer_memo.get(id(layer))
        if key is None:
            key = (layer.feature_vector(), layer.extra_flop_factor)
            self._layer_memo[id(layer)] = key
            self._pinned.append(layer)
        return key

    _lkey = layer_key

    def i_index(self, layer: LayerSpec, scheme: Scheme, halo: int) -> int:
        key = (self._lkey(layer), scheme, halo)
        idx = self._i_keys.get(key)
        if idx is None:
            self.i_misses += 1
            idx = len(self._i_rows)
            self._i_keys[key] = idx
            self._i_rows.append(i_features(layer, scheme, self._tb, halo))
            self._i_factors.append(layer.extra_flop_factor)
        else:
            self.i_hits += 1
        return idx

    def s_index(self, layer: LayerSpec, nxt: Optional[LayerSpec],
                src: Scheme, dst: Optional[Scheme]) -> int:
        key = (self._lkey(layer),
               None if nxt is None else (nxt.k, nxt.fan_in, nxt.conv_t),
               src, dst)
        idx = self._s_keys.get(key)
        if idx is None:
            self.s_misses += 1
            idx = len(self._s_rows)
            self._s_keys[key] = idx
            self._s_rows.append(s_features(layer, nxt, src, dst, self._tb))
        else:
            self.s_hits += 1
        return idx

    @property
    def i_entries(self) -> int:
        return len(self._i_rows)

    @property
    def s_entries(self) -> int:
        return len(self._s_rows)

    def evaluate(self, est: Optional[CostEstimator] = None,
                 ivals: Optional[np.ndarray] = None,
                 svals: Optional[np.ndarray] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Resolve every registered query in two batched estimator calls.

        ``est`` re-evaluates the *same registered rows* under a different
        estimator — the incremental-replanning hook: registration (the
        Python-heavy enumeration/dedup phase) depends only on graph
        geometry and the testbed projection, so a capability change that
        leaves ``cluster.compat_testbed()`` intact reuses it wholesale.
        ``ivals`` / ``svals`` skip re-evaluating that side entirely and
        return the passed array (row-level invalidation: a derate report
        dirties only the i-rows — s-costs read the testbed projection
        only — while a link slowdown dirties only the s-rows)."""
        est = self._est if est is None else est
        if ivals is None:
            ivals = (est.i_cost_batch(
                np.asarray(self._i_rows, np.float64), self._tb,
                np.asarray(self._i_factors, np.float64))
                if self._i_rows else np.empty(0))
        elif len(ivals) != len(self._i_rows):
            raise ValueError(f"cached ivals cover {len(ivals)} rows, "
                             f"builder has {len(self._i_rows)}")
        if svals is None:
            svals = (est.s_cost_batch(
                np.asarray(self._s_rows, np.float64), self._tb)
                if self._s_rows else np.empty(0))
        elif len(svals) != len(self._s_rows):
            raise ValueError(f"cached svals cover {len(svals)} rows, "
                             f"builder has {len(self._s_rows)}")
        # push dedup deltas since the previous evaluate() in one batch
        # (re-evaluations of a long-lived builder don't double count)
        for attr, name, table in (
                ("i_hits", "cost_tables.dedup_hits", "i"),
                ("i_misses", "cost_tables.dedup_misses", "i"),
                ("s_hits", "cost_tables.dedup_hits", "s"),
                ("s_misses", "cost_tables.dedup_misses", "s")):
            delta = getattr(self, attr) - self._pushed[attr]
            if delta:
                _obs_metrics.inc(name, delta, table=table)
                self._pushed[attr] = getattr(self, attr)
        return np.asarray(ivals, np.float64), np.asarray(svals, np.float64)


def admissible_segments(ls: Sequence[LayerSpec],
                        schemes: Sequence[Scheme], nodes: int, cap: int):
    """Enumerate every admissible NT segment of a chain — the single source
    of the halo-degeneration rule shared by table building and prefetch.

    Yields ``(i, pi, seg_queries, halo_cut)`` per segment start and scheme:
    ``seg_queries[L-1]`` lists the ``(layer_index, halo)`` i-queries of
    segment ``[i .. i+L-1]`` (ascending offset, the scalar accumulation
    order); ``halo_cut`` is True when the halo degenerated into full
    replication before ``cap`` was reached.  Non-spatial schemes only admit
    singleton segments (NT is undefined for OutC).
    """
    n = len(ls)
    for i in range(n):
        hi = min(i + cap, n)
        # halo vectors are scheme-independent: compute once per (i, b)
        halos_by_b = {b: halo_growth(ls[i:b + 1], b - i)
                      for b in range(i + 1, hi)}
        for pi, p in enumerate(schemes):
            queries: List[List[Tuple[int, int]]] = [[(i, 0)]]
            halo_cut = False
            if p.spatial:
                ext = min_shard_extent(ls[i], p, nodes)
                for b in range(i + 1, hi):
                    halos = halos_by_b[b]
                    if 2 * halos[0] >= ext:
                        halo_cut = True
                        break   # degenerated into replication
                    queries.append([(i + off, halos[off])
                                    for off in range(b - i + 1)])
            yield i, pi, queries, halo_cut


@dataclasses.dataclass
class ChainTables:
    """Precomputed costs for one chain of layers.

    ``seg[i, pi, L-1]`` is the summed i-cost (halos included) of segment
    ``[i .. i+L-1]`` under ``schemes[pi]``, ``+inf`` where inadmissible
    (non-spatial multi-layer fusion, halo degenerated into replication, or
    beyond ``max_segment``).  Admissible lengths form a prefix per
    ``(i, pi)`` because the halo is monotone in segment length.
    ``sbound[b, pi, qi]`` is the T-boundary s-cost between layers ``b`` and
    ``b+1``; ``s_final[pi]`` the gather-to-root of the last layer (NaN-free
    only when built ``with_final``).
    """

    schemes: Tuple[Scheme, ...]
    seg: np.ndarray
    sbound: np.ndarray
    s_final: np.ndarray
    halo_cuts: int = 0

    @property
    def n(self) -> int:
        return self.seg.shape[0]

    def seg_options(self, i: int, pi: int,
                    head_solo: bool = False) -> List[Tuple[int, float]]:
        """Ascending ``(b, segcost)`` options for segments starting at
        ``i`` — the batched stand-in for the reference ``seg_costs``."""
        if head_solo and i == 0:
            cap = 1
        else:
            cap = min(self.seg.shape[2], self.n - i)
        row = self.seg[i, pi]
        out: List[Tuple[int, float]] = []
        for L in range(cap):
            v = row[L]
            if v == _INF:
                break   # admissible lengths are a prefix
            out.append((i + L, float(v)))
        return out

    def bound(self, b: int, pi: int, qi: int) -> float:
        return float(self.sbound[b, pi, qi])

    def final(self, pi: int) -> float:
        """Gather-to-root s-cost of the last layer (``with_final`` only)."""
        return float(self.s_final[pi])


# ---------------------------------------------------------------------------
# Pareto reductions over (compute, sync) cost pairs.
#
# The throughput objectives carry two accumulators per partial plan — the
# per-request device occupancy (sum of segment i-costs) and link occupancy
# (sum of sync s-costs) — and every composition step in the DP is monotone
# in both, so exact search reduces to nondominated-set propagation.  These
# are the batched primitives: one lexsort + cummin per frontier merge, the
# same numpy-reduction style as the latency DP's argmin scans.
# ---------------------------------------------------------------------------

def pareto_front_2d(a: np.ndarray, b: np.ndarray,
                    ub: float = _INF) -> np.ndarray:
    """Indices of the nondominated (min-``a``, min-``b``) points, sorted by
    ``a`` ascending.  Duplicate values collapse to the first occurrence in
    the input order (the scalar scan's tie-breaking); points with either
    coordinate beyond ``ub`` are dropped (any completion only adds cost, so
    they can never beat an incumbent whose total is ``ub``)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    idx = np.arange(len(a))
    if ub != _INF:
        ok = (a <= ub) & (b <= ub)
        idx = idx[ok]
        if not len(idx):
            return idx
        a, b = a[idx], b[idx]
    order = np.lexsort((idx, b, a))     # a asc, then b asc, then input order
    a_s, b_s = a[order], b[order]
    keep = np.empty(len(order), bool)
    keep[0] = True
    if len(order) > 1:
        cm = np.minimum.accumulate(b_s)
        keep[1:] = b_s[1:] < cm[:-1]
    return idx[order[keep]]


def pareto_front_nd(cols: Sequence[np.ndarray]) -> np.ndarray:
    """Indices of the nondominated rows under elementwise minimisation of
    ``cols`` (pairwise O(m^2) domination — used on the small per-branch
    option tables of the DAG composition, where m stays in the tens)."""
    M = np.stack([np.asarray(c, np.float64) for c in cols], axis=1)
    m = len(M)
    if m <= 1:
        return np.arange(m)
    le = (M[:, None, :] <= M[None, :, :]).all(axis=2)
    lt = (M[:, None, :] < M[None, :, :]).any(axis=2)
    dominated = (le & lt).any(axis=0)
    # drop exact-duplicate rows, keeping the first occurrence
    eq = (M[:, None, :] == M[None, :, :]).all(axis=2)
    first_dup = np.triu(eq, 1).any(axis=0)
    return np.nonzero(~(dominated | first_dup))[0]


def plan_chain_tables(ls: Sequence[LayerSpec], builder: CostTableBuilder,
                      schemes: Sequence[Scheme], max_segment: int,
                      allow_fusion: bool, nodes: int,
                      with_final: bool = True
                      ) -> Callable[[np.ndarray, np.ndarray], ChainTables]:
    """Phase 1: register every admissible segment/boundary query of one
    chain with ``builder``.  Returns a finalizer that assembles the
    :class:`ChainTables` once the builder has been evaluated (several
    chains — e.g. all branches of a DAG — share one builder and thus one
    batched estimator call)."""
    n = len(ls)
    k = len(schemes)
    cap = max(1, min(max_segment, n)) if allow_fusion else 1
    # segment index plans: seg_idx[(i, pi)] = list over L of per-layer row
    # indices (ascending offset — summed in scalar order later)
    seg_idx: Dict[Tuple[int, int], List[List[int]]] = {}
    halo_cuts = 0
    for i, pi, queries, halo_cut in admissible_segments(ls, schemes, nodes,
                                                        cap):
        p = schemes[pi]
        seg_idx[(i, pi)] = [[builder.i_index(ls[m], p, halo)
                             for m, halo in q] for q in queries]
        halo_cuts += halo_cut
    bound_idx = np.empty((max(n - 1, 0), k, k), np.int64)
    for b in range(n - 1):
        for pi, p in enumerate(schemes):
            for qi, q in enumerate(schemes):
                bound_idx[b, pi, qi] = builder.s_index(ls[b], ls[b + 1], p, q)
    final_idx = np.asarray(
        [builder.s_index(ls[-1], None, p, None) for p in schemes]
        if (with_final and n) else [], np.int64)

    def finalize(ivals: np.ndarray, svals: np.ndarray) -> ChainTables:
        seg = np.full((n, k, cap), _INF)
        for (i, pi), rows in seg_idx.items():
            for L, idxs in enumerate(rows):
                c = 0.0
                for idx in idxs:   # scalar accumulation order
                    c += ivals[idx]
                seg[i, pi, L] = c
        sbound = svals[bound_idx] if n > 1 else \
            np.empty((0, k, k), np.float64)
        s_final = svals[final_idx] if final_idx.size else \
            np.full(k, np.nan)
        return ChainTables(tuple(schemes), seg, sbound, s_final, halo_cuts)

    return finalize


def build_chain_tables(ls: Sequence[LayerSpec], est: CostEstimator,
                       tb: Testbed, schemes: Sequence[Scheme],
                       max_segment: int, allow_fusion: bool,
                       with_final: bool = True
                       ) -> Tuple[ChainTables, int, int]:
    """One-chain convenience wrapper: returns ``(tables, i_rows, s_rows)``
    evaluated in a single pair of batched estimator calls."""
    builder = CostTableBuilder(est, tb)
    fin = plan_chain_tables(ls, builder, schemes, max_segment, allow_fusion,
                            tb.nodes, with_final)
    ivals, svals = builder.evaluate()
    return fin(ivals, svals), builder.i_entries, builder.s_entries


class PrefetchedEstimator:
    """``CostEstimator`` view that answers scalar queries from one batched
    prefetch over everything a plan on ``graph`` could ask.

    Used by consumers that still walk plans one cost at a time — the
    exhaustive oracle scoring thousands of candidate plans, and the
    fixed-plan baselines — so their per-query cost drops to a dict lookup.
    Unknown queries fall back to the wrapped estimator (and are cached), so
    the view is always exact.
    """

    def __init__(self, est: CostEstimator, tb: Testbed):
        self._est = est
        self._i: Dict[tuple, float] = {}
        self._s: Dict[tuple, float] = {}
        # plain-int hit/miss counters (the scalar path is called in the
        # oracle's innermost loop — no registry indirection here; read
        # them via cache_info() or push_metrics())
        self.hits = 0
        self.misses = 0

    @classmethod
    def for_graph(cls, graph: ModelGraph, est: CostEstimator, tb: Testbed,
                  schemes: Sequence[Scheme] = ALL_SCHEMES,
                  allow_fusion: bool = True) -> CostEstimator:
        """Prefetch every i/s query reachable by a feasible plan: all
        non-degenerate segments of every branch, all internal boundaries,
        every junction delivery, and the final gather.  Estimators without
        the batched protocol are returned unwrapped (scalar semantics may
        depend on more than the feature expression, e.g. layer names)."""
        if not hasattr(est, "i_cost_batch"):
            return est
        self = cls(est, tb)
        builder = CostTableBuilder(est, tb)
        layers = graph.layers
        i_keys: List[Tuple[tuple, int]] = []
        s_keys: List[Tuple[tuple, int]] = []

        def reg_s(layer, nxt, src, dst):
            s_keys.append((_s_key(layer, nxt, src, dst),
                           builder.s_index(layer, nxt, src, dst)))

        for br in graph.linearize():
            ls = [layers[i] for i in br.ids]
            n = len(ls)
            cap = n if allow_fusion else 1
            for _, pi, queries, _ in admissible_segments(ls, schemes,
                                                         tb.nodes, cap):
                p = schemes[pi]
                for q in queries:
                    for m, halo in q:
                        i_keys.append((_i_key(ls[m], p, halo),
                                       builder.i_index(ls[m], p, halo)))
            for b in range(n - 1):
                for p in schemes:
                    for q in schemes:
                        reg_s(ls[b], ls[b + 1], p, q)
            tail = ls[-1]
            consumers = graph.consumer_ids[br.ids[-1]]
            if not consumers:
                for p in schemes:
                    reg_s(tail, None, p, None)
            for c in consumers:
                for p in schemes:
                    for q in schemes:
                        reg_s(tail, layers[c], p, q)

        ivals, svals = builder.evaluate()
        for key, idx in i_keys:
            self._i[key] = float(ivals[idx])
        for key, idx in s_keys:
            self._s[key] = float(svals[idx])
        return self

    # ---- CostEstimator protocol ------------------------------------------
    def i_cost(self, layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int = 0) -> float:
        key = _i_key(layer, scheme, extra_halo)
        hit = self._i.get(key)
        if hit is None:
            self.misses += 1
            hit = self._est.i_cost(layer, scheme, tb, extra_halo=extra_halo)
            self._i[key] = hit
        else:
            self.hits += 1
        return hit

    def s_cost(self, layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed) -> float:
        key = _s_key(layer, nxt, src, dst)
        hit = self._s.get(key)
        if hit is None:
            self.misses += 1
            hit = self._est.s_cost(layer, nxt, src, dst, tb)
            self._s[key] = hit
        else:
            self.hits += 1
        return hit

    def cache_info(self) -> Tuple[int, int]:
        """(hits, misses) of the scalar lookup path."""
        return (self.hits, self.misses)

    def push_metrics(self) -> None:
        """Batch the counters into the installed metrics registry (a
        no-op without one)."""
        _obs_metrics.inc("prefetch.hits", self.hits)
        _obs_metrics.inc("prefetch.misses", self.misses)

    def i_cost_batch(self, X: np.ndarray, tb: Testbed,
                     flop_factor: Optional[np.ndarray] = None) -> np.ndarray:
        return self._est.i_cost_batch(X, tb, flop_factor)

    def s_cost_batch(self, X: np.ndarray, tb: Testbed) -> np.ndarray:
        return self._est.s_cost_batch(X, tb)
