"""Heterogeneous cluster subsystem: specs, weighted costing, simulator.

Quick start::

    from repro.cluster import mixed_fast_slow, cluster_plan_search, simulate
    cluster = mixed_fast_slow(6)            # 2 fast + 4 slow devices
    res = cluster_plan_search(graph, cluster)
    rep = simulate(graph, res.plan, cluster, n_requests=32)
"""
from __future__ import annotations

from typing import Sequence

from repro.core.dpp import SearchResult, plan_search
from repro.core.graph import ModelGraph
from repro.core.partition import ALL_SCHEMES, Scheme

from .estimator import ClusterAnalyticEstimator
from .simsched import SimReport, Stage, build_stages, simulate
from .spec import (CLUSTER_PRESETS, ClusterSpec, DeviceSpec, LinkSpec,
                   asym_uplink, homogeneous, mixed_fast_slow, stepped,
                   topology_edges)


def cluster_plan_search(graph: ModelGraph, cluster: ClusterSpec,
                        weighted: bool = True,
                        schemes: Sequence[Scheme] = ALL_SCHEMES,
                        max_segment: int = 32,
                        allow_fusion: bool = True) -> SearchResult:
    """DPP over a cluster: batched tables throughout (the cluster estimator
    implements the full batched protocol, so heterogeneous layouts never
    fall back to scalar calls).  ``weighted=False`` plans with even shard
    fractions on the same silicon — the homogeneous-assumption baseline."""
    est = ClusterAnalyticEstimator(cluster, weighted=weighted)
    return plan_search(graph, est, cluster.compat_testbed(), schemes=schemes,
                       max_segment=max_segment, allow_fusion=allow_fusion)


__all__ = [
    "CLUSTER_PRESETS", "ClusterAnalyticEstimator", "ClusterSpec",
    "DeviceSpec", "LinkSpec", "SimReport", "Stage", "asym_uplink",
    "build_stages", "cluster_plan_search", "homogeneous", "mixed_fast_slow",
    "simulate", "stepped", "topology_edges",
]
