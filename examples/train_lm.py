"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the framework's full substrate: synthetic data pipeline, AdamW +
cosine schedule, remat'd scanned blocks, checkpointing.  Single process;
add ``--devices N`` to run data-parallel over N fake CPU devices (the same
sharding rules the production mesh uses).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import os
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_lm.npz")
    args = ap.parse_args()

    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    from repro.checkpoint import save_pytree
    from repro.configs.base import ModelConfig
    from repro.data import SyntheticLMDataset
    from repro.models.transformer import Model
    from repro.optim import adamw_init
    from repro.runtime.steps import make_train_step

    # ~100M params: 12L x d768 (GQA 12h/4kv), vocab 32k
    cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                      d_model=768, n_heads=12, n_kv=4, d_ff=2048,
                      vocab=32000, dtype="float32")
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params")

    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq,
                            global_batch=args.batch, seed=0)
    opt = adamw_init(params)
    step_fn = make_train_step(model, peak_lr=3e-4, warmup=20,
                              total=args.steps)

    if args.devices > 1:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.runtime.shard_plan import (Strategy, batch_specs, named,
                                              opt_specs, param_specs)
        mesh = jax.make_mesh((args.devices, 1), ("data", "model"))
        st = Strategy()
        p_spec = param_specs(jax.eval_shape(lambda: params), mesh, st,
                             "train")
        p_sh = named(p_spec, mesh)
        o_sh = named(opt_specs(p_spec, None), mesh)
        b_sh = named(batch_specs(jax.eval_shape(lambda: ds.batch(0)), mesh),
                     mesh)
        ctx = mesh
        step_fn = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh,
                                         NamedSharding(mesh, P())))
    else:
        import contextlib
        ctx = contextlib.nullcontext()
        step_fn = jax.jit(step_fn)

    t0 = time.time()
    with ctx:
        for i, batch in zip(range(args.steps), ds):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, metrics = step_fn(params, opt, batch)
            if i % 20 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"({(time.time() - t0):.1f}s)")
    save_pytree(params, args.ckpt)
    print(f"checkpoint -> {args.ckpt}")
    final = float(metrics["loss"])
    print(f"final loss {final:.4f} (start ~{jnp.log(cfg.vocab):.2f})")
    return 0 if final < 9.5 else 1


if __name__ == "__main__":
    sys.exit(main())
