"""Online calibration — fold measurements back into the planner's costs.

The learned and analytic estimators price a plan from first principles;
the machine (or the discrete-event simulator standing in for it) reports
what actually happened.  This module closes that loop with two small,
composable correctors:

* :class:`OnlineCalibrator` — a per-device multiplicative residual model.
  ``predicted_occupancy`` prices a plan's per-device / per-link busy
  seconds from the same stage decomposition the simulator executes
  (``simsched.build_stages``), so a measurement and its prediction are
  term-for-term comparable.  ``observe`` folds a measurement —
  a :class:`~repro.cluster.simsched.SimReport` or any scalar-occupancy
  object shaped like ``ExecStats.to_occupancy()`` (``dev_occupancy_s`` /
  ``link_occupancy_s`` / ``period_s``, optional ``failures``) — into
  exponentially-weighted per-device compute corrections and a scalar sync
  correction.  ``axis_scales()`` exports the corrections in exactly the
  ``(beta, alpha)`` form ``refine_with_simulator`` re-weights the cached
  frontier with, and ``ClusterGBDTEstimator`` consumes the same object to
  correct learned costs at call time.

* :func:`fold_queueing_delay` — the serving-side correction: the
  analytic ``P99_BOUNDED`` objective bounds *service* latency, but an
  open arrival process adds queueing delay the per-request model cannot
  see.  Given measured ``sweep_serving`` rows, it subtracts the measured
  queueing-delay curve (interpolated at the target arrival rate) from
  the p99 bound, so the planner's analytic constraint lands where the
  measured tail actually sits.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import ModelGraph
from repro.core.plan import Plan

from .simsched import SimReport, build_stages
from .spec import ClusterSpec


@dataclasses.dataclass(frozen=True)
class CalibrationSample:
    """One folded measurement: what was predicted, what was measured,
    and the correction state after the update."""

    plan_signature: Tuple[Tuple[int, int], ...]   # (scheme, mode) per layer
    predicted_period_s: float
    measured_period_s: float
    trusted: bool
    compute_scale: Tuple[float, ...]
    sync_scale: float


class OnlineCalibrator:
    """Per-device multiplicative residual corrector (EMA over samples).

    ``compute_scale[d]`` multiplies every compute-second prediction for
    device ``d``; ``sync_scale`` multiplies every link-second prediction.
    Scales start at 1.0 (no correction) and move toward each measured
    measured-over-predicted ratio with weight ``decay`` per observation
    (``decay=1.0`` trusts the newest sample outright, small values
    smooth over measurement noise).

    Trust: a measurement with a nonzero ``failures`` attribute (the
    mesh executor's retry/timeout/fallback counter surfaced by
    ``ExecStats.to_occupancy()``) is recorded in the history but does not
    move the scales — the same untrusted-sample rule
    ``refine_with_simulator`` applies.
    """

    def __init__(self, cluster: ClusterSpec, decay: float = 0.5):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.cluster = cluster
        self.decay = decay
        self.compute_scale = np.ones(cluster.n, np.float64)
        self.sync_scale = 1.0
        self.history: List[CalibrationSample] = []

    # ---- prediction -------------------------------------------------------
    def predicted_occupancy(self, graph: ModelGraph, plan: Plan,
                            weighted: bool = True, batch_size: int = 1
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Uncorrected per-device / per-link busy seconds of one request —
        the sums ``simulate`` accumulates into ``device_busy_s`` /
        ``link_busy_s``, priced without running the event loop."""
        dev = np.zeros(self.cluster.n, np.float64)
        link = np.zeros(len(self.cluster.links), np.float64)
        for st in build_stages(graph, plan, self.cluster, weighted=weighted,
                               batch_size=batch_size):
            if st.kind == "compute":
                dev += np.asarray(st.durations, np.float64)
            else:
                link += np.asarray(st.durations, np.float64)
        return dev, link

    def predict_period(self, graph: ModelGraph, plan: Plan,
                       weighted: bool = True, batch_size: int = 1) -> float:
        """Corrected steady-state period bound: the busiest corrected
        resource paces the pipeline."""
        dev, link = self.predicted_occupancy(graph, plan, weighted,
                                             batch_size)
        busiest_dev = float(np.max(dev * self.compute_scale)) if dev.size \
            else 0.0
        busiest_link = float(np.max(link)) * self.sync_scale if link.size \
            else 0.0
        return max(busiest_dev, busiest_link)

    def axis_scales(self) -> Tuple[float, float]:
        """``(beta, alpha)`` for frontier re-selection: the straggler-side
        compute correction and the sync correction (capability-weighted
        shards equalize per-device time, so the post-correction straggler
        is the device with the largest correction)."""
        return float(np.max(self.compute_scale)), float(self.sync_scale)

    # ---- measurement folding ----------------------------------------------
    def observe(self, graph: ModelGraph, plan: Plan, measured,
                weighted: bool = True, batch_size: int = 1) -> bool:
        """Fold one measurement; returns ``True`` when the sample was
        trusted (scales moved).

        ``measured`` is either a :class:`SimReport` (per-device busy
        vectors divide by ``n_requests``) or a scalar-occupancy object
        (``dev_occupancy_s`` / ``link_occupancy_s`` / ``period_s``),
        whose bottleneck ratios apply at the predicted straggler device /
        busiest link — a scalar probe cannot localize the residual, so it
        corrects where the prediction says the bottleneck is.
        """
        dev, link = self.predicted_occupancy(graph, plan, weighted,
                                             batch_size)
        pred_period = max(float(np.max(dev)) if dev.size else 0.0,
                          float(np.max(link)) if link.size else 0.0)
        if isinstance(measured, SimReport):
            served = max(measured.n_requests, 1)
            m_dev = np.asarray(measured.device_busy_s, np.float64) / served
            m_link = np.asarray(measured.link_busy_s, np.float64) / served
            trusted = True
            meas_period = (1.0 / measured.throughput_rps
                           if measured.throughput_rps > 0.0 else 0.0)
            dev_ratio = np.where(dev > 0.0, m_dev / np.maximum(dev, 1e-30),
                                 1.0)
            link_max = float(np.max(m_link)) if m_link.size else 0.0
            pred_link_max = float(np.max(link)) if link.size else 0.0
            sync_ratio = (link_max / pred_link_max
                          if pred_link_max > 0.0 else 1.0)
        else:
            trusted = getattr(measured, "failures", 0) == 0
            meas_period = float(measured.period_s)
            dev_ratio = np.ones_like(dev)
            straggler = int(np.argmax(dev)) if dev.size else 0
            if dev.size and dev[straggler] > 0.0:
                dev_ratio[straggler] = \
                    float(measured.dev_occupancy_s) / dev[straggler]
            pred_link_max = float(np.max(link)) if link.size else 0.0
            sync_ratio = (float(measured.link_occupancy_s) / pred_link_max
                          if pred_link_max > 0.0 else 1.0)
        if trusted:
            self.compute_scale = ((1.0 - self.decay) * self.compute_scale
                                  + self.decay * dev_ratio)
            self.sync_scale = ((1.0 - self.decay) * self.sync_scale
                               + self.decay * sync_ratio)
        self.history.append(CalibrationSample(
            plan_signature=tuple((int(s), int(m)) for s, m in plan.steps),
            predicted_period_s=pred_period,
            measured_period_s=meas_period,
            trusted=trusted,
            compute_scale=tuple(float(x) for x in self.compute_scale),
            sync_scale=float(self.sync_scale)))
        return trusted


def fold_queueing_delay(p99_bound_s: float, rows: Sequence[dict],
                        arrival_rate_rps: float,
                        service_p99_s: Optional[float] = None) -> float:
    """Tighten an analytic p99 bound by the measured queueing delay.

    ``rows`` are measured ``sweep_serving`` rows (the BENCH_serving
    record format).  The queueing-delay curve is each row's p99 in excess
    of the service-only tail — ``service_p99_s`` when the caller knows it
    (e.g. a closed-loop single-request run), else the minimum measured
    p99 across the sweep (the lightest-load row, where queueing is
    negligible).  The curve is interpolated at ``arrival_rate_rps``
    (clamped to the measured range) and subtracted from the bound,
    floored at zero; the result is what ``Objective.P99_BOUNDED``'s
    ``latency_bound_s`` should be so the *measured* tail meets the
    original bound under that arrival rate.
    """
    if p99_bound_s <= 0.0:
        raise ValueError(f"p99 bound must be positive, got {p99_bound_s}")
    if not rows:
        return p99_bound_s
    rates = np.asarray([float(r["arrival_rate_rps"]) for r in rows])
    p99s = np.asarray([float(r["p99_ms"]) * 1e-3 for r in rows])
    order = np.argsort(rates)
    rates, p99s = rates[order], p99s[order]
    base = float(np.min(p99s)) if service_p99_s is None \
        else float(service_p99_s)
    delays = np.maximum(p99s - base, 0.0)
    delay = float(np.interp(arrival_rate_rps, rates, delays))
    return max(p99_bound_s - delay, 0.0)
