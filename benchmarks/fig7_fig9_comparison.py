"""Fig. 7 / Fig. 9 — solution comparison on the 4-node and 3-node testbeds:
4 models x 6 solutions (3 fixed, layerwise, fused, FlexPie), estimated
inference time + FlexPie speedup over each baseline."""
from __future__ import annotations

from repro.core import Testbed
from repro.core.baselines import all_solutions
from repro.configs.edge_models import EDGE_MODELS

from .common import EST, emit, time_call


def run(nodes: int, fig: str, bandwidth: float = 1.0) -> None:
    tb = Testbed(nodes=nodes, bandwidth_gbps=bandwidth)
    for model, fn in EDGE_MODELS.items():
        g = fn()
        us, sols = time_call(lambda: all_solutions(g, EST, tb), repeats=1)
        times = {k: v[1] for k, v in sols.items()}
        flex = times["flexpie"]
        speedups = {k: times[k] / flex for k in times if k != "flexpie"}
        derived = ";".join(f"{k}={v * 1e3:.2f}ms" for k, v in times.items())
        derived += ";" + ";".join(f"x_{k}={v:.2f}"
                                  for k, v in speedups.items())
        emit(f"{fig}/{model}-{nodes}node", us, derived)


if __name__ == "__main__":
    run(4, "fig7")
    run(3, "fig9")
