"""Layer-graph IR for the edge-inference planner.

FlexPie consumes a computation graph of DNN layers (Fig. 3).  We model the
graph as an ordered chain of :class:`LayerSpec` (residual adds are folded into
``extra_flop_factor`` of the layer that closes the block — the planner only
needs shapes, FLOPs and receptive fields, not autodiff semantics).  The real
tensor programs live in ``repro/models`` and ``repro/runtime/engine.py``; this
IR is what the combinatorial optimizer reasons about.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import List, Optional, Sequence, Tuple


class ConvT(enum.IntEnum):
    """Layer categories (the ``ConvT`` categorical feature of Fig. 4)."""

    CONV = 0          # standard convolution
    DWCONV = 1        # depthwise convolution
    POINTWISE = 2     # 1x1 convolution
    POOL = 3          # max/avg pool (no weights)
    FC = 4            # fully connected / matmul (BERT, classifier heads)
    ADD = 5           # residual add (elementwise)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the inference graph.

    Shapes follow the paper's feature expression (Fig. 4): input feature map
    ``InH x InW x InC``, output ``OutH x OutW x OutC``, kernel ``K``, stride
    ``S``, padding ``P``.  For FC/matmul layers the convention is
    ``InH = OutH = seq_len`` (BERT tokens), ``InW = OutW = 1``,
    ``InC/OutC = feature dims`` and ``K = S = 1, P = 0``.
    """

    name: str
    conv_t: ConvT
    in_h: int
    in_w: int
    in_c: int
    out_c: int
    k: int = 1
    s: int = 1
    p: int = 0
    extra_flop_factor: float = 1.0  # folds residual adds / activations

    @property
    def out_h(self) -> int:
        return (self.in_h + 2 * self.p - self.k) // self.s + 1

    @property
    def out_w(self) -> int:
        return (self.in_w + 2 * self.p - self.k) // self.s + 1

    # ---- workload ---------------------------------------------------------
    def flops(self) -> float:
        """Total MACs*2 for the full (unpartitioned) layer."""
        oh, ow = self.out_h, self.out_w
        if self.conv_t == ConvT.CONV or self.conv_t == ConvT.POINTWISE:
            f = 2.0 * oh * ow * self.out_c * self.in_c * self.k * self.k
        elif self.conv_t == ConvT.DWCONV:
            f = 2.0 * oh * ow * self.out_c * self.k * self.k
        elif self.conv_t == ConvT.POOL:
            f = 1.0 * oh * ow * self.out_c * self.k * self.k
        elif self.conv_t == ConvT.FC:
            f = 2.0 * self.in_h * self.in_c * self.out_c
        elif self.conv_t == ConvT.ADD:
            f = 1.0 * oh * ow * self.out_c
        else:  # pragma: no cover - exhaustive enum
            raise ValueError(self.conv_t)
        return f * self.extra_flop_factor

    def out_elems(self) -> int:
        return self.out_h * self.out_w * self.out_c

    def in_elems(self) -> int:
        return self.in_h * self.in_w * self.in_c

    def weight_elems(self) -> int:
        if self.conv_t in (ConvT.CONV, ConvT.POINTWISE):
            return self.k * self.k * self.in_c * self.out_c
        if self.conv_t == ConvT.DWCONV:
            return self.k * self.k * self.out_c
        if self.conv_t == ConvT.FC:
            return self.in_c * self.out_c
        return 0

    def feature_vector(self) -> Tuple[float, ...]:
        """Shape part of the Fig. 4 feature expression (7 of 12 dims)."""
        return (
            float(self.in_h), float(self.in_w), float(self.in_c),
            float(self.out_h), float(self.out_w), float(self.out_c),
            float(self.k), float(self.s), float(self.p), float(self.conv_t),
        )

    def with_input(self, in_h: int, in_w: int) -> "LayerSpec":
        return dataclasses.replace(self, in_h=in_h, in_w=in_w)


@dataclasses.dataclass(frozen=True)
class ModelGraph:
    """Chain of layers; ``layers[i+1].in_* == layers[i].out_*`` must hold."""

    name: str
    layers: Tuple[LayerSpec, ...]

    def __post_init__(self) -> None:
        for a, b in zip(self.layers, self.layers[1:]):
            if (a.out_h, a.out_w) != (b.in_h, b.in_w) or a.out_c != b.in_c:
                raise ValueError(
                    f"{self.name}: layer chain mismatch {a.name} "
                    f"({a.out_h},{a.out_w},{a.out_c}) -> {b.name} "
                    f"({b.in_h},{b.in_w},{b.in_c})")

    def __len__(self) -> int:
        return len(self.layers)

    def total_flops(self) -> float:
        return sum(l.flops() for l in self.layers)

    def spatial(self) -> bool:
        """True if the graph has spatial (conv) layers at all."""
        return any(l.conv_t in (ConvT.CONV, ConvT.DWCONV, ConvT.POINTWISE,
                                ConvT.POOL) for l in self.layers)


# ---------------------------------------------------------------------------
# Receptive-field math — the heart of NT-mode (redundant-compute) planning.
# ---------------------------------------------------------------------------

def halo_growth(layers: Sequence[LayerSpec], upto: int) -> List[int]:
    """Cumulative output-halo each layer must additionally produce so that
    layer ``upto`` can be computed with zero communication (NT fusion).

    ``halo[m]`` = number of extra *output* rows (per side) layer ``m`` must
    compute, given layers ``m+1..upto`` are fused after it.  ``halo[upto] = 0``.
    Standard receptive-field recurrence, applied backwards:
        need[m] = need[m+1] * S_{m+1} + (K_{m+1} - 1)   (in layer-m output rows)
    For FC/ADD layers K=S=1 so the halo never grows through them.
    """
    n = upto + 1
    halo = [0] * n
    for m in range(upto - 1, -1, -1):
        nxt = layers[m + 1]
        halo[m] = halo[m + 1] * nxt.s + (nxt.k - 1)
    return halo


def chain(name: str, specs: Sequence[LayerSpec]) -> ModelGraph:
    return ModelGraph(name=name, layers=tuple(specs))
