"""Elastic clusters — device membership, capability reports, and
incremental live replanning.

Production edge fleets are not static :class:`ClusterSpec` instances:
devices join, leave, throttle, and die mid-stream.  This module adds the
planner-side core:

* :class:`DeviceRegistry` — a membership layer keyed by
  :class:`DeviceSpec`: heartbeat/lease state machine
  (``JOINING → LIVE → SUSPECT → DEAD``, graceful ``LEFT``) with
  configurable miss thresholds, plus capability **derate reports**
  (a throttling device reports a multiplier on its effective capability
  with its heartbeat).  ``registry.cluster()`` projects the live
  membership onto a plain :class:`ClusterSpec`, so everything downstream
  (planner, simulator, executor) consumes ordinary cluster specs.
* :class:`ElasticPlanner` — incremental replanning on cluster events.
  Instead of re-solving the Pareto-frontier DP from scratch it reuses, in
  order of cheapness:

  1. **whole frontiers** for previously seen cluster states (flapping
     devices revisit states — an LRU keyed by the full capability
     signature);
  2. **the query registration** (`core.dpp.FrontierTables`) whenever the
     testbed projection (node count / topology / bottleneck link) is
     unchanged — the Python-heavy enumeration phase is skipped and only
     the numpy batch evaluation reruns;
  3. **sync-cost rows verbatim** across capability changes — s-costs read
     only the testbed projection, so a derate invalidates *only the
     i-rows* of the cached cost tables;
  4. **the entire cached frontier, rescaled**, when the new i-costs are a
     uniform positive multiple of the cached ones (per-axis positive
     rescaling cannot change a nondominated set) — zero DP work;
  5. **surviving suffix frontiers** of the chain DP / per-branch pinned
     tables of the DAG DP via ``FrontierTables.frontier(warm=True)``.

  On top of frontier selection the planner scores **plan migration** as
  an explicit term: moving to a new plan costs the weight bytes that must
  move between devices (scheme-aware ownership: spatial schemes
  replicate filters, OutC shards them) plus draining the requests in
  flight, amortized over an expected serving horizon — so it can
  rationally choose *keep the degraded plan* over *migrate to the new
  optimum*.  ``replan()`` returns the decision with both scores.

Memory feasibility is enforced plan-aware: :func:`plan_device_bytes`
computes each device's owned weight bytes + peak activation shard for a
*specific plan*, and the planner walks the frontier in objective order
until a fitting plan is found (:class:`CapacityError` when none fits).
"""
from __future__ import annotations

import dataclasses
import enum
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import Topology
from repro.core.dpp import (FrontierTables, Objective, PlanFrontier,
                            pipeline_objective_key)
from repro.core.graph import ModelGraph
from repro.core.partition import (ALL_SCHEMES, DTYPE_BYTES, Scheme,
                                  weighted_split_sizes)
from repro.core.plan import Plan, plan_pipeline_cost
from repro.obs import flight as _obs_flight
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

from .estimator import ClusterAnalyticEstimator
from .spec import ClusterSpec, DeviceSpec, LinkSpec, topology_edges


class MembershipError(RuntimeError):
    """Raised on invalid registry transitions or an empty live set."""


class CapacityError(RuntimeError):
    """No frontier plan fits the surviving devices' memory."""


# ---------------------------------------------------------------------------
# membership state machine
# ---------------------------------------------------------------------------

class DeviceState(enum.Enum):
    JOINING = "joining"      # announced, no heartbeat yet
    LIVE = "live"            # heartbeating within the lease
    SUSPECT = "suspect"      # >= suspect_misses heartbeats missed
    DEAD = "dead"            # >= dead_misses missed — evicted from plans
    LEFT = "left"            # graceful departure


#: states whose devices still participate in plans (a SUSPECT device is
#: kept until the lease declares it DEAD — eviction is the disruptive act)
PLANNABLE_STATES = (DeviceState.LIVE, DeviceState.SUSPECT)


@dataclasses.dataclass
class Member:
    """One registered device and its lease/capability state."""

    spec: DeviceSpec
    state: DeviceState
    joined_at: float
    last_heartbeat: float
    derate: float = 1.0            # reported capability multiplier
    misses: int = 0

    def effective_spec(self) -> DeviceSpec:
        """The spec the planner sees: the reported derate folds into
        ``eff_derate`` (capability weights are ``gflops * eff_derate``)."""
        if self.derate == 1.0:
            return self.spec
        return dataclasses.replace(
            self.spec, eff_derate=self.spec.eff_derate * self.derate)


@dataclasses.dataclass(frozen=True)
class StateChange:
    """One registry transition, returned by the mutating calls."""

    name: str
    old: DeviceState
    new: DeviceState
    at: float


class DeviceRegistry:
    """Heartbeat/lease membership over :class:`DeviceSpec` entries.

    The registry is clock-agnostic: every call takes ``now`` explicitly,
    so simulated churn timelines and wall-clock deployments share one
    implementation.  A device misses a heartbeat when ``now`` advances
    ``heartbeat_interval_s`` past its last one; ``suspect_misses`` misses
    demote LIVE → SUSPECT (still planned), ``dead_misses`` misses evict
    (SUSPECT → DEAD — the disruptive transition callers replan on).
    """

    def __init__(self, link: LinkSpec = LinkSpec(),
                 topology: Topology = Topology.RING,
                 heartbeat_interval_s: float = 1.0,
                 suspect_misses: int = 2, dead_misses: int = 5,
                 name: str = "elastic",
                 _template: Optional[ClusterSpec] = None) -> None:
        if heartbeat_interval_s <= 0.0:
            raise ValueError("heartbeat_interval_s must be positive")
        if not (0 < suspect_misses <= dead_misses):
            raise ValueError("need 0 < suspect_misses <= dead_misses")
        self.link = link
        self.topology = topology
        self.heartbeat_interval_s = heartbeat_interval_s
        self.suspect_misses = suspect_misses
        self.dead_misses = dead_misses
        self.name = name
        self.link_factor = 1.0     # fleet-wide congestion multiplier
        self._members: "OrderedDict[str, Member]" = OrderedDict()
        self._template = _template
        self._version = 0

    # -- construction ------------------------------------------------------

    @classmethod
    def from_cluster(cls, cluster: ClusterSpec, now: float = 0.0,
                     **kwargs) -> "DeviceRegistry":
        """Seed a registry from a static cluster: every device joins LIVE
        at ``now``.  While the live membership equals the seed set, the
        seed's per-edge link graph is preserved (asymmetric presets keep
        their slow link); any membership change falls back to the uniform
        link template (the seed's bottleneck link)."""
        link = LinkSpec(bandwidth_gbps=cluster.bottleneck_bw_gbps,
                        latency_us=cluster.max_latency_us)
        reg = cls(link=link, topology=cluster.topology,
                  name=f"{cluster.name}-elastic", _template=cluster,
                  **kwargs)
        for d in cluster.devices:
            reg.join(d, now=now)
            reg.heartbeat(d.name, now=now)
        return reg

    # -- queries -----------------------------------------------------------

    def member(self, name: str) -> Member:
        m = self._members.get(name)
        if m is None:
            raise MembershipError(f"unknown device {name!r}")
        return m

    def get(self, name: str) -> Optional[Member]:
        """Like :meth:`member` but ``None`` for unknown names."""
        return self._members.get(name)

    def members(self) -> Tuple[Member, ...]:
        return tuple(self._members.values())

    def live_members(self) -> Tuple[Member, ...]:
        """Members in a plannable state, in join order."""
        return tuple(m for m in self._members.values()
                     if m.state in PLANNABLE_STATES)

    @property
    def version(self) -> int:
        """Monotone counter bumped on every observable change."""
        return self._version

    def signature(self) -> tuple:
        """Hashable capability state of the plannable membership — equal
        signatures produce equal ``cluster()`` projections (the elastic
        planner's frontier-cache key)."""
        return (tuple((m.spec, m.derate) for m in self.live_members()),
                self.link_factor, self.topology)

    def cluster(self) -> ClusterSpec:
        """Project the plannable membership onto a :class:`ClusterSpec`."""
        live = self.live_members()
        if not live:
            raise MembershipError("no live devices in the registry")
        devices = tuple(m.effective_spec() for m in live)
        template = self._template
        if (template is not None and self.link_factor == 1.0
                and devices == template.devices):
            return template
        link = LinkSpec(
            bandwidth_gbps=self.link.bandwidth_gbps * self.link_factor,
            latency_us=self.link.latency_us)
        n_edges = len(topology_edges(len(devices), self.topology))
        eff = {}
        if template is not None:
            eff = dict(eff_inh=template.eff_inh, eff_inw=template.eff_inw,
                       eff_outc=template.eff_outc,
                       eff_grid=template.eff_grid)
        return ClusterSpec(name=f"{self.name}-v{self._version}",
                           devices=devices, links=(link,) * n_edges,
                           topology=self.topology, **eff)

    # -- transitions -------------------------------------------------------

    def join(self, spec: DeviceSpec, now: float) -> StateChange:
        """Announce a device.  It stays JOINING (not planned) until its
        first heartbeat; a DEAD/LEFT name may rejoin with a fresh lease."""
        old = self._members.get(spec.name)
        if old is not None and old.state not in (DeviceState.DEAD,
                                                 DeviceState.LEFT):
            raise MembershipError(f"{spec.name!r} is already "
                                  f"{old.state.value}")
        prev = old.state if old is not None else DeviceState.LEFT
        self._members[spec.name] = Member(
            spec=spec, state=DeviceState.JOINING, joined_at=now,
            last_heartbeat=now)
        self._members.move_to_end(spec.name)
        self._version += 1
        return StateChange(spec.name, prev, DeviceState.JOINING, now)

    def leave(self, name: str, now: float) -> StateChange:
        """Graceful departure — immediate eviction, no lease wait."""
        m = self.member(name)
        old = m.state
        m.state = DeviceState.LEFT
        self._version += 1
        return StateChange(name, old, DeviceState.LEFT, now)

    def heartbeat(self, name: str, now: float,
                  derate: Optional[float] = None) -> Optional[StateChange]:
        """Record a heartbeat (optionally carrying a capability derate
        report).  JOINING/SUSPECT devices return to LIVE; DEAD/LEFT
        devices must :meth:`join` again first."""
        m = self.member(name)
        if m.state in (DeviceState.DEAD, DeviceState.LEFT):
            raise MembershipError(
                f"{name!r} is {m.state.value}; rejoin before heartbeating")
        m.last_heartbeat = now
        m.misses = 0
        change = None
        if m.state != DeviceState.LIVE:
            change = StateChange(name, m.state, DeviceState.LIVE, now)
            m.state = DeviceState.LIVE
            self._version += 1
        if derate is not None:
            self.report_derate(name, derate, now)
        return change

    def report_derate(self, name: str, derate: float, now: float) -> None:
        """Capability report: the device's effective throughput is
        ``derate`` times its spec (thermal throttling, co-tenant load).
        ``derate=1.0`` clears the report."""
        if derate <= 0.0:
            raise ValueError(f"derate must be positive, got {derate}")
        m = self.member(name)
        if m.derate != derate:
            m.derate = derate
            self._version += 1

    def set_link_factor(self, factor: float) -> None:
        """Fleet-wide interconnect congestion multiplier on bandwidth."""
        if factor <= 0.0:
            raise ValueError(f"link factor must be positive, got {factor}")
        if factor != self.link_factor:
            self.link_factor = factor
            self._version += 1

    def tick(self, now: float) -> List[StateChange]:
        """Advance the lease clock: count missed heartbeats and demote
        LIVE → SUSPECT → DEAD.  Returns the transitions (callers replan
        when any ``new == DEAD`` appears)."""
        changes: List[StateChange] = []
        for m in self._members.values():
            if m.state not in (DeviceState.LIVE, DeviceState.SUSPECT):
                continue
            m.misses = max(
                0, int((now - m.last_heartbeat)
                       / self.heartbeat_interval_s))
            want = m.state
            if m.misses >= self.dead_misses:
                want = DeviceState.DEAD
            elif m.misses >= self.suspect_misses:
                want = DeviceState.SUSPECT
            if want != m.state:
                changes.append(StateChange(m.spec.name, m.state, want, now))
                m.state = want
                self._version += 1
        return changes


# ---------------------------------------------------------------------------
# plan-aware memory + weight-ownership geometry
# ---------------------------------------------------------------------------

def _owned_intervals(layer, scheme: Scheme,
                     weights: Sequence[float]) -> List[Tuple[int, int]]:
    """Per-device owned interval of ``layer``'s out-channel axis under
    ``scheme``: spatial schemes replicate the full filter bank on every
    device, OutC shards it by capability share."""
    oc = layer.out_c
    if scheme == Scheme.OUTC:
        out = []
        at = 0
        for share in weighted_split_sizes(oc, list(weights)):
            out.append((at, at + share))
            at += share
        return out
    return [(0, oc)] * len(weights)


def plan_device_bytes(graph: ModelGraph, plan: Plan,
                      cluster: ClusterSpec) -> np.ndarray:
    """Per-device resident bytes of executing ``plan`` on ``cluster``:
    owned weight bytes (scheme-aware — spatial schemes replicate filters,
    OutC shards them by capability share) plus the peak activation shard
    (input + output feature maps of the heaviest layer).  The plan-aware
    counterpart of the advisory ``ClusterSpec.memory_ok``; NT halo
    overhang is ignored (it is bounded by the shard itself)."""
    n = cluster.n
    caps = list(cluster.capability_weights)
    w_owned = np.zeros(n)
    act_peak = np.zeros(n)
    for layer, (scheme, _mode) in zip(graph.layers, plan.steps):
        we = layer.weight_elems()
        oc = max(layer.out_c, 1)
        if we:
            per_ch = we * DTYPE_BYTES / oc
            w_owned += np.asarray(
                [(b - a) * per_ch
                 for a, b in _owned_intervals(layer, scheme, caps)])
        if scheme == Scheme.GRID2D:
            frac = np.full(n, 1.0 / n)
        elif scheme == Scheme.OUTC:
            frac = np.asarray(weighted_split_sizes(oc, caps)) / oc
        else:
            ext = layer.out_h if scheme == Scheme.INH else layer.out_w
            ext = max(ext, 1)
            frac = np.asarray(weighted_split_sizes(ext, caps)) / ext
        in_frac = np.ones(n) if scheme == Scheme.OUTC else frac
        act = (layer.in_elems() * in_frac
               + layer.out_elems() * frac) * DTYPE_BYTES
        act_peak = np.maximum(act_peak, act)
    return w_owned + act_peak


def plan_memory_ok(graph: ModelGraph, plan: Plan,
                   cluster: ClusterSpec) -> Tuple[bool, ...]:
    """Per-device fit of ``plan`` against ``mem_mb`` budgets."""
    need = plan_device_bytes(graph, plan, cluster)
    return tuple(float(b) <= d.mem_mb * 1e6
                 for b, d in zip(need, cluster.devices))


@dataclasses.dataclass(frozen=True)
class MigrationCost:
    """Cost of cutting the fleet over from one plan/cluster to another."""

    bytes_moved: float          # weight bytes that must travel
    move_s: float               # transfer time over the bottleneck link
    drain_s: float              # in-flight requests finishing on the old plan
    devices_touched: int        # devices receiving any bytes

    @property
    def total_s(self) -> float:
        return self.move_s + self.drain_s


def migration_cost_s(graph: ModelGraph, old_plan: Optional[Plan],
                     old_cluster: Optional[ClusterSpec], new_plan: Plan,
                     new_cluster: ClusterSpec, *, inflight: int = 0,
                     old_period_s: float = 0.0) -> MigrationCost:
    """Weight bytes to move + requests in flight drained — the explicit
    migration term of the elastic planner's keep-vs-migrate decision.

    Ownership is matched **by device name** across the old and new
    clusters: a surviving device only fetches the out-channel intervals
    it does not already hold (spatial schemes hold the full bank, so a
    spatial → spatial transition moves nothing on survivors); a new
    device fetches everything it owns.  ``old_plan=None`` (cold start)
    charges the full new footprint.  Transfer time is the moved bytes
    over the new cluster's bottleneck link plus one propagation latency
    per receiving device; drain time is ``inflight * old_period_s``.
    """
    caps_new = list(new_cluster.capability_weights)
    old_by_name: Dict[str, int] = {}
    caps_old: List[float] = []
    if old_plan is not None and old_cluster is not None:
        old_by_name = {d.name: i
                       for i, d in enumerate(old_cluster.devices)}
        caps_old = list(old_cluster.capability_weights)
    moved = np.zeros(new_cluster.n)
    for li, (layer, (scheme, _mode)) in enumerate(
            zip(graph.layers, new_plan.steps)):
        we = layer.weight_elems()
        if not we:
            continue
        oc = max(layer.out_c, 1)
        per_ch = we * DTYPE_BYTES / oc
        new_iv = _owned_intervals(layer, scheme, caps_new)
        old_iv = None
        if old_by_name:
            old_iv = _owned_intervals(
                layer, old_plan.steps[li][0], caps_old)
        for d, (a, b) in enumerate(new_iv):
            name = new_cluster.devices[d].name
            held = (0, 0)
            if old_iv is not None and name in old_by_name:
                held = old_iv[old_by_name[name]]
            overlap = max(0, min(b, held[1]) - max(a, held[0]))
            moved[d] += (b - a - overlap) * per_ch
    bytes_moved = float(moved.sum())
    touched = int(np.count_nonzero(moved))
    bw = new_cluster.bottleneck_bw_gbps * 1e9 / 8.0
    move_s = (bytes_moved / bw
              + touched * new_cluster.max_latency_us * 1e-6)
    drain_s = max(inflight, 0) * max(old_period_s, 0.0)
    return MigrationCost(bytes_moved=bytes_moved, move_s=move_s,
                         drain_s=drain_s, devices_touched=touched)


# ---------------------------------------------------------------------------
# incremental replanner
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplanDecision:
    """Outcome of one :meth:`ElasticPlanner.replan` call."""

    plan: Plan
    migrate: bool               # False = keep the (degraded) current plan
    period_s: float             # analytic pipeline period of the choice
    score_s: float              # migration + horizon-amortized serving time
    migration: MigrationCost
    keep_score_s: Optional[float]   # score of the keep option (None if
    #                                 there was no current plan to keep)
    plan_wall_s: float          # planner wall time of this decision
    point_idx: Optional[int]    # frontier index (None when keeping)
    frontier: PlanFrontier
    reuse: Dict                 # which incremental reuse paths fired


class ElasticPlanner:
    """Incremental Pareto-frontier replanning over cluster events.

    One instance persists across events and owns the caches; see the
    module docstring for the reuse ladder.  ``replan(cluster, ...)``
    builds (or reuses) the frontier for the cluster, selects the
    objective-best **memory-feasible** point, scores it against keeping
    the current plan (migration + horizon amortization), and returns the
    rational choice.
    """

    def __init__(self, graph: ModelGraph, *, weighted: bool = True,
                 schemes: Sequence[Scheme] = ALL_SCHEMES,
                 max_segment: int = 32, allow_fusion: bool = True,
                 horizon_requests: float = 500.0, inflight: int = 4,
                 enforce_memory: bool = True, rescale_tol: float = 1e-9,
                 cache_size: int = 8) -> None:
        self.graph = graph
        self.weighted = weighted
        self.schemes = tuple(schemes)
        self.max_segment = max_segment
        self.allow_fusion = allow_fusion
        self.horizon_requests = horizon_requests
        self.inflight = inflight
        self.enforce_memory = enforce_memory
        self.rescale_tol = rescale_tol
        self.cache_size = cache_size
        # per testbed-projection: registration + last evaluated rows
        self._by_tb: "OrderedDict[tuple, Dict]" = OrderedDict()
        # whole-frontier LRU over full capability signatures (flapping)
        self._fr_cache: "OrderedDict[tuple, PlanFrontier]" = OrderedDict()
        self.replans = 0

    # -- caching -----------------------------------------------------------

    @staticmethod
    def cluster_signature(cluster: ClusterSpec, weighted: bool) -> tuple:
        return (cluster.devices, cluster.links, cluster.topology,
                cluster.eff_inh, cluster.eff_inw, cluster.eff_outc,
                cluster.eff_grid, weighted)

    def _lru_put(self, store: "OrderedDict", key, value) -> None:
        store[key] = value
        store.move_to_end(key)
        while len(store) > self.cache_size:
            store.popitem(last=False)

    def frontier_for(self, cluster: ClusterSpec
                     ) -> Tuple[PlanFrontier, Dict]:
        """The complete (``prune_ub=False``) frontier for ``cluster``,
        via the cheapest reuse path available.  Returns ``(frontier,
        reuse)`` where ``reuse`` records what fired."""
        reuse: Dict = {"frontier_cache": False, "registration": False,
                       "svals": False, "rescale": None,
                       "suffix_reused_layers": 0,
                       "branch_tables_reused": 0}
        sig = self.cluster_signature(cluster, self.weighted)
        hit = self._fr_cache.get(sig)
        if hit is not None:
            self._fr_cache.move_to_end(sig)
            reuse["frontier_cache"] = True
            return hit, reuse

        est = ClusterAnalyticEstimator(cluster, weighted=self.weighted)
        tb = cluster.compat_testbed()
        tb_key = (tb, self.weighted)
        entry = self._by_tb.get(tb_key)
        if entry is None:
            ft = FrontierTables.register(self.graph, est, tb, self.schemes,
                                         self.max_segment,
                                         self.allow_fusion)
            entry = {"ft": ft, "ivals": None, "svals": None,
                     "frontier": None}
            self._lru_put(self._by_tb, tb_key, entry)
        else:
            self._by_tb.move_to_end(tb_key)
            reuse["registration"] = True
        ft: FrontierTables = entry["ft"]

        # s-rows depend only on the testbed projection — reuse verbatim
        svals = entry["svals"]
        if svals is not None:
            reuse["svals"] = True
        ivals, svals = ft.evaluate(est=est, svals=svals)

        fr: Optional[PlanFrontier] = None
        prev_ivals = entry["ivals"]
        if (prev_ivals is not None and entry["frontier"] is not None
                and len(prev_ivals) == len(ivals) and len(ivals)):
            # uniform-rescale fast path: a capability change that scales
            # every i-cost by one factor scales the frontier's compute
            # axis without touching the nondominated set or its plans
            with np.errstate(divide="ignore", invalid="ignore"):
                ratio = np.asarray(ivals) / np.asarray(prev_ivals)
            finite = ratio[np.isfinite(ratio)]
            if len(finite):
                c = float(finite[0])
                if c > 0.0 and np.all(
                        np.abs(finite - c) <= self.rescale_tol * c):
                    old_fr: PlanFrontier = entry["frontier"]
                    fr = dataclasses.replace(
                        old_fr,
                        points=old_fr.points * np.asarray([c, 1.0]))
                    reuse["rescale"] = c
        if fr is None:
            fr = ft.frontier(ivals, svals, warm=True)
            reuse["suffix_reused_layers"] = \
                ft.last_reuse.get("suffix_reused_layers", 0)
            reuse["branch_tables_reused"] = \
                ft.last_reuse.get("branch_tables_reused", 0)
        entry["ivals"] = np.asarray(ivals)
        entry["svals"] = np.asarray(svals)
        entry["frontier"] = fr
        self._lru_put(self._fr_cache, sig, fr)
        return fr, reuse

    # -- selection ---------------------------------------------------------

    def _select_feasible(self, fr: PlanFrontier, cluster: ClusterSpec,
                         objective: Objective,
                         latency_bound_s: Optional[float]
                         ) -> Tuple[int, Plan]:
        """Best frontier point in objective order that fits the devices'
        memory (first point when ``enforce_memory`` is off) — plans are
        only materialised until one fits."""
        order = sorted(range(len(fr.points)), key=lambda i:
                       pipeline_objective_key(float(fr.points[i, 0]),
                                              float(fr.points[i, 1]),
                                              objective, latency_bound_s))
        for i in order:
            plan = fr.plan(i)
            if (not self.enforce_memory
                    or all(plan_memory_ok(self.graph, plan, cluster))):
                return i, plan
        raise CapacityError(
            f"{self.graph.name}: no frontier plan fits the "
            f"{cluster.n} surviving devices' memory budgets")

    def replan(self, cluster: ClusterSpec, old_plan: Optional[Plan] = None,
               old_cluster: Optional[ClusterSpec] = None, *,
               objective: Objective = Objective.THROUGHPUT,
               latency_bound_s: Optional[float] = None,
               old_period_s: Optional[float] = None,
               consider_keep: bool = True) -> ReplanDecision:
        """Plan for ``cluster``, rationally weighing migration from
        ``old_plan`` (on ``old_cluster``): each candidate is scored as
        ``migration_total_s + horizon_requests * period_s`` and the
        minimum wins — a mildly degraded plan whose migration would cost
        more than the horizon saves is *kept*.  With no ``old_plan`` the
        frontier optimum is adopted (cold start; migration charged from
        an empty fleet)."""
        t0 = time.perf_counter()
        self.replans += 1
        # replan breakdown spans (planner track): incremental frontier
        # build -> feasible selection -> cutover (migration) scoring
        with _obs_trace.span(_obs_trace.PLANNER_TRACK, "replan.frontier",
                             cat="planner", graph=self.graph.name,
                             devices=cluster.n) as sp:
            fr, reuse = self.frontier_for(cluster)
            sp.set(**{k: v for k, v in reuse.items() if k != "rescale"})
        _obs_metrics.inc("replan.count", graph=self.graph.name)
        for key, val in reuse.items():
            if key == "rescale":
                amt = 1.0 if val is not None else 0.0
            elif isinstance(val, bool):
                amt = 1.0 if val else 0.0
            else:
                amt = float(val)
            _obs_metrics.inc("replan.reuse", amt, path=key)
        est = ClusterAnalyticEstimator(cluster, weighted=self.weighted)
        tb = cluster.compat_testbed()
        with _obs_trace.span(_obs_trace.PLANNER_TRACK, "replan.select",
                             cat="planner"):
            best_i, best_plan = self._select_feasible(
                fr, cluster, objective, latency_bound_s)
        a, b = float(fr.points[best_i, 0]), float(fr.points[best_i, 1])
        best_period = max(a, b)

        with _obs_trace.span(_obs_trace.PLANNER_TRACK, "replan.cutover",
                             cat="planner"):
            keep_score: Optional[float] = None
            if old_plan is not None:
                # keep's period is re-costed on the NEW cluster — the
                # old plan now runs on derated/survivor capabilities,
                # not the rate it enjoyed when it was planned
                pc = plan_pipeline_cost(self.graph, old_plan, est, tb)
                keep_period = pc.bottleneck_s
                keep_mig = migration_cost_s(
                    self.graph, old_plan, old_cluster, old_plan, cluster,
                    inflight=0, old_period_s=0.0)
                keep_ok = (not self.enforce_memory
                           or all(plan_memory_ok(self.graph, old_plan,
                                                 cluster)))
                if keep_ok and consider_keep:
                    keep_score = (keep_mig.total_s
                                  + self.horizon_requests * keep_period)

            mig = migration_cost_s(
                self.graph, old_plan, old_cluster, best_plan, cluster,
                inflight=self.inflight,
                old_period_s=0.0 if old_period_s is None
                else old_period_s)
            move_score = mig.total_s + self.horizon_requests * best_period

        if (keep_score is not None and old_plan is not None
                and keep_score <= move_score):
            wall = time.perf_counter() - t0
            _obs_metrics.inc("replan.kept", graph=self.graph.name)
            _obs_flight.get_flight().record(
                "replan", graph=self.graph.name, kept=True,
                wall_s=wall, period_s=keep_period)
            return ReplanDecision(
                plan=old_plan, migrate=keep_mig.bytes_moved > 0.0,
                period_s=keep_period, score_s=keep_score,
                migration=keep_mig, keep_score_s=keep_score,
                plan_wall_s=wall, point_idx=None, frontier=fr,
                reuse=reuse)
        wall = time.perf_counter() - t0
        _obs_metrics.inc("replan.migrated", graph=self.graph.name)
        _obs_flight.get_flight().record(
            "replan", graph=self.graph.name, kept=False, wall_s=wall,
            period_s=best_period)
        return ReplanDecision(
            plan=best_plan, migrate=True, period_s=best_period,
            score_s=move_score, migration=mig, keep_score_s=keep_score,
            plan_wall_s=wall, point_idx=best_i, frontier=fr, reuse=reuse)
