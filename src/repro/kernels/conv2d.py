"""Pallas TPU conv2d shard kernels — the FlexPie compute hot spot.

The edge engine's partitioned inference runs conv shards with halo rows
(§2.3 of the paper).  :func:`conv2d_shard` is the TPU-native version of one
shard's compute and consumes the NT-mode shard layout *directly*: the local
input slice — its own rows plus the halo rows backward-chained from the
segment tail — lands in VMEM as-is, and any zero padding at the graph
boundary is applied once into a VMEM scratch buffer on the first grid step
(``pl.when(i == 0)``; scratch persists across the sequential grid), so no
padded copy of the feature map is ever re-materialized in HBM per segment
layer.

The compute is im2col without materializing the im2col matrix: the output
is tiled by rows and each (kh, kw) kernel tap is an MXU matmul
``[tile_h*W, Cin] @ [Cin, Cout]`` accumulated in f32.  Strided convs load
the contiguous tap span and re-stride in registers; depthwise convs replace
the tap matmul with a VPU broadcast-multiply.  A tile deliberately reads
``K-1`` rows past its own range — exactly the redundant-compute region the
planner accounts for.

Degenerate geometries (``out_h <= 0`` or ``out_w <= 0`` after padding)
raise :class:`UnsupportedGeometry`; callers (``ops.conv2d``, the engine's
pallas backend) catch it and fall back to the XLA path.  Validated with
``interpret=True`` (this container is CPU-only); the grid/BlockSpec/scratch
structure is the TPU deployment artifact.

The kernel is executor-agnostic: the single-process engine hands it the
host-sliced local input, and the mesh executor
(``runtime.mesh_exec``) traces the *same* kernel inside per-device
``shard_map`` programs where the halo-extended slice is assembled by
collectives (``ppermute`` neighbor exchange / ``all_gather``) instead of
host indexing — the shard layout contract above is what makes that
drop-in.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Pads = Tuple[int, int, int, int]   # (top, bottom, left, right)


class UnsupportedGeometry(ValueError):
    """Raised when a conv geometry cannot be lowered to the Pallas kernel
    (callers fall back to XLA)."""


def shard_out_shape(in_h: int, in_w: int, k: int, stride: int,
                    pads: Pads) -> Tuple[int, int]:
    """Output (H, W) of a conv over a [in_h, in_w] shard with explicit
    per-side zero padding ``pads`` and square kernel ``k``."""
    pt, pb, pl_, pr = pads
    out_h = (in_h + pt + pb - k) // stride + 1
    out_w = (in_w + pl_ + pr - k) // stride + 1
    return out_h, out_w


def _shard_kernel(x_ref, w_ref, o_ref, xp_ref, *, k: int, stride: int,
                  pads: Pads, tile_h: int, out_w: int, cin: int, cout: int,
                  depthwise: bool, in_h: int, in_w: int):
    i = pl.program_id(0)
    pt, _, pl_, _ = pads

    @pl.when(i == 0)
    def _fill_scratch():
        # one VMEM zero-fill for the whole shard; halo rows arrive in the
        # raw input and are consumed in place (never copied through HBM)
        xp_ref[...] = jnp.zeros_like(xp_ref)
        xp_ref[pt:pt + in_h, pl_:pl_ + in_w, :] = x_ref[...]

    rspan = (tile_h - 1) * stride + 1
    cspan = (out_w - 1) * stride + 1
    if depthwise:
        acc = jnp.zeros((tile_h, out_w, cout), jnp.float32)
    else:
        acc = jnp.zeros((tile_h * out_w, cout), jnp.float32)
    for kh in range(k):
        for kw in range(k):
            # logical padded rows [i*tile_h*s + kh, ...) strided by s
            span = xp_ref[pl.dslice(i * tile_h * stride + kh, rspan),
                          pl.dslice(kw, cspan), :]
            xs = span[::stride, ::stride, :].astype(jnp.float32)
            if depthwise:
                acc = acc + xs * w_ref[kh, kw, 0].astype(jnp.float32)
            else:
                xm = xs.reshape(tile_h * out_w, cin)
                wm = w_ref[kh, kw].astype(jnp.float32)      # [cin, cout]
                acc = acc + jax.lax.dot_general(
                    xm, wm, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
    o_ref[...] = acc.reshape(tile_h, out_w, cout).astype(o_ref.dtype)


def conv2d_shard(x: jnp.ndarray, w: jnp.ndarray, *, pads: Pads = (0, 0, 0, 0),
                 stride: int = 1, depthwise: bool = False, tile_h: int = 8,
                 interpret: bool = True) -> jnp.ndarray:
    """One conv shard over the NT-mode local layout.

    ``x``: [Hl, Wl, Cin] — the node's raw input slice, halo rows included,
    NOT zero-padded.  ``w``: [K, K, Cin, Cout] (depthwise: [K, K, 1, C]).
    ``pads`` is the logical zero padding of this shard's position in the
    full feature map (interior shards: all zero — their "padding" is real
    halo data already inside ``x``).
    """
    K = w.shape[0]
    if w.shape[1] != K:
        raise UnsupportedGeometry(f"non-square kernel {w.shape[:2]}")
    if stride < 1:
        raise UnsupportedGeometry(f"stride {stride}")
    Hl, Wl, cin = x.shape
    cout = cin if depthwise else w.shape[3]
    out_h, out_w = shard_out_shape(Hl, Wl, K, stride, pads)
    if out_h <= 0 or out_w <= 0 or cin <= 0 or cout <= 0:
        raise UnsupportedGeometry(
            f"degenerate output {out_h}x{out_w}x{cout} for input "
            f"{Hl}x{Wl}x{cin}, k={K}, s={stride}, pads={pads}")
    pt, pb, pl_, pr = pads
    tile_h = max(1, min(tile_h, out_h))
    nt = -(-out_h // tile_h)
    # scratch must cover the last tile's deepest tap row (padded rows past
    # out_h are computed then dropped)
    rows = max(Hl + pt + pb, (nt * tile_h - 1) * stride + K)
    cols = Wl + pl_ + pr
    kernel = functools.partial(
        _shard_kernel, k=K, stride=stride, pads=pads, tile_h=tile_h,
        out_w=out_w, cin=cin, cout=cout, depthwise=depthwise,
        in_h=Hl, in_w=Wl)
    out = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0, 0, 0)),     # shard in VMEM
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_h, out_w, cout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nt * tile_h, out_w, cout), x.dtype),
        scratch_shapes=[pltpu.VMEM((rows, cols, cin), x.dtype)],
        interpret=interpret,
    )(x, w)
    return out[:out_h]


def conv2d_tiled(x: jnp.ndarray, w: jnp.ndarray, *, padding: int = 0,
                 stride: int = 1, tile_h: int = 8,
                 interpret: bool = True) -> jnp.ndarray:
    """Full-tensor convenience form: x [H, W, Cin] unpadded, symmetric
    ``padding``.  Thin wrapper over :func:`conv2d_shard` (a one-shard
    "plan"); kept as the historical public name."""
    return conv2d_shard(x, w, pads=(padding,) * 4, stride=stride,
                        tile_h=tile_h, interpret=interpret)
