"""Layer-graph IR for the edge-inference planner.

FlexPie consumes a computation graph of DNN layers (Fig. 3).  The IR is a
DAG of :class:`LayerSpec` nodes: each layer names its producers via
``inputs`` (empty = the previous layer in the tuple, which keeps plain
chains working with zero changes).  Multi-input merge layers (``ADD``,
``CONCAT``) carry real branch structure — residual blocks and
Inception-style modules are no longer folded into ``extra_flop_factor``.
:meth:`ModelGraph.linearize` decomposes the DAG into chain *branches*
joined at fork/merge junctions; the planner, cost model and engine all
operate per-branch and compose at the junctions.  The real tensor programs
live in ``repro/models`` and ``repro/runtime/engine.py``; this IR is what
the combinatorial optimizer reasons about.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Dict, List, Sequence, Tuple

#: Sentinel producer name meaning "the graph input tensor".
GRAPH_INPUT = "@input"


class ConvT(enum.IntEnum):
    """Layer categories (the ``ConvT`` categorical feature of Fig. 4)."""

    CONV = 0          # standard convolution
    DWCONV = 1        # depthwise convolution
    POINTWISE = 2     # 1x1 convolution
    POOL = 3          # max/avg pool (no weights)
    FC = 4            # fully connected / matmul (BERT, classifier heads)
    ADD = 5           # residual add (elementwise, multi-input merge)
    CONCAT = 6        # channel concatenation (Inception-style merge)
    ATTN = 7          # fused attention block (QKV + scores + out proj)
    FFN = 8           # fused transformer FFN (up proj + act + down proj)


#: Layer types allowed to have fan-in >= 2.
MERGE_TYPES = (ConvT.ADD, ConvT.CONCAT)

#: Transformer block layer types (sequence lives in ``in_h``, like FC).
ATTN_TYPES = (ConvT.ATTN, ConvT.FFN)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the inference graph.

    Shapes follow the paper's feature expression (Fig. 4): input feature map
    ``InH x InW x InC``, output ``OutH x OutW x OutC``, kernel ``K``, stride
    ``S``, padding ``P``.  For FC/matmul layers the convention is
    ``InH = OutH = seq_len`` (BERT tokens), ``InW = OutW = 1``,
    ``InC/OutC = feature dims`` and ``K = S = 1, P = 0``.

    ``inputs`` names this layer's producers.  Empty means "the previous
    layer in the graph tuple" (the chain-compat default; the graph input for
    layer 0).  Merge layers (``ADD``/``CONCAT``) list two or more producers;
    ``ADD`` inputs must agree on all dims, ``CONCAT`` inputs must agree
    spatially and their channels sum to ``in_c``.  :data:`GRAPH_INPUT`
    refers to the raw graph input (multi-tower models).

    Transformer blocks follow the FC convention (``InH = seq_len``,
    ``InW = 1``, ``K = S = 1, P = 0``): ``ATTN`` is a fused attention block
    (pre-norm + QKV projections + scaled-dot-product attention + output
    projection + residual) whose head count geometry lives in ``heads`` —
    OutC partitions split at *head* granularity, never inside a head —
    with the score/AV work (which scales with the attended KV length, not
    a weight shape) folded into ``extra_flop_factor`` by the graph
    builder.  ``FFN`` is the fused two-matmul MLP; its hidden width is
    likewise folded (``extra_flop_factor = 2 * d_ff / d_model``).
    """

    name: str
    conv_t: ConvT
    in_h: int
    in_w: int
    in_c: int
    out_c: int
    k: int = 1
    s: int = 1
    p: int = 0
    extra_flop_factor: float = 1.0  # folds activations / attention scores
    inputs: Tuple[str, ...] = ()    # producer names; () = chain default
    heads: int = 0                  # ATTN head count (0 = not an ATTN layer)

    @property
    def out_h(self) -> int:
        return (self.in_h + 2 * self.p - self.k) // self.s + 1

    @property
    def out_w(self) -> int:
        return (self.in_w + 2 * self.p - self.k) // self.s + 1

    @property
    def fan_in(self) -> int:
        """Number of producer tensors (1 for chain-default layers)."""
        return max(1, len(self.inputs))

    # ---- workload ---------------------------------------------------------
    def flops(self) -> float:
        """Total MACs*2 for the full (unpartitioned) layer."""
        oh, ow = self.out_h, self.out_w
        if self.conv_t == ConvT.CONV or self.conv_t == ConvT.POINTWISE:
            f = 2.0 * oh * ow * self.out_c * self.in_c * self.k * self.k
        elif self.conv_t == ConvT.DWCONV:
            f = 2.0 * oh * ow * self.out_c * self.k * self.k
        elif self.conv_t == ConvT.POOL:
            f = 1.0 * oh * ow * self.out_c * self.k * self.k
        elif self.conv_t == ConvT.FC:
            f = 2.0 * self.in_h * self.in_c * self.out_c
        elif self.conv_t == ConvT.ADD:
            # (fan_in - 1) elementwise adds; the folded chain form counts one
            f = max(1, self.fan_in - 1) * 1.0 * oh * ow * self.out_c
        elif self.conv_t == ConvT.CONCAT:
            f = 1.0 * oh * ow * self.out_c   # copy cost
        elif self.conv_t in (ConvT.ATTN, ConvT.FFN):
            # projection MACs; scores/AV (ATTN) and the hidden width (FFN)
            # ride in extra_flop_factor (set by the graph builder)
            f = 2.0 * self.in_h * self.in_c * self.out_c
        else:  # pragma: no cover - exhaustive enum
            raise ValueError(self.conv_t)
        return f * self.extra_flop_factor

    def out_elems(self) -> int:
        return self.out_h * self.out_w * self.out_c

    def in_elems(self) -> int:
        return self.in_h * self.in_w * self.in_c

    def weight_elems(self) -> int:
        if self.conv_t in (ConvT.CONV, ConvT.POINTWISE):
            return self.k * self.k * self.in_c * self.out_c
        if self.conv_t == ConvT.DWCONV:
            return self.k * self.k * self.out_c
        if self.conv_t == ConvT.FC:
            return self.in_c * self.out_c
        if self.conv_t == ConvT.ATTN:
            return 4 * self.in_c * self.out_c   # wq, wk, wv, wo
        if self.conv_t == ConvT.FFN:
            # 2 * d * d_ff, recovered from the folded hidden-width factor
            return int(self.in_c * self.out_c * self.extra_flop_factor)
        return 0

    def feature_vector(self) -> Tuple[float, ...]:
        """Shape + structure part of the feature expression (12 values; see
        ``I_FEATURE_NAMES``/``S_FEATURE_NAMES`` in ``core/estimator.py`` for
        the full i-/s-feature layouts these embed into)."""
        return (
            float(self.in_h), float(self.in_w), float(self.in_c),
            float(self.out_h), float(self.out_w), float(self.out_c),
            float(self.k), float(self.s), float(self.p), float(self.conv_t),
            float(self.fan_in), float(self.heads),
        )

    def with_input(self, in_h: int, in_w: int) -> "LayerSpec":
        return dataclasses.replace(self, in_h=in_h, in_w=in_w)


@dataclasses.dataclass(frozen=True)
class Branch:
    """A maximal chain of layer indices between junctions of the DAG."""

    ids: Tuple[int, ...]

    @property
    def head(self) -> int:
        return self.ids[0]

    @property
    def tail(self) -> int:
        return self.ids[-1]

    def __len__(self) -> int:
        return len(self.ids)


@dataclasses.dataclass(frozen=True)
class ModelGraph:
    """DAG of layers, stored in topological order.

    Plain chains (no explicit ``inputs``) behave exactly as before:
    ``layers[i+1].in_* == layers[i].out_*`` must hold and every planner /
    engine path is unchanged.  Branched graphs additionally validate merge
    shapes, require a unique output layer in the last position, and expose
    the branch decomposition via :meth:`linearize`.
    """

    name: str
    layers: Tuple[LayerSpec, ...]

    def __post_init__(self) -> None:
        self._validate()

    # ---- structure --------------------------------------------------------
    @functools.cached_property
    def producer_ids(self) -> Tuple[Tuple[int, ...], ...]:
        """Resolved producer indices per layer; ``-1`` is the graph input."""
        counts: Dict[str, int] = {}
        for l in self.layers:
            counts[l.name] = counts.get(l.name, 0) + 1
        by_name: Dict[str, int] = {}
        out: List[Tuple[int, ...]] = []
        for i, l in enumerate(self.layers):
            if l.inputs:
                ids = []
                for nm in l.inputs:
                    if nm == GRAPH_INPUT:
                        ids.append(-1)
                        continue
                    if counts.get(nm, 0) > 1:
                        raise ValueError(
                            f"{self.name}: input {nm!r} of {l.name} is "
                            f"ambiguous (duplicate layer name)")
                    j = by_name.get(nm)
                    if j is None:
                        raise ValueError(
                            f"{self.name}: {l.name} references unknown or "
                            f"later layer {nm!r} (layers must be in "
                            f"topological order)")
                    ids.append(j)
                out.append(tuple(ids))
            else:
                out.append((i - 1,) if i else (-1,))
            by_name[l.name] = i
        return tuple(out)

    @functools.cached_property
    def consumer_ids(self) -> Tuple[Tuple[int, ...], ...]:
        cons: List[List[int]] = [[] for _ in self.layers]
        for i, prods in enumerate(self.producer_ids):
            for j in prods:
                if j >= 0:
                    cons[j].append(i)
        return tuple(tuple(c) for c in cons)

    def fan_in(self, i: int) -> int:
        return len(self.producer_ids[i])

    def fan_out(self, i: int) -> int:
        return len(self.consumer_ids[i])

    @functools.cached_property
    def is_chain(self) -> bool:
        """True iff every layer consumes exactly the previous one."""
        return all(prods == ((i - 1,) if i else (-1,))
                   for i, prods in enumerate(self.producer_ids))

    def _validate(self) -> None:
        prods = self.producer_ids
        if not self.layers:
            return
        l0 = self.layers[0]
        # the graph input's shape is fixed by layer 0's declared input
        in_shape = (l0.in_h, l0.in_w, l0.in_c)

        def pshape(j: int) -> Tuple[int, int, int]:
            if j < 0:
                return in_shape
            p = self.layers[j]
            return (p.out_h, p.out_w, p.out_c)

        def pname(j: int) -> str:
            return GRAPH_INPUT if j < 0 else self.layers[j].name

        for i, l in enumerate(self.layers):
            ins = prods[i]
            if len(ins) >= 2 and l.conv_t not in MERGE_TYPES:
                raise ValueError(
                    f"{self.name}: {l.name} ({l.conv_t.name}) has fan-in "
                    f"{len(ins)}; only ADD/CONCAT layers may merge")
            if l.conv_t in ATTN_TYPES and (l.k, l.s, l.p) != (1, 1, 0):
                raise ValueError(
                    f"{self.name}: {l.name} ({l.conv_t.name}) must have "
                    f"K=S=1, P=0 (sequence lives in InH)")
            if l.conv_t == ConvT.ATTN:
                if l.heads < 1 or l.out_c % l.heads:
                    raise ValueError(
                        f"{self.name}: ATTN {l.name} needs heads >= 1 "
                        f"dividing out_c (heads={l.heads}, out_c={l.out_c})")
            elif l.heads:
                raise ValueError(
                    f"{self.name}: {l.name} ({l.conv_t.name}) carries "
                    f"heads={l.heads}; only ATTN layers have head geometry")
            if l.conv_t == ConvT.ADD and len(ins) >= 2:
                for j in ins:
                    if pshape(j) != (l.in_h, l.in_w, l.in_c):
                        ph, pw, pc = pshape(j)
                        raise ValueError(
                            f"{self.name}: ADD {l.name} input {pname(j)} "
                            f"({ph},{pw},{pc}) != "
                            f"({l.in_h},{l.in_w},{l.in_c})")
                if l.out_c != l.in_c:
                    raise ValueError(f"{self.name}: ADD {l.name} must "
                                     f"preserve channels")
            elif l.conv_t == ConvT.CONCAT and len(ins) >= 2:
                for j in ins:
                    if pshape(j)[:2] != (l.in_h, l.in_w):
                        ph, pw, _ = pshape(j)
                        raise ValueError(
                            f"{self.name}: CONCAT {l.name} input "
                            f"{pname(j)} ({ph},{pw}) != "
                            f"({l.in_h},{l.in_w})")
                csum = sum(pshape(j)[2] for j in ins)
                if csum != l.in_c or l.out_c != l.in_c:
                    raise ValueError(
                        f"{self.name}: CONCAT {l.name} channels {csum} != "
                        f"in_c {l.in_c} (out_c {l.out_c})")
            elif i > 0 or ins[0] >= 0:
                ph, pw, pc = pshape(ins[0])
                if (ph, pw) != (l.in_h, l.in_w) or pc != l.in_c:
                    raise ValueError(
                        f"{self.name}: layer chain mismatch {pname(ins[0])} "
                        f"({ph},{pw},{pc}) -> {l.name} "
                        f"({l.in_h},{l.in_w},{l.in_c})")
        if not self.is_chain and self.layers:
            sinks = [i for i in range(len(self.layers))
                     if not self.consumer_ids[i]]
            if len(sinks) != 1 or sinks[0] != len(self.layers) - 1:
                raise ValueError(
                    f"{self.name}: branched graph must have exactly one "
                    f"output layer, placed last (sinks: "
                    f"{[self.layers[i].name for i in sinks]})")

    @functools.cached_property
    def _branches(self) -> Tuple[Branch, ...]:
        prods, cons = self.producer_ids, self.consumer_ids
        branch_of: Dict[int, int] = {}
        chains: List[List[int]] = []
        for i in range(len(self.layers)):
            p = prods[i]
            extend = (len(p) == 1 and p[0] >= 0 and len(cons[p[0]]) == 1)
            if extend:
                bi = branch_of[p[0]]
                chains[bi].append(i)
            else:
                bi = len(chains)
                chains.append([i])
            branch_of[i] = bi
        return tuple(Branch(tuple(c)) for c in chains)

    def linearize(self) -> Tuple[Branch, ...]:
        """Decompose the DAG into chain branches cut at every fork output
        and merge input.  Branches are returned in topological order (head
        index ascending); every cross-branch producer is a branch tail."""
        return self._branches

    def __len__(self) -> int:
        return len(self.layers)

    def total_flops(self) -> float:
        return sum(l.flops() for l in self.layers)

    def spatial(self) -> bool:
        """True if the graph has spatial (conv) layers at all."""
        return any(l.conv_t in (ConvT.CONV, ConvT.DWCONV, ConvT.POINTWISE,
                                ConvT.POOL) for l in self.layers)


# ---------------------------------------------------------------------------
# Kernel-geometry helpers — the conformance-grid axes for the Pallas shard
# kernels (tests/test_kernel_conformance.py sweeps every key returned here).
# ---------------------------------------------------------------------------

def conv_geometries(graph: "ModelGraph"
                    ) -> Tuple[Tuple[ConvT, int, int, int], ...]:
    """All distinct ``(conv_t, k, s, p)`` geometry keys occurring in the
    graph, sorted.  This is exactly the set of per-layer kernel geometries a
    backend must support (or cleanly fall back on) to execute the model."""
    return tuple(sorted({(l.conv_t, l.k, l.s, l.p) for l in graph.layers}))


def shard_halo_pads(p: int) -> Tuple[Tuple[int, int, int, int], ...]:
    """The distinct ``(top, bottom, left, right)`` zero-pad signatures a
    shard of a ``p``-padded conv can occupy under the spatial schemes: a
    corner / edge / interior cell of a 2-D grid sees the map padding only on
    its outward sides — inward sides carry real halo rows instead (the 1-D
    InH/InW splits are the edge-row/col subsets).  ``p == 0`` collapses to
    the single all-zero signature."""
    tb = [(p, p), (p, 0), (0, 0), (0, p)] if p else [(0, 0)]
    return tuple(dict.fromkeys(
        (t, b, lft, r) for t, b in tb for lft, r in tb))


# ---------------------------------------------------------------------------
# Receptive-field math — the heart of NT-mode (redundant-compute) planning.
# ---------------------------------------------------------------------------

def halo_growth(layers: Sequence[LayerSpec], upto: int) -> List[int]:
    """Cumulative output-halo each layer must additionally produce so that
    layer ``upto`` can be computed with zero communication (NT fusion).

    ``halo[m]`` = number of extra *output* rows (per side) layer ``m`` must
    compute, given layers ``m+1..upto`` are fused after it.  ``halo[upto] = 0``.
    Standard receptive-field recurrence, applied backwards:
        need[m] = need[m+1] * S_{m+1} + (K_{m+1} - 1)   (in layer-m output rows)
    For FC/ADD/CONCAT layers K=S=1 so the halo never grows through them.
    An ATTN layer attends over the whole sequence, so its receptive field
    is the full ``in_h`` extent: fusing *into* attention means every shard
    recomputes the entire prefix, and the recurrence charges exactly that
    (the planner then prices NT-through-ATTN as full replication and puts a
    T boundary there instead).
    ``layers`` is a chain (one branch of the DAG); NT fusion never crosses
    fork/merge junctions, so the recurrence stays 1-D.
    """
    n = upto + 1
    halo = [0] * n
    for m in range(upto - 1, -1, -1):
        nxt = layers[m + 1]
        grow = nxt.in_h if nxt.conv_t == ConvT.ATTN else (nxt.k - 1)
        halo[m] = halo[m + 1] * nxt.s + grow
    return halo


def chain(name: str, specs: Sequence[LayerSpec],
          drop_edges: bool = False) -> ModelGraph:
    """Chain-compat constructor: each layer consumes the previous one.

    Layers carrying explicit ``inputs`` edges are rejected — silently
    re-chaining them would build a semantically different model (residual
    ADDs degrade to the identity).  Pass ``drop_edges=True`` to strip the
    edges on purpose (e.g. to compare a DAG against its chain skeleton).
    """
    if any(l.inputs for l in specs):
        if not drop_edges:
            bad = [l.name for l in specs if l.inputs]
            raise ValueError(
                f"{name}: layers {bad} carry DAG input edges; build a "
                f"ModelGraph directly, or pass drop_edges=True to chain() "
                f"to deliberately discard them")
        specs = tuple(dataclasses.replace(l, inputs=()) if l.inputs else l
                      for l in specs)
    return ModelGraph(name=name, layers=tuple(specs))
