"""Serving benchmark: arrival-rate sweep with batch-size choice under a
p99 bound, latency-objective vs throughput-objective plans.

For each (model, cluster) scenario this drives the pipeline head policy
(``cluster.serving``): sweep request arrival rates from well below to
beyond the pipeline's capacity, let ``choose_batch`` pick the goodput-
maximizing batch size under a p99 latency bound, and record the achieved
goodput/p99 for both the latency-optimal and the throughput-optimal plan.
The headline is ``max_goodput_gain`` — how much more load the
throughput-planned pipeline sustains within the same tail-latency budget.

``--json [PATH]`` writes ``BENCH_serving.json`` (the nightly artifact);
``--smoke`` shrinks the grids.
"""
from __future__ import annotations

import json
import sys

from repro.cluster import (CLUSTER_PRESETS, cluster_plan_search,
                           sweep_serving)
from repro.configs.edge_models import EDGE_MODELS
from repro.core import Objective

from .common import emit, json_arg

#: (model, preset, nodes) scenarios — heterogeneous serving clusters
SCENARIOS = [
    ("mobilenet", "mixed_fast_slow", 4),
    ("mobilenet", "asym_uplink", 4),
    ("inception", "stepped", 8),
    ("resnet18", "asym_uplink", 8),
]


def run(json_path: str | None = None, smoke: bool = False) -> dict:
    scenarios = SCENARIOS[:2] if smoke else SCENARIOS
    batch_sizes = (1, 2, 4) if smoke else (1, 2, 4, 8)
    n_batches = 16 if smoke else 32
    #: arrival rates as fractions of the throughput plan's analytic
    #: capacity; beyond 1.0 the pipeline must shed via batching or fail
    rate_fracs = [0.5, 0.9, 1.1] if smoke else [0.3, 0.5, 0.7, 0.9,
                                                1.0, 1.1, 1.3]
    out: dict = {"batch_sizes": list(batch_sizes),
                 "rate_fracs": rate_fracs, "scenarios": {}}

    for model, pname, nodes in scenarios:
        g = EDGE_MODELS[model]()
        cl = CLUSTER_PRESETS[pname](nodes)
        lat = cluster_plan_search(g, cl)
        thr = cluster_plan_search(g, cl, objective=Objective.THROUGHPUT)
        cap = 1.0 / thr.cost
        rates = [f * cap for f in rate_fracs]
        # p99 budget: a few single-request latencies — tight enough that
        # unbounded batching breaks it, loose enough for pipelining
        p99_bound = lat.cost * 8.0
        rec: dict = {"nodes": nodes,
                     "analytic_capacity_rps": cap,
                     "p99_bound_ms": p99_bound * 1e3,
                     "plans": {}}
        for tag, res in (("latency", lat), ("throughput", thr)):
            rows = sweep_serving(g, res.plan, cl, rates, p99_bound,
                                 batch_sizes, n_batches)
            feasible = [r["goodput_rps"] for r in rows if r["feasible"]]
            rec["plans"][tag] = {
                "max_goodput_rps": max(feasible) if feasible else 0.0,
                "rates": rows,
            }
        lat_g = rec["plans"]["latency"]["max_goodput_rps"]
        thr_g = rec["plans"]["throughput"]["max_goodput_rps"]
        rec["max_goodput_gain"] = (thr_g / lat_g if lat_g > 0.0
                                   else float("inf") if thr_g > 0.0
                                   else 1.0)
        out["scenarios"][f"{pname}/{model}/n{nodes}"] = rec
        emit(f"serving/{pname}/{model}", 0.0,
             f"nodes={nodes};max_goodput_latency={lat_g:.1f};"
             f"max_goodput_throughput={thr_g:.1f};"
             f"gain={rec['max_goodput_gain']:.3f}")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)
    return out


if __name__ == "__main__":
    argv = sys.argv[1:]
    run(json_path=json_arg(argv, default="BENCH_serving.json"),
        smoke="--smoke" in argv)
