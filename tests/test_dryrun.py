"""End-to-end dry-run smoke (subprocess: 512 fake devices, production mesh).

Compiles one cheap (arch x shape) pair on the real (16,16) mesh and checks
the full record pipeline: lowering, memory analysis, loop-aware roofline
terms, planner strategy.  The exhaustive 40x2 sweep lives in
``experiments/dryrun/`` (python -m repro.launch.dryrun --all).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_record_pipeline():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import json
        from repro.launch.dryrun import run_one
        rec = run_one('olmo-1b', 'decode_32k', verbose=False)
        assert rec['mesh'] == '16x16' and rec['chips'] == 256
        assert rec['hlo_flops'] > 0 and rec['hlo_bytes'] > 0
        assert rec['bottleneck'] in ('compute', 'memory', 'collective')
        assert 0 < rec['useful_ratio'] < 10
        assert rec['mem_per_device']['temp_size_bytes'] is not None
        # decode reads weights + KV every token -> memory-bound
        assert rec['bottleneck'] == 'memory'
        print('DRYRUN_RECORD_OK', json.dumps(rec['strategy']))
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert "DRYRUN_RECORD_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_dryrun_multipod_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.axis_names == ('data', 'model') and m1.devices.size == 256
        assert m2.axis_names == ('pod', 'data', 'model')
        assert m2.devices.size == 512
        print('MESH_OK')
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert "MESH_OK" in r.stdout, r.stdout + r.stderr
