"""The paper's benchmark models (§4) as planner layer graphs.

MobileNet v1 (224x224), ResNet-18 / ResNet-101 (224x224) and BERT-base
(seq 128), plus a small Inception-style model.  ResNet blocks carry **real
residual edges** (``LayerSpec.inputs``) — the ADD layers are true two-input
merges, with 1x1 projection convs on downsampling skips — and the Inception
modules merge four parallel branches with CONCAT.  BERT blocks are modelled
as FC/matmul chains (ConvT.FC), which reproduces the paper's observation
that scheme choice barely matters for matmul-dominated models.  Plain
chains (MobileNet, BERT) still use the ``chain`` constructor, so every
pre-existing call site keeps working unchanged.
"""
from __future__ import annotations

from typing import List

from repro.core.graph import ConvT, LayerSpec, ModelGraph, chain


def _conv(name, h, w, cin, cout, k, s, p, t=ConvT.CONV,
          inputs=()) -> LayerSpec:
    return LayerSpec(name, t, h, w, cin, cout, k, s, p, inputs=tuple(inputs))


def mobilenet_v1(width: int = 224) -> ModelGraph:
    layers: List[LayerSpec] = []
    h = w = width

    def add(l: LayerSpec):
        layers.append(l)
        return l.out_h, l.out_w

    h, w = add(_conv("conv0", h, w, 3, 32, 3, 2, 1))
    cfg = [  # (dw stride, pointwise out channels)
        (1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
        (1, 512), (1, 512), (1, 512), (1, 512), (1, 512), (2, 1024), (1, 1024),
    ]
    cin = 32
    for i, (s, cout) in enumerate(cfg):
        h, w = add(_conv(f"dw{i+1}", h, w, cin, cin, 3, s, 1, ConvT.DWCONV))
        h, w = add(_conv(f"pw{i+1}", h, w, cin, cout, 1, 1, 0, ConvT.POINTWISE))
        cin = cout
    h, w = add(_conv("avgpool", h, w, 1024, 1024, int(h), int(h), 0, ConvT.POOL))
    layers.append(LayerSpec("fc", ConvT.FC, 1, 1, 1024, 1000))
    return chain("mobilenet", layers)


def _res_block(layers, name, h, w, cin, cout, stride, src) -> tuple:
    """Basic block with a real residual edge; projection conv on the skip
    when the main path changes shape."""
    layers.append(_conv(f"{name}a", h, w, cin, cout, 3, stride, 1,
                        inputs=(src,)))
    oh, ow = layers[-1].out_h, layers[-1].out_w
    layers.append(_conv(f"{name}b", oh, ow, cout, cout, 3, 1, 1,
                        inputs=(f"{name}a",)))
    skip = src
    if stride != 1 or cin != cout:
        layers.append(_conv(f"{name}s", h, w, cin, cout, 1, stride, 0,
                            ConvT.POINTWISE, inputs=(src,)))
        skip = f"{name}s"
    layers.append(LayerSpec(f"{name}+", ConvT.ADD, oh, ow, cout, cout,
                            inputs=(f"{name}b", skip)))
    return oh, ow, f"{name}+"


def _bottleneck(layers, name, h, w, cin, cmid, cout, stride, src) -> tuple:
    layers.append(_conv(f"{name}a", h, w, cin, cmid, 1, 1, 0,
                        ConvT.POINTWISE, inputs=(src,)))
    layers.append(_conv(f"{name}b", h, w, cmid, cmid, 3, stride, 1,
                        inputs=(f"{name}a",)))
    oh, ow = layers[-1].out_h, layers[-1].out_w
    layers.append(_conv(f"{name}c", oh, ow, cmid, cout, 1, 1, 0,
                        ConvT.POINTWISE, inputs=(f"{name}b",)))
    skip = src
    if stride != 1 or cin != cout:
        layers.append(_conv(f"{name}s", h, w, cin, cout, 1, stride, 0,
                            ConvT.POINTWISE, inputs=(src,)))
        skip = f"{name}s"
    layers.append(LayerSpec(f"{name}+", ConvT.ADD, oh, ow, cout, cout,
                            inputs=(f"{name}c", skip)))
    return oh, ow, f"{name}+"


def resnet18(width: int = 224) -> ModelGraph:
    layers: List[LayerSpec] = []
    h = w = width
    layers.append(_conv("conv1", h, w, 3, 64, 7, 2, 3))
    h, w = layers[-1].out_h, layers[-1].out_w
    layers.append(_conv("maxpool", h, w, 64, 64, 3, 2, 1, ConvT.POOL))
    h, w = layers[-1].out_h, layers[-1].out_w
    plan = [(64, 1), (64, 1), (128, 2), (128, 1), (256, 2), (256, 1),
            (512, 2), (512, 1)]
    cin, src = 64, "maxpool"
    for i, (cout, s) in enumerate(plan):
        h, w, src = _res_block(layers, f"b{i}", h, w, cin, cout, s, src)
        cin = cout
    layers.append(_conv("avgpool", h, w, 512, 512, int(h), int(h), 0,
                        ConvT.POOL, inputs=(src,)))
    layers.append(LayerSpec("fc", ConvT.FC, 1, 1, 512, 1000))
    return ModelGraph(name="resnet18", layers=tuple(layers))


def resnet101(width: int = 224) -> ModelGraph:
    layers: List[LayerSpec] = []
    h = w = width
    layers.append(_conv("conv1", h, w, 3, 64, 7, 2, 3))
    h, w = layers[-1].out_h, layers[-1].out_w
    layers.append(_conv("maxpool", h, w, 64, 64, 3, 2, 1, ConvT.POOL))
    h, w = layers[-1].out_h, layers[-1].out_w
    stages = [(64, 256, 3, 1), (128, 512, 4, 2), (256, 1024, 23, 2),
              (512, 2048, 3, 2)]
    cin, src = 64, "maxpool"
    for si, (cmid, cout, reps, stride) in enumerate(stages):
        for r in range(reps):
            h, w, src = _bottleneck(layers, f"s{si}r{r}", h, w, cin, cmid,
                                    cout, stride if r == 0 else 1, src)
            cin = cout
    layers.append(_conv("avgpool", h, w, 2048, 2048, int(h), int(h), 0,
                        ConvT.POOL, inputs=(src,)))
    layers.append(LayerSpec("fc", ConvT.FC, 1, 1, 2048, 1000))
    return ModelGraph(name="resnet101", layers=tuple(layers))


def _inception_module(layers, name, h, w, cin, c1, c3r, c3, c5r, c5, cp,
                      src) -> tuple:
    """GoogLeNet-style module: four parallel branches joined by CONCAT."""
    layers.append(_conv(f"{name}.1x1", h, w, cin, c1, 1, 1, 0,
                        ConvT.POINTWISE, inputs=(src,)))
    layers.append(_conv(f"{name}.3r", h, w, cin, c3r, 1, 1, 0,
                        ConvT.POINTWISE, inputs=(src,)))
    layers.append(_conv(f"{name}.3x3", h, w, c3r, c3, 3, 1, 1,
                        inputs=(f"{name}.3r",)))
    layers.append(_conv(f"{name}.5r", h, w, cin, c5r, 1, 1, 0,
                        ConvT.POINTWISE, inputs=(src,)))
    layers.append(_conv(f"{name}.5x5", h, w, c5r, c5, 5, 1, 2,
                        inputs=(f"{name}.5r",)))
    layers.append(_conv(f"{name}.pool", h, w, cin, cin, 3, 1, 1,
                        ConvT.POOL, inputs=(src,)))
    layers.append(_conv(f"{name}.pp", h, w, cin, cp, 1, 1, 0,
                        ConvT.POINTWISE, inputs=(f"{name}.pool",)))
    cat = c1 + c3 + c5 + cp
    layers.append(LayerSpec(f"{name}.cat", ConvT.CONCAT, h, w, cat, cat,
                            inputs=(f"{name}.1x1", f"{name}.3x3",
                                    f"{name}.5x5", f"{name}.pp")))
    return cat, f"{name}.cat"


def inception_small(width: int = 64) -> ModelGraph:
    """Two stacked Inception modules over a small stem — the branched
    planning benchmark (GoogLeNet-style fork/concat topology)."""
    layers: List[LayerSpec] = []
    h = w = width
    layers.append(_conv("stem", h, w, 3, 32, 3, 2, 1))
    h = w = layers[-1].out_h
    cin, src = 32, "stem"
    cin, src = _inception_module(layers, "i1", h, w, cin,
                                 16, 12, 24, 4, 8, 8, src)
    cin, src = _inception_module(layers, "i2", h, w, cin,
                                 24, 16, 32, 6, 12, 12, src)
    layers.append(_conv("avgpool", h, w, cin, cin, int(h), int(h), 0,
                        ConvT.POOL, inputs=(src,)))
    layers.append(LayerSpec("fc", ConvT.FC, 1, 1, cin, 100))
    return ModelGraph(name="inception_small", layers=tuple(layers))


def bert_base(seq: int = 128, d: int = 768, n_layers: int = 12,
              d_ff: int = 3072) -> ModelGraph:
    """BERT as a matmul chain: per block QKV proj, attn-out proj (attention
    score matmuls folded into extra_flop_factor), two FFN matmuls."""
    layers: List[LayerSpec] = []
    for i in range(n_layers):
        layers.append(LayerSpec(f"b{i}.qkv", ConvT.FC, seq, 1, d, 3 * d))
        # attention matmuls ~ 2*seq*seq*d flops folded into the out-proj
        attn_extra = 1.0 + (2.0 * seq * seq * d) / (2.0 * seq * 3 * d * d)
        layers.append(LayerSpec(f"b{i}.attn_out", ConvT.FC, seq, 1, 3 * d, d,
                                extra_flop_factor=attn_extra))
        layers.append(LayerSpec(f"b{i}.ffn_up", ConvT.FC, seq, 1, d, d_ff))
        layers.append(LayerSpec(f"b{i}.ffn_down", ConvT.FC, seq, 1, d_ff, d))
    return chain("bert", layers)


EDGE_MODELS = {
    "mobilenet": mobilenet_v1,
    "resnet18": resnet18,
    "resnet101": resnet101,
    "inception": inception_small,
    "bert": bert_base,
}
