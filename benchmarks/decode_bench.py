"""Autoregressive decode benchmark: prefill vs decode tokens/s and
sharded-vs-single-device equivalence flags -> ``BENCH_decode.json``.

For each transformer spec and node count this searches a decode plan
(head-sharding testbed), runs greedy decode through :class:`DecodeSession`
on the local executor and on the mesh executor (8 fake host devices,
respawn pattern shared with ``mesh_bench``), and records:

* ``head_sharded`` — the planner chose OutC on every ATTN step (the
  decode-graph cost physics held up);
* ``tokens_match_local`` / ``tokens_match_mesh`` — greedy tokens are
  identical to the single-device contiguous oracle
  (``reference_decode``), token for token;
* ``logits_rel_err`` — max relative logits error vs the oracle;
* ``prefill_tok_s`` / ``decode_tok_s`` — warm tokens/s for the prompt
  pass and the generation loop (the decode-phase number is the one the
  paged cache exists for);
* ``decode_step_us`` — warm per-token step wall time, local executor.

``check_regression.py --kind decode`` gates the three boolean flags
**hard**; every timing is **advisory** — same CPU-fake-device rationale
as ``BENCH_mesh.json`` (see ``noise_note``), and interpret-mode Pallas
timings would be meaningless anyway.  The smoke subset (per-push CI)
covers the tiny spec at 2/4 nodes; the full run adds 8 nodes, the larger
spec, and a pallas-backend decode flag.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit, json_arg

#: OutC-friendly decode testbed (SRIO-class link latency) — matches the
#: equivalence suite in tests/test_decode.py
BANDWIDTH_GBPS = 5.0
LINK_LATENCY_US = 1.0

SPECS = {
    "tiny": dict(n_layers=2, d_model=256, n_heads=8, d_ff=1024, vocab=64),
    "small": dict(n_layers=4, d_model=512, n_heads=8, d_ff=2048,
                  vocab=256),
}
SMOKE = {"tiny": (2, 4)}
FULL = {"tiny": (2, 4, 8), "small": (2, 4, 8)}

PROMPT_LEN = 8
N_NEW = 8
KV_LEN = 2048      # planning horizon for the decode-step cost model

NOISE_NOTE = (
    "All *_us / *_tok_s fields are advisory on CPU CI: mesh 'devices' "
    "are XLA host-platform fakes time-sharing one CPU and the pallas "
    "decode kernel runs in interpret mode. Only the boolean flags "
    "(head_sharded/tokens_match_local/tokens_match_mesh/"
    "tokens_match_pallas) are gated.")

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MESH_DEVICES = 8


def _bench_point(spec_name: str, nodes: int, full: bool) -> dict:
    import time

    import numpy as np
    from repro.core import Scheme, Testbed
    from repro.runtime.decode import (DecodeSession, TransformerSpec,
                                      greedy_decode, init_transformer,
                                      plan_decode, reference_decode)
    from repro.runtime.session import ExecConfig

    spec = TransformerSpec(**SPECS[spec_name])
    w = init_transformer(spec, seed=1)
    prompt = [(7 * i + 3) % spec.vocab for i in range(PROMPT_LEN)]
    ref_toks, ref_lg = reference_decode(spec, w, prompt, N_NEW)
    scale = max(1.0, float(np.max(np.abs(np.asarray(ref_lg)))))

    tb = Testbed(nodes=nodes, bandwidth_gbps=BANDWIDTH_GBPS,
                 link_latency_us=LINK_LATENCY_US)
    plan = plan_decode(spec, KV_LEN, nodes, tb=tb).plan
    head_sharded = all(s == Scheme.OUTC for i, (s, _) in
                       enumerate(plan.steps) if i % 2 == 0)

    def _decode(config):
        sess = DecodeSession(spec, w, plan, nodes, config, page_size=16,
                             capacity=PROMPT_LEN + N_NEW + 8)
        t0 = time.perf_counter()
        sess.prefill(prompt[:-1])
        t1 = time.perf_counter()
        # greedy_decode prefills its prompt arg: feed it the held-back
        # last prompt token so the cache sees the full prompt exactly once
        toks, lg = greedy_decode(sess, prompt[-1:], N_NEW)
        t2 = time.perf_counter()
        err = float(np.max(np.abs(np.asarray(lg) -
                                  np.asarray(ref_lg)))) / scale
        return toks == ref_toks, err, t1 - t0, t2 - t1

    # warm + timed local pass (second DecodeSession reuses the process-wide
    # compiled step via jit cache keyed on geometry)
    _decode(ExecConfig())
    ok_local, rel_err, prefill_s, decode_s = _decode(ExecConfig())

    ok_mesh = None
    if nodes <= MESH_DEVICES:
        ok_mesh, _, _, _ = _decode(ExecConfig(executor="mesh"))

    rec = {
        "head_sharded": head_sharded,
        "schemes": [s.name for s, _ in plan.steps],
        "tokens_match_local": ok_local,
        "tokens_match_mesh": ok_mesh,
        "logits_rel_err": rel_err,
        "prefill_tok_s": (PROMPT_LEN - 1) / max(prefill_s, 1e-12),
        "decode_tok_s": (N_NEW + 1) / max(decode_s, 1e-12),
        "decode_step_us": decode_s / (N_NEW + 1) * 1e6,
    }
    if full:
        ok_pallas, _, _, _ = _decode(ExecConfig(backend="pallas"))
        rec["tokens_match_pallas"] = ok_pallas
    return rec


def _run_inner(json_path: str | None, smoke: bool) -> dict:
    import jax
    assert len(jax.devices()) >= MESH_DEVICES, jax.devices()
    grid = SMOKE if smoke else FULL
    record = {"devices": len(jax.devices()), "noise_note": NOISE_NOTE,
              "prompt_len": PROMPT_LEN, "n_new": N_NEW, "kv_len": KV_LEN,
              "specs": {}}
    for spec_name, node_counts in grid.items():
        record["specs"][spec_name] = {}
        for nodes in node_counts:
            rec = _bench_point(spec_name, nodes, full=not smoke)
            record["specs"][spec_name][str(nodes)] = rec
            flags = "ok" if (rec["head_sharded"]
                             and rec["tokens_match_local"]
                             and rec["tokens_match_mesh"] is not False
                             and rec.get("tokens_match_pallas", True)) \
                else "FLAG"
            emit(f"decode_{spec_name}_n{nodes}", rec["decode_step_us"],
                 f"decode={rec['decode_tok_s']:.0f}tok/s "
                 f"prefill={rec['prefill_tok_s']:.0f}tok/s "
                 f"rel_err={rec['logits_rel_err']:.1e} {flags}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
    return record


def run(json_path: str | None = None, smoke: bool = False) -> dict:
    """Entry point used by ``benchmarks.run``: respawns in a subprocess
    with forced host devices when this process is short of them (jax
    device count is fixed at init — same pattern as ``mesh_bench``)."""
    import jax
    if len(jax.devices()) >= MESH_DEVICES:
        return _run_inner(json_path, smoke)
    out_path = os.path.abspath(json_path) if json_path else \
        os.path.join(_ROOT, "BENCH_decode.json")
    cmd = [sys.executable, "-m", "benchmarks.decode_bench",
           "--json", out_path]
    if smoke:
        cmd.append("--smoke")
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={MESH_DEVICES}"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    r = subprocess.run(cmd, env=env, cwd=_ROOT, capture_output=True,
                       text=True, timeout=3600)
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr)
    if r.returncode != 0:
        raise RuntimeError("decode_bench subprocess failed")
    with open(out_path) as f:
        return json.load(f)


if __name__ == "__main__":
    argv = sys.argv[1:]
    run(json_path=json_arg(argv, default="BENCH_decode.json"),
        smoke="--smoke" in argv)
