"""Data-driven TPU cost estimator — the paper's CE idea on dry-run data.

The edge-side CE learns from measured traces; here the "measurements" are
the loop-aware profiler outputs of every compiled dry-run record.  A GBDT
regressor maps (architecture dims, shape mode, strategy flags) ->
log(total roofline time); leave-one-out error shows how well a learned CE
would generalize across the pool — the TPU analogue of §3.2.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.configs.registry import get_config
from repro.gbdt import GBDTRegressor

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")

_MODE = {"train": 0.0, "prefill": 1.0, "decode": 2.0}


def _features(rec: dict):
    cfg = get_config(rec["arch"])
    st = rec.get("strategy", {})
    fam = {"dense": 0, "moe": 1, "ssm": 2, "hybrid": 3, "encdec": 4,
           "vlm": 5}[cfg.family]
    return [
        float(cfg.n_layers), float(cfg.d_model), float(cfg.n_heads),
        float(cfg.n_kv), float(cfg.d_ff), float(cfg.vocab), float(fam),
        float(cfg.moe.n_experts if cfg.moe else 0),
        float(rec["seq"]), float(rec["batch"]), _MODE[rec["mode"]],
        1.0 if st.get("attn") == "tp" else 0.0,
        1.0 if st.get("fsdp") else 0.0,
    ]


def load_dataset():
    xs, ys, names = [], [], []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(p))
        if rec.get("mesh") != "16x16":
            continue
        t = rec["t_compute_s"] + rec["t_memory_s"] + rec["t_collective_s"]
        xs.append(_features(rec))
        ys.append(np.log(max(t, 1e-9)))
        names.append(f"{rec['arch']}/{rec['shape']}")
    return np.asarray(xs), np.asarray(ys), names


def run() -> None:
    xs, ys, names = load_dataset()
    if len(xs) < 10:
        emit("tpu_ce/missing", 0.0, "need dry-run records first")
        return
    # leave-one-out over the (small) pool
    errs = []
    for i in range(len(xs)):
        m = np.ones(len(xs), bool)
        m[i] = False
        g = GBDTRegressor(n_estimators=60, max_depth=3, learning_rate=0.2,
                          subsample=1.0).fit(xs[m], ys[m])
        pred = g.predict(xs[i:i + 1])[0]
        errs.append(abs(pred - ys[i]))
    errs = np.asarray(errs)
    emit("tpu_ce/loo", 0.0,
         f"records={len(xs)};median_logerr={np.median(errs):.2f}"
         f"(x{np.exp(np.median(errs)):.2f});"
         f"p90=x{np.exp(np.percentile(errs, 90)):.2f}")


if __name__ == "__main__":
    run()
