"""Partition plans and their cost semantics.

A plan assigns every layer ``L_i`` a pair ``P_i = (p_i, t_i)`` (§3.3).  The
cost semantics shared by DPP, the exhaustive oracle and all baselines:

* The plan decomposes into **segments** — maximal runs ``[a..b]`` with
  ``t_a .. t_{b-1} = NT`` and ``t_b = T`` (the last layer is always T,
  Algorithm 1 lines 11-12).
* Within a multi-layer segment every layer must use the *same spatial* scheme
  (halo-fused redundant compute is only meaningful when consecutive layers
  share a spatial split; OutC needs the full next-layer input, so OutC can
  never be in NT mode).
* Layer ``m`` of segment ``[a..b]`` computes an output enlarged by the
  receptive-field halo ``h_m`` (``graph.halo_growth``) — the redundant
  computation of §2.3.
* Each segment end pays the s-cost to re-layout its output into the next
  segment's scheme; the final layer pays a gather-to-root sync.

DAG graphs add junction rules on top (segments live *within* branches of
``ModelGraph.linearize()``):

* Fork layers (fan-out >= 2), merge layers (fan-in >= 2) and every branch
  tail are forced T-mode sync points — NT fusion never crosses a junction.
* A fork pays one s-cost per non-merge consumer (sequential broadcast).
* A merge pays the **max** over its incoming branch deliveries (the paper's
  branch transfers overlap; the slowest re-layout gates the merge).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from .cost import Testbed
from .estimator import CostEstimator
from .graph import LayerSpec, ModelGraph, halo_growth
from .partition import Mode, Scheme, min_shard_extent


@dataclasses.dataclass(frozen=True)
class Plan:
    """``steps[i] = (scheme, mode)`` for layer i (topological order)."""

    steps: Tuple[Tuple[Scheme, Mode], ...]

    def __post_init__(self) -> None:
        if self.steps and self.steps[-1][1] != Mode.T:
            raise ValueError("last layer must be in T mode")

    def __len__(self) -> int:
        return len(self.steps)

    def segments(self) -> List[Tuple[int, int]]:
        """Inclusive (start, end) of each T-terminated segment (chain
        interpretation; for branched graphs use per-branch segments)."""
        return steps_segments(self.steps)

    def validate(self) -> None:
        _validate_steps_slice(self.steps, where="segment")

    def validate_for(self, graph: ModelGraph) -> None:
        """Graph-aware validation: chain rules plus DAG junction rules."""
        if len(self.steps) != len(graph):
            raise ValueError("plan/graph length mismatch")
        if graph.is_chain:
            self.validate()
            return
        for i in range(len(graph)):
            if (graph.fan_in(i) >= 2 or graph.fan_out(i) >= 2) \
                    and self.steps[i][1] != Mode.T:
                raise ValueError(
                    f"junction layer {graph.layers[i].name} must be T-mode")
        for br in graph.linearize():
            sl = tuple(self.steps[i] for i in br.ids)
            if sl[-1][1] != Mode.T:
                raise ValueError(
                    f"branch tail {graph.layers[br.tail].name} must be "
                    f"T-mode (NT fusion cannot cross a junction)")
            _validate_steps_slice(sl, where=f"branch@{br.head}")


def steps_segments(steps: Sequence[Tuple[Scheme, Mode]]
                   ) -> List[Tuple[int, int]]:
    """Inclusive (start, end) segment spans of a step sequence."""
    segs, a = [], 0
    for i, (_, t) in enumerate(steps):
        if t == Mode.T:
            segs.append((a, i))
            a = i + 1
    return segs


def _validate_steps_slice(steps: Sequence[Tuple[Scheme, Mode]],
                          where: str) -> None:
    for a, b in steps_segments(steps):
        if b > a:
            schemes = {steps[m][0] for m in range(a, b + 1)}
            if len(schemes) != 1:
                raise ValueError(
                    f"{where} [{a},{b}] mixes schemes {schemes}")
            if not steps[a][0].spatial:
                raise ValueError(
                    f"{where} [{a},{b}] uses non-spatial scheme in NT mode")


@dataclasses.dataclass(frozen=True)
class PipelineCost:
    """Two-resource occupancy of one plan under pipelined execution.

    The simulator's resource model (``cluster.simsched``) has two resource
    classes: devices execute every compute stage, links carry every sync
    stage.  In a saturated pipeline each class processes its whole
    per-request workload back to back across overlapping requests, so the
    steady-state inter-departure time is the larger per-request occupancy —
    not the single-request latency, which pays both classes in series.

    ``compute_s`` sums the segment compute stages (straggler times, halos
    included); ``sync_s`` sums the sync stages (internal boundaries, fork
    deliveries, per-merge max over incoming deliveries, final gather).
    """

    compute_s: float
    sync_s: float

    @property
    def bottleneck_s(self) -> float:
        """Steady-state pipeline period: the busier resource class."""
        return max(self.compute_s, self.sync_s)

    @property
    def latency_s(self) -> float:
        """Single-request time: both classes in series (== plan_cost)."""
        return self.compute_s + self.sync_s

    @property
    def throughput_rps(self) -> float:
        t = self.bottleneck_s
        return 1.0 / t if t > 0.0 else float("inf")


def plan_pipeline_cost(graph: ModelGraph, plan: Plan, est: CostEstimator,
                       tb: Testbed) -> PipelineCost:
    """Pipelined cost of ``plan``: per-resource-class occupancy sums.

    Stage decomposition and estimator call pattern are identical to
    :func:`dag_plan_cost` (same segments, same s-queries, merge deliveries
    combine with max) — the two accumulators just land in separate buckets,
    so ``compute_s + sync_s`` equals the latency cost up to float
    association.
    """
    plan.validate_for(graph)
    layers = graph.layers
    compute = 0.0
    sync = 0.0
    merge_deliveries: Dict[int, List[float]] = {}
    for br in graph.linearize():
        ids = br.ids
        ls = [layers[i] for i in ids]
        steps = [plan.steps[i] for i in ids]
        for a, b in steps_segments(steps):
            scheme = steps[a][0]
            halos = halo_growth(ls[a:b + 1], b - a)
            for off, m in enumerate(range(a, b + 1)):
                compute += est.i_cost(ls[m], scheme, tb,
                                      extra_halo=halos[off] if b > a else 0)
            if b < len(ids) - 1:
                sync += est.s_cost(ls[b], ls[b + 1], scheme,
                                   steps[b + 1][0], tb)
        p_tail = steps[-1][0]
        consumers = graph.consumer_ids[ids[-1]]
        if not consumers:
            sync += est.s_cost(ls[-1], None, p_tail, None, tb)
        for c in consumers:
            d = est.s_cost(ls[-1], layers[c], p_tail, plan.steps[c][0], tb)
            if graph.fan_in(c) >= 2:
                merge_deliveries.setdefault(c, []).append(d)
            else:
                sync += d
    for ds in merge_deliveries.values():
        sync += max(ds)
    return PipelineCost(compute_s=compute, sync_s=sync)


def plan_stage_counts(graph: ModelGraph, plan: Plan) -> Tuple[int, int]:
    """``(compute_stages, sync_stages)`` of the plan's pipeline stage DAG.

    The shared stage-decomposition arithmetic: ``cluster.simsched`` builds
    exactly this many stages, and the engine's ``ExecStats`` reports the
    same compute-stage count from its executed segments — one contract
    across the analytic model, the simulator, and the real execution path.
    """
    plan.validate_for(graph)
    n_compute = 0
    n_sync = 0
    merges = set()
    for br in graph.linearize():
        ids = br.ids
        steps = [plan.steps[i] for i in ids]
        segs = steps_segments(steps)
        n_compute += len(segs)
        n_sync += len(segs) - 1          # internal boundaries
        consumers = graph.consumer_ids[ids[-1]]
        if not consumers:
            n_sync += 1                  # final gather
        for c in consumers:
            if graph.fan_in(c) >= 2:
                merges.add(c)            # one merge stage per merge layer
            else:
                n_sync += 1              # fork delivery
    return n_compute, n_sync + len(merges)


def plan_cost(graph: ModelGraph, plan: Plan, est: CostEstimator,
              tb: Testbed) -> float:
    """Total estimated inference time of ``plan`` (seconds).

    A chain is the single-branch special case of the DAG semantics (same
    segments, same estimator calls in the same order), so one evaluator
    serves both."""
    if len(plan) != len(graph):
        raise ValueError("plan/graph length mismatch")
    return dag_plan_cost(graph, plan, est, tb)


def dag_plan_cost(graph: ModelGraph, plan: Plan, est: CostEstimator,
                  tb: Testbed) -> float:
    """Plan cost for a branched graph: per-branch chain costs, plus fork
    broadcasts (summed) and merge deliveries (max over incoming branches).
    Reduces exactly to the chain semantics on a single-branch graph."""
    plan.validate_for(graph)
    layers = graph.layers
    total = 0.0
    merge_deliveries: Dict[int, List[float]] = {}
    for br in graph.linearize():
        ids = br.ids
        ls = [layers[i] for i in ids]
        steps = [plan.steps[i] for i in ids]
        for a, b in steps_segments(steps):
            scheme = steps[a][0]
            halos = halo_growth(ls[a:b + 1], b - a)
            for off, m in enumerate(range(a, b + 1)):
                total += est.i_cost(ls[m], scheme, tb,
                                    extra_halo=halos[off] if b > a else 0)
            if b < len(ids) - 1:   # boundary inside the branch
                total += est.s_cost(ls[b], ls[b + 1], scheme,
                                    steps[b + 1][0], tb)
        # crossing out of the branch tail
        p_tail = steps[-1][0]
        consumers = graph.consumer_ids[ids[-1]]
        if not consumers:   # graph output: gather to root
            total += est.s_cost(ls[-1], None, p_tail, None, tb)
        for c in consumers:
            d = est.s_cost(ls[-1], layers[c], p_tail, plan.steps[c][0], tb)
            if graph.fan_in(c) >= 2:
                merge_deliveries.setdefault(c, []).append(d)
            else:
                total += d
    for ds in merge_deliveries.values():
        total += max(ds)
    return total


def segment_halos(layers: Sequence[LayerSpec], a: int, b: int) -> List[int]:
    """Halo (extra output rows per side) for each layer of segment [a..b]."""
    return halo_growth(layers[a:b + 1], b - a)


def segment_feasible(layers: Sequence[LayerSpec], a: int, b: int,
                     scheme: Scheme, nodes: int) -> bool:
    """A multi-layer NT segment is feasible while its cumulative halo has not
    degenerated into full replication.  Shared by DPP (as a prune — the halo
    is monotone in segment length, so breaking early is exact) and by the
    exhaustive oracle (as a plan filter), keeping their search spaces equal.
    """
    if b == a:
        return True
    if not scheme.spatial:
        return False
    halos = halo_growth(layers[a:b + 1], b - a)
    return 2 * halos[0] < min_shard_extent(layers[a], scheme, nodes)


def plan_feasible(graph: ModelGraph, plan: Plan, nodes: int) -> bool:
    if graph.is_chain:
        return all(segment_feasible(graph.layers, a, b, plan.steps[a][0],
                                    nodes)
                   for a, b in plan.segments())
    for br in graph.linearize():
        ls = [graph.layers[i] for i in br.ids]
        steps = [plan.steps[i] for i in br.ids]
        if not all(segment_feasible(ls, a, b, steps[a][0], nodes)
                   for a, b in steps_segments(steps)):
            return False
    return True


def fixed_plan(graph: ModelGraph, scheme: Scheme) -> Plan:
    return Plan(tuple((scheme, Mode.T) for _ in graph.layers))
