"""Heterogeneity-aware learned estimator + online calibration, and the
measurement-path bugfixes that ride along: feature-prefix compatibility,
hetero trace-label parity vs the batched hetero physics, hetero-trained-
beats-homogeneous plan quality, calibration error shrinkage, the
zero-throughput refine guard, conservative p99, and bounded GBDT caches.
"""
import dataclasses

import numpy as np
import pytest

from repro.cluster import (ClusterAnalyticEstimator, ClusterGBDTEstimator,
                           OnlineCalibrator, cluster_plan_search,
                           fold_queueing_delay, mixed_fast_slow,
                           refine_with_simulator, simulate, stepped)
from repro.configs.edge_models import resnet18
from repro.core import (GBDTEstimator, HETERO_FEATURE_NAMES,
                        I_FEATURE_NAMES, N_HETERO_FEATURES,
                        S_FEATURE_NAMES, Testbed, hetero_summary,
                        plan_search)
from repro.core import testbed_summary as uniform_summary
from repro.core.estimator import i_features, latency_class, s_features
from repro.core.graph import ConvT, LayerSpec, chain
from repro.core.partition import Scheme
from repro.core.plan import plan_cost
from repro.sim import (TraceConfig, generate_i_traces, generate_s_traces,
                       hetero_trace_config, train_estimators)


def small_chain():
    return chain("cal4", [
        LayerSpec("c0", ConvT.CONV, 24, 24, 3, 8, 3, 1, 1),
        LayerSpec("c1", ConvT.CONV, 24, 24, 8, 8, 3, 1, 1),
        LayerSpec("pw", ConvT.POINTWISE, 24, 24, 8, 16, 1, 1, 0),
        LayerSpec("c2", ConvT.CONV, 24, 24, 16, 8, 3, 1, 1),
    ])


# ---------------------------------------------------------------------------
# feature expression: hetero columns are a pure suffix
# ---------------------------------------------------------------------------

def test_feature_prefix_exact():
    layer = LayerSpec("c", ConvT.CONV, 28, 28, 16, 32, 3, 1, 1)
    nxt = LayerSpec("n", ConvT.POINTWISE, 28, 28, 32, 64, 1, 1, 0)
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    summary = hetero_summary([1.0, 2.0, 3.0, 4.0], [0.5, 1.0], 10.0)
    base_i = i_features(layer, Scheme.INH, tb, 1)
    wide_i = i_features(layer, Scheme.INH, tb, 1, hetero=summary)
    assert len(base_i) == len(I_FEATURE_NAMES) == 17
    assert len(wide_i) == 17 + N_HETERO_FEATURES
    assert wide_i[:17] == base_i and wide_i[17:] == summary
    base_s = s_features(layer, nxt, Scheme.INH, Scheme.OUTC, tb)
    wide_s = s_features(layer, nxt, Scheme.INH, Scheme.OUTC, tb,
                        hetero=summary)
    assert len(base_s) == len(S_FEATURE_NAMES) == 20
    assert wide_s[:20] == base_s and wide_s[20:] == summary
    assert len(HETERO_FEATURE_NAMES) == N_HETERO_FEATURES == 5


def test_hetero_summary_values_and_validation():
    n = 4
    tb = Testbed(nodes=n)
    uni = uniform_summary(tb)
    assert uni[:3] == [1.0 / n] * 3 and uni[3] == 1.0
    assert uni[4] == latency_class(tb.link_latency_us)
    s = hetero_summary([1.0, 3.0], [0.25, 1.0], 100.0)
    assert s[0] == 0.25 and s[2] == 0.75 and abs(s[1] - 0.5) < 1e-15
    assert s[3] == 0.25 and s[4] == 2.0
    assert latency_class(10.0) == 0.0
    assert latency_class(50.0) == 1.0
    assert latency_class(500.0) == 2.0
    with pytest.raises(ValueError):
        hetero_summary([1.0, 0.0], [1.0], 10.0)


def test_cluster_summary_matches_cluster_spec():
    cl = mixed_fast_slow(4)
    s = hetero_summary(cl.capability_weights,
                       [lk.bandwidth_gbps for lk in cl.links],
                       cl.max_latency_us)
    w = np.asarray(cl.capability_weights)
    assert s[0] == pytest.approx(w.min() / w.sum())
    assert s[2] == pytest.approx(w.max() / w.sum())
    assert s[0] < s[2]          # genuinely heterogeneous


# ---------------------------------------------------------------------------
# trace generation: default stream preserved, hetero rows widened + labeled
# by the hetero batched physics
# ---------------------------------------------------------------------------

def test_default_trace_stream_unchanged_and_deterministic():
    cfg = TraceConfig(n_samples=200, seed=3)
    xa, ya = generate_i_traces(cfg)
    xb, yb = generate_i_traces(cfg)
    assert xa.shape == (200, 17)
    assert np.array_equal(xa, xb) and np.array_equal(ya, yb)
    sa, sya = generate_s_traces(cfg)
    assert sa.shape == (200, 20)
    sb, syb = generate_s_traces(cfg)
    assert np.array_equal(sa, sb) and np.array_equal(sya, syb)


def test_hetero_traces_widened_with_summary_columns():
    cfg = hetero_trace_config(n_samples=300, seed=2)
    x, _ = generate_i_traces(cfg)
    assert x.shape == (300, 17 + N_HETERO_FEATURES)
    shares = x[:, 17:20]
    # every row carries a valid share triple (min <= mean <= max, sum-free)
    assert np.all(shares[:, 0] <= shares[:, 1] + 1e-15)
    assert np.all(shares[:, 1] <= shares[:, 2] + 1e-15)
    # homogeneous rows carry the uniform testbed summary (min == max)
    hom = np.isclose(shares[:, 0], shares[:, 2])
    het = ~hom
    assert hom.any() and het.any()
    nodes = x[hom, 14]
    assert np.allclose(x[hom, 17], 1.0 / nodes)
    xs, _ = generate_s_traces(cfg)
    assert xs.shape == (300, 20 + N_HETERO_FEATURES)


def test_i_trace_labels_match_hetero_batched_physics():
    """Single-preset, single-node-count, noise-free config: every label is
    exactly what ClusterAnalyticEstimator prices for that cluster."""
    cl = mixed_fast_slow(4)
    cfg = TraceConfig(n_samples=60, noise_sigma=0.0, seed=5,
                      node_choices=(4,),
                      cluster_presets=("mixed_fast_slow",),
                      hetero_fraction=1.0)
    x, y = generate_i_traces(cfg)
    expect = ClusterAnalyticEstimator(cl).i_cost_batch(
        x, cl.compat_testbed())
    np.testing.assert_allclose(np.exp(y), np.maximum(expect, 1e-9),
                               rtol=1e-12)


def test_s_trace_labels_match_projected_sync():
    cl = stepped(4)
    cfg = TraceConfig(n_samples=60, noise_sigma=0.0, seed=6,
                      node_choices=(4,), cluster_presets=("stepped",),
                      hetero_fraction=1.0)
    x, y = generate_s_traces(cfg)
    expect = ClusterAnalyticEstimator(cl).s_cost_batch(
        x, cl.compat_testbed())
    np.testing.assert_allclose(np.exp(y), np.maximum(expect, 1e-9),
                               rtol=1e-12)


# ---------------------------------------------------------------------------
# hetero-trained GBDT as a first-class planner estimator
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trained():
    """One small hetero-trained + homogeneous-trained estimator pair
    (shared by the planner-integration and plan-quality tests)."""
    kw = dict(n_estimators=25, max_depth=6)
    het = train_estimators(
        hetero_trace_config(n_samples=6000, seed=0, hetero_fraction=0.7),
        gbdt_kwargs=kw)
    hom = train_estimators(TraceConfig(n_samples=6000, seed=0),
                           gbdt_kwargs=kw)
    return het, hom


def test_forest_records_fit_width(trained):
    het, hom = trained
    assert het.i_model.n_features_ == 17 + N_HETERO_FEATURES
    assert het.s_model.n_features_ == 20 + N_HETERO_FEATURES
    assert hom.i_model.n_features_ == 17


def test_forest_width_survives_save_load(tmp_path, trained):
    _, hom = trained
    path = str(tmp_path / "i.npz")
    hom.i_model.save(path)
    from repro.gbdt import GBDTRegressor
    back = GBDTRegressor.load(path)
    assert back.n_features_ == 17
    x, _ = generate_i_traces(TraceConfig(n_samples=50, seed=9))
    np.testing.assert_allclose(back.predict(x), hom.i_model.predict(x),
                               rtol=1e-15)


def test_cluster_gbdt_rejects_homogeneous_forest(trained):
    _, hom = trained
    with pytest.raises(ValueError, match="hetero"):
        ClusterGBDTEstimator(hom, mixed_fast_slow(4))


def test_cluster_gbdt_scalar_batch_row_parity(trained):
    het, _ = trained
    cl = mixed_fast_slow(4)
    ce = ClusterGBDTEstimator(het, cl)
    tb = cl.compat_testbed()
    layer = LayerSpec("c", ConvT.CONV, 28, 28, 16, 32, 3, 1, 1)
    rows = [i_features(layer, s, tb, 0) for s in
            (Scheme.INH, Scheme.OUTC, Scheme.GRID2D)]
    batch = ce.i_cost_batch(np.asarray(rows, np.float64), tb)
    for row_s, got in zip((Scheme.INH, Scheme.OUTC, Scheme.GRID2D), batch):
        assert ce.i_cost(layer, row_s, tb) == pytest.approx(float(got),
                                                            rel=1e-12)
    with pytest.raises(ValueError, match="testbed"):
        ce.i_cost(layer, Scheme.INH, Testbed(nodes=3))


def test_hetero_beats_homogeneous_plan_quality(trained):
    """The acceptance comparison at test scale: on mixed_fast_slow and
    stepped, the plan the hetero-trained GBDT picks (priced by the
    analytic cluster oracle) must strictly beat the plan the
    homogeneous-trained GBDT picks (the full-budget version runs in
    benchmarks/estimator_quality.py and is CI-gated)."""
    het, hom = trained
    g = resnet18(96)
    for preset in (mixed_fast_slow, stepped):
        cl = preset(6)
        tb = cl.compat_testbed()
        oracle = cluster_plan_search(g, cl)
        ae = ClusterAnalyticEstimator(cl)
        ce = ClusterGBDTEstimator(het, cl)
        het_cost = plan_cost(
            g, cluster_plan_search(g, cl, estimator=ce).plan, ae, tb)
        hom_cost = plan_cost(g, plan_search(g, hom, tb).plan, ae, tb)
        assert het_cost < hom_cost, preset.__name__
        assert het_cost < 1.5 * oracle.cost, preset.__name__


# ---------------------------------------------------------------------------
# online calibration
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Meas:
    dev_occupancy_s: float
    link_occupancy_s: float
    period_s: float
    failures: int = 0


def test_predicted_occupancy_matches_simulator_accounting():
    g = small_chain()
    cl = mixed_fast_slow(4)
    plan = cluster_plan_search(g, cl).plan
    cal = OnlineCalibrator(cl)
    dev, link = cal.predicted_occupancy(g, plan)
    rep = simulate(g, plan, cl, n_requests=6)
    np.testing.assert_allclose(dev, np.asarray(rep.device_busy_s) / 6,
                               rtol=1e-9)
    np.testing.assert_allclose(link, np.asarray(rep.link_busy_s) / 6,
                               rtol=1e-9)


def test_calibration_shrinks_period_error_on_skewed_occupancy():
    """Seeded skew: the machine runs two devices 1.7x slower and links
    1.3x slower than the physics says.  A handful of observations must
    cut the predicted-period error by >= 2x (acceptance criterion)."""
    g = small_chain()
    cl = mixed_fast_slow(4)
    plan = cluster_plan_search(g, cl).plan
    cal = OnlineCalibrator(cl, decay=0.6)
    dev, link = cal.predicted_occupancy(g, plan)
    skew = np.where(np.arange(cl.n) == int(np.argmax(dev)), 1.7, 1.0)
    true_dev = float(np.max(dev * skew))
    true_link = float(np.max(link)) * 1.3
    true_period = max(true_dev, true_link)
    meas = _Meas(dev_occupancy_s=true_dev, link_occupancy_s=true_link,
                 period_s=true_period)
    err0 = abs(cal.predict_period(g, plan) - true_period)
    assert err0 > 0.0
    for _ in range(6):
        assert cal.observe(g, plan, meas)
    err1 = abs(cal.predict_period(g, plan) - true_period)
    assert err1 <= err0 / 2.0
    beta, alpha = cal.axis_scales()
    assert beta == pytest.approx(np.max(cal.compute_scale))
    assert alpha == pytest.approx(cal.sync_scale)
    assert len(cal.history) == 6 and all(s.trusted for s in cal.history)


def test_untrusted_measurement_does_not_move_scales():
    g = small_chain()
    cl = mixed_fast_slow(4)
    plan = cluster_plan_search(g, cl).plan
    cal = OnlineCalibrator(cl, decay=1.0)
    bad = _Meas(dev_occupancy_s=1e3, link_occupancy_s=1e3, period_s=1e3,
                failures=2)
    assert not cal.observe(g, plan, bad)
    assert np.all(cal.compute_scale == 1.0) and cal.sync_scale == 1.0
    assert len(cal.history) == 1 and not cal.history[0].trusted


def test_sim_report_observation_near_identity():
    """Folding the simulator's own report back must leave the scales near
    1.0 — the predicted occupancy IS the simulator's accounting."""
    g = small_chain()
    cl = stepped(4)
    plan = cluster_plan_search(g, cl).plan
    cal = OnlineCalibrator(cl, decay=1.0)
    cal.observe(g, plan, simulate(g, plan, cl, n_requests=8))
    np.testing.assert_allclose(cal.compute_scale, 1.0, rtol=1e-6)
    assert cal.sync_scale == pytest.approx(1.0, rel=1e-6)


def test_refine_accepts_calibrator_and_warm_starts():
    g = small_chain()
    cl = mixed_fast_slow(4)
    cal = OnlineCalibrator(cl, decay=1.0)
    res = refine_with_simulator(g, cl, n_requests=6, calibrator=cal)
    assert res.best_throughput_rps > 0.0
    assert len(cal.history) >= 1
    # warm start: a second refinement begins from the folded scales
    beta, alpha = cal.axis_scales()
    res2 = refine_with_simulator(g, cl, n_requests=6, calibrator=cal)
    assert res2.steps[0].beta == pytest.approx(beta)
    assert res2.steps[0].alpha == pytest.approx(alpha)


def test_calibrator_validation():
    with pytest.raises(ValueError):
        OnlineCalibrator(mixed_fast_slow(4), decay=0.0)
    with pytest.raises(ValueError):
        OnlineCalibrator(mixed_fast_slow(4), decay=1.5)


def test_fold_queueing_delay():
    rows = [{"arrival_rate_rps": 10.0, "p99_ms": 100.0},
            {"arrival_rate_rps": 20.0, "p99_ms": 150.0}]
    # at the light-load rate the measured delay is zero: bound unchanged
    assert fold_queueing_delay(0.5, rows, 10.0) == pytest.approx(0.5)
    # midway: 25 ms of measured queueing delay comes off the bound
    assert fold_queueing_delay(0.5, rows, 15.0) == pytest.approx(0.475)
    # beyond the measured range: clamped to the last measured delay
    assert fold_queueing_delay(0.5, rows, 100.0) == pytest.approx(0.45)
    # the bound never goes negative
    assert fold_queueing_delay(0.04, rows, 20.0) == 0.0
    # a known service tail shifts the whole curve
    assert fold_queueing_delay(0.5, rows, 10.0, service_p99_s=0.05) \
        == pytest.approx(0.45)
    assert fold_queueing_delay(0.5, [], 10.0) == 0.5
    with pytest.raises(ValueError):
        fold_queueing_delay(0.0, rows, 10.0)


# ---------------------------------------------------------------------------
# satellite bugfix regressions
# ---------------------------------------------------------------------------

def test_refine_survives_zero_throughput_report(monkeypatch):
    """A degenerate simulator report (zero throughput) historically raised
    ZeroDivisionError at ``period = 1.0 / rps``; it must now be treated
    as an untrusted sample."""
    import repro.cluster.refine as refine_mod
    real = refine_mod.simulate

    def degenerate(graph, plan, cluster, **kw):
        rep = real(graph, plan, cluster, **kw)
        return dataclasses.replace(rep, throughput_rps=0.0)

    monkeypatch.setattr(refine_mod, "simulate", degenerate)
    res = refine_with_simulator(small_chain(), mixed_fast_slow(4),
                                n_requests=4, max_iters=3)
    assert res.plan is not None
    assert not res.converged          # never certified off a bad sample
    assert all(s.sim_period_s == 0.0 for s in res.steps)


def test_refine_inf_throughput_does_not_fake_convergence(monkeypatch):
    """``simulate`` can legitimately report inf throughput; the resulting
    ``period = 0.0`` must not satisfy the rel_tol stationarity check."""
    import repro.cluster.refine as refine_mod
    real = refine_mod.simulate

    def infinite(graph, plan, cluster, **kw):
        rep = real(graph, plan, cluster, **kw)
        return dataclasses.replace(rep, throughput_rps=float("inf"))

    monkeypatch.setattr(refine_mod, "simulate", infinite)
    res = refine_with_simulator(small_chain(), mixed_fast_slow(4),
                                n_requests=4, max_iters=3, rel_tol=0.5)
    assert not res.converged
    assert all(s.sim_period_s == 0.0 for s in res.steps)


def test_p99_is_conservative_order_statistic():
    """SimReport's p99 must be a latency some request actually saw, at or
    above the linear interpolation that under-read the tail."""
    g = small_chain()
    cl = mixed_fast_slow(4)
    plan = cluster_plan_search(g, cl).plan
    rep = simulate(g, plan, cl, n_requests=16,
                   arrival_period_s=1e-4)
    lat = np.asarray(rep.latencies_s)
    assert lat.min() < lat.max()      # a real distribution, not a constant
    assert any(np.isclose(rep.p99_latency_s, x) for x in lat)
    assert rep.p99_latency_s >= np.percentile(lat, 99) - 1e-15
    assert rep.p99_latency_s >= np.percentile(lat, 99,
                                              method="higher") - 1e-15


def test_gbdt_scalar_caches_are_bounded(trained):
    _, hom = trained
    est = GBDTEstimator(hom.i_model, hom.s_model, cache_size=32)
    cl = mixed_fast_slow(4)
    tb = cl.compat_testbed()
    for c in range(3, 100):
        layer = LayerSpec(f"c{c}", ConvT.POINTWISE, 14, 14, c, 2 * c,
                          1, 1, 0)
        est.i_cost(layer, Scheme.OUTC, tb)
        est.s_cost(layer, None, Scheme.OUTC, None, tb)
    assert len(est._i_cache) <= 32 and len(est._s_cache) <= 32
    hits, misses = est.cache_info()
    assert misses == 2 * 97 and hits == 0
    # repeat queries within the window hit
    layer = LayerSpec("c99", ConvT.POINTWISE, 14, 14, 99, 198, 1, 1, 0)
    est.i_cost(layer, Scheme.OUTC, tb)
    assert est.cache_info() == (1, 2 * 97)
    est.clear_cache()
    assert len(est._i_cache) == 0
    with pytest.raises(ValueError):
        GBDTEstimator(hom.i_model, hom.s_model, cache_size=0)
