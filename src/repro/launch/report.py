"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
records in experiments/dryrun/*.json.

Usage: PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
Prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List


def load(dirpath: str) -> List[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(recs: List[dict]) -> str:
    lines = ["| arch | shape | mesh | compile | HBM/device (args+temp) | "
             "collective schedule (per-device bytes) |",
             "|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        mem = r.get("mem_per_device", {})
        args = (mem.get("argument_size_bytes") or 0) / 1e9
        temp = (mem.get("temp_size_bytes") or 0) / 1e9
        coll = ", ".join(f"{k}:{v / 1e9:.2f}GB"
                         for k, v in sorted(r.get("coll_bytes", {}).items())
                         if v > 1e6) or "none>1MB"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_s', '?')}s | {args:.2f}+{temp:.2f} GB | "
            f"{coll} |")
    return "\n".join(lines)


def roofline_table(recs: List[dict]) -> str:
    lines = ["| arch | shape | t_compute | t_memory | t_collective | "
             "bottleneck | MODEL_FLOPS | useful ratio | next lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16":
            continue
        lever = _lever(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['t_compute_s'])} | "
            f"{_fmt_s(r['t_memory_s'])} | {_fmt_s(r['t_collective_s'])} | "
            f"**{r['bottleneck']}** | {r['model_flops']:.3g} | "
            f"{r['useful_ratio']:.3f} | {lever} |")
    return "\n".join(lines)


def _lever(r: dict) -> str:
    b = r["bottleneck"]
    shape = r["shape"]
    if b == "collective":
        if shape == "train_4k":
            return "reduce FSDP all-gather: larger per-layer shards / TP"
        return "re-layout boundaries: planner scheme change"
    if b == "memory":
        if "decode" in shape or shape == "long_500k":
            return "shrink per-token reads: resident weights, bf16 cache"
        return "remat policy / fused attention tiles"
    return "MXU-align tiles; raise arithmetic intensity"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    pods = {r["mesh"] for r in recs}
    n16 = sum(1 for r in recs if r["mesh"] == "16x16")
    nmp = sum(1 for r in recs if r["mesh"] == "2x16x16")
    print(f"## §Dry-run ({n16} single-pod + {nmp} multi-pod records, "
          f"meshes: {sorted(pods)})\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (single-pod 16x16, per-device terms)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
