"""End-to-end behaviour tests for the FlexPie system."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import AnalyticEstimator, Testbed, Topology, chain
from repro.core.baselines import all_solutions, performance_scores
from repro.core.dpp import plan_search
from repro.core.partition import Mode
from repro.configs.edge_models import EDGE_MODELS, mobilenet_v1
from repro.runtime.engine import init_weights, run_reference
from repro.runtime.session import Session

EST = AnalyticEstimator()


def test_flexpie_wins_all_benchmarks_both_testbeds():
    """Paper §4: FlexPie scores 1.0 across 4 models x {3,4}-node testbeds."""
    for nodes in (3, 4):
        tb = Testbed(nodes=nodes, bandwidth_gbps=1.0)
        for name, fn in EDGE_MODELS.items():
            sols = all_solutions(fn(), EST, tb)
            scores = performance_scores({k: v[1] for k, v in sols.items()})
            assert scores["flexpie"] == pytest.approx(1.0), (name, nodes)


def test_bandwidth_drives_fusion():
    """§2.3 trade-off: lower bandwidth -> more NT (redundant compute)."""
    g = mobilenet_v1()
    nt = {}
    for bw in (5.0, 0.5):
        plan = plan_search(g, EST, Testbed(nodes=4, bandwidth_gbps=bw)).plan
        nt[bw] = sum(1 for _, m in plan.steps if m == Mode.NT)
    assert nt[0.5] >= nt[5.0]
    assert nt[0.5] > 0


def test_testbed_changes_optimal_plan():
    """§2.2: the optimal scheme assignment depends on the testbed."""
    g = mobilenet_v1()
    p4 = plan_search(g, EST, Testbed(nodes=4)).plan
    p3 = plan_search(g, EST, Testbed(nodes=3)).plan
    assert p4.steps != p3.steps


def test_topology_affects_cost():
    g = mobilenet_v1()
    costs = {}
    for topo in (Topology.RING, Topology.PS, Topology.MESH):
        tb = Testbed(nodes=4, bandwidth_gbps=0.5, topology=topo)
        costs[topo] = plan_search(g, EST, tb).cost
    assert costs[Topology.PS] > costs[Topology.MESH]


def test_planner_plan_executes_exactly_end_to_end():
    """Plan from the optimizer -> engine -> bit-exact output (reduced res)."""
    g_full = mobilenet_v1(width=32)
    g = chain("mb32", g_full.layers[:7])
    key = jax.random.PRNGKey(0)
    ws = init_weights(g, key)
    x = jax.random.normal(key, (32, 32, 3))
    ref = run_reference(g, ws, x)
    for nodes in (3, 4):
        plan = plan_search(g, EST, Testbed(nodes=nodes,
                                           bandwidth_gbps=0.5)).plan
        out, stats = Session(g, ws, plan, nodes).run(x)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
        assert stats.sync_points >= 1


def test_bert_insensitive_to_scheme():
    """Paper limitation: BERT's matmul layers parallelize trivially."""
    from repro.configs.edge_models import bert_base
    g = bert_base()
    tb = Testbed(nodes=4, bandwidth_gbps=5.0)
    sols = all_solutions(g, EST, tb)
    times = {k: v[1] for k, v in sols.items()}
    flexible = [times["layerwise"], times["fused_fixed"], times["flexpie"]]
    assert max(flexible) / min(flexible) < 1.05


def test_search_time_scales_polynomially():
    import time
    from repro.configs.edge_models import resnet101
    g = resnet101()      # 136 layers
    t0 = time.time()
    res = plan_search(g, EST, Testbed(nodes=4))
    dt = time.time() - t0
    assert dt < 30.0, dt
    assert res.cost > 0
