"""FlexPie core: flexible combinatorial optimization for model partition."""
from .graph import ConvT, LayerSpec, ModelGraph, chain, halo_growth
from .partition import ALL_SCHEMES, Mode, Scheme
from .cost import Testbed, Topology
from .estimator import AnalyticEstimator, GBDTEstimator
from .plan import Plan, fixed_plan, plan_cost, plan_feasible
from .dpp import SearchResult, plan_search
from .exhaustive import exhaustive_search
from . import baselines

__all__ = [
    "ConvT", "LayerSpec", "ModelGraph", "chain", "halo_growth",
    "ALL_SCHEMES", "Mode", "Scheme", "Testbed", "Topology",
    "AnalyticEstimator", "GBDTEstimator", "Plan", "fixed_plan", "plan_cost",
    "plan_feasible", "SearchResult", "plan_search", "exhaustive_search",
    "baselines",
]
