"""Mesh executor: run a FlexPie plan on a real JAX device mesh.

The local engine (``runtime.engine``) executes every planned node's shard
program sequentially in one process — the pipelining the planner optimizes
for exists only in the analytic ``PipelineCost`` model and the
``cluster.simsched`` discrete-event schedule.  This module makes the plan
physical: each planned node's per-segment shard program is placed on its
own JAX device (CPU CI fakes the devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``), expressed as
``shard_map`` programs over a 1-D ``nodes`` mesh axis so all shards of a
segment execute concurrently.  Host-side slicing becomes collectives:

* **Neighbor halo exchange** — at a T boundary between two segments that
  share an InH/InW scheme, each node's next input rect extends only into
  its immediate neighbors' rows.  The boundary rows travel by
  ``jax.lax.ppermute`` (one shift up, one shift down); the receiving node
  splices them onto its own rows to assemble the halo-extended local
  slice that its compiled segment records consume — the same
  ``_segment_records`` signatures, and therefore the same Pallas shard
  kernels, as the local executor.
* **Gather re-layout** — scheme changes, OutC/2D-grid layouts, fork
  deliveries, CONCAT/ADD merges and the final gather are
  ``jax.lax.all_gather`` + static re-placement (every device rebuilds the
  full boundary tensor, then slices its next region; the per-node slice
  arithmetic lives in a ``lax.switch`` over ``axis_index('nodes')``, so
  one traced program serves all devices while each executes only its own
  branch).

**Double-buffered boundaries** (``overlap=True``, the default): a segment
whose exit boundary is permute-compatible computes its *border strips
first* — the rows its neighbors will need — issues the ``ppermute`` on
them, and only then computes its interior rows.  In the dataflow graph
the exchange depends only on the border compute, so segment *k+1*'s halo
exchange is in flight while segment *k*'s interior compute still runs
(XLA async collectives overlap them on real backends; on the CPU host
platform the schedule is still valid, just serialized).  With
``overlap=False`` every boundary exchange is dispatched as its own sync
stage, giving a 1:1 correspondence with ``cluster.simsched.build_stages``
— that is the mode ``instrument=True`` validation uses, and
:func:`validate_stage_decomposition` checks the measured stage DAG
against the simulator's.

Stats contract: geometry accounting (``sync_points`` / ``bytes_received``
/ ``redundant_elems`` / ``compute_stages``) is computed from the same
backward-chained rects as the local executor and is bit-identical to it;
measured ``stage_times`` / ``wall_s`` are instrumentation-only fields
excluded from ``ExecStats`` equality.

A 1-node plan degenerates to plain jitted programs on the first device —
no ``shard_map``, no collectives.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.graph import LayerSpec, ModelGraph
from repro.core.partition import DTYPE_BYTES, Scheme
from repro.core.plan import Plan, steps_segments
from repro.launch.mesh import make_nodes_mesh
from repro.obs import flight as _obs_flight
from repro.obs import trace as _obs_trace
from repro.runtime.engine import (BACKENDS, ExecStats, Rect, StageTime,
                                  _apply_record_b, _merge_comm_bytes,
                                  _rect_elems, _rect_isect,
                                  _segment_records, backward_chain,
                                  exact_regions, merge_tensors)

AXIS = "nodes"

#: terminal-stage-failure behaviours of ``run_partitioned_mesh``
FALLBACKS = ("raise", "local")


class StageFailure(RuntimeError):
    """Base of the mesh executor's fault exceptions (a dispatched pipeline
    stage did not complete)."""


class StageTimeoutError(StageFailure):
    """A stage exceeded ``stage_timeout_s``.  Timeouts are counted in
    ``ExecStats.timeouts`` but never retried — a wedged collective stays
    wedged, re-dispatching just stacks another stuck module on the pool."""


class StageDispatchError(StageFailure):
    """A stage dispatch raised and exhausted its ``stage_retries``
    re-attempts (each re-attempt is counted in ``ExecStats.retries``)."""


def _timeout_message(label: str, timeout_s: float, nodes: int) -> str:
    return (
        f"mesh stage {label!r} exceeded stage_timeout_s={timeout_s:g}s "
        f"({nodes} plan nodes). Likely causes, most common first: "
        f"(1) CPU host-platform thread-pool starvation — all fake devices "
        f"share one dispatch pool, so threads parked in one stage module's "
        f"collective rendezvous can starve another module's participants "
        f"(the known 'collective_ops_utils ... may be stuck' stall; reduce "
        f"XLA_FLAGS=--xla_force_host_platform_device_count or keep the "
        f"executor's serialized CPU dispatch enabled); "
        f"(2) first-call XLA compilation of a large stage program — warm "
        f"the program cache with one untimed run or raise the timeout; "
        f"(3) a genuinely lost device — pass fallback='local' to degrade "
        f"to the single-process engine instead of raising."
    )


#: compiled stage programs keyed by full static signature (mesh devices,
#: per-node record tuples, shapes, backend) — repeated blocks across a
#: model and repeated ``run_partitioned_mesh`` calls reuse one executable
_PROG_CACHE: Dict[tuple, object] = {}


def mesh_program_cache_info() -> Tuple[int, int]:
    """(entries, -1) — entry count of the mesh stage-program cache."""
    return (len(_PROG_CACHE), -1)


def clear_mesh_program_cache() -> None:
    _PROG_CACHE.clear()


# ---------------------------------------------------------------------------
# axis-generic helpers (InH splits rows, InW splits columns)
# ---------------------------------------------------------------------------

def _slc(x, a: int, b: int, axis: int):
    return x[a:b] if axis == 0 else x[:, a:b]

def _cat(parts, axis: int):
    parts = [p for p in parts if p.shape[axis] > 0]
    if len(parts) == 1:
        return parts[0]
    return jnp.concatenate(parts, axis=axis)

def _pad_dim(x, size: int, axis: int):
    if x.shape[axis] == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, size - x.shape[axis])
    return jnp.pad(x, widths)

def _pad3(x, shape3: Tuple[int, int, int]):
    widths = [(0, s - d) for d, s in zip(x.shape, shape3)]
    if all(w == (0, 0) for w in widths):
        return x
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# carried state between pipeline stages
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Full:
    """Boundary tensor replicated on every device."""

    arr: jnp.ndarray


@dataclasses.dataclass
class _Rows:
    """Sharded 1-D spatial layout: node ``n`` holds rows/cols
    ``ranges[n]`` of the boundary tensor (padded to ``pad``), plus the
    halo blocks received from its neighbors for the next segment."""

    block: jnp.ndarray                   # [N, pad, ...] sharded over AXIS
    axis: int                            # 0 = rows (InH), 1 = cols (InW)
    ranges: Tuple[Tuple[int, int], ...]
    up: Optional[jnp.ndarray]            # [N, h_up, ...] sharded
    dn: Optional[jnp.ndarray]            # [N, h_dn, ...]
    halo: Tuple[int, int]


@dataclasses.dataclass
class _Cells:
    """Sharded exact-region layout: node ``n`` owns ``cells[n]`` of the
    boundary tensor, zero-padded into a uniform stack."""

    stack: jnp.ndarray                   # [N, cmax, Rm, Cm, Chm] sharded
    cells: Tuple[Tuple[Rect, ...], ...]
    shape: Tuple[int, int, int]          # full boundary tensor shape


@dataclasses.dataclass(frozen=True)
class _CellProg:
    reg: Rect
    in_rect: Rect
    recs: tuple


@dataclasses.dataclass(frozen=True)
class _RowsPlan:
    """Permute-compatible boundary: per-node owned ranges plus the global
    halo sizes the ppermute exchange must carry."""

    axis: int
    ranges: Tuple[Tuple[int, int], ...]
    h_up: int
    h_dn: int


def _run_recs(recs, ws, x, backend: str):
    for rec, w in zip(recs, ws):
        x = _apply_record_b(rec, w, x, backend)
    return x


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------

class _MeshRun:
    def __init__(self, graph: ModelGraph, mesh, nodes: int, backend: str,
                 instrument: bool, overlap: bool, stats: ExecStats,
                 dtype, stage_timeout_s: Optional[float] = None,
                 stage_retries: int = 0,
                 fault_hook: Optional[Callable[[str, str, int],
                                               None]] = None) -> None:
        self.graph = graph
        self.mesh = mesh
        self.n = nodes
        self.backend = backend
        self.instrument = instrument
        self.overlap = overlap
        self.stats = stats
        self.dtype = dtype
        self.stage_timeout_s = stage_timeout_s
        self.stage_retries = stage_retries
        self.fault_hook = fault_hook
        # observability: tracer is cached once (None = tracing off, the
        # zero-overhead default); the flight ring is always on — deque
        # appends never touch numerics, so runs stay bit-identical
        self.tracer = _obs_trace.get_tracer()
        self.flight = _obs_flight.get_flight()
        self.mesh_key = tuple(int(d.id) for d in mesh.devices.flat) \
            if mesh is not None else (0,)
        # The host ("cpu") platform executes dispatched modules on one
        # shared thread pool: with many collective-bearing stage modules
        # in flight, threads parked in one module's collective rendezvous
        # can starve the participants of another (observed as
        # collective_ops_utils "may be stuck" stalls on deep models).
        # Serialize stage dispatches there; on real accelerator backends
        # per-device FIFO launch order makes async dispatch safe and the
        # pipeline stays in flight.
        self.serialize = (
            self.n > 1 and mesh is not None
            and mesh.devices.flat[0].platform == "cpu")

    # -- program cache ----------------------------------------------------

    def _cached(self, key: tuple, build):
        full_key = (self.mesh_key, self.backend, self.n, self.overlap) + key
        fn = _PROG_CACHE.get(full_key)
        if fn is None:
            fn = build()
            _PROG_CACHE[full_key] = fn
        return fn

    def _smap(self, fn, in_specs, out_specs):
        """jit(shard_map(fn)) over the nodes axis; plain jit at N == 1
        (degenerate plans bypass collectives entirely)."""
        if self.n == 1:
            return jax.jit(fn)
        return jax.jit(shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False))

    # -- dispatch + instrumentation ---------------------------------------

    def _dispatch(self, kind: str, label: str, fn, *args):
        """Run one pipeline stage with the fault policy: a stage that
        exceeds ``stage_timeout_s`` raises :class:`StageTimeoutError`
        (counted, never retried — see the class docstring); any other
        dispatch exception is re-attempted up to ``stage_retries`` times
        (each counted) before :class:`StageDispatchError`.  ``fault_hook``
        is a test seam called as ``(kind, label, attempt)`` before every
        attempt — raising from it injects a deterministic fault.

        Every dispatch rides the flight ring; terminal failures dump a
        postmortem artifact (``obs.flight.dump_postmortem`` — a no-op
        unless a postmortem directory is configured)."""
        attempt = 0
        self.flight.record("stage_dispatch", stage_kind=kind,
                           label=label)
        while True:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(kind, label, attempt)
                return self._execute(kind, label, fn, *args)
            except StageTimeoutError:
                self.stats.timeouts += 1
                self.flight.record("stage_timeout", stage_kind=kind,
                                   label=label,
                                   timeout_s=self.stage_timeout_s)
                _obs_flight.dump_postmortem(
                    "stage_timeout",
                    context={"kind": kind, "label": label,
                             "timeout_s": self.stage_timeout_s,
                             "nodes": self.n, "attempt": attempt})
                raise
            except StageFailure as exc:
                self.flight.record("stage_failure", stage_kind=kind,
                                   label=label)
                _obs_flight.dump_postmortem(
                    "stage_failure",
                    context={"kind": kind, "label": label,
                             "nodes": self.n, "attempt": attempt,
                             "error": repr(exc)})
                raise
            except Exception as exc:
                if attempt >= self.stage_retries:
                    self.flight.record("stage_dispatch_error",
                                       stage_kind=kind,
                                       label=label, attempts=attempt + 1)
                    _obs_flight.dump_postmortem(
                        "stage_dispatch_error",
                        context={"kind": kind, "label": label,
                                 "nodes": self.n,
                                 "attempts": attempt + 1,
                                 "stage_retries": self.stage_retries,
                                 "error": repr(exc)})
                    raise StageDispatchError(
                        f"mesh stage {label!r} failed after "
                        f"{attempt + 1} attempt(s) "
                        f"(stage_retries={self.stage_retries}): "
                        f"{exc!r}") from exc
                self.stats.retries += 1
                self.flight.record("stage_retry", stage_kind=kind,
                                   label=label,
                                   attempt=attempt)
                if self.tracer is not None:
                    self.tracer.instant(_obs_trace.CONTROL_TRACK,
                                        f"retry:{label}", cat="retry",
                                        attempt=attempt)
                attempt += 1

    def _watched(self, label: str, body):
        """Run ``body`` under the per-stage watchdog: a daemon worker
        thread does the (blocking) JAX work while this thread joins with
        ``stage_timeout_s``.  A stuck collective cannot be interrupted —
        on timeout the worker is abandoned (daemonized, so it cannot hang
        interpreter exit) and :class:`StageTimeoutError` surfaces."""
        timeout = self.stage_timeout_s
        if timeout is None:
            return body()
        box: Dict[str, object] = {}

        def worker():
            try:
                box["out"] = body()
            except BaseException as exc:    # noqa: BLE001 — re-raised
                box["err"] = exc

        th = threading.Thread(target=worker, daemon=True,
                              name=f"mesh-stage:{label}")
        th.start()
        th.join(timeout)
        if th.is_alive():
            raise StageTimeoutError(
                _timeout_message(label, timeout, self.n))
        if "err" in box:
            raise box["err"]
        return box["out"]

    def _execute(self, kind: str, label: str, fn, *args):
        timed = self.stage_timeout_s is not None
        if not self.instrument:
            def body():
                out = fn(*args)
                # async dispatch returns before the module runs — with a
                # watchdog armed the stage must block inside it or the
                # timeout would never observe the execution
                if self.serialize or timed:
                    jax.block_until_ready(out)
                return out
            return self._watched(label, body) if timed else body()

        def body():
            tr = self.tracer
            t0 = time.perf_counter()
            t0_us = tr.now_us() if tr is not None else 0.0
            out = fn(*args)
            dev_done: Tuple[float, ...] = ()
            lead = out[0] if isinstance(out, (tuple, list)) else out
            if kind == "compute" and self.n > 1 \
                    and hasattr(lead, "addressable_shards"):
                shards = sorted(lead.addressable_shards,
                                key=lambda s: s.index[0].start or 0)
                done = []
                for sh in shards:
                    sh.data.block_until_ready()
                    done.append(time.perf_counter() - t0)
                dev_done = tuple(done)
            jax.block_until_ready(out)
            wall = time.perf_counter() - t0
            self.stats.stage_times.append(
                StageTime(kind, label, wall, dev_done))
            if tr is not None:
                # one control-track stage span per StageTime row (the
                # 1:1 contract), plus a per-device span bounded by each
                # shard's completion time
                tr.add_complete(_obs_trace.CONTROL_TRACK, label, t0_us,
                                wall * 1e6, cat=_obs_trace.STAGE_CAT,
                                args={"kind": kind})
                for d, done_s in enumerate(dev_done):
                    tr.add_complete(_obs_trace.device_track(d), label,
                                    t0_us, done_s * 1e6, cat="device",
                                    args={"kind": kind})
            return out
        return self._watched(label, body) if timed else body()

    # -- boundary classification ------------------------------------------

    def _permute_plan(self, scheme: Scheme, regs_b, layers, a2: int,
                      b2: int, q2: Scheme) -> Optional[_RowsPlan]:
        """Neighbor-exchange eligibility of the boundary into segment
        ``[a2..b2]``: same 1-D spatial scheme on both sides and every
        node's next input rect contained in its own + immediate
        neighbors' ranges (equivalently: every range can donate the
        global halo strips)."""
        if self.n == 1 or scheme != q2 \
                or q2 not in (Scheme.INH, Scheme.INW):
            return None
        axis = 0 if q2 == Scheme.INH else 1
        ranges = tuple(cells[0][axis] for cells in regs_b)
        next_regs = exact_regions(layers[b2], q2, self.n)
        h_up = h_dn = 0
        for nd in range(self.n):
            _, in_rect = backward_chain(layers, a2, b2, next_regs[nd][0])
            i0, i1 = in_rect[axis]
            o0, o1 = ranges[nd]
            h_up = max(h_up, o0 - i0)
            h_dn = max(h_dn, i1 - o1)
        h_up, h_dn = max(h_up, 0), max(h_dn, 0)
        if min(r1 - r0 for r0, r1 in ranges) < max(h_up + h_dn, 1):
            return None
        return _RowsPlan(axis, ranges, h_up, h_dn)

    # -- entry assembly (inside a switch branch) --------------------------

    def _entry_slice(self, state_kind: str, entry_meta, nd: int,
                     in_rect: Rect, full, x_rows, u, d):
        """The halo-extended local input slice of node ``nd``'s segment
        program — from the replicated full tensor (gather path) or from
        own rows + received ppermute halos (permute path)."""
        if state_kind == "full":
            (r, c, _) = in_rect
            return full[r[0]:r[1], c[0]:c[1], :]
        axis, ranges, h_up, h_dn = entry_meta
        o0, o1 = ranges[nd]
        i0, i1 = in_rect[axis]
        ext = _cat([u, _slc(x_rows, 0, o1 - o0, axis), d], axis)
        return _slc(ext, i0 - (o0 - h_up), i1 - (o0 - h_up), axis)

    # -- compute stage: segment -> cells ----------------------------------

    def _seg_to_cells(self, label: str, weights_seg, state,
                      cellprogs: List[List[_CellProg]],
                      out_shape: Tuple[int, int, int]) -> _Cells:
        n = self.n
        cmax = max(len(ps) for ps in cellprogs)
        rm = cm = chm = 0
        for ps in cellprogs:
            for cp in ps:
                (r, c, ch) = cp.reg
                rm = max(rm, r[1] - r[0])
                cm = max(cm, c[1] - c[0])
                chm = max(chm, ch[1] - ch[0])
        pad_shape = (rm, cm, chm)
        state_kind, entry_meta, args = self._entry_args(state)
        backend = self.backend
        dtype = self.dtype

        def branch(nd):
            progs = cellprogs[nd]

            def run(full, x_rows, u, d, ws):
                outs = []
                for cp in progs:
                    xs = self._entry_slice(state_kind, entry_meta, nd,
                                           cp.in_rect, full, x_rows, u, d)
                    y = _run_recs(cp.recs, ws, xs, backend)
                    outs.append(_pad3(y, pad_shape))
                while len(outs) < cmax:
                    outs.append(jnp.zeros(pad_shape, dtype))
                return jnp.stack(outs)
            return run

        sig = ("seg2cells", state_kind, entry_meta, pad_shape, cmax,
               tuple(tuple(ps) for ps in cellprogs))

        def build():
            branches = [branch(nd) for nd in range(n)]
            if n == 1:
                def fn1(full, x_rows, u, d, ws):
                    return branches[0](full, x_rows, u, d, ws)[None]
                return self._smap(fn1, None, None)

            def fn(full, x_rows, u, d, ws):
                xr = None if x_rows is None else x_rows[0]
                uu = None if u is None else u[0]
                dd = None if d is None else d[0]
                idx = jax.lax.axis_index(AXIS)
                out = jax.lax.switch(
                    idx, [lambda f, xr, uu, dd, w, _br=br:
                          _br(f, xr, uu, dd, w) for br in branches],
                    full, xr, uu, dd, ws)
                return out[None]
            in_specs = (P(), P(AXIS), P(AXIS), P(AXIS), P())
            return self._smap(fn, in_specs, P(AXIS))
        prog = self._cached(sig, build)
        stack = self._dispatch("compute", label, prog, *args, weights_seg)
        cells = tuple(tuple(cp.reg for cp in ps) for ps in cellprogs)
        return _Cells(stack=stack, cells=cells, shape=out_shape)

    # -- compute stage: segment -> rows (+ overlapped halo exchange) ------

    def _seg_to_rows(self, label: str, bound_label: str, layers, a: int,
                     b: int, weights_seg, state,
                     cellprogs: List[List[_CellProg]],
                     rp: _RowsPlan) -> _Rows:
        n = self.n
        axis = rp.axis
        pad_out = max(r1 - r0 for r0, r1 in rp.ranges)
        state_kind, entry_meta, args = self._entry_args(state)
        backend = self.backend
        dtype = self.dtype
        lb = layers[b]
        other = (lb.out_w if axis == 0 else lb.out_h)
        strip_shape = ((rp.h_dn, other, lb.out_c) if axis == 0
                       else (other, rp.h_dn, lb.out_c))

        def strip_progs(nd):
            """(top, interior, bottom) record programs of node nd's region
            — border strips first, so the ppermute issued on them
            overlaps the interior compute (the double buffer)."""
            cp = cellprogs[nd][0]
            (r, c, ch) = cp.reg
            r0, r1 = cp.reg[axis]
            t1 = min(r0 + rp.h_dn, r1)
            b0 = max(r1 - rp.h_up, t1)
            out: List[Tuple[tuple, int]] = []
            for s0, s1 in ((r0, t1), (t1, b0), (b0, r1)):
                if s1 <= s0:
                    out.append((None, 0))
                    continue
                reg = tuple((s0, s1) if i == axis else cp.reg[i]
                            for i in range(3))
                need, _ = backward_chain(layers, a, b, reg)  # type: ignore
                out.append((_segment_records(layers, a, b, need,
                                             cp.in_rect), s1 - s0))
            return out

        use_overlap = self.overlap and (rp.h_up > 0 or rp.h_dn > 0)

        def branch(nd):
            cp = cellprogs[nd][0]
            strips = strip_progs(nd) if use_overlap else None

            def run(full, x_rows, u, d, ws):
                xs = self._entry_slice(state_kind, entry_meta, nd,
                                       cp.in_rect, full, x_rows, u, d)
                if strips is None:
                    y = _run_recs(cp.recs, ws, xs, backend)
                    top = _slc(y, 0, rp.h_dn, axis)
                    bot = _slc(y, y.shape[axis] - rp.h_up,
                               y.shape[axis], axis)
                    return (_pad_dim(y, pad_out, axis), top, bot)
                parts = []
                for recs, span in strips:
                    if recs is None:
                        sh = list(strip_shape)
                        sh[axis] = 0
                        parts.append(jnp.zeros(tuple(sh), dtype))
                    else:
                        parts.append(_run_recs(recs, ws, xs, backend))
                top, interior, bot = parts
                # sends are the full-height border strips (padded with
                # interior rows when a strip spans less than the halo)
                y = _cat([top, interior, bot], axis)
                send_up = _slc(y, 0, rp.h_dn, axis)
                send_dn = _slc(y, y.shape[axis] - rp.h_up,
                               y.shape[axis], axis)
                return (_pad_dim(y, pad_out, axis), send_up, send_dn)
            return run

        sig = ("seg2rows", state_kind, entry_meta, axis, pad_out,
               rp.ranges, rp.h_up, rp.h_dn, use_overlap,
               tuple(cellprogs[nd][0] for nd in range(n)))

        def build():
            branches = [branch(nd) for nd in range(n)]
            perm_dn = [(i, i + 1) for i in range(n - 1)]
            perm_up = [(i + 1, i) for i in range(n)[:-1]]

            def fn(full, x_rows, u, d, ws):
                xr = None if x_rows is None else x_rows[0]
                uu = None if u is None else u[0]
                dd = None if d is None else d[0]
                idx = jax.lax.axis_index(AXIS)
                y, send_up, send_dn = jax.lax.switch(
                    idx, [lambda f, xr, uu, dd, w, _br=br:
                          _br(f, xr, uu, dd, w) for br in branches],
                    full, xr, uu, dd, ws)
                if not use_overlap:
                    return (y[None],)
                up_recv = (jax.lax.ppermute(send_dn, AXIS, perm_dn)
                           if rp.h_up > 0 else send_dn[0:0] if axis == 0
                           else send_dn)
                dn_recv = (jax.lax.ppermute(send_up, AXIS, perm_up)
                           if rp.h_dn > 0 else send_up)
                return (y[None], up_recv[None], dn_recv[None])
            in_specs = (P(), P(AXIS), P(AXIS), P(AXIS), P())
            n_out = 3 if use_overlap else 1
            return self._smap(fn, in_specs, tuple([P(AXIS)] * n_out))
        prog = self._cached(sig, build)
        out = self._dispatch("compute", label, prog, *args, weights_seg)
        if use_overlap:
            block, up, dn = out
            return _Rows(block, axis, rp.ranges, up, dn,
                         (rp.h_up, rp.h_dn))
        block = out[0]
        # non-overlap mode: the exchange is its own sync stage, 1:1 with
        # the simulator's boundary stage
        up, dn = self._halo_sync_stage(bound_label, block, rp)
        return _Rows(block, axis, rp.ranges, up, dn, (rp.h_up, rp.h_dn))

    def _halo_sync_stage(self, label: str, block, rp: _RowsPlan):
        n = self.n
        axis = rp.axis
        pad = block.shape[1 + 0] if axis == 0 else block.shape[2]
        sig = ("halo_sync", axis, rp.ranges, rp.h_up, rp.h_dn,
               tuple(block.shape))

        def build():
            perm_dn = [(i, i + 1) for i in range(n - 1)]
            perm_up = [(i + 1, i) for i in range(n - 1)]

            def sends(nd):
                rn = rp.ranges[nd][1] - rp.ranges[nd][0]

                def run(x):
                    return (_slc(x, 0, rp.h_dn, axis),
                            _slc(x, rn - rp.h_up, rn, axis))
                return run

            def fn(blk):
                x = blk[0]
                idx = jax.lax.axis_index(AXIS)
                send_up, send_dn = jax.lax.switch(
                    idx, [lambda xx, _s=sends(nd): _s(xx)
                          for nd in range(n)], x)
                up_recv = (jax.lax.ppermute(send_dn, AXIS, perm_dn)
                           if rp.h_up > 0 else send_dn)
                dn_recv = (jax.lax.ppermute(send_up, AXIS, perm_up)
                           if rp.h_dn > 0 else send_up)
                return up_recv[None], dn_recv[None]
            return self._smap(fn, (P(AXIS),), (P(AXIS), P(AXIS)))
        del pad
        prog = self._cached(sig, build)
        return self._dispatch("sync", label, prog, block)

    # -- sync stage: cells -> replicated full -----------------------------

    def _gather_stage(self, label: str, state: _Cells) -> _Full:
        n = self.n
        cells = state.cells
        shape = state.shape
        dtype = self.dtype
        sig = ("gather", cells, shape, tuple(state.stack.shape))

        def build():
            def rebuild(allc):
                full = jnp.zeros(shape, dtype)
                for nd in range(n):
                    for j, (r, c, ch) in enumerate(cells[nd]):
                        dr, dc, dch = (r[1] - r[0], c[1] - c[0],
                                       ch[1] - ch[0])
                        if dr <= 0 or dc <= 0 or dch <= 0:
                            continue
                        full = full.at[r[0]:r[1], c[0]:c[1],
                                       ch[0]:ch[1]].set(
                            allc[nd, j, :dr, :dc, :dch])
                return full
            if n == 1:
                return jax.jit(rebuild)

            def fn(stack):
                return rebuild(jax.lax.all_gather(stack[0], AXIS))
            return self._smap(fn, (P(AXIS),), P())
        prog = self._cached(sig, build)
        return _Full(self._dispatch("sync", label, prog, state.stack))

    # -- merge stages ------------------------------------------------------

    def _merge_stages(self, l_m: LayerSpec, prods: Sequence[int],
                      outs: Dict[int, object], x_full) -> _Full:
        """One sync stage gathering every producer's shards (the
        simulator's single per-merge delivery stage) followed by the merge
        layer's own singleton compute stage."""
        n = self.n
        shapes = []
        stacks = []
        metas = []
        for pid in prods:
            if pid == -1:
                metas.append(None)
                shapes.append(tuple(x_full.shape))
            else:
                st = outs[pid]
                assert isinstance(st, _Cells)
                metas.append((st.cells, st.shape))
                shapes.append(st.shape)
                stacks.append(st.stack)
        dtype = self.dtype
        sig = ("merge", tuple(metas), tuple(shapes))

        def build():
            def rebuild(meta, allc):
                cells, shape = meta
                full = jnp.zeros(shape, dtype)
                for nd in range(n):
                    for j, (r, c, ch) in enumerate(cells[nd]):
                        dr, dc, dch = (r[1] - r[0], c[1] - c[0],
                                       ch[1] - ch[0])
                        if dr <= 0 or dc <= 0 or dch <= 0:
                            continue
                        full = full.at[r[0]:r[1], c[0]:c[1],
                                       ch[0]:ch[1]].set(
                            allc[nd, j, :dr, :dc, :dch])
                return full

            def core(x_rep, stks):
                fulls = []
                it = iter(stks)
                for meta in metas:
                    if meta is None:
                        fulls.append(x_rep)
                    else:
                        s = next(it)
                        allc = (s[0] if n == 1
                                else jax.lax.all_gather(s[0], AXIS))
                        if n == 1:
                            allc = s[0] if s.ndim == 5 else s
                        fulls.append(rebuild(meta, allc))
                return tuple(fulls)
            if n == 1:
                def fn1(x_rep, stks):
                    fulls = []
                    it = iter(stks)
                    for meta in metas:
                        if meta is None:
                            fulls.append(x_rep)
                        else:
                            fulls.append(rebuild(meta, next(it)))
                    return tuple(fulls)
                return jax.jit(fn1)

            def fn(x_rep, stks):
                return core(x_rep, stks)
            return self._smap(fn, (P(), P(AXIS)),
                              tuple([P()] * len(metas)))
        prog = self._cached(sig, build)
        fulls = self._dispatch("sync", f"merge->{l_m.name}", prog,
                               x_full, tuple(stacks))

        msig = ("merge_apply", l_m.conv_t, tuple(shapes))

        def mbuild():
            def fn(fulls_in):
                return merge_tensors(l_m, list(fulls_in))
            return jax.jit(fn)
        mprog = self._cached(msig, mbuild)
        merged = self._dispatch(
            "compute", f"seg[{l_m.name}..{l_m.name}]", mprog, fulls)
        return _Full(merged)

    # -- plumbing ----------------------------------------------------------

    def _entry_args(self, state):
        """(state_kind, static entry meta, traced args) of a compute
        stage.  Traced args are always the 4-tuple (full, rows, up, dn)
        with the unused ones None, so every stage shares one signature."""
        if isinstance(state, _Full):
            return "full", None, (state.arr, None, None, None)
        assert isinstance(state, _Rows)
        meta = (state.axis, state.ranges) + state.halo
        return "rows", meta, (None, state.block, state.up, state.dn)

    # -- branch execution --------------------------------------------------

    def run_branch(self, layers: Sequence[LayerSpec], weights,
                   steps, state, owned):
        segs = steps_segments(list(steps))
        regs_b = None
        for si, (a, b) in enumerate(segs):
            scheme = steps[a][0]
            lb = layers[b]
            regs_b = exact_regions(lb, scheme, self.n)
            cellprogs: List[List[_CellProg]] = []
            computed = 0
            for nd, cells in enumerate(regs_b):
                ps = []
                for reg in cells:
                    need, in_rect = backward_chain(layers, a, b, reg)
                    if owned is not None:
                        held = sum(_rect_elems(_rect_isect(in_rect, o))
                                   for o in owned[nd])
                        self.stats.bytes_received += DTYPE_BYTES * (
                            _rect_elems(in_rect) - held)
                    for li in range(a, b):
                        computed += _rect_elems(need[li])
                    ps.append(_CellProg(
                        reg, in_rect,
                        _segment_records(layers, a, b, need, in_rect)))
                cellprogs.append(ps)
            self.stats.sync_points += 1
            self.stats.redundant_elems += float(computed)
            self.stats.compute_stages += 1
            label = f"seg[{layers[a].name}..{layers[b].name}]"

            rows_plan = None
            if si + 1 < len(segs):
                a2, b2 = segs[si + 1]
                rows_plan = self._permute_plan(scheme, regs_b, layers,
                                               a2, b2, steps[a2][0])
            ws = tuple(weights[a:b + 1])
            out_shape = (lb.out_h, lb.out_w, lb.out_c)
            if rows_plan is None:
                state = self._seg_to_cells(label, ws, state, cellprogs,
                                           out_shape)
                if si + 1 < len(segs):
                    state = self._gather_stage(f"bound@{lb.name}", state)
            else:
                state = self._seg_to_rows(label, f"bound@{lb.name}",
                                          layers, a, b, ws, state,
                                          cellprogs, rows_plan)
            owned = regs_b
        assert regs_b is not None, "branch must contain >= 1 segment"
        return state, owned


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def _run_degraded(graph: ModelGraph, weights, x, plan: Plan, nodes: int,
                  backend: str, stats: ExecStats
                  ) -> Tuple[jnp.ndarray, ExecStats]:
    """Degraded single-process fallback: execute the plan's shard
    programs host-side (``runtime.engine`` local executor — no devices
    needed) and carry the mesh run's failure counters over so
    ``ExecStats.failure_count`` (and through it
    ``MeasuredOccupancy.failures``) records the degradation."""
    from repro.runtime import engine as _engine
    _obs_flight.get_flight().record("fallback_local",
                                    graph=graph.name, nodes=nodes)
    out, local_stats = _engine._run_partitioned_local(
        graph, weights, x, plan, nodes, backend=backend)
    local_stats.retries = stats.retries
    local_stats.timeouts = stats.timeouts
    local_stats.fallbacks = stats.fallbacks + 1
    return out, local_stats


def run_partitioned_mesh(graph: ModelGraph, weights, x: jnp.ndarray,
                         plan: Plan, nodes: int, *,
                         backend: str = "xla", mesh=None,
                         instrument: bool = False,
                         overlap: bool = True,
                         stage_timeout_s: Optional[float] = None,
                         stage_retries: int = 0,
                         fallback: str = "raise",
                         fault_hook: Optional[Callable[[str, str, int],
                                                       None]] = None
                         ) -> Tuple[jnp.ndarray, ExecStats]:
    """Execute ``plan`` on a real JAX device mesh — one device per plan
    node.  See the module docstring for the stage/collective model.
    Returns the reassembled full output (replicated) and ``ExecStats``
    whose geometry accounting equals the local executor's; with
    ``instrument=True`` the stats additionally carry measured per-stage
    wall times (run twice and read the second run's stats — the first
    call pays compilation).

    Fault handling: ``stage_timeout_s`` arms a per-stage watchdog (the
    timeout covers first-call compilation — warm the program cache or
    budget for it); ``stage_retries`` bounds re-dispatches of a failed
    stage; ``fallback="local"`` degrades to the single-process engine
    instead of raising when the backing platform has fewer devices than
    the plan needs (mesh shrink) or a stage fails terminally.
    ``fault_hook(kind, label, attempt)`` is called before every stage
    attempt — a test seam for deterministic fault injection.
    ``ExecStats.retries/timeouts/fallbacks`` record what happened."""
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r} not in {BACKENDS}")
    if nodes < 1:
        raise ValueError(f"nodes must be >= 1, got {nodes}")
    if fallback not in FALLBACKS:
        raise ValueError(f"fallback {fallback!r} not in {FALLBACKS}")
    if stage_retries < 0:
        raise ValueError(f"stage_retries must be >= 0, got {stage_retries}")
    if stage_timeout_s is not None and stage_timeout_s <= 0:
        raise ValueError(
            f"stage_timeout_s must be > 0, got {stage_timeout_s}")
    stats = ExecStats()
    if mesh is None and nodes > 1 and fallback == "local" \
            and len(jax.devices()) < nodes:
        # mesh shrink: the plan wants more devices than the platform has
        # left — degrade instead of failing make_nodes_mesh
        return _run_degraded(graph, weights, x, plan, nodes, backend,
                             stats)
    if mesh is None:
        mesh = make_nodes_mesh(nodes) if nodes > 1 else None
    if mesh is not None:
        if AXIS not in mesh.shape or mesh.shape[AXIS] != nodes or \
                len(mesh.shape) != 1:
            raise ValueError(
                f"mesh must be 1-D over axis {AXIS!r} with size {nodes}, "
                f"got {dict(mesh.shape)}")
    run = _MeshRun(graph, mesh, nodes, backend, instrument, overlap,
                   stats, x.dtype, stage_timeout_s, stage_retries,
                   fault_hook)
    try:
        return _mesh_body(run, graph, weights, x, plan, nodes, stats)
    except StageFailure:
        if fallback != "local":
            raise
        return _run_degraded(graph, weights, x, plan, nodes, backend,
                             stats)


def _mesh_body(run: _MeshRun, graph: ModelGraph, weights, x, plan: Plan,
               nodes: int, stats: ExecStats
               ) -> Tuple[jnp.ndarray, ExecStats]:
    t0 = time.perf_counter()

    if graph.is_chain:
        plan.validate()
        if len(plan) != len(graph):
            raise ValueError("plan/graph length mismatch")
        state, _ = run.run_branch(graph.layers, weights, plan.steps,
                                  _Full(x), None)
        out = run._gather_stage("gather", state).arr
        jax.block_until_ready(out)
        stats.wall_s = time.perf_counter() - t0
        return out, stats

    plan.validate_for(graph)
    layers = graph.layers
    outs: Dict[int, object] = {}
    owned_map: Dict[int, Optional[List[List[Rect]]]] = {-1: None}
    final = None
    for br in graph.linearize():
        ids = list(br.ids)
        head = ids[0]
        prods = graph.producer_ids[head]
        if len(prods) >= 2:
            l_m = layers[head]
            q = plan.steps[head][0]
            regs = exact_regions(l_m, q, nodes)
            stats.sync_points += 1
            stats.compute_stages += 1
            stats.bytes_received += _merge_comm_bytes(
                l_m, prods,
                [layers[p].out_c if p >= 0 else layers[0].in_c
                 for p in prods],
                owned_map, regs)
            cur = run._merge_stages(l_m, prods, outs, x)
            owned = regs
            rest = ids[1:]
        else:
            src = prods[0]
            if src == -1:
                cur, owned = _Full(x), None
            else:
                tail = outs[src]
                assert isinstance(tail, _Cells)
                cur = run._gather_stage(f"fork->{layers[head].name}",
                                        tail)
                owned = owned_map[src]
            rest = ids
        if rest:
            ls = [layers[i] for i in rest]
            ws = [weights[i] for i in rest]
            st = [plan.steps[i] for i in rest]
            cur, owned = run.run_branch(ls, ws, st, cur, owned)
        if isinstance(cur, _Full):
            # merge-only branch (no trailing layers): keep replicated;
            # re-shard into the merge layout for downstream consumers
            cur = _full_to_cells(run, cur, owned,
                                 (layers[ids[-1]].out_h,
                                  layers[ids[-1]].out_w,
                                  layers[ids[-1]].out_c))
        elif isinstance(cur, _Rows):
            raise AssertionError("branch tails always exit as cells")
        outs[ids[-1]] = cur
        owned_map[ids[-1]] = owned
        if not graph.consumer_ids[ids[-1]]:
            final = run._gather_stage("gather", cur)
    assert final is not None
    out = final.arr
    jax.block_until_ready(out)
    stats.wall_s = time.perf_counter() - t0
    return out, stats


def _full_to_cells(run: _MeshRun, state: _Full, owned,
                   shape: Tuple[int, int, int]) -> _Cells:
    """Re-shard a replicated tensor into its owned layout (merge-only
    branches: the merged tensor is replicated but downstream consumers
    expect the branch tail in shard form).  Pure slicing — no collective,
    each device takes its own cells."""
    n = run.n
    cells = tuple(tuple(c for c in owned[nd]) for nd in range(n))
    rm = cm = chm = 0
    for ps in cells:
        for (r, c, ch) in ps:
            rm = max(rm, r[1] - r[0])
            cm = max(cm, c[1] - c[0])
            chm = max(chm, ch[1] - ch[0])
    cmax = max(len(ps) for ps in cells)
    pad_shape = (rm, cm, chm)
    dtype = run.dtype
    sig = ("reshard", cells, pad_shape, cmax, shape)

    def build():
        def branch(nd):
            def f(full):
                outs = [_pad3(full[r[0]:r[1], c[0]:c[1], ch[0]:ch[1]],
                              pad_shape)
                        for (r, c, ch) in cells[nd]]
                while len(outs) < cmax:
                    outs.append(jnp.zeros(pad_shape, dtype))
                return jnp.stack(outs)
            return f
        branches = [branch(nd) for nd in range(n)]
        if n == 1:
            return jax.jit(lambda full: branches[0](full)[None])

        def fn(full):
            idx = jax.lax.axis_index(AXIS)
            return jax.lax.switch(idx, branches, full)[None]
        return run._smap(fn, (P(),), P(AXIS))
    prog = run._cached(sig, build)
    stack = run._dispatch("sync", "reshard", prog, state.arr)
    return _Cells(stack=stack, cells=cells, shape=shape)


# ---------------------------------------------------------------------------
# stage-decomposition validation against the simulator
# ---------------------------------------------------------------------------

def validate_stage_decomposition(stats: ExecStats, stages) -> dict:
    """Compare the measured stage DAG (mesh executor with
    ``instrument=True, overlap=False``) against
    ``cluster.simsched.build_stages``: the (kind, label) multisets must
    match 1:1 (the PR 4 stage-decomposition contract made physical);
    per-stage durations are paired up for inspection but never asserted
    here — CPU host devices share cores, so wall times are advisory
    (the bench records them with a documented noise tolerance).

    Two documented physical-vs-model equivalences are applied before
    comparing:

    * ``reshard`` stages (merge-only branch re-sharding, a pure local
      slice) are ignored — the simulator has no counterpart because
      they move no bytes;
    * a sim ``bound@X`` where ``X`` is a merge layer is *subsumed* by
      the measured ``merge->X`` stage — the mesh merge gather leaves the
      merged tensor replicated on every device, so the simulator's
      post-merge distribution boundary has no separate physical stage
      (its bytes already traveled in the ``all_gather``).  Subsumed
      stages are reported in ``subsumed``, not ``missing``."""
    from collections import Counter
    meas = Counter((s.kind, s.label) for s in stats.stage_times
                   if s.label != "reshard")
    sim = Counter((s.kind, s.label) for s in stages)
    merge_names = {s.label[len("merge->"):] for s in stages
                   if s.kind == "sync" and s.label.startswith("merge->")}
    subsumed = []
    for name in merge_names:
        key = ("sync", f"bound@{name}")
        k = sim[key] - meas[key]
        if k > 0:
            sim[key] -= k
            subsumed.extend([key] * k)
    missing = sorted((sim - meas).elements())
    extra = sorted((meas - sim).elements())
    per_stage = []
    meas_by = {}
    for s in stats.stage_times:
        meas_by.setdefault((s.kind, s.label), []).append(s.wall_s)
    for s in stages:
        walls = meas_by.get((s.kind, s.label), [])
        per_stage.append({
            "kind": s.kind, "label": s.label,
            "sim_s": max(s.durations) if s.durations else 0.0,
            "measured_s": walls.pop(0) if walls else None,
        })
    return {"structure_match": not missing and not extra,
            "missing": missing, "extra": extra,
            "subsumed": sorted(subsumed), "stages": per_stage}
