"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable, Tuple

from repro.core import AnalyticEstimator, Testbed
from repro.configs.edge_models import EDGE_MODELS

EST = AnalyticEstimator()


def time_call(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out   # us


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
