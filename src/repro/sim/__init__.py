"""Edge-testbed simulator: the stand-in for the paper's SRIO DSP cluster."""
from .trace import (HETERO_PRESETS, TraceConfig, generate_i_traces,
                    generate_s_traces, hetero_trace_config, train_estimators)

__all__ = ["HETERO_PRESETS", "TraceConfig", "generate_i_traces",
           "generate_s_traces", "hetero_trace_config", "train_estimators"]
