"""Sharding rules: every emitted PartitionSpec must divide its tensor, for
every architecture x strategy x mode, on a production-shaped (4,4) proxy
mesh (same divisibility structure as (16,16) scaled down for CPU)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.transformer import Model
from repro.runtime.shard_plan import (Strategy, cache_specs,
                                      param_specs)


class FakeMesh:
    """Axis-size lookup stand-in (no devices needed for spec validation)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _check_specs(specs, shapes, mesh):
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_t = jax.tree.leaves(shapes)
    assert len(flat_s) == len(flat_t)
    for spec, leaf in zip(flat_s, flat_t):
        shape = tuple(leaf.shape)
        for dim, axes in zip(shape, tuple(spec)):
            if axes is None:
                continue
            assert dim % _axis_size(mesh, axes) == 0, (shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("st", [
    Strategy(attn="tp", ffn="tp", moe="ep"),
    Strategy(attn="sp", ffn="sp", moe="tp"),
    Strategy(attn="tp", ffn="tp", fsdp=False, decode_resident=True),
])
def test_param_specs_divisible(arch, st):
    cfg = get_config(arch)
    model = Model(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    for mesh in (MESH, MESH_MP):
        specs = param_specs(params_shape, mesh, st, mode="train")
        _check_specs(specs, params_shape, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    model = Model(cfg)
    cache_shape = jax.eval_shape(lambda: model.cache_init(128, 4096))
    specs = cache_specs(cache_shape, MESH, Strategy())
    _check_specs(specs, cache_shape, MESH)


def test_opt_state_inherits_param_specs():
    from repro.runtime.shard_plan import opt_specs
    cfg = get_config("olmo-1b")
    model = Model(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_spec = param_specs(params_shape, MESH, Strategy(), "train")
    o_spec = opt_specs(p_spec, params_shape)
    assert o_spec["m"] is p_spec and o_spec["v"] is p_spec
    assert o_spec["step"] == P()


def test_planner_strategy_feasible_everywhere():
    """choose_strategy must return divisibility-feasible choices."""
    from repro.runtime.planner import choose_strategy
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for mode in ("train", "prefill", "decode"):
            st = choose_strategy(cfg, MESH, mode)
            assert st.attn in ("tp", "sp") and st.ffn in ("tp", "sp")
            if cfg.moe and cfg.moe.n_experts % 16 != 0:
                assert st.moe == "tp"
