"""Synthetic LM data pipeline.

Deterministic, seekable token stream (numpy PRNG keyed by (seed, step)) so
every host in a multi-host launch can materialize its own shard of the
global batch without communication: host h takes rows
``[h*B/nhosts, (h+1)*B/nhosts)`` of the global batch — the standard
data-parallel input pattern.  Tokens follow a Zipfian distribution with a
Markov bigram structure, so the training loss has real signal to descend.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np
import jax.numpy as jnp
from jax import ShapeDtypeStruct


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self) -> None:
        assert self.global_batch % self.n_hosts == 0
        rng = np.random.default_rng(self.seed + 12345)
        # fixed Zipf unigram + low-rank bigram mixing table
        ranks = np.arange(1, self.vocab + 1)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._shift = rng.integers(1, self.vocab, size=(257,))

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Local shard of the global batch for ``step`` (seekable)."""
        rng = np.random.default_rng(
            (self.seed, step, self.host_id, 0xBEEF))
        b = self.local_batch
        toks = rng.choice(self.vocab, size=(b, self.seq_len + 1),
                          p=self._unigram).astype(np.int32)
        # Markov structure: token[t+1] correlates with token[t]
        mask = rng.random((b, self.seq_len)) < 0.5
        nxt = (toks[:, :-1] + self._shift[toks[:, :-1] % 257]) % self.vocab
        toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_specs(cfg, seq_len: int, global_batch: int,
                     *, mode: str = "train") -> Dict[str, ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run pattern:
    weak-type-correct, shardable, no device allocation)."""
    i32 = jnp.int32
    if mode == "decode":
        out = {"tokens": ShapeDtypeStruct((global_batch, 1), i32)}
        return out
    out = {"tokens": ShapeDtypeStruct((global_batch, seq_len), i32)}
    if mode == "train":
        out["labels"] = ShapeDtypeStruct((global_batch, seq_len), i32)
    if cfg.family == "vlm":
        out["vision_embeds"] = ShapeDtypeStruct(
            (global_batch, cfg.vision_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        out["audio_embeds"] = ShapeDtypeStruct(
            (global_batch, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return out
