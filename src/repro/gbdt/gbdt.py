"""Gradient-boosted decision trees (squared error) — XGBoost stand-in."""
from __future__ import annotations

import io
from typing import List, Optional

import numpy as np

from .tree import RegressionTree


class GBDTRegressor:
    def __init__(self, n_estimators: int = 120, learning_rate: float = 0.15,
                 max_depth: int = 6, min_child_weight: float = 2.0,
                 reg_lambda: float = 1.0, n_bins: int = 64,
                 subsample: float = 0.9, seed: int = 0):
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_depth = max_depth
        self.min_child_weight = min_child_weight
        self.reg_lambda = reg_lambda
        self.n_bins = n_bins
        self.subsample = subsample
        self.seed = seed
        self.base_: float = 0.0
        self.trees_: List[RegressionTree] = []

    # ---- binning ----------------------------------------------------------
    def _make_bins(self, x: np.ndarray) -> List[np.ndarray]:
        edges = []
        qs = np.linspace(0, 1, self.n_bins + 1)[1:-1]
        for f in range(x.shape[1]):
            e = np.unique(np.quantile(x[:, f], qs))
            edges.append(e)
        return edges

    @staticmethod
    def _bin(x: np.ndarray, edges: List[np.ndarray]) -> np.ndarray:
        out = np.empty(x.shape, dtype=np.int32)
        for f, e in enumerate(edges):
            out[:, f] = np.searchsorted(e, x[:, f], side="left")
        return out

    # ---- fit / predict ----------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray,
            eval_set=None, verbose_every: int = 0) -> "GBDTRegressor":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        rng = np.random.default_rng(self.seed)
        edges = self._make_bins(x)
        binned = self._bin(x, edges)
        self.base_ = float(y.mean())
        pred = np.full_like(y, self.base_)
        self.trees_ = []
        hess = np.ones_like(y)
        for t in range(self.n_estimators):
            grad = pred - y
            if self.subsample < 1.0:
                m = rng.random(len(y)) < self.subsample
                tree = RegressionTree(self.max_depth, self.min_child_weight,
                                      self.reg_lambda).fit(
                    binned[m], edges, grad[m], hess[m])
            else:
                tree = RegressionTree(self.max_depth, self.min_child_weight,
                                      self.reg_lambda).fit(
                    binned, edges, grad, hess)
            upd = tree.predict(x)
            pred += self.learning_rate * upd
            self.trees_.append(tree)
            if verbose_every and (t + 1) % verbose_every == 0:
                msg = f"[gbdt] tree {t+1}: train_rmse={np.sqrt(np.mean((pred-y)**2)):.4f}"
                if eval_set is not None:
                    ex, ey = eval_set
                    ep = self.predict(ex)
                    msg += f" eval_rmse={np.sqrt(np.mean((ep-ey)**2)):.4f}"
                print(msg)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        out = np.full(x.shape[0], self.base_)
        for tree in self.trees_:
            out += self.learning_rate * tree.predict(x)
        return out

    # ---- persistence (npz) -------------------------------------------------
    def save(self, path: str) -> None:
        flat = {"base": np.array([self.base_]),
                "lr": np.array([self.learning_rate]),
                "n_trees": np.array([len(self.trees_)])}
        for i, tr in enumerate(self.trees_):
            arr = np.array([[n.feature, n.threshold, n.left, n.right, n.value,
                             1.0 if n.is_leaf else 0.0] for n in tr.nodes])
            flat[f"tree_{i}"] = arr
        np.savez_compressed(path, **flat)

    @classmethod
    def load(cls, path: str) -> "GBDTRegressor":
        data = np.load(path)
        obj = cls(n_estimators=int(data["n_trees"][0]),
                  learning_rate=float(data["lr"][0]))
        obj.base_ = float(data["base"][0])
        obj.trees_ = []
        from .tree import _Node
        for i in range(int(data["n_trees"][0])):
            arr = data[f"tree_{i}"]
            tr = RegressionTree()
            tr.nodes = [
                _Node(feature=int(r[0]), threshold=float(r[1]), left=int(r[2]),
                      right=int(r[3]), value=float(r[4]), is_leaf=r[5] > 0.5)
                for r in arr]
            obj.trees_.append(tr)
        return obj
