"""Batched cost paths vs the scalar reference: bit-parity everywhere.

The planner's correctness story is layered: (1) each estimator's
``*_cost_batch`` bit-matches its scalar protocol, (2) the cost tables hold
exactly those values, (3) the batched DP replicates the scalar
tie-breaking.  These tests pin layer (1) and (2); ``test_dpp``/``test_dag``
pin layer (3) end to end.
"""
import random

import numpy as np
import pytest

from repro.core import (ALL_SCHEMES, AnalyticEstimator, PrefetchedEstimator,
                        Scheme, Testbed, build_chain_tables, chain,
                        plan_cost, plan_feasible)
from repro.core.estimator import i_features, s_features
from repro.core.exhaustive import enumerate_plans
from repro.core.graph import halo_growth
from repro.sim.trace import TraceConfig, _random_layer, _random_testbed

EST = AnalyticEstimator()


def _sample_cases(n, seed=0):
    rng = np.random.default_rng(seed)
    cfg = TraceConfig()
    for _ in range(n):
        layer = _random_layer(rng)
        tb = _random_testbed(rng, cfg)
        yield rng, layer, tb


def test_analytic_i_batch_bit_matches_scalar():
    rows, factors, want = [], [], []
    for rng, layer, tb in _sample_cases(600):
        scheme = Scheme(int(rng.integers(0, 4)))
        halo = int(rng.integers(1, 5)) if (scheme.spatial
                                           and rng.random() < 0.5) else 0
        rows.append(i_features(layer, scheme, tb, halo))
        factors.append(layer.extra_flop_factor)
        want.append(EST.i_cost(layer, scheme, tb, extra_halo=halo))
    got = EST.i_cost_batch(np.asarray(rows), Testbed(), np.asarray(factors))
    assert np.array_equal(got, np.asarray(want))


def test_analytic_s_batch_bit_matches_scalar():
    rows, want = [], []
    for rng, layer, tb in _sample_cases(600, seed=1):
        src = Scheme(int(rng.integers(0, 4)))
        if rng.random() < 0.15:
            nxt, dst = None, None
        else:
            nxt = _random_layer(rng)
            dst = Scheme(int(rng.integers(0, 4)))
        rows.append(s_features(layer, nxt, src, dst, tb))
        want.append(EST.s_cost(layer, nxt, src, dst, tb))
    got = EST.s_cost_batch(np.asarray(rows), Testbed())
    assert np.array_equal(got, np.asarray(want))


def _rand_chain(rng, n):
    from repro.core.graph import ConvT, LayerSpec
    layers = []
    h, c = rng.choice([14, 28, 56]), rng.choice([16, 32])
    for i in range(n):
        t = rng.choice([ConvT.CONV, ConvT.POINTWISE, ConvT.DWCONV])
        k, s, p = {ConvT.CONV: (3, 1, 1), ConvT.POINTWISE: (1, 1, 0),
                   ConvT.DWCONV: (3, 1, 1)}[t]
        cout = c if t == ConvT.DWCONV else rng.choice([c, 2 * c])
        layers.append(LayerSpec(f"l{i}", t, h, h, c, cout, k, s, p))
        h, c = layers[-1].out_h, cout
    return chain("rand", layers)


@pytest.mark.parametrize("seed", range(4))
def test_chain_tables_hold_scalar_values(seed):
    """Every finite ``seg`` entry equals the scalar i-cost sum; every
    boundary entry equals the scalar s-cost."""
    rng = random.Random(seed)
    g = _rand_chain(rng, rng.randint(3, 8))
    tb = Testbed(nodes=rng.choice([3, 4, 5]))
    tbl, _, _ = build_chain_tables(g.layers, EST, tb, ALL_SCHEMES,
                                   max_segment=32, allow_fusion=True)
    n = len(g.layers)
    for i in range(n):
        for pi, p in enumerate(ALL_SCHEMES):
            for L in range(tbl.seg.shape[2]):
                v = tbl.seg[i, pi, L]
                if v == float("inf"):
                    continue
                b = i + L
                halos = halo_growth(g.layers[i:b + 1], L)
                want = 0.0
                for off, m in enumerate(range(i, b + 1)):
                    want += EST.i_cost(g.layers[m], p, tb,
                                       extra_halo=halos[off] if L else 0)
                assert v == want
    for b in range(n - 1):
        for pi, p in enumerate(ALL_SCHEMES):
            for qi, q in enumerate(ALL_SCHEMES):
                assert tbl.sbound[b, pi, qi] == \
                    EST.s_cost(g.layers[b], g.layers[b + 1], p, q, tb)
    for pi, p in enumerate(ALL_SCHEMES):
        assert tbl.s_final[pi] == EST.s_cost(g.layers[-1], None, p, None, tb)


def test_prefetched_estimator_scores_plans_exactly():
    rng = random.Random(7)
    g = _rand_chain(rng, 4)
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    pf = PrefetchedEstimator.for_graph(g, EST, tb)
    checked = 0
    for plan in enumerate_plans(len(g)):
        if not plan_feasible(g, plan, tb.nodes):
            continue
        assert plan_cost(g, plan, pf, tb) == plan_cost(g, plan, EST, tb)
        checked += 1
    assert checked > 50
