"""Simulator-in-the-loop plan refinement — close the loop between the
analytic pipelined-cost DP and the discrete-event schedule.

The analytic frontier scores a plan as ``(compute, sync)`` occupancy sums
built from per-stage straggler maxes and busiest-link bounds.  On
heterogeneous clusters and DAGs those are upper bounds: the straggler
device can differ per layer, parallel-branch transfers overlap on
different links, and the greedy schedule can hide more (or less) than the
two-class model assumes.  The simulator measures the truth: per-device
and per-link busy seconds of the actual pipelined schedule.

The key observation that makes refinement cheap: re-weighting the DP's
segment costs by a per-class factor (``beta`` on every i-cost, ``alpha``
on every s-cost) rescales the frontier axes but cannot change the
*nondominated set* — a pair dominated under one positive scaling is
dominated under all of them.  So the refinement loop never rebuilds
tables or re-runs the DP; it re-selects a point on the cached frontier
(built with ``prune_ub=False`` so the set is complete — the latency-
optimum cutoff ``plan_search`` uses is only exact for unscaled
selection):

1. pick the point minimizing ``max(beta*compute, alpha*sync)``
   (initially ``beta = alpha = 1``);
2. simulate its plan; measure per-request bottleneck occupancy of each
   resource class (``max_d device_busy / requests``, same for links);
3. set ``beta``/``alpha`` to the measured-over-analytic ratios and repeat
   until the selected point stops moving (a fixed point) or a selection
   repeats (a cycle — keep the simulator-best iterate).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.dpp import Objective, PlanFrontier, pipeline_frontier
from repro.core.graph import ModelGraph
from repro.core.partition import ALL_SCHEMES, Scheme
from repro.core.plan import Plan
from repro.obs import flight as _obs_flight
from repro.obs import metrics as _obs_metrics

from .estimator import ClusterAnalyticEstimator
from .simsched import SimReport, simulate
from .spec import ClusterSpec


class RefineOscillationError(RuntimeError):
    """The scaled re-selection entered a cycle (A -> B -> A -> ...)
    without reaching a fixed point: the measured occupancy ratios
    disagree with the analytic axes in a way no single ``(beta, alpha)``
    reweighting resolves.  Raised only under ``on_oscillation="raise"``;
    the default ``"best"`` accepts the simulator-best iterate instead."""


@dataclasses.dataclass(frozen=True)
class RefineStep:
    """One iterate: the frontier point tried and what the simulator saw."""

    point_idx: int
    compute_s: float          # analytic axis values of the tried point
    sync_s: float
    beta: float               # compute-axis weight used for this selection
    alpha: float              # sync-axis weight
    sim_throughput_rps: float
    sim_period_s: float       # 1 / throughput
    dev_occupancy_s: float    # measured max per-device busy per request
    link_occupancy_s: float   # measured max per-link busy per request


@dataclasses.dataclass(frozen=True)
class RefineResult:
    plan: Plan
    report: Optional[SimReport]  # simulator report of the returned plan
    #                              (None when occupancy came from real
    #                               measurements instead of the simulator)
    steps: Tuple[RefineStep, ...]
    converged: bool            # True when a selection fixed point was hit
    best_throughput_rps: float = 0.0

    @property
    def throughput_rps(self) -> float:
        return self.best_throughput_rps


def refine_with_simulator(graph: ModelGraph, cluster: ClusterSpec,
                          n_requests: int = 32, max_iters: int = 5,
                          weighted: bool = True,
                          schemes: Sequence[Scheme] = ALL_SCHEMES,
                          max_segment: int = 32,
                          allow_fusion: bool = True,
                          frontier: Optional[PlanFrontier] = None,
                          occupancy_fn: Optional[Callable[[Plan], object]]
                          = None,
                          rel_tol: Optional[float] = None,
                          on_oscillation: str = "best",
                          calibrator: Optional[object] = None
                          ) -> RefineResult:
    """Throughput plan with simulator-calibrated resource weights.

    Returns the simulator-best plan over all iterates (never worse than
    the unrefined ``Objective.THROUGHPUT`` plan, which is iterate 0).
    Pass ``frontier`` to reuse an already-built :class:`PlanFrontier`
    (build it with ``prune_ub=False`` if the scaled re-selection must be
    exact over the complete nondominated set; a pruned frontier still
    refines, just within the latency-optimum trust region).

    ``occupancy_fn`` replaces the simulator as the occupancy source with
    *real measurements*: called with each candidate plan, it must return
    an object with ``dev_occupancy_s`` / ``link_occupancy_s`` /
    ``period_s`` attributes — e.g. ``ExecStats.to_occupancy()`` from a
    warm instrumented mesh-executor run
    (``runtime.mesh_exec.run_partitioned_mesh(..., instrument=True)``).
    The fixed-point loop is unchanged; only the measured-over-analytic
    ratios now come from the machine instead of the model, and the
    returned :class:`RefineResult` has ``report=None``.

    Termination: the loop runs at most ``max_iters`` simulations and
    stops early at a selection fixed point (``converged=True``), a
    selection cycle, or — with ``rel_tol`` set — as soon as the measured
    period moves by less than ``rel_tol`` relative to the previous
    iterate (near-stationary measurements on noisy occupancy sources
    would otherwise never repeat a selection exactly).
    ``on_oscillation="raise"`` turns a detected cycle into
    :class:`RefineOscillationError` instead of silently returning the
    simulator-best iterate.

    ``calibrator`` (a ``cluster.calibrate.OnlineCalibrator``) carries
    corrections *across* refinement calls: the loop warm-starts
    ``(beta, alpha)`` from ``calibrator.axis_scales()`` instead of
    ``(1, 1)`` and folds every *trusted* iterate back via
    ``calibrator.observe`` (untrusted samples never move the calibrator,
    matching the axis-weight rule below).

    Fault awareness: an ``occupancy_fn`` result with a nonzero
    ``failures`` attribute (``ExecStats.to_occupancy()`` sets it from the
    run's retry/timeout/fallback counters) is an *untrusted sample* — the
    step is recorded but the axis weights keep their previous values, so
    one faulty measurement cannot steer the selection, and a repeat
    selection off a faulty sample is not certified as ``converged``.
    """
    if on_oscillation not in ("best", "raise"):
        raise ValueError(f"on_oscillation {on_oscillation!r} not in "
                         f"('best', 'raise')")
    if rel_tol is not None and rel_tol < 0.0:
        raise ValueError(f"rel_tol must be >= 0, got {rel_tol}")
    est = ClusterAnalyticEstimator(cluster, weighted=weighted)
    fr = frontier if frontier is not None else pipeline_frontier(
        graph, est, cluster.compat_testbed(), schemes, max_segment,
        allow_fusion, prune_ub=False)

    beta = alpha = 1.0
    if calibrator is not None:
        beta, alpha = calibrator.axis_scales()
    seen: set = set()
    steps: List[RefineStep] = []
    best: Optional[Tuple[float, Plan, SimReport]] = None
    converged = False
    last_failed = False
    for _ in range(max_iters):
        idx = fr.select(Objective.THROUGHPUT, compute_scale=beta,
                        sync_scale=alpha)
        if idx in seen:
            fixed_point = len(steps) > 0 and idx == steps[-1].point_idx
            converged = fixed_point and not last_failed
            if not fixed_point and on_oscillation == "raise":
                cycle = [s.point_idx for s in steps] + [idx]
                _obs_flight.get_flight().record(
                    "refine_oscillation", graph=graph.name, cycle=cycle)
                _obs_flight.dump_postmortem(
                    "refine_oscillation",
                    context={"graph": graph.name, "cycle": cycle,
                             "beta": beta, "alpha": alpha,
                             "iters": len(steps)})
                raise RefineOscillationError(
                    f"refinement cycles over frontier points {cycle} "
                    f"without reaching a fixed point; pass "
                    f"on_oscillation='best' to accept the "
                    f"simulator-best iterate, or set rel_tol to accept "
                    f"near-stationary measurements as converged")
            break
        seen.add(idx)
        a = float(fr.points[idx, 0])
        b = float(fr.points[idx, 1])
        plan = fr.plan(idx)
        rep: Optional[SimReport] = None
        failed = False
        measured: object = None
        if occupancy_fn is not None:
            occ = occupancy_fn(plan)
            measured = occ
            period = float(occ.period_s)
            rps = 1.0 / period if period > 0.0 else 0.0
            dev_occ = float(occ.dev_occupancy_s)
            link_occ = float(occ.link_occupancy_s)
            failed = getattr(occ, "failures", 0) > 0
        else:
            rep = simulate(graph, plan, cluster, n_requests=n_requests,
                           weighted=weighted)
            rps = rep.throughput_rps
            # a degenerate report (zero or infinite throughput — e.g. an
            # all-zero-duration stage DAG) has no meaningful period; treat
            # it as an untrusted sample rather than dividing by it (the
            # historical ``1.0 / rps`` raised ZeroDivisionError on 0 and
            # poisoned the rel_tol check with inf)
            finite = 0.0 < rps < float("inf")
            period = 1.0 / rps if finite else 0.0
            failed = not finite
            measured = rep
            served = rep.n_requests
            dev_occ = max(rep.device_busy_s) / served
            link_occ = (max(rep.link_busy_s) / served
                        if rep.link_busy_s else 0.0)
        steps.append(RefineStep(
            point_idx=idx, compute_s=a, sync_s=b, beta=beta, alpha=alpha,
            sim_throughput_rps=rps, sim_period_s=period,
            dev_occupancy_s=dev_occ, link_occupancy_s=link_occ))
        # per-iteration convergence gauges (no-ops unless a metrics
        # registry is installed — see obs.metrics)
        it = len(steps) - 1
        _obs_metrics.gauge("refine.beta", beta, graph=graph.name)
        _obs_metrics.gauge("refine.alpha", alpha, graph=graph.name)
        _obs_metrics.gauge("refine.period_s", period, graph=graph.name)
        _obs_metrics.observe("refine.throughput_rps", rps,
                             graph=graph.name)
        _obs_metrics.inc("refine.iterations", graph=graph.name)
        _obs_flight.get_flight().record(
            "refine_step", graph=graph.name, iter=it, point_idx=idx,
            beta=beta, alpha=alpha, period_s=period, untrusted=failed)
        # an untrusted sample may only seed best (the assert below needs
        # one iterate) — it never displaces a trusted one
        if best is None or (not failed and rps > best[0]):
            best = (rps, plan, rep)
        if failed:
            last_failed = True
            continue      # keep previous axis weights
        last_failed = False
        if calibrator is not None:
            calibrator.observe(graph, plan, measured, weighted=weighted)
        if rel_tol is not None and len(steps) >= 2:
            prev = steps[-2].sim_period_s
            if abs(period - prev) <= rel_tol * max(prev, 1e-30):
                converged = True
                break
        # measured-over-analytic occupancy ratios become the axis weights
        beta = dev_occ / a if a > 0.0 else 1.0
        alpha = link_occ / b if b > 0.0 else 1.0
    assert best is not None
    return RefineResult(plan=best[1], report=best[2],
                        steps=tuple(steps), converged=converged,
                        best_throughput_rps=best[0])
