"""Public jit'd wrappers around the Pallas kernels.

``flash_attention`` takes the model-layout [B, H, S, hd] (+ GQA kv heads),
pads the sequence to block multiples and dispatches to the kernel;
``conv2d`` picks the Pallas path for stride-1 convs and the jnp reference
otherwise.  ``interpret=True`` everywhere in this container (CPU); on a TPU
deployment the same calls compile natively.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from .conv2d import conv2d_tiled
from .flash_attention import flash_attention_bh


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B, H, S, hd]; k/v: [B, KV, S, hd] with H % KV == 0."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    blk = max(block_q, block_k)
    pad = (-S) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    out = flash_attention_bh(
        q.reshape(B * H, Sp, hd), k.reshape(B * H, Sp, hd),
        v.reshape(B * H, Sp, hd), causal=causal, window=window,
        scale=1.0 / math.sqrt(hd), block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out.reshape(B, H, Sp, hd)[:, :, :S, :]


@functools.partial(jax.jit, static_argnames=("padding", "stride", "tile_h",
                                             "interpret"))
def conv2d(x: jnp.ndarray, w: jnp.ndarray, *, padding: int = 0,
           stride: int = 1, tile_h: int = 8,
           interpret: bool = True) -> jnp.ndarray:
    """x: [H, W, Cin]; w: [K, K, Cin, Cout]."""
    if stride == 1:
        return conv2d_tiled(x, w, padding=padding, tile_h=tile_h,
                            interpret=interpret)
    # strided layers: jnp reference path (kernel targets the stride-1
    # 3x3/1x1 bulk of the edge benchmarks)
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=[(padding, padding)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out[0].astype(x.dtype)
