"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch, shape, mesh):

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

``cost_analysis()`` supplies FLOPs and bytes; collective bytes are parsed
from the optimized HLO (operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes produced by each collective category in the SPMD-partitioned
    module — per-device quantities, since post-SPMD shapes are per-shard.

    Line-based parse: ``%name = <result shapes> <op>(...)``; async pairs
    (``-start``/``-done``) are counted once via the ``-start`` op.
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            tok = f" {op}("
            tok_start = f" {op}-start("
            use = None
            if tok_start in line:
                use = line.split(tok_start)[0]
            elif tok in line and f"{op}-done" not in line:
                use = line.split(tok)[0]
            if use is not None:
                # result shapes are on the lhs of the op token
                rhs = use.split("=", 1)[-1]
                out[op] = out.get(op, 0) + _shape_bytes(rhs)
                break
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                 # per device (cost_analysis is post-SPMD)
    hlo_bytes: float                 # per device
    coll_bytes: Dict[str, int]       # per device
    model_flops: float = 0.0         # whole model (all chips)
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.hbm_bw

    @property
    def t_collective(self) -> float:
        # collective bytes are already per-device; each device drives ~4 ICI
        # links on a v5e torus — credit one link (conservative)
        return sum(self.coll_bytes.values()) / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": dict(self.coll_bytes),
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
        }


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=byts, coll_bytes=coll,
                    model_flops=model_flops)


def model_flops_estimate(cfg, seq: int, batch: int, mode: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference forward), with
    N = active params (MoE counts routed active + shared)."""
    # active params per token
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0.0
    hd = cfg.hd if cfg.n_heads else 0
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.mla:
            m = cfg.mla
            attn = (d * m.q_lora + m.q_lora * cfg.n_heads * (m.qk_nope
                                                             + m.qk_rope)
                    + d * m.kv_lora + d * m.qk_rope
                    + m.kv_lora * cfg.n_heads * (m.qk_nope + m.v_head)
                    + cfg.n_heads * m.v_head * d)
        else:
            attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv * hd \
                + cfg.n_heads * hd * d
        if cfg.moe:
            mo = cfg.moe
            ffn = 3 * d * mo.d_ff_expert * (mo.top_k + mo.n_shared)
        else:
            ffn = (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
        per_layer = attn + ffn
    elif cfg.family == "ssm":
        per_layer = 6 * d * d + 2 * d * cfg.d_ff   # r,k,v,g,decay,out + cm
    elif cfg.family == "hybrid":
        din = cfg.ssm.expand * d
        per_layer = 2 * d * din + din * d          # z,x,out projections
    elif cfg.family == "encdec":
        attn = 4 * d * d
        per_layer = attn * 2 + (2 * d * cfg.d_ff)  # self+cross, gelu mlp
    n_active = emb + L * per_layer
    tokens = batch * (seq if mode in ("train", "prefill") else 1)
    mult = 6.0 if mode == "train" else 2.0
    return mult * n_active * tokens
