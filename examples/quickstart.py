"""Quickstart: FlexPie end to end in 60 seconds.

1. Build MobileNet's layer graph.
2. Run the FCO planner (DPP + analytic cost oracle) for a 4-node edge
   testbed and print the chosen per-layer (scheme, mode) plan.
3. Execute the plan on simulated nodes and verify exact reassembly.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import AnalyticEstimator, Testbed, chain
from repro.core.baselines import all_solutions, performance_scores
from repro.core.dpp import plan_search
from repro.configs.edge_models import mobilenet_v1
from repro.runtime.engine import init_weights, run_reference
from repro.runtime.session import Session


def main() -> None:
    est = AnalyticEstimator()
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)

    g = mobilenet_v1()
    res = plan_search(g, est, tb)
    print(f"== FlexPie plan for {g.name} on {tb.nodes} nodes "
          f"@ {tb.bandwidth_gbps} Gb/s "
          f"(est. {res.cost * 1e3:.2f} ms, "
          f"{res.stats.i_calls + res.stats.s_calls} estimator calls)")
    for layer, (scheme, mode) in zip(g.layers, res.plan.steps):
        print(f"  {layer.name:10s} {scheme.name:7s} {mode.name}")

    print("\n== vs baselines")
    sols = all_solutions(g, est, tb)
    scores = performance_scores({k: v[1] for k, v in sols.items()})
    for k, (plan, t) in sorted(sols.items(), key=lambda kv: kv[1][1]):
        print(f"  {k:14s} {t * 1e3:8.2f} ms   score={scores[k]:.3f}")

    print("\n== executing the plan on 4 simulated nodes (56x56 prefix)")
    g_small = chain("mb_prefix", mobilenet_v1(width=56).layers[:9])
    key = jax.random.PRNGKey(0)
    ws = init_weights(g_small, key)
    x = jax.random.normal(key, (56, 56, 3))
    plan = plan_search(g_small, est, tb).plan
    out, stats = Session(g_small, ws, plan, tb.nodes).run(x)
    ref = run_reference(g_small, ws, x)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"  reassembly max|err| = {err:.2e}  "
          f"(sync points: {stats.sync_points}, "
          f"received: {stats.bytes_received / 1e3:.1f} KB)")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
