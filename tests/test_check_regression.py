"""The CI perf-regression gate: doctored baselines and flipped parity
flags must fail, the committed baseline must pass against itself."""
import copy
import json
import os

from benchmarks.check_regression import (check_churn, check_estimator,
                                         check_kernels, check_mesh,
                                         check_search, check_sweep, main)

_BASE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                     "baselines")

SEARCH = {
    "models": {
        "mobilenet": {
            "analytic": {"batched_us": 9000.0, "match": True},
            "gbdt": {"batched_us": 15000.0, "match": True},
        },
    },
    "optimality_5layer": {"match": True},
}

SWEEP = {
    "presets": {
        "uniform": {
            "oracle": {"4": {"rel_gap": 1e-15,
                             "rel_gap_throughput": 1e-15}},
            "models": {"mobilenet": {"4": {"planner_us": 12000.0}}},
        },
    },
    "weighted_beats_even_per_model": {"mobilenet": True},
    "throughput_beats_latency": {"best_gain": 1.31, "where": "x"},
}


KERNELS = {
    "interpret": True,
    "kernels": {
        "conv3x3_s1": {"pallas_us": 9000.0, "xla_us": 700.0, "ratio": 12.9,
                       "max_rel_err": 1e-7, "conformant": True},
    },
    "backend_equiv": {
        "resnet18": {"rel_err": 1e-6, "stats_equal": True, "agree": True},
    },
}


MESH = {
    "nodes": 4,
    "devices": 8,
    "noise_note": "advisory",
    "models": {
        "mobilenet": {"rel_err": 0.0, "agree": True, "stats_equal": True,
                      "structure_match": True, "missing": [], "extra": [],
                      "local_us": 40000.0, "mesh_wall_us": 60000.0},
        "resnet18": {"rel_err": 0.0, "agree": True, "stats_equal": True,
                     "structure_match": True, "missing": [], "extra": [],
                     "local_us": 50000.0, "mesh_wall_us": 70000.0},
        "bert": {"rel_err": 0.0, "agree": True, "stats_equal": True,
                 "structure_match": True, "missing": [], "extra": [],
                 "local_us": 4000.0, "mesh_wall_us": 6000.0},
    },
}


ESTIMATOR = {
    "budget": {"n_samples": 20000, "trees": 60, "mode": "smoke"},
    "presets": {
        "mixed_fast_slow": {
            "hetero_oracle_ratio": 1.015, "hom_oracle_ratio": 1.184,
            "hetero_within_5pct": True, "hetero_beats_hom": True,
            "cells": {"resnet18/n6": {"hetero_ratio": 1.02,
                                      "hom_ratio": 1.55}},
        },
        "stepped": {
            "hetero_oracle_ratio": 1.023, "hom_oracle_ratio": 1.274,
            "hetero_within_5pct": True, "hetero_beats_hom": True,
            "cells": {},
        },
    },
    "calibration": {"initial_rel_err": 0.4, "final_rel_err": 0.01,
                    "reduction": 40.0, "reduced_2x": True},
    "train_hetero_us": 3e7,
    "train_hom_us": 2e7,
    "noise_note": "advisory",
}


def test_clean_record_passes():
    assert check_search(SEARCH, SEARCH, 2.0, 5000.0) == []
    assert check_sweep(SWEEP, SWEEP, 2.0, 5000.0) == []
    assert check_kernels(KERNELS, KERNELS, 2.0, 5000.0) == []
    assert check_mesh(MESH, MESH, 2.0, 5000.0) == []
    assert check_estimator(ESTIMATOR, ESTIMATOR, 2.0, 5000.0) == []


def test_estimator_quality_flips_fail():
    """The seeded estimator-quality flags are hard gates; a training-time
    blowup alone is advisory and never fails."""
    for preset, flag, needle in (
            ("mixed_fast_slow", "hetero_within_5pct", "within 5%"),
            ("stepped", "hetero_beats_hom", "homogeneous-trained")):
        cur = copy.deepcopy(ESTIMATOR)
        cur["presets"][preset][flag] = False
        bad = check_estimator(cur, ESTIMATOR, 2.0, 5000.0)
        assert len(bad) == 1 and needle in bad[0], (flag, bad)
    cur = copy.deepcopy(ESTIMATOR)
    cur["calibration"]["reduced_2x"] = False
    bad = check_estimator(cur, ESTIMATOR, 2.0, 5000.0)
    assert len(bad) == 1 and "calibration" in bad[0]
    # 100x training slowdown: advisory only
    cur = copy.deepcopy(ESTIMATOR)
    cur["train_hetero_us"] = 3e9
    assert check_estimator(cur, ESTIMATOR, 2.0, 5000.0) == []


def test_estimator_missing_sections_fail():
    cur = copy.deepcopy(ESTIMATOR)
    del cur["presets"]["stepped"]
    assert any("missing" in b
               for b in check_estimator(cur, ESTIMATOR, 2.0, 5000.0))
    cur2 = copy.deepcopy(ESTIMATOR)
    del cur2["calibration"]
    assert any("calibration record missing" in b
               for b in check_estimator(cur2, ESTIMATOR, 2.0, 5000.0))


def test_mesh_flag_flips_fail():
    """Mesh equivalence / stats / stage-structure flags are hard gates;
    timings never gate (advisory on CPU)."""
    for flag, needle in (("agree", "diverged from the single-process"),
                         ("stats_equal", "geometry accounting"),
                         ("structure_match", "stage structure")):
        cur = copy.deepcopy(MESH)
        cur["models"]["mobilenet"][flag] = False
        bad = check_mesh(cur, MESH, 2.0, 5000.0)
        assert len(bad) == 1 and needle in bad[0], (flag, bad)
    # a 100x time blowup alone must NOT fail the gate
    cur = copy.deepcopy(MESH)
    cur["models"]["mobilenet"]["mesh_wall_us"] = 6e6
    assert check_mesh(cur, MESH, 2.0, 5000.0) == []


def test_mesh_smoke_subset_vs_full_baseline():
    """The per-push job runs the smoke models against the full-set
    baseline: optional models may be absent, the smoke set may not."""
    cur = copy.deepcopy(MESH)
    del cur["models"]["bert"]          # optional model: tolerated
    assert check_mesh(cur, MESH, 2.0, 5000.0) == []
    del cur["models"]["resnet18"]      # smoke model: required
    bad = check_mesh(cur, MESH, 2.0, 5000.0)
    assert len(bad) == 1 and "missing" in bad[0]


def test_kernel_conformance_flips_fail():
    """A kernel drifting out of tolerance or an engine backend divergence
    is a correctness failure regardless of timing."""
    cur = copy.deepcopy(KERNELS)
    cur["kernels"]["conv3x3_s1"]["conformant"] = False
    assert any("no longer conformant" in b
               for b in check_kernels(cur, KERNELS, 2.0, 5000.0))
    cur2 = copy.deepcopy(KERNELS)
    cur2["backend_equiv"]["resnet18"]["agree"] = False
    assert any("diverged" in b
               for b in check_kernels(cur2, KERNELS, 2.0, 5000.0))
    cur3 = copy.deepcopy(KERNELS)
    cur3["backend_equiv"]["resnet18"]["stats_equal"] = False
    assert any("backend-independent" in b
               for b in check_kernels(cur3, KERNELS, 2.0, 5000.0))
    cur4 = copy.deepcopy(KERNELS)
    del cur4["kernels"]["conv3x3_s1"]
    del cur4["backend_equiv"]["resnet18"]
    bad = check_kernels(cur4, KERNELS, 2.0, 5000.0)
    assert len(bad) == 2 and all("missing" in b for b in bad)


def test_kernel_time_regression_fails_and_noise_floor_exempts():
    doctored = copy.deepcopy(KERNELS)
    doctored["kernels"]["conv3x3_s1"]["pallas_us"] = 4000.0
    bad = check_kernels(KERNELS, doctored, 2.0, 1000.0)
    assert len(bad) == 1 and "2x baseline" in bad[0]
    assert check_kernels(KERNELS, doctored, 2.0, 5000.0) == []


def test_search_time_regression_fails():
    doctored = copy.deepcopy(SEARCH)
    doctored["models"]["mobilenet"]["analytic"]["batched_us"] = 4000.0
    bad = check_search(SEARCH, doctored, 2.0, 1000.0)
    assert len(bad) == 1 and "2x baseline" in bad[0]


def test_noise_floor_exempts_micro_timings():
    doctored = copy.deepcopy(SEARCH)
    doctored["models"]["mobilenet"]["analytic"]["batched_us"] = 4000.0
    assert check_search(SEARCH, doctored, 2.0, 5000.0) == []


def test_flipped_match_flag_fails_regardless_of_timing():
    cur = copy.deepcopy(SEARCH)
    cur["models"]["mobilenet"]["gbdt"]["match"] = False
    bad = check_search(cur, SEARCH, 2.0, 5000.0)
    assert any("no longer matches" in b for b in bad)
    cur2 = copy.deepcopy(SEARCH)
    cur2["optimality_5layer"]["match"] = False
    assert any("exhaustive" in b
               for b in check_search(cur2, SEARCH, 2.0, 5000.0))


def test_missing_model_fails():
    cur = copy.deepcopy(SEARCH)
    del cur["models"]["mobilenet"]
    assert any("missing" in b for b in check_search(cur, SEARCH, 2.0,
                                                    5000.0))


def test_sweep_parity_and_gain_flips_fail():
    cur = copy.deepcopy(SWEEP)
    cur["presets"]["uniform"]["oracle"]["4"]["rel_gap_throughput"] = 1e-3
    assert any("THROUGHPUT oracle" in b
               for b in check_sweep(cur, SWEEP, 2.0, 5000.0))
    cur2 = copy.deepcopy(SWEEP)
    cur2["weighted_beats_even_per_model"]["mobilenet"] = False
    assert any("even splits" in b
               for b in check_sweep(cur2, SWEEP, 2.0, 5000.0))
    cur3 = copy.deepcopy(SWEEP)
    cur3["throughput_beats_latency"]["best_gain"] = 1.1
    assert any("1.2x" in b for b in check_sweep(cur3, SWEEP, 2.0, 5000.0))


def test_sweep_missing_correctness_sections_fail():
    """Dropping a parity/win field from the current record must trip the
    gate — correctness checks are keyed off the baseline's sections."""
    cur = copy.deepcopy(SWEEP)
    del cur["presets"]["uniform"]["oracle"]["4"]["rel_gap_throughput"]
    assert any("parity field missing" in b
               for b in check_sweep(cur, SWEEP, 2.0, 5000.0))
    cur2 = copy.deepcopy(SWEEP)
    del cur2["presets"]["uniform"]["oracle"]["4"]
    assert any("parity record missing" in b
               for b in check_sweep(cur2, SWEEP, 2.0, 5000.0))
    cur3 = copy.deepcopy(SWEEP)
    del cur3["weighted_beats_even_per_model"]["mobilenet"]
    assert any("flag missing" in b
               for b in check_sweep(cur3, SWEEP, 2.0, 5000.0))
    cur4 = copy.deepcopy(SWEEP)
    del cur4["throughput_beats_latency"]
    assert any("record missing" in b
               for b in check_sweep(cur4, SWEEP, 2.0, 5000.0))


def test_sweep_planner_time_regression_fails():
    doctored = copy.deepcopy(SWEEP)
    doctored["presets"]["uniform"]["models"]["mobilenet"]["4"][
        "planner_us"] = 5000.0
    bad = check_sweep(SWEEP, doctored, 2.0, 1000.0)
    assert len(bad) == 1 and "planner time" in bad[0]


def test_cli_end_to_end(tmp_path):
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(SEARCH))
    base = tmp_path / "base.json"
    base.write_text(json.dumps(SEARCH))
    assert main(["--kind", "search", "--current", str(cur),
                 "--baseline", str(base)]) == 0
    doctored = copy.deepcopy(SEARCH)
    doctored["models"]["mobilenet"]["analytic"]["batched_us"] = 1000.0
    base.write_text(json.dumps(doctored))
    assert main(["--kind", "search", "--current", str(cur),
                 "--baseline", str(base), "--min-us", "500"]) == 1


def test_committed_baselines_pass_against_themselves():
    checkers = {"search": check_search, "sweep": check_sweep,
                "kernels": check_kernels, "mesh": check_mesh,
                "churn": check_churn, "estimator": check_estimator}
    for kind, checker in checkers.items():
        path = os.path.join(_BASE, f"BENCH_{kind}.json")
        with open(path) as f:
            rec = json.load(f)
        assert checker(rec, rec, 2.0, 5000.0) == []
