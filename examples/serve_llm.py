"""Serve a small model with batched requests: prefill + KV-cache decode.

Demonstrates the serving path every decode-shape dry-run lowers: batched
prompts, teacher-free autoregressive generation with per-layer cache pages,
greedy sampling.

Run:  PYTHONPATH=src python examples/serve_llm.py --arch llama3-8b
      (reduced config; any of the 10 assigned archs works)
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.transformer import Model


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              dtype="float32")
    if cfg.family == "vlm":
        cfg = dataclasses.replace(cfg, vision_tokens=0)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)
    cap = cfg.attn_window or (P + args.gen)
    cache = model.cache_init(B, capacity=cap)
    extra = {}
    if cfg.family == "encdec":
        audio = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model)) * 0.02
        cache["xlayers"] = model.encode_cross(params, audio)
    if cfg.family == "vlm":
        extra["vision_embeds"] = jnp.zeros((B, 0, cfg.d_model))

    step = jax.jit(model.decode_step)

    # prefill via decode steps (single-token engine; a production server
    # would run the fused prefill kernel and hand the cache over)
    t0 = time.time()
    for t in range(P):
        logits, cache = step(params, cache, prompts[:, t:t + 1],
                             jnp.int32(t))
    t_prefill = time.time() - t0

    generated = []
    tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True)
    t0 = time.time()
    for i in range(args.gen):
        generated.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(P + i))
        tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={P} gen={args.gen}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms   "
          f"decode: {t_decode * 1e3 / args.gen:.1f} ms/token")
    for b in range(min(B, 2)):
        print(f"  req{b}: {list(map(int, prompts[b, :6]))}... -> "
              f"{list(map(int, gen[b, :10]))}...")
    assert bool(jnp.isfinite(logits).all())
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
