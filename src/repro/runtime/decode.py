"""Autoregressive transformer decode over the partition planner's plans.

The missing FlexPie workload: a decoder-only transformer generating one
token at a time.  This module supplies the whole vertical slice —

* :class:`TransformerSpec` + :func:`decode_graph` / :func:`prefill_graph`:
  the workload expressed in the planner IR (``ConvT.ATTN`` / ``ConvT.FFN``
  layers carrying head counts and folded score-matmul flops), so
  :func:`repro.core.dpp.plan_search` prices head-sharded decode like any
  other graph.
* :func:`init_transformer` / :func:`reference_decode`: a seeded pre-norm
  reference model with a contiguous, single-device KV cache — the oracle
  every sharded execution must match token for token.
* :class:`DecodeSession`: decode-step execution of a searched plan on
  ``nodes`` devices with the distributed paged KV cache
  (:class:`repro.runtime.kv_cache.PagedKVCache`).  ``Scheme.OUTC`` on an
  ATTN layer shards *heads* across nodes — each node projects, caches, and
  attends only its own heads, and the single cross-node exchange is the
  head-output gather feeding the (replicated) output projection.
  ``Scheme.OUTC`` on an FFN layer column-shards ``w1`` the same way
  (Megatron-style) with the gather before ``w2``.  Any other scheme runs
  the layer replicated.  Both the local executor and the mesh executor
  (``shard_map`` + ``all_gather``, one compiled step program reused for
  every position) are supported via :class:`~repro.runtime.session.
  ExecConfig`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph import ConvT, LayerSpec, ModelGraph, chain
from repro.core.partition import Scheme, split_sizes
from repro.kernels.flash_attention import NEG_INF, flash_decode_paged
from repro.runtime.kv_cache import PagedKVCache
from repro.runtime.session import ExecConfig

__all__ = [
    "TransformerSpec", "decode_graph", "prefill_graph", "init_transformer",
    "reference_decode", "DecodeSession", "greedy_decode", "plan_decode",
]

AXIS = "nodes"


# --------------------------------------------------------------------------
# workload spec + planner IR
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TransformerSpec:
    """Decoder-only transformer shape (pre-norm, MHA, ReLU FFN)."""

    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int = 256

    def __post_init__(self) -> None:
        if self.n_layers < 1 or self.d_model < 1 or self.d_ff < 1:
            raise ValueError(f"bad transformer shape {self}")
        if self.n_heads < 1 or self.d_model % self.n_heads:
            raise ValueError(f"d_model {self.d_model} not divisible by "
                             f"n_heads {self.n_heads}")

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def attn_flop_factor(spec: TransformerSpec, kv_len: int) -> float:
    """True attention flops relative to the IR base (one d->d matmul).

    Per query token: four d*d projections (8d^2) plus score and value
    matmuls against ``kv_len`` cached keys (4*d*kv_len), over the 2d^2
    base the estimator charges a ``d -> d`` layer."""
    d = spec.d_model
    return 4.0 + 2.0 * float(kv_len) / d


def ffn_flop_factor(spec: TransformerSpec) -> float:
    """Two d*d_ff matmuls over the 2d^2 base."""
    return 2.0 * spec.d_ff / spec.d_model


def _graph(spec: TransformerSpec, q_len: int, kv_len: int,
           name: str) -> ModelGraph:
    layers: List[LayerSpec] = []
    af = attn_flop_factor(spec, kv_len)
    ff = ffn_flop_factor(spec)
    for i in range(spec.n_layers):
        layers.append(LayerSpec(f"b{i}.attn", ConvT.ATTN, q_len, 1,
                                spec.d_model, spec.d_model,
                                extra_flop_factor=af, heads=spec.n_heads))
        layers.append(LayerSpec(f"b{i}.ffn", ConvT.FFN, q_len, 1,
                                spec.d_model, spec.d_model,
                                extra_flop_factor=ff))
    return chain(name, layers)


def decode_graph(spec: TransformerSpec, kv_len: int) -> ModelGraph:
    """One decode step (``q_len == 1``) attending to ``kv_len`` cached
    keys — the steady-state workload the planner should optimise for."""
    return _graph(spec, 1, kv_len, f"decode_kv{kv_len}")


def prefill_graph(spec: TransformerSpec, seq_len: int) -> ModelGraph:
    """Prompt ingestion: ``seq_len`` queries attending to ``seq_len``
    keys (causal on average halves the score flops; the factor keeps the
    full-matrix upper bound, matching the kernels' padded execution)."""
    return _graph(spec, seq_len, seq_len, f"prefill_s{seq_len}")


def plan_decode(spec: TransformerSpec, kv_len: int, nodes: int, tb=None,
                **kwargs):
    """Search a decode-step plan: :func:`plan_search` over
    :func:`decode_graph` with the analytic estimator."""
    from repro.core.cost import Testbed
    from repro.core.dpp import plan_search
    from repro.core.estimator import AnalyticEstimator
    if tb is None:
        tb = Testbed(nodes=nodes, bandwidth_gbps=5.0)
    if tb.nodes != nodes:
        raise ValueError(f"testbed nodes {tb.nodes} != {nodes}")
    return plan_search(decode_graph(spec, kv_len), AnalyticEstimator(), tb,
                       **kwargs)


# --------------------------------------------------------------------------
# seeded model + single-device oracle
# --------------------------------------------------------------------------
def init_transformer(spec: TransformerSpec, seed: int = 0) -> Dict:
    """Seeded float32 weights: ``{"emb": [vocab, d], "blocks": [{wq, wk,
    wv, wo: [d, d], w1: [d, d_ff], w2: [d_ff, d]}, ...]}``."""
    rng = np.random.default_rng(seed)
    d, dff = spec.d_model, spec.d_ff

    def g(rows, cols, scale):
        return jnp.asarray(rng.normal(0.0, scale, (rows, cols)),
                           jnp.float32)

    blocks = []
    for _ in range(spec.n_layers):
        blocks.append({
            "wq": g(d, d, d ** -0.5), "wk": g(d, d, d ** -0.5),
            "wv": g(d, d, d ** -0.5), "wo": g(d, d, d ** -0.5),
            "w1": g(d, dff, d ** -0.5), "w2": g(dff, d, dff ** -0.5),
        })
    return {"emb": g(spec.vocab, d, 1.0), "blocks": blocks}


def _rmsnorm(x: jnp.ndarray) -> jnp.ndarray:
    return x * jax.lax.rsqrt(jnp.mean(x * x) + 1e-6)


def _reference_step(spec: TransformerSpec, weights: Dict, x: jnp.ndarray,
                    caches: List[Tuple[jnp.ndarray, jnp.ndarray]]):
    """One pre-norm block stack step with contiguous growing K/V."""
    H, hd = spec.n_heads, spec.head_dim
    scale = 1.0 / math.sqrt(hd)
    new = []
    for blk, (K, V) in zip(weights["blocks"], caches):
        a = _rmsnorm(x)
        q = (a @ blk["wq"]).reshape(H, hd)
        k = (a @ blk["wk"]).reshape(H, hd)
        v = (a @ blk["wv"]).reshape(H, hd)
        K = jnp.concatenate([K, k[None]], axis=0)     # [t, H, hd]
        V = jnp.concatenate([V, v[None]], axis=0)
        s = jnp.einsum("hd,thd->ht", q, K) * scale
        p = jax.nn.softmax(s, axis=-1)
        x = x + jnp.einsum("ht,thd->hd", p, V).reshape(-1) @ blk["wo"]
        f = _rmsnorm(x)
        x = x + jnp.maximum(f @ blk["w1"], 0.0) @ blk["w2"]
        new.append((K, V))
    return x, new


def reference_decode(spec: TransformerSpec, weights: Dict,
                     prompt: Sequence[int], n_new: int):
    """Greedy single-device decode oracle → ``(tokens, logits)`` where
    ``logits`` is ``[n_new, vocab]`` (the distribution each emitted token
    was argmaxed from)."""
    z = jnp.zeros((0, spec.n_heads, spec.head_dim), jnp.float32)
    caches = [(z, z) for _ in range(spec.n_layers)]
    emb = weights["emb"]
    x = None
    for tok in prompt:
        x, caches = _reference_step(spec, weights, emb[tok], caches)
    tokens, logits = [], []
    for _ in range(n_new):
        lg = x @ emb.T
        tok = int(jnp.argmax(lg))
        tokens.append(tok)
        logits.append(lg)
        x, caches = _reference_step(spec, weights, emb[tok], caches)
    return tokens, jnp.stack(logits)


# --------------------------------------------------------------------------
# sharded decode execution
# --------------------------------------------------------------------------
def _paged_attn(q, kp, vp, table, kv_len, *, scale, backend):
    """Decode attention over one node's paged pools.

    ``q``: [lh, hd]; ``kp``/``vp``: [lh, P, ps, hd]; ``table``: static
    [P] logical→physical map; ``kv_len`` traced.  The XLA path gathers
    the *full* logical capacity (static shapes — the step compiles once)
    and masks positions ``>= kv_len``; masked scores contribute exactly
    0.0 to the softmax sums, so padding never perturbs live outputs."""
    lh, _, _, hd = kp.shape
    if backend == "pallas":
        return flash_decode_paged(q, kp, vp, table, kv_len, scale=scale,
                                  interpret=True)
    k = kp[:, table].reshape(lh, -1, hd)              # logical order
    v = vp[:, table].reshape(lh, -1, hd)
    s = jnp.einsum("hd,htd->ht", q, k) * scale
    live = jnp.arange(k.shape[1])[None, :] < kv_len
    s = jnp.where(live, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("ht,htd->hd", p, v)


def _offsets(split: Sequence[int]) -> List[int]:
    out = [0]
    for s in split:
        out.append(out[-1] + s)
    return out


class DecodeSession:
    """Stateful decode of one plan on ``nodes`` devices.

    ``plan.steps`` must pair up with :func:`decode_graph`'s layers —
    entry ``2i`` is block ``i``'s ATTN layer, ``2i+1`` its FFN.  An OutC
    ATTN step head-shards block ``i`` (KV pages live only on the owning
    nodes); an OutC FFN step column-shards ``w1``.  Everything else is
    replicated (every node keeps all heads, all pools stay full — memory
    accounting via :meth:`PagedKVCache.bytes_per_node` reflects that).

    ``config.executor`` picks single-process simulation (``"local"``) or
    the ``shard_map`` mesh executor; ``config.backend`` picks the
    attention inner (``"xla"`` gather-and-mask vs the ``"pallas"`` paged
    decode kernel).  One step program is compiled per session and reused
    for every position — ``pos`` is traced, geometry is static.
    """

    def __init__(self, spec: TransformerSpec, weights: Dict, plan,
                 nodes: int, config: ExecConfig = ExecConfig(), *,
                 page_size: int = 16, capacity: int = 256,
                 cache_seed: int = 0, mesh=None):
        if len(plan.steps) != 2 * spec.n_layers:
            raise ValueError(f"plan has {len(plan.steps)} steps, decode "
                             f"graph needs {2 * spec.n_layers}")
        self.spec = spec
        self.weights = weights
        self.plan = plan
        self.nodes = int(nodes)
        self.config = config
        H, dff = spec.n_heads, spec.d_ff
        self.attn_sharded = [plan.steps[2 * i][0] == Scheme.OUTC
                             for i in range(spec.n_layers)]
        self.ffn_sharded = [plan.steps[2 * i + 1][0] == Scheme.OUTC
                            for i in range(spec.n_layers)]
        self.head_split = [split_sizes(H, nodes) if sh else [H] * nodes
                           for sh in self.attn_sharded]
        self.ff_split = [split_sizes(dff, nodes) if sh else [dff] * nodes
                         for sh in self.ffn_sharded]
        self.cache = PagedKVCache(self.head_split, spec.head_dim,
                                  page_size, capacity, seed=cache_seed)
        self._mesh = mesh
        if config.executor == "mesh":
            if mesh is None:
                from repro.launch.mesh import make_nodes_mesh
                self._mesh = make_nodes_mesh(nodes)
            self._step_fn = self._build_mesh_step()
            self._mesh_pools = self._stack_pools()
        else:
            self._step_fn = jax.jit(self._build_local_step())

    # ---- shared -----------------------------------------------------------
    @property
    def mesh(self):
        return self._mesh

    def step(self, token: int) -> jnp.ndarray:
        """Process one token at the cache's current position; returns the
        final hidden state (feed ``h @ emb.T`` to sample the next)."""
        x = self.weights["emb"][int(token)]
        pos = jnp.int32(self.cache.length)
        if self.config.executor == "mesh":
            h = self._mesh_step(x, pos)
        else:
            h = self._local_step(x, pos)
        self.cache.advance(1)
        return h

    def prefill(self, prompt: Sequence[int]) -> jnp.ndarray:
        """Sequential decode-steps over the prompt (the serving simulator
        models batched prefill; execution reuses the one step program)."""
        h = None
        for tok in prompt:
            h = self.step(tok)
        return h

    # ---- local executor ---------------------------------------------------
    def _build_local_step(self):
        spec, nodes = self.spec, self.nodes
        H, hd, dff = spec.n_heads, spec.head_dim, spec.d_ff
        ps = self.cache.page_size
        table = np.asarray(self.cache.page_table)
        jtable = jnp.asarray(table)
        scale = 1.0 / math.sqrt(hd)
        backend = self.config.backend
        attn_sh, ffn_sh = self.attn_sharded, self.ffn_sharded
        hsplits = self.head_split
        foffs = [_offsets(fs) for fs in self.ff_split]

        def step(x, pos, weights, kpools, vpools):
            kv_len = pos + 1
            phys = jtable[pos // ps]
            row = pos % ps
            nk, nv = [], []
            for i, blk in enumerate(weights["blocks"]):
                a = _rmsnorm(x)
                if attn_sh[i]:
                    hs, off = hsplits[i], _offsets(hsplits[i])
                    outs, lk, lv = [], [], []
                    for n in range(nodes):
                        if hs[n] == 0:
                            lk.append(kpools[i][n])
                            lv.append(vpools[i][n])
                            continue
                        cols = slice(off[n] * hd, off[n + 1] * hd)
                        q = (a @ blk["wq"][:, cols]).reshape(hs[n], hd)
                        k = (a @ blk["wk"][:, cols]).reshape(hs[n], hd)
                        v = (a @ blk["wv"][:, cols]).reshape(hs[n], hd)
                        kp = kpools[i][n].at[:, phys, row].set(k)
                        vp = vpools[i][n].at[:, phys, row].set(v)
                        lk.append(kp)
                        lv.append(vp)
                        outs.append(_paged_attn(q, kp, vp, table, kv_len,
                                                scale=scale,
                                                backend=backend))
                    o = jnp.concatenate(outs, 0).reshape(-1)
                else:
                    # replicated: one full computation; every node's pool
                    # receives the same K/V (replication costs memory on
                    # every node — by design)
                    q = (a @ blk["wq"]).reshape(H, hd)
                    k = (a @ blk["wk"]).reshape(H, hd)
                    v = (a @ blk["wv"]).reshape(H, hd)
                    lk = [kp.at[:, phys, row].set(k) for kp in kpools[i]]
                    lv = [vp.at[:, phys, row].set(v) for vp in vpools[i]]
                    o = _paged_attn(q, lk[0], lv[0], table, kv_len,
                                    scale=scale,
                                    backend=backend).reshape(-1)
                nk.append(lk)
                nv.append(lv)
                x = x + o @ blk["wo"]
                f = _rmsnorm(x)
                if ffn_sh[i]:
                    fo = foffs[i]
                    hv = jnp.concatenate(
                        [jnp.maximum(f @ blk["w1"][:, fo[n]:fo[n + 1]],
                                     0.0)
                         for n in range(nodes) if fo[n + 1] > fo[n]], -1)
                else:
                    hv = jnp.maximum(f @ blk["w1"], 0.0)
                x = x + hv @ blk["w2"]
            return x, nk, nv

        return step

    def _local_step(self, x, pos):
        L = self.spec.n_layers
        kp = [[self.cache.pages(i, n)[0] for n in range(self.nodes)]
              for i in range(L)]
        vp = [[self.cache.pages(i, n)[1] for n in range(self.nodes)]
              for i in range(L)]
        h, nk, nv = self._step_fn(x, pos, self.weights, kp, vp)
        for i in range(L):
            for n in range(self.nodes):
                self.cache.store(i, n, nk[i][n], nv[i][n])
        return h

    # ---- mesh executor ----------------------------------------------------
    def _stack_pools(self):
        """Zero-pad each layer's per-node pools to ``max_lh`` and stack
        into ``[nodes, max_lh, P, ps, hd]`` (the shard_map carries)."""
        kps, vps = [], []
        for i, hs in enumerate(self.head_split):
            mx = max(hs)
            lk, lv = [], []
            for n in range(self.nodes):
                kp, vp = self.cache.pages(i, n)
                pad = [(0, mx - hs[n]), (0, 0), (0, 0), (0, 0)]
                lk.append(jnp.pad(kp, pad))
                lv.append(jnp.pad(vp, pad))
            kps.append(jnp.stack(lk))
            vps.append(jnp.stack(lv))
        return kps, vps

    def _build_mesh_step(self):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        spec, nodes = self.spec, self.nodes
        H, hd, d, dff = spec.n_heads, spec.head_dim, spec.d_model, spec.d_ff
        ps = self.cache.page_size
        table = np.asarray(self.cache.page_table)
        jtable = jnp.asarray(table)
        scale = 1.0 / math.sqrt(hd)
        backend = self.config.backend
        attn_sh, ffn_sh = self.attn_sharded, self.ffn_sharded
        hsplits, fsplits = self.head_split, self.ff_split

        # stacked per-node parameter shards, zero-padded to the layer max
        shard_p, rep_p = {"qkv": [], "w1": []}, {"wo": [], "w2": []}
        for i, blk in enumerate(self.weights["blocks"]):
            hs, mx = hsplits[i], max(hsplits[i])
            off = _offsets(hs)

            def col_shards(w, widths, offs, mxw):
                return jnp.stack([
                    jnp.pad(w[:, offs[n]:offs[n + 1]],
                            [(0, 0), (0, mxw - widths[n])])
                    for n in range(nodes)])
            if attn_sh[i]:
                qkv = tuple(col_shards(blk[key],
                                       [h * hd for h in hs],
                                       [o * hd for o in off], mx * hd)
                            for key in ("wq", "wk", "wv"))
            else:
                qkv = tuple(jnp.stack([blk[key]] * nodes)
                            for key in ("wq", "wk", "wv"))
            shard_p["qkv"].append(qkv)
            fs, fmx = fsplits[i], max(fsplits[i])
            if ffn_sh[i]:
                shard_p["w1"].append(col_shards(blk["w1"], fs,
                                                _offsets(fs), fmx))
            else:
                shard_p["w1"].append(jnp.stack([blk["w1"]] * nodes))
            rep_p["wo"].append(blk["wo"])
            rep_p["w2"].append(blk["w2"])
        self._mesh_shard_p, self._mesh_rep_p = shard_p, rep_p

        def body(x, pos, rep, shard, kps, vps):
            kv_len = pos + 1
            phys = jtable[pos // ps]
            row = pos % ps
            nk, nv = [], []
            for i in range(spec.n_layers):
                wq, wk, wv = (w[0] for w in shard["qkv"][i])
                mx = max(hsplits[i])
                a = _rmsnorm(x)
                q = (a @ wq).reshape(mx, hd)
                k = (a @ wk).reshape(mx, hd)
                v = (a @ wv).reshape(mx, hd)
                kp = kps[i][0].at[:, phys, row].set(k)
                vp = vps[i][0].at[:, phys, row].set(v)
                nk.append(kp[None])
                nv.append(vp[None])
                o = _paged_attn(q, kp, vp, table, kv_len, scale=scale,
                                backend=backend).reshape(-1)
                if attn_sh[i] and nodes > 1:
                    # the one decode-step exchange: head outputs gather,
                    # padded lanes sliced off by static per-node widths
                    g = jax.lax.all_gather(o, AXIS)
                    o = jnp.concatenate(
                        [g[n, :hsplits[i][n] * hd] for n in range(nodes)
                         if hsplits[i][n]], -1)
                else:
                    o = o[:H * hd]
                x = x + o @ rep["wo"][i]
                f = _rmsnorm(x)
                hv = jnp.maximum(f @ shard["w1"][i][0], 0.0)
                if ffn_sh[i] and nodes > 1:
                    g = jax.lax.all_gather(hv, AXIS)
                    hv = jnp.concatenate(
                        [g[n, :fsplits[i][n]] for n in range(nodes)
                         if fsplits[i][n]], -1)
                else:
                    hv = hv[:dff]
                x = x + hv @ rep["w2"][i]
            return x, nk, nv

        return jax.jit(shard_map(
            body, mesh=self._mesh,
            in_specs=(P(), P(), P(), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(), P(AXIS), P(AXIS)),
            check_rep=False))

    def _mesh_step(self, x, pos):
        kps, vps = self._mesh_pools
        h, nk, nv = self._step_fn(x, pos, self._mesh_rep_p,
                                  self._mesh_shard_p, kps, vps)
        self._mesh_pools = (nk, nv)
        # mirror trimmed slices back so the cache object stays the
        # inspectable source of truth (lazy slices — cheap)
        for i, hs in enumerate(self.head_split):
            for n in range(self.nodes):
                self.cache.store(i, n, nk[i][n, :hs[n]], nv[i][n, :hs[n]])
        return h


def greedy_decode(session: DecodeSession, prompt: Sequence[int],
                  n_new: int):
    """Greedy generation through a :class:`DecodeSession` →
    ``(tokens, logits)`` shaped exactly like :func:`reference_decode`."""
    h = session.prefill(prompt)
    emb = session.weights["emb"]
    tokens, logits = [], []
    for _ in range(n_new):
        lg = h @ emb.T
        tok = int(jnp.argmax(lg))
        tokens.append(tok)
        logits.append(lg)
        h = session.step(tok)
    return tokens, jnp.stack(logits)
