"""State-space blocks: Mamba2 (SSD, Zamba2's workhorse) and RWKV-6 (Finch).

Both are implemented as exact per-token recurrences via ``lax.scan`` for
training/prefill, plus O(1)-state single-token decode steps.  A chunked
(parallel) Mamba2 scan is a recorded perf-iteration candidate; the recurrent
form is the correctness oracle.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import dense_init


# ---------------------------------------------------------------------------
# Mamba2 (simplified SSD: per-head scalar decay, diagonal A)
# ---------------------------------------------------------------------------

def mamba2_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def init_mamba2(cfg, key) -> dict:
    s = cfg.ssm
    d_inner, n_heads = mamba2_dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    # separate projections (vs the reference's packed in_proj) so each output
    # dim can carry its own sharding without slicing a sharded axis
    return {
        "w_z": dense_init(ks[0], cfg.d_model, d_inner, dt),
        "w_x": dense_init(ks[1], cfg.d_model, d_inner, dt),
        "w_b": dense_init(ks[2], cfg.d_model, s.d_state, dt),
        "w_c": dense_init(ks[3], cfg.d_model, s.d_state, dt),
        "w_dt": dense_init(ks[4], cfg.d_model, n_heads, dt),
        "conv_w": (jax.random.normal(ks[5], (s.d_conv, d_inner), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((d_inner,), dt),
        "a_log": jnp.zeros((n_heads,), jnp.float32),     # A = -exp(a_log)
        "dt_bias": jnp.full((n_heads,), -2.0, jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "w_out": dense_init(ks[6], d_inner, cfg.d_model, dt),
    }


def _mamba2_core(cfg, p, xbc: jnp.ndarray, z: jnp.ndarray, b: jnp.ndarray,
                 c: jnp.ndarray, dtv: jnp.ndarray,
                 h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Recurrent SSD over time.  xbc [B,S,d_inner] (post-conv), b/c
    [B,S,N], dtv [B,S,H]; h0 [B,H,hd,N] -> (y [B,S,d_inner], hT)."""
    s = cfg.ssm
    d_inner, H = mamba2_dims(cfg)
    hd = s.head_dim
    B_, S, _ = xbc.shape
    a = -jnp.exp(p["a_log"])                              # [H]
    dt_act = jax.nn.softplus(dtv + p["dt_bias"])          # [B,S,H]
    xh = xbc.reshape(B_, S, H, hd)

    def step(h, inp):
        xt, bt, ct, dtt = inp                             # [B,H,hd],[B,N],[B,N],[B,H]
        decay = jnp.exp(dtt * a)                          # [B,H]
        dx = dtt[..., None] * xt                          # [B,H,hd]
        h = h * decay[..., None, None] + dx[..., None] * bt[:, None, None, :]
        y = jnp.einsum("bhdn,bn->bhd", h, ct)
        return h, y

    xs = (xh.transpose(1, 0, 2, 3), b.transpose(1, 0, 2),
          c.transpose(1, 0, 2), dt_act.transpose(1, 0, 2))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3)                          # [B,S,H,hd]
    y = y + p["d_skip"][None, None, :, None] * xh
    return y.reshape(B_, S, d_inner).astype(xbc.dtype), hT


def _mamba2_split(cfg, p, x):
    z = x @ p["w_z"]
    xi = x @ p["w_x"]
    b = (x @ p["w_b"]).astype(jnp.float32)
    c = (x @ p["w_c"]).astype(jnp.float32)
    dtv = (x @ p["w_dt"]).astype(jnp.float32)
    return z, xi, b, c, dtv


def _mamba2_chunked(cfg, p, xbc, b, c, dtv, h0, chunk: int):
    """Chunk-parallel SSD: per-head scalar decays make the pairwise ratio
    matrix [C, C] per head — one state IO per chunk instead of per token."""
    s = cfg.ssm
    d_inner, H = mamba2_dims(cfg)
    hd = s.head_dim
    B_, S, _ = xbc.shape
    a = -jnp.exp(p["a_log"])                                # [H]
    dt_act = jax.nn.softplus(dtv + p["dt_bias"])            # [B,S,H]
    xh = xbc.reshape(B_, S, H, hd).astype(jnp.float32)

    C = chunk
    pad = (-S) % C
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt_act = jnp.pad(dt_act, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // C
    xs = (xh.reshape(B_, nc, C, H, hd).transpose(1, 0, 2, 3, 4),
          b.reshape(B_, nc, C, -1).transpose(1, 0, 2, 3),
          c.reshape(B_, nc, C, -1).transpose(1, 0, 2, 3),
          dt_act.reshape(B_, nc, C, H).transpose(1, 0, 2, 3))
    tri = jnp.tril(jnp.ones((C, C), jnp.float32))           # inclusive

    def chunk_step(h, inp):
        xb, bb, cb, dtb = inp              # [B,C,H,hd],[B,C,N],[B,C,N],[B,C,H]
        lam = dtb * a                                       # [B,C,H] (<=0)
        A = jnp.cumsum(lam, axis=1)                         # inclusive
        # scores[t,u] = (C_t . B_u) e^{A_t - A_u} dt_u  (u <= t)
        ratio = jnp.exp(jnp.clip(A[:, :, None] - A[:, None], -60.0, 0.0))
        cb_dot_bu = jnp.einsum("btn,bun->btu", cb, bb)      # [B,C,C]
        scores = cb_dot_bu[:, None] * ratio.transpose(0, 3, 1, 2) \
            * dtb.transpose(0, 2, 1)[:, :, None, :]         # [B,H,C,C]
        scores = scores * tri[None, None]
        intra = jnp.einsum("bhtu,buhd->bthd", scores, xb)
        inter = jnp.exp(A)[..., None] * jnp.einsum(
            "btn,bhdn->bthd", cb, h).transpose(0, 1, 2, 3)
        # state: h_C = e^{A_C} h0 + sum_u e^{A_C - A_u} dt_u x_u (x) B_u
        Ac = A[:, -1]                                       # [B,H]
        wgt = jnp.exp(jnp.clip(Ac[:, None] - A, -60.0, 0.0)) \
            * dtb                                           # [B,C,H]
        h1 = jnp.exp(Ac)[..., None, None] * h + jnp.einsum(
            "buh,buhd,bun->bhdn", wgt, xb, bb)
        return h1, intra + inter

    hT, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, xs)
    ys = ys.transpose(1, 0, 2, 3, 4).reshape(B_, S + pad, H, hd)[:, :S]
    ys = ys + p["d_skip"][None, None, :, None] * xh[:, :S]
    return ys.reshape(B_, S, d_inner).astype(xbc.dtype), hT


def mamba2_full(cfg, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Train/prefill path.  x [B,S,d] -> [B,S,d]."""
    s = cfg.ssm
    d_inner, H = mamba2_dims(cfg)
    B_, S, _ = x.shape
    z, xi, b, c, dtv = _mamba2_split(cfg, p, x)
    # causal depthwise conv over time
    pad = jnp.pad(xi, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    xconv = sum(pad[:, i:i + S, :] * p["conv_w"][i][None, None, :]
                for i in range(s.d_conv))
    xbc = jax.nn.silu(xconv + p["conv_b"])
    h0 = jnp.zeros((B_, H, s.head_dim, s.d_state), jnp.float32)
    if s.chunk:
        y, _ = _mamba2_chunked(cfg, p, xbc, b, c, dtv, h0, s.chunk)
    else:
        y, _ = _mamba2_core(cfg, p, xbc, z, b, c, dtv, h0)
    y = y * jax.nn.silu(z)
    return y @ p["w_out"]


def mamba2_state_init(cfg, batch: int) -> dict:
    s = cfg.ssm
    d_inner, H = mamba2_dims(cfg)
    return {
        "h": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, d_inner), jnp.dtype(cfg.dtype)),
    }


def mamba2_decode(cfg, p: dict, x: jnp.ndarray,
                  state: dict) -> Tuple[jnp.ndarray, dict]:
    """One token.  x [B,1,d]."""
    s = cfg.ssm
    d_inner, H = mamba2_dims(cfg)
    B_ = x.shape[0]
    z, xi, b, c, dtv = _mamba2_split(cfg, p, x)
    hist = jnp.concatenate([state["conv"], xi], axis=1)   # [B,d_conv,din]
    xconv = jnp.einsum("bkd,kd->bd", hist, p["conv_w"])[:, None, :]
    xbc = jax.nn.silu(xconv + p["conv_b"])
    y, hT = _mamba2_core(cfg, p, xbc, z, b, c, dtv, state["h"])
    y = y * jax.nn.silu(z)
    return y @ p["w_out"], {"h": hT, "conv": hist[:, 1:, :]}


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): data-dependent decay linear attention
# ---------------------------------------------------------------------------

def rwkv6_dims(cfg):
    hd = cfg.ssm.head_dim
    return cfg.d_model // hd, hd          # (n_heads, head_dim)


def init_rwkv6(cfg, key) -> dict:
    d = cfg.d_model
    H, hd = rwkv6_dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "w_r": dense_init(ks[0], d, d, dt),
        "w_k": dense_init(ks[1], d, d, dt),
        "w_v": dense_init(ks[2], d, d, dt),
        "w_g": dense_init(ks[3], d, d, dt),
        "w_decay": dense_init(ks[4], d, d, dt),   # data-dependent decay proj
        "decay_bias": jnp.full((d,), -4.0, jnp.float32),
        "u_bonus": jnp.zeros((H, hd), jnp.float32),
        "w_out": dense_init(ks[5], d, d, dt),
        "ln_w": jnp.ones((d,), dt),               # per-head group norm scale
        # channel-mix
        "cm_k": dense_init(ks[6], d, cfg.d_ff, dt),
        "cm_v": dense_init(ks[7], cfg.d_ff, d, dt),
    }


def _rwkv6_core(cfg, p, r, k, v, w, s0):
    """Linear-attention recurrence.
    r,k,v [B,S,H,hd]; w (decay in (0,1)) [B,S,H,hd]; s0 [B,H,hd,hd]."""
    u = p["u_bonus"]                                       # [H,hd]

    def step(s, inp):
        rt, kt, vt, wt = inp                               # [B,H,hd] each
        kv = kt[..., :, None] * vt[..., None, :]           # [B,H,hd,hd]
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), sT                    # [B,S,H,hd]


def _rwkv6_proj(cfg, p, x):
    H, hd = rwkv6_dims(cfg)
    B_, S, d = x.shape
    f32 = jnp.float32
    r = (x @ p["w_r"]).reshape(B_, S, H, hd).astype(f32)
    k = (x @ p["w_k"]).reshape(B_, S, H, hd).astype(f32)
    v = (x @ p["w_v"]).reshape(B_, S, H, hd).astype(f32)
    g = jax.nn.silu(x @ p["w_g"])
    decay = jnp.exp(-jnp.exp((x @ p["w_decay"]).astype(f32)
                             + p["decay_bias"]))
    w = decay.reshape(B_, S, H, hd)
    return r, k, v, g, w


def _rwkv6_out(cfg, p, ys, g):
    B_, S, H_hd = ys.shape[0], ys.shape[1], ys.shape[2] * ys.shape[3]
    y = ys.reshape(B_, S, H_hd)
    # group-norm per head approximated by rmsnorm over the full dim
    y = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-6)
    y = (y * p["ln_w"].astype(jnp.float32)).astype(g.dtype)
    return (y * g) @ p["w_out"]


def _rwkv6_chunked(cfg, p, r, k, v, w, s0, chunk: int):
    """Chunk-parallel RWKV-6 (GLA-style): per-token state IO becomes one
    state read/write per chunk; intra-chunk interactions are masked matmuls
    with pairwise decay ratios exp(L_{t-1} - L_u) <= 1 (always safe — decay
    only accumulates).  Exact (up to fp) vs the per-token recurrence."""
    B_, S, H, hd = r.shape
    C = chunk
    pad = (-S) % C
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    nc = (S + pad) // C
    u = p["u_bonus"]                                        # [H,hd]

    def reshape(t):
        return t.reshape(B_, nc, C, H, hd).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = map(reshape, (r, k, v, w))             # [nc,B,C,H,hd]

    tri = jnp.tril(jnp.ones((C, C), jnp.float32), k=-1)     # strict lower

    def chunk_step(s, inp):
        rb, kb, vb, wb = inp                                # [B,C,H,hd]
        logw = jnp.log(jnp.maximum(wb, 1e-30))
        L = jnp.cumsum(logw, axis=1)                        # L_t (inclusive)
        Lm1 = L - logw                                      # L_{t-1}
        # intra-chunk: A[t,u] = sum_d r_t k_u exp(L_{t-1}-L_u), u < t
        ex = jnp.exp(jnp.clip(Lm1[:, :, None] - L[:, None], -60.0, 0.0))
        scores = jnp.einsum("bthd,buhd,btuhd->bhtu", rb, kb, ex)
        scores = scores * tri[None, None]
        intra = jnp.einsum("bhtu,buhd->bthd", scores, vb)
        # diagonal bonus term
        diag = jnp.einsum("bthd,bthd->bth", rb * u[None, None], kb)
        intra = intra + diag[..., None] * vb
        # inter-chunk: r~_t . S0
        inter = jnp.einsum("bthk,bhkv->bthv", rb * jnp.exp(Lm1), s)
        # state update: S1 = diag(exp(L_C)) S0 + sum_u (k_u exp(L_C-L_u))v_u
        Lc = L[:, -1]                                       # [B,H,hd]
        kk = kb * jnp.exp(jnp.clip(Lc[:, None] - L, -60.0, 0.0))
        s1 = jnp.exp(Lc)[..., None] * s + jnp.einsum(
            "buhk,buhv->bhkv", kk, vb)
        return s1, intra + inter

    sT, ys = jax.lax.scan(jax.checkpoint(chunk_step), s0, (rc, kc, vc, wc))
    ys = ys.transpose(1, 0, 2, 3, 4).reshape(B_, S + pad, H, hd)
    return ys[:, :S], sT


def rwkv6_time_mix(cfg, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    H, hd = rwkv6_dims(cfg)
    B_ = x.shape[0]
    r, k, v, g, w = _rwkv6_proj(cfg, p, x)
    s0 = jnp.zeros((B_, H, hd, hd), jnp.float32)
    if cfg.ssm.chunk:
        ys, _ = _rwkv6_chunked(cfg, p, r, k, v, w, s0, cfg.ssm.chunk)
    else:
        ys, _ = _rwkv6_core(cfg, p, r, k, v, w, s0)
    return _rwkv6_out(cfg, p, ys, g)


def rwkv6_state_init(cfg, batch: int) -> dict:
    H, hd = rwkv6_dims(cfg)
    return {"s": jnp.zeros((batch, H, hd, hd), jnp.float32)}


def rwkv6_decode(cfg, p: dict, x: jnp.ndarray,
                 state: dict) -> Tuple[jnp.ndarray, dict]:
    r, k, v, g, w = _rwkv6_proj(cfg, p, x)
    ys, sT = _rwkv6_core(cfg, p, r, k, v, w, state["s"])
    return _rwkv6_out(cfg, p, ys, g), {"s": sT}


def rwkv6_channel_mix(cfg, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.square(jax.nn.relu(x @ p["cm_k"]))
    return h @ p["cm_v"]
