"""Public jit'd wrappers around the Pallas kernels.

``flash_attention`` takes the model-layout [B, H, S, hd] (+ GQA kv heads),
pads the sequence to block multiples and dispatches to the kernel;
``conv2d`` / ``dwconv2d`` route through the shard kernel for any supported
geometry (stride >= 1, square kernel, non-degenerate output) with an
automatic XLA fallback otherwise; ``matmul`` is the row-tiled MXU kernel
behind the engine's FC layers.  ``interpret=True`` everywhere in this
container (CPU); on a TPU deployment the same calls compile natively.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv2d import UnsupportedGeometry, conv2d_shard
from .flash_attention import flash_attention_bh


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B, H, S, hd]; k/v: [B, KV, S, hd] with H % KV == 0."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    blk = max(block_q, block_k)
    pad = (-S) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    out = flash_attention_bh(
        q.reshape(B * H, Sp, hd), k.reshape(B * H, Sp, hd),
        v.reshape(B * H, Sp, hd), causal=causal, window=window,
        scale=1.0 / math.sqrt(hd), block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out.reshape(B, H, Sp, hd)[:, :, :S, :]


# ---------------------------------------------------------------------------
# Row-tiled matmul — the FC / pointwise-as-matmul shard kernel.
# ---------------------------------------------------------------------------

def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def matmul_tiled(x: jnp.ndarray, w: jnp.ndarray, *, tile_m: int = 128,
                 interpret: bool = True) -> jnp.ndarray:
    """x: [M, Cin] @ w: [Cin, Cout], output rows tiled by ``tile_m`` (each
    tile is one MXU matmul; rows pad to the tile multiple and are dropped
    on return).  Engine FC shards are [seq, Cin] with Cin/Cout possibly
    channel-sliced by the plan — any shape goes."""
    M, cin = x.shape
    cout = w.shape[1]
    if M == 0 or cin == 0 or cout == 0:
        raise UnsupportedGeometry(f"degenerate matmul {x.shape} @ {w.shape}")
    tile_m = max(1, min(tile_m, M))
    nt = -(-M // tile_m)
    pad = nt * tile_m - M
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((tile_m, cin), lambda i: (i, 0)),
            pl.BlockSpec((cin, cout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt * tile_m, cout), x.dtype),
        interpret=interpret,
    )(xp, w)
    return out[:M]


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def matmul(x: jnp.ndarray, w: jnp.ndarray, *, tile_m: int = 128,
           interpret: bool = True) -> jnp.ndarray:
    """Jit'd :func:`matmul_tiled` with XLA fallback on degenerate shapes."""
    try:
        return matmul_tiled(x, w, tile_m=tile_m, interpret=interpret)
    except UnsupportedGeometry:
        return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Conv wrappers: Pallas when supported, XLA fallback otherwise.
# ---------------------------------------------------------------------------

def _conv_xla(x: jnp.ndarray, w: jnp.ndarray, *, padding: int, stride: int,
              groups: int = 1) -> jnp.ndarray:
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=[(padding, padding)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    return out[0].astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("padding", "stride", "tile_h",
                                             "interpret"))
def conv2d(x: jnp.ndarray, w: jnp.ndarray, *, padding: int = 0,
           stride: int = 1, tile_h: int = 8,
           interpret: bool = True) -> jnp.ndarray:
    """x: [H, W, Cin]; w: [K, K, Cin, Cout]; any stride.  Pallas path for
    every non-degenerate square-kernel geometry; degenerate outputs
    (``out_h/out_w <= 0``) fall back to XLA cleanly."""
    try:
        return conv2d_shard(x, w, pads=(padding,) * 4, stride=stride,
                            tile_h=tile_h, interpret=interpret)
    except UnsupportedGeometry:
        return _conv_xla(x, w, padding=padding, stride=stride)


@functools.partial(jax.jit, static_argnames=("padding", "stride", "tile_h",
                                             "interpret"))
def dwconv2d(x: jnp.ndarray, w: jnp.ndarray, *, padding: int = 0,
             stride: int = 1, tile_h: int = 8,
             interpret: bool = True) -> jnp.ndarray:
    """Depthwise conv: x [H, W, C]; w [K, K, 1, C] (engine layout)."""
    try:
        return conv2d_shard(x, w, pads=(padding,) * 4, stride=stride,
                            depthwise=True, tile_h=tile_h,
                            interpret=interpret)
    except UnsupportedGeometry:
        return _conv_xla(x, w, padding=padding, stride=stride,
                         groups=x.shape[-1])
