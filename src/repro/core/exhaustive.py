"""Brute-force search over the full (scheme, mode) space.

Used only for small graphs: the Theorem-1 property tests compare DPP's result
against this oracle under the same plan-validity constraints.
"""
from __future__ import annotations

import itertools
from typing import Iterator, Optional, Sequence, Tuple

from .cost import Testbed
from .estimator import CostEstimator
from .graph import ModelGraph
from .partition import ALL_SCHEMES, Mode, Scheme
from .plan import Plan, plan_cost, plan_feasible


def enumerate_plans(n: int, schemes: Sequence[Scheme] = ALL_SCHEMES,
                    allow_fusion: bool = True) -> Iterator[Plan]:
    """All valid plans: segmentations x per-segment schemes.

    Multi-layer segments must use a single spatial scheme (see plan.py).
    """
    mode_opts = (Mode.T, Mode.NT) if allow_fusion else (Mode.T,)
    for modes in itertools.product(mode_opts, repeat=n - 1):
        modes = (*modes, Mode.T)
        # segment boundaries
        segs, a = [], 0
        for i, t in enumerate(modes):
            if t == Mode.T:
                segs.append((a, i))
                a = i + 1
        per_seg_choices = []
        for (sa, sb) in segs:
            if sb > sa:
                per_seg_choices.append([s for s in schemes if s.spatial])
            else:
                per_seg_choices.append(list(schemes))
        for combo in itertools.product(*per_seg_choices):
            steps: list = [None] * n
            for (sa, sb), s in zip(segs, combo):
                for m in range(sa, sb + 1):
                    steps[m] = (s, modes[m])
            yield Plan(tuple(steps))


def exhaustive_search(graph: ModelGraph, est: CostEstimator, tb: Testbed,
                      schemes: Sequence[Scheme] = ALL_SCHEMES,
                      allow_fusion: bool = True) -> Tuple[Plan, float]:
    best: Optional[Plan] = None
    best_cost = float("inf")
    for plan in enumerate_plans(len(graph), schemes, allow_fusion):
        if not plan_feasible(graph, plan, tb.nodes):
            continue
        c = plan_cost(graph, plan, est, tb)
        if c < best_cost:
            best, best_cost = plan, c
    assert best is not None
    return best, best_cost
