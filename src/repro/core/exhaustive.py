"""Brute-force search over the full (scheme, mode) space.

Used only for small graphs: the Theorem-1 property tests compare DPP's result
against this oracle under the same plan-validity constraints.  Branched
graphs enumerate per-branch chain plans (merge layers pinned to T-mode
singleton segments, branch tails always T) and take the product across
branches, scoring with the shared ``dag_plan_cost`` semantics.
"""
from __future__ import annotations

import itertools
from typing import Iterator, List, Optional, Sequence, Tuple

from .cost import Testbed
from .cost_tables import PrefetchedEstimator
from .dpp import Objective, pipeline_objective_key
from .estimator import CostEstimator
from .graph import ModelGraph
from .partition import ALL_SCHEMES, Mode, Scheme
from .plan import Plan, plan_cost, plan_feasible, plan_pipeline_cost


def enumerate_plans(n: int, schemes: Sequence[Scheme] = ALL_SCHEMES,
                    allow_fusion: bool = True) -> Iterator[Plan]:
    """All valid chain plans: segmentations x per-segment schemes.

    Multi-layer segments must use a single spatial scheme (see plan.py).
    """
    mode_opts = (Mode.T, Mode.NT) if allow_fusion else (Mode.T,)
    for modes in itertools.product(mode_opts, repeat=n - 1):
        modes = (*modes, Mode.T)
        # segment boundaries
        segs, a = [], 0
        for i, t in enumerate(modes):
            if t == Mode.T:
                segs.append((a, i))
                a = i + 1
        per_seg_choices = []
        for (sa, sb) in segs:
            if sb > sa:
                per_seg_choices.append([s for s in schemes if s.spatial])
            else:
                per_seg_choices.append(list(schemes))
        for combo in itertools.product(*per_seg_choices):
            steps: list = [None] * n
            for (sa, sb), s in zip(segs, combo):
                for m in range(sa, sb + 1):
                    steps[m] = (s, modes[m])
            yield Plan(tuple(steps))


def enumerate_dag_plans(graph: ModelGraph,
                        schemes: Sequence[Scheme] = ALL_SCHEMES,
                        allow_fusion: bool = True) -> Iterator[Plan]:
    """All valid plans of a branched graph: product of per-branch chain
    plans, with merge heads restricted to T-mode (junction sync points)."""
    branches = graph.linearize()
    per_branch: List[List[Plan]] = []
    for br in branches:
        plans = list(enumerate_plans(len(br.ids), schemes, allow_fusion))
        if graph.fan_in(br.head) >= 2:
            plans = [p for p in plans if p.steps[0][1] == Mode.T]
        per_branch.append(plans)
    n = len(graph)
    for combo in itertools.product(*per_branch):
        steps: list = [None] * n
        for br, p in zip(branches, combo):
            for idx, st in zip(br.ids, p.steps):
                steps[idx] = st
        yield Plan(tuple(steps))


def exhaustive_search(graph: ModelGraph, est: CostEstimator, tb: Testbed,
                      schemes: Sequence[Scheme] = ALL_SCHEMES,
                      allow_fusion: bool = True,
                      objective: Objective = Objective.LATENCY,
                      latency_bound_s: Optional[float] = None
                      ) -> Tuple[Plan, float]:
    """Oracle optimum under ``objective``.  Returns ``(plan, cost)`` where
    ``cost`` is the latency for ``LATENCY`` and the pipeline bottleneck
    time for the throughput objectives (scored with
    ``plan.plan_pipeline_cost`` and ordered by the same
    ``pipeline_objective_key`` the DP frontier selection uses)."""
    # one batched prefetch answers every estimator query the enumeration
    # can make (the plan space revisits the same segments endlessly, so
    # scoring degenerates to dict lookups)
    pf = PrefetchedEstimator.for_graph(graph, est, tb, schemes, allow_fusion)
    best: Optional[Plan] = None
    gen = (enumerate_plans(len(graph), schemes, allow_fusion)
           if graph.is_chain
           else enumerate_dag_plans(graph, schemes, allow_fusion))
    if objective != Objective.LATENCY:
        best_key: Optional[tuple] = None
        best_bottleneck = float("inf")
        for plan in gen:
            if not plan_feasible(graph, plan, tb.nodes):
                continue
            pc = plan_pipeline_cost(graph, plan, pf, tb)
            key = pipeline_objective_key(pc.compute_s, pc.sync_s, objective,
                                         latency_bound_s)
            if best_key is None or key < best_key:
                best, best_key = plan, key
                best_bottleneck = pc.bottleneck_s
        assert best is not None
        return best, best_bottleneck
    best_cost = float("inf")
    for plan in gen:
        if not plan_feasible(graph, plan, tb.nodes):
            continue
        c = plan_cost(graph, plan, pf, tb)
        if c < best_cost:
            best, best_cost = plan, c
    assert best is not None
    return best, best_cost
