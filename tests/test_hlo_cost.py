"""Loop-aware HLO cost analyzer: exactness vs unrolled ground truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_match_unroll():
    w = jnp.zeros((64, 64))
    x = jnp.zeros((64, 64))

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=23)
        return out

    def unrolled(x, w):
        for _ in range(23):
            x = x @ w
        return x

    fs = analyze_hlo(_compile(scanned, x, w))["flops"]
    fu = analyze_hlo(_compile(unrolled, x, w))["flops"]
    expected = 2 * 64 ** 3 * 23
    assert fu == pytest.approx(expected, rel=0.01)
    assert fs == pytest.approx(expected, rel=0.01)


def test_xla_cost_analysis_undercounts_loops():
    """Documents WHY the analyzer exists: XLA counts while bodies once."""
    w = jnp.zeros((64, 64))
    x = jnp.zeros((64, 64))

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=23)
        return out

    compiled = jax.jit(scanned).lower(x, w).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jaxlib returns [dict]
        ca = ca[0]
    xla_flops = ca["flops"]
    ours = analyze_hlo(compiled.as_text())["flops"]
    assert ours > 10 * xla_flops


def test_dot_flops_with_batch_dims():
    a = jnp.zeros((4, 32, 16))
    b = jnp.zeros((4, 16, 8))
    tot = analyze_hlo(_compile(lambda a, b: jnp.einsum("bij,bjk->bik",
                                                       a, b), a, b))
    assert tot["flops"] == pytest.approx(2 * 4 * 32 * 16 * 8, rel=0.05)


def test_nested_scan_multiplies():
    x = jnp.zeros((32, 32))

    def fn(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ x, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    tot = analyze_hlo(_compile(fn, x))
    assert tot["flops"] == pytest.approx(2 * 32 ** 3 * 15, rel=0.05)


def test_bytes_in_place_dus():
    """dynamic-update-slice into a big buffer costs the slice, not the
    buffer."""
    big = jnp.zeros((4096, 1024))
    upd = jnp.ones((1, 1024))

    def fn(big, upd):
        return jax.lax.dynamic_update_slice(big, upd, (17, 0))

    tot = analyze_hlo(_compile(fn, big, upd))
    assert tot["bytes"] < big.size * 4 * 0.5   # far below whole-buffer cost
