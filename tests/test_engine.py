"""Distributed edge engine: exact reassembly for arbitrary valid plans."""
import random

import jax
import jax.numpy as jnp
import pytest

from repro.core import AnalyticEstimator, Testbed, chain
from repro.core.dpp import plan_search
from repro.core.graph import ConvT, LayerSpec
from repro.core.partition import ALL_SCHEMES, Mode, Scheme
from repro.core.plan import Plan, fixed_plan, plan_feasible
from repro.runtime.engine import (clear_segment_cache, init_weights,
                                  run_reference, segment_cache_info)
from repro.runtime.session import ExecConfig, Session

EST = AnalyticEstimator()


def _toy_graph():
    layers = [
        LayerSpec("c0", ConvT.CONV, 24, 24, 3, 8, 3, 1, 1),
        LayerSpec("dw", ConvT.DWCONV, 24, 24, 8, 8, 3, 1, 1),
        LayerSpec("pw", ConvT.POINTWISE, 24, 24, 8, 16, 1, 1, 0),
        LayerSpec("c1", ConvT.CONV, 24, 24, 16, 16, 3, 2, 1),
        LayerSpec("add", ConvT.ADD, 12, 12, 16, 16),
        LayerSpec("c2", ConvT.CONV, 12, 12, 16, 8, 3, 1, 1),
    ]
    return chain("toy", layers)


@pytest.fixture(scope="module")
def toy():
    g = _toy_graph()
    key = jax.random.PRNGKey(0)
    ws = init_weights(g, key)
    x = jax.random.normal(key, (24, 24, 3))
    return g, ws, x, run_reference(g, ws, x)


@pytest.mark.parametrize("nodes", [3, 4, 5])
@pytest.mark.parametrize("scheme", list(ALL_SCHEMES))
def test_fixed_schemes_exact(toy, nodes, scheme):
    g, ws, x, ref = toy
    out, _ = Session(g, ws, fixed_plan(g, scheme), nodes).run(x)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


@pytest.mark.parametrize("nodes", [3, 4])
@pytest.mark.parametrize("bw", [0.5, 5.0])
def test_flexpie_plans_exact(toy, nodes, bw):
    g, ws, x, ref = toy
    plan = plan_search(g, EST, Testbed(nodes=nodes, bandwidth_gbps=bw)).plan
    out, stats = Session(g, ws, plan, nodes).run(x)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
    assert stats.sync_points == len(plan.segments())


def test_random_feasible_plans_exact(toy):
    """Property: ANY valid plan reassembles exactly (not just optimal ones)."""
    g, ws, x, ref = toy
    rng = random.Random(0)
    n = len(g)
    checked = 0
    while checked < 10:
        steps = []
        for i in range(n):
            scheme = rng.choice(list(ALL_SCHEMES))
            mode = Mode.T if i == n - 1 else rng.choice([Mode.T, Mode.NT])
            steps.append((scheme, mode))
        # enforce segment uniformity (walk backwards)
        for i in range(n - 2, -1, -1):
            if steps[i][1] == Mode.NT:
                nxt_scheme = steps[i + 1][0]
                if not nxt_scheme.spatial:
                    steps[i + 1] = (Scheme.INH, steps[i + 1][1])
                    nxt_scheme = Scheme.INH
                steps[i] = (nxt_scheme, Mode.NT)
        plan = Plan(tuple(steps))
        try:
            plan.validate()
        except ValueError:
            continue
        if not plan_feasible(g, plan, 4):
            continue
        out, _ = Session(g, ws, plan, 4).run(x)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
        checked += 1


def test_comm_accounting_matches_paper_narrative(toy):
    """OutC gathers the whole input (costly, Fig. 1c); NT fusion cuts comm."""
    g, ws, x, ref = toy
    _, s_outc = Session(g, ws, fixed_plan(g, Scheme.OUTC), 4).run(x)
    _, s_inh = Session(g, ws, fixed_plan(g, Scheme.INH), 4).run(x)
    plan = plan_search(g, EST, Testbed(nodes=4, bandwidth_gbps=0.5)).plan
    _, s_flex = Session(g, ws, plan, 4).run(x)
    assert s_outc.bytes_received > 5 * s_inh.bytes_received
    assert s_flex.bytes_received <= s_inh.bytes_received


def test_jit_segment_cache_reuses_repeated_blocks():
    """Repeated block geometry compiles once: resnet-style repetition plus
    a second run must be all cache hits, and jit output == eager output."""
    from repro.configs.edge_models import resnet18
    g_full = resnet18(width=32)
    g = chain("rn_prefix", g_full.layers[:2], drop_edges=True)
    layers = list(g.layers)
    # two geometrically identical extra blocks under different names
    for tag in ("x", "y"):
        layers.append(LayerSpec(f"{tag}a", ConvT.CONV, 8, 8, 64, 64, 3, 1,
                                1))
    g = chain("rn_rep", layers)
    key = jax.random.PRNGKey(2)
    ws = init_weights(g, key)
    x = jax.random.normal(key, (32, 32, 3))
    ref = run_reference(g, ws, x)

    clear_segment_cache()
    plan = fixed_plan(g, Scheme.INH)
    sess = Session(g, ws, plan, 4)
    out, _ = sess.run(x)
    info1 = segment_cache_info()
    assert info1.hits > 0          # identical blocks / interior cells share
    out2, _ = sess.run(x)
    info2 = segment_cache_info()
    assert info2.misses == info1.misses   # second run: no new compilations
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
    eager, _ = Session(g, ws, plan, 4,
                       ExecConfig(jit_segments=False)).run(x)
    assert float(jnp.max(jnp.abs(out2 - eager))) < 1e-6


def test_mobilenet_slice_exact():
    """A real benchmark prefix stays exact under the planner's plan."""
    from repro.configs.edge_models import mobilenet_v1
    g_full = mobilenet_v1(width=56)      # reduced input resolution
    g = chain("mb_prefix", g_full.layers[:9])
    key = jax.random.PRNGKey(1)
    ws = init_weights(g, key)
    x = jax.random.normal(key, (56, 56, 3))
    ref = run_reference(g, ws, x)
    plan = plan_search(g, EST, Testbed(nodes=4, bandwidth_gbps=0.5)).plan
    out, _ = Session(g, ws, plan, 4).run(x)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
