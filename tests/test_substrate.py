"""Substrate layers: data pipeline, optimizer, checkpointing, schedules."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.data import SyntheticLMDataset
from repro.optim import adamw_init, adamw_update, cosine_schedule


def test_dataset_deterministic_and_seekable():
    ds = SyntheticLMDataset(vocab=128, seq_len=32, global_batch=8, seed=1)
    b0a = ds.batch(0)
    b0b = ds.batch(0)
    b1 = ds.batch(1)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
    assert not np.array_equal(b0a["tokens"], b1["tokens"])
    assert b0a["tokens"].shape == (8, 32)
    # shifted labels
    np.testing.assert_array_equal(b0a["tokens"][:, 1:], b0a["labels"][:, :-1])


def test_dataset_host_sharding_partitions_global_batch():
    full = SyntheticLMDataset(vocab=64, seq_len=8, global_batch=8, seed=2)
    h0 = SyntheticLMDataset(vocab=64, seq_len=8, global_batch=8, seed=2,
                            n_hosts=2, host_id=0)
    h1 = SyntheticLMDataset(vocab=64, seq_len=8, global_batch=8, seed=2,
                            n_hosts=2, host_id=1)
    assert h0.local_batch == h1.local_batch == 4
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - jnp.array([1.0, 2.0])) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(g, opt, params, lr=5e-2, weight_decay=0.0)
    assert float(loss(params)) < 1e-3


def test_adamw_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    huge = {"w": jnp.full(3, 1e9)}
    p2, _ = adamw_update(huge, opt, params, lr=1.0, grad_clip=1.0,
                         weight_decay=0.0)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 10.0


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.int32(0), peak_lr=1.0, warmup=10,
                                 total=100)) == pytest.approx(0.0)
    assert float(cosine_schedule(jnp.int32(10), peak_lr=1.0, warmup=10,
                                 total=100)) == pytest.approx(1.0, abs=1e-3)
    end = float(cosine_schedule(jnp.int32(100), peak_lr=1.0, warmup=10,
                                total=100, floor=0.1))
    assert end == pytest.approx(0.1, abs=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": [jnp.zeros(2), jnp.full((1,), 7.0)]}}
    p = str(tmp_path / "ckpt.npz")
    save_pytree(tree, p)
    out = load_pytree(jax.tree.map(lambda x: x, tree), p)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    p = str(tmp_path / "c.npz")
    save_pytree({"a": jnp.zeros((2,))}, p)
    with pytest.raises(ValueError):
        load_pytree({"a": jnp.zeros((3,))}, p)
