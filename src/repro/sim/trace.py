"""Trace generation + estimator training (§3.2, "330K pieces of trace data").

On the paper's testbed the traces are wall-clock measurements; here they are
drawn from the analytic testbed physics (``core/cost.py``) with multiplicative
log-normal measurement noise — the same role, no hardware.  The GBDT
estimators are then trained on (features -> log seconds) pairs and plugged
into DPP, giving the full data-driven FCO loop end to end.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.cost import (Testbed, Topology, compute_time_batch_s,
                             sync_time_batch_s)
from repro.core.estimator import (GBDTEstimator, i_features, s_features)
from repro.core.graph import ConvT, LayerSpec
from repro.core.partition import Scheme
from repro.gbdt import GBDTRegressor


@dataclasses.dataclass
class TraceConfig:
    n_samples: int = 330_000
    noise_sigma: float = 0.05       # log-normal measurement noise
    seed: int = 0
    node_choices: Tuple[int, ...] = (3, 4, 5, 6)
    bw_choices: Tuple[float, ...] = (0.5, 1.0, 5.0)
    topo_choices: Tuple[Topology, ...] = (Topology.RING, Topology.PS,
                                          Topology.MESH)


def _random_layer(rng: np.random.Generator) -> LayerSpec:
    t = ConvT(rng.choice([0, 1, 2, 3, 4, 5, 6],
                         p=[0.33, 0.14, 0.24, 0.08, 0.11, 0.05, 0.05]))
    if t == ConvT.FC:
        seq = int(rng.choice([1, 64, 128, 256, 512]))
        return LayerSpec("t", t, seq, 1, int(rng.choice([256, 512, 768, 1024,
                                                         2048, 3072])),
                         int(rng.choice([256, 512, 768, 1000, 3072])))
    h = int(rng.choice([7, 14, 28, 56, 112, 224]))
    cin = int(rng.choice([3, 16, 32, 64, 128, 256, 512, 1024]))
    if t == ConvT.DWCONV:
        cout, k, s, p = cin, 3, int(rng.choice([1, 2])), 1
    elif t == ConvT.POINTWISE:
        cout, k, s, p = int(rng.choice([16, 32, 64, 128, 256, 512, 1024])), 1, 1, 0
    elif t == ConvT.POOL:
        cout, k, s, p = cin, int(rng.choice([2, 3])), 2, 0
    elif t in (ConvT.ADD, ConvT.CONCAT):
        # multi-input merge: the fan-in feature comes from len(inputs);
        # the dummy producer names never resolve (features only)
        fan = int(rng.integers(2, 5))
        cout, k, s, p = cin, 1, 1, 0
        return LayerSpec("t", t, h, h, cin, cout, k, s, p,
                         inputs=tuple(f"in{j}" for j in range(fan)))
    else:
        cout = int(rng.choice([16, 32, 64, 128, 256, 512]))
        k = int(rng.choice([3, 5, 7]))
        s = int(rng.choice([1, 2]))
        p = k // 2
    if h + 2 * p < k:
        k = 1
        p = 0
    return LayerSpec("t", t, h, h, cin, cout, k, s, p)


def _random_testbed(rng: np.random.Generator, cfg: TraceConfig) -> Testbed:
    return Testbed(nodes=int(rng.choice(cfg.node_choices)),
                   bandwidth_gbps=float(rng.choice(cfg.bw_choices)),
                   topology=Topology(int(rng.choice(cfg.topo_choices))))


def generate_i_traces(cfg: TraceConfig) -> Tuple[np.ndarray, np.ndarray]:
    """i-Estimator traces: features -> log(compute seconds).

    Sampling stays scalar (it drives the RNG stream, kept draw-for-draw
    identical to the historical loop), but the tens of thousands of
    ground-truth times come from **one** ``compute_time_batch_s`` call.
    A spatial scheme is required for a nonzero halo, so every sampled
    configuration is valid by construction.
    """
    rng = np.random.default_rng(cfg.seed)
    xs: List[List[float]] = []
    factors: List[float] = []
    noise: List[float] = []
    while len(xs) < cfg.n_samples:
        layer = _random_layer(rng)
        tb = _random_testbed(rng, cfg)
        scheme = Scheme(int(rng.integers(0, 4)))
        halo = 0
        if scheme.spatial and rng.random() < 0.4:
            halo = int(rng.integers(1, 5))
        noise.append(float(np.exp(rng.normal(0.0, cfg.noise_sigma))))
        xs.append(i_features(layer, scheme, tb, halo))
        factors.append(layer.extra_flop_factor)
    X = np.asarray(xs)
    t = compute_time_batch_s(X, Testbed(), np.asarray(factors)) \
        * np.asarray(noise)
    return X, np.log(np.maximum(t, 1e-9))


def generate_s_traces(cfg: TraceConfig) -> Tuple[np.ndarray, np.ndarray]:
    """s-Estimator traces: features -> log(sync seconds).  Same structure
    as :func:`generate_i_traces`: scalar sampling, one batched
    ``sync_time_batch_s`` evaluation."""
    rng = np.random.default_rng(cfg.seed + 1)
    xs: List[List[float]] = []
    noise: List[float] = []
    while len(xs) < cfg.n_samples:
        layer = _random_layer(rng)
        tb = _random_testbed(rng, cfg)
        src = Scheme(int(rng.integers(0, 4)))
        if rng.random() < 0.1:
            nxt, dst = None, None
        else:
            nxt = _random_layer(rng)
            dst = Scheme(int(rng.integers(0, 4)))
        noise.append(float(np.exp(rng.normal(0.0, cfg.noise_sigma))))
        xs.append(s_features(layer, nxt, src, dst, tb))
    X = np.asarray(xs)
    t = sync_time_batch_s(X, Testbed()) * np.asarray(noise)
    return X, np.log(np.maximum(t, 1e-9))


def train_estimators(cfg: Optional[TraceConfig] = None,
                     gbdt_kwargs: Optional[dict] = None,
                     verbose: bool = False) -> GBDTEstimator:
    """End-to-end: sample traces from the simulator, fit both GBDTs."""
    cfg = cfg or TraceConfig()
    kw = dict(n_estimators=120, learning_rate=0.15, max_depth=7)
    kw.update(gbdt_kwargs or {})
    xi, yi = generate_i_traces(cfg)
    xs, ys = generate_s_traces(cfg)
    i_model = GBDTRegressor(**kw, seed=cfg.seed).fit(
        xi, yi, verbose_every=40 if verbose else 0)
    s_model = GBDTRegressor(**kw, seed=cfg.seed + 7).fit(
        xs, ys, verbose_every=40 if verbose else 0)
    return GBDTEstimator(i_model, s_model)
