"""Churn sweep: replanning strategies under seeded fault injection.

Replays seeded churn scenarios (``cluster.churn``) on ≥2 cluster presets
and compares the three replanning strategies — ``never``, ``scratch``,
``incremental`` — on the two metrics the elastic planner exists for:

* **time-to-recover** (per injected fault: detection delay + planner
  wall + cutover stall until steady-state serving resumes);
* **goodput** (requests served over the whole horizon, outages and
  cutover stalls at rate zero).

Per preset, the gated ``wins`` flags assert that incremental replanning
beats BOTH baselines on BOTH metrics, aggregated over the gated
scenarios (``mixed`` + ``flap``), and that it actually exercised its
reuse paths (frontier cache / registration / sync-row reuse) — these are
hard CI flags via ``check_regression --kind churn``.  Absolute timings
(planner wall, recovery seconds) are advisory on shared CPU runners:
the win *margins* are dominated by deterministic model terms (detection
delay, drain, weight movement) plus the structural wall gap between a
cold solve and a cache hit, which is why the flags are stable where raw
durations are not.

CSV rows: ``churn_<preset>_<strategy>,<planner_wall_us>,<derived>``.
``--json [PATH]`` writes the full record (default BENCH_churn.json).
"""
from __future__ import annotations

import json
import sys
from typing import Dict

import numpy as np

NOISE_NOTE = ("goodput/recovery comparisons are modeled (deterministic "
              "simulator rates + explicit detection/migration terms); "
              "only the planner-wall component varies with CPU load — "
              "win flags are gated, raw timings are advisory")

#: presets x scenario generators that the CI flags gate on
GATED_PRESETS = ("mixed_fast_slow", "stepped")
GATED_SCENARIOS = ("mixed", "flap")
MODEL = "mobilenet"
SEED = 0


def _strategy_record(r) -> Dict:
    return dict(
        goodput_rps=r.goodput_rps,
        served_requests=r.served_requests,
        mean_recovery_s=r.mean_recovery_s,
        max_recovery_s=r.max_recovery_s,
        n_faults=len(r.recoveries_s),
        n_replans=r.n_replans,
        n_keeps=r.n_keeps,
        n_migrations=r.n_migrations,
        plan_wall_us=r.plan_wall_total_s * 1e6,
        stall_s=r.stall_total_s,
        reuse=dict(r.reuse_counts),
    )


def collect(smoke: bool = True) -> Dict:
    from repro.cluster.churn import (CHURN_SCENARIOS, STRATEGIES,
                                     compare_strategies, random_scenario)
    from repro.cluster.spec import CLUSTER_PRESETS
    from repro.configs.edge_models import EDGE_MODELS

    graph = EDGE_MODELS[MODEL]()
    record: Dict = {"model": MODEL, "seed": SEED,
                    "noise_note": NOISE_NOTE, "presets": {}}
    scenario_names = GATED_SCENARIOS if smoke else tuple(CHURN_SCENARIOS)
    for pname in GATED_PRESETS:
        cluster = CLUSTER_PRESETS[pname](4)
        prec: Dict = {"scenarios": {}, "aggregate": {}, "wins": {}}
        agg = {s: dict(served=0.0, horizon=0.0, recoveries=[],
                       wall_s=0.0, keeps=0, reuse=0)
               for s in STRATEGIES}
        for sname in scenario_names:
            scen = CHURN_SCENARIOS[sname](cluster, seed=SEED)
            results = compare_strategies(graph, cluster, scen)
            prec["scenarios"][scen.name] = {
                s: _strategy_record(r) for s, r in results.items()}
            gated = sname in GATED_SCENARIOS
            for s, r in results.items():
                if not gated:
                    continue
                a = agg[s]
                a["served"] += r.served_requests
                a["horizon"] += r.horizon_s
                a["recoveries"] += list(r.recoveries_s)
                a["wall_s"] += r.plan_wall_total_s
                a["keeps"] += r.n_keeps
                a["reuse"] += sum(r.reuse_counts.values())
        if not smoke:
            # seeded random-process scenarios: advisory coverage only
            for seed in (1, 2, 3):
                scen = random_scenario(cluster, seed=seed)
                results = compare_strategies(graph, cluster, scen)
                prec["scenarios"][scen.name] = {
                    s: _strategy_record(r) for s, r in results.items()}
        for s, a in agg.items():
            prec["aggregate"][s] = dict(
                goodput_rps=a["served"] / a["horizon"],
                mean_recovery_s=float(np.mean(a["recoveries"]))
                if a["recoveries"] else 0.0,
                plan_wall_us=a["wall_s"] * 1e6,
                n_keeps=a["keeps"], reuse_total=a["reuse"])
        inc = prec["aggregate"]["incremental"]
        scr = prec["aggregate"]["scratch"]
        nev = prec["aggregate"]["never"]
        prec["wins"] = dict(
            recovery_beats_scratch=(inc["mean_recovery_s"]
                                    < scr["mean_recovery_s"]),
            recovery_beats_never=(inc["mean_recovery_s"]
                                  < nev["mean_recovery_s"]),
            goodput_beats_scratch=(inc["goodput_rps"]
                                   > scr["goodput_rps"]),
            goodput_beats_never=(inc["goodput_rps"]
                                 > nev["goodput_rps"]),
            incremental_reused=inc["reuse_total"] > 0,
        )
        record["presets"][pname] = prec
    return record


def run(smoke: bool = True, json_path: str | None = None,
        trace_dir: str | None = None) -> Dict:
    import os

    from .common import emit

    if trace_dir:
        # capture the planner-side spans (replan frontier/select/cutover,
        # detect instants) and reuse counters for the whole sweep
        from repro.obs import Metrics, Tracer, set_metrics, set_tracer, \
            write_trace
        os.makedirs(trace_dir, exist_ok=True)
        tr = Tracer()
        mx = Metrics()
        set_tracer(tr)
        set_metrics(mx)
        try:
            record = collect(smoke=smoke)
        finally:
            set_tracer(None)
            set_metrics(None)
        write_trace(os.path.join(trace_dir, "churn.trace.json"), tr)
        mx.export(os.path.join(trace_dir, "churn_metrics.json"))
    else:
        record = collect(smoke=smoke)
    for pname, prec in record["presets"].items():
        for s, a in prec["aggregate"].items():
            emit(f"churn_{pname}_{s}", a["plan_wall_us"],
                 f"goodput={a['goodput_rps']:.1f}rps "
                 f"mean_rec={a['mean_recovery_s']:.3f}s "
                 f"keeps={a['n_keeps']}")
        wins = prec["wins"]
        emit(f"churn_{pname}_wins", 0.0,
             " ".join(f"{k}={'T' if v else 'F'}"
                      for k, v in sorted(wins.items())))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
        print(f"# wrote {json_path}", file=sys.stderr)
    return record


if __name__ == "__main__":
    from .common import json_arg, trace_dir_arg
    argv = sys.argv[1:]
    print("name,us_per_call,derived")
    run(smoke="--full" not in argv,
        json_path=json_arg(argv, default="BENCH_churn.json"),
        trace_dir=trace_dir_arg(argv))
