"""Dynamic Partition Planner — Algorithm 1 (§3.3), extended to DAGs.

Reverse-order DP over T-states.  ``S[i][p]`` is the optimal remaining time
from layer ``i`` to the end, given layer ``i``'s input is exactly sharded in
layout ``p``.  NT runs appear only *inside* segments ``[i..b]`` that start and
end at T boundaries — exactly the paper's Key designs 1-3: an NT-prefixed
subsequence has indeterminate workload (footnote 3), so such states are never
evaluated on their own.

Pruning (the paper's "piecing together" list):
  1. reverse search never expands NT-start states (they exist only inside
     segment enumeration);
  2. suffix costs ``S[b+1][p']`` are reused across all segments ending at b;
  3. dynamic threshold — segment cost is monotone in segment length, so the
     backtrack stops as soon as the partial segment cost alone exceeds the
     incumbent (and when the halo swallows the whole shard, at which point
     redundant compute has degenerated into full replication).

Branched graphs (fan-in/fan-out >= 2) run the same reverse DP **per branch**
of ``ModelGraph.linearize()`` and compose at junctions: branch tails and
junction layers are forced T-mode sync points, fork deliveries are summed,
and each merge pays the max over its incoming branch re-layouts (see
``plan.dag_plan_cost`` — the DP and the cost function share one semantics,
which is what keeps the Theorem-1 oracle property on DAGs).  The junction
skeleton must be a "ladder" — parallel branch bundles between consecutive
fork/merge points, which covers residual blocks and Inception-style modules;
arbitrary multi-source or nested-fork DAGs raise ``ValueError``.

Two drivers share that search structure:

* :func:`plan_search` — the production path.  Every i-/s-cost the DP can
  touch is precomputed through ``core.cost_tables`` in one batched
  ``i_cost_batch`` + one ``s_cost_batch`` estimator call, the chain DP
  becomes numpy reductions over the scheme axis, and ``SearchStats`` is
  derived from the table masks.
* :func:`plan_search_reference` — the original scalar-call implementation,
  kept verbatim as the parity oracle.  Both estimators guarantee their
  batched entry points bit-match the scalar ones, and the batched DP
  replicates the scalar tie-breaking (first minimum wins in ``b`` then
  ``q`` order), so both drivers return bit-identical plans and costs.
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

from .cost import Testbed
from .cost_tables import (CostTableBuilder, pareto_front_2d, pareto_front_nd,
                          plan_chain_tables)
from .estimator import CostEstimator
from .graph import ModelGraph, halo_growth
from .partition import ALL_SCHEMES, Mode, Scheme, min_shard_extent
from .plan import Plan, PipelineCost

_INF = float("inf")


class Objective(enum.Enum):
    """What the planner optimizes for.

    * ``LATENCY`` — single-request inference time (the paper's objective):
      every compute and sync stage in series.
    * ``THROUGHPUT`` — steady-state pipelined serving rate: requests
      overlap, devices and links work concurrently, and the plan's period
      is the busier resource class (``PipelineCost.bottleneck_s``).
    * ``P99_BOUNDED`` — max throughput subject to an analytic
      single-request latency bound (``latency_bound_s``): the tail-latency
      proxy the serving layer refines with the simulator's real p99.
    """

    LATENCY = "latency"
    THROUGHPUT = "throughput"
    P99_BOUNDED = "p99_bounded"


def pipeline_objective_key(compute_s: float, sync_s: float,
                           objective: "Objective",
                           latency_bound_s: Optional[float] = None) -> tuple:
    """Total order over (compute, sync) cost pairs for one objective —
    shared by the DP's frontier selection and the exhaustive oracle, so
    both sides break ties identically.

    ``P99_BOUNDED`` sorts feasible plans (latency within the bound) before
    infeasible ones; when no plan is feasible both sides therefore degrade
    to the latency optimum."""
    mx = max(compute_s, sync_s)
    sm = compute_s + sync_s
    if objective == Objective.THROUGHPUT:
        return (mx, sm)
    if objective == Objective.P99_BOUNDED:
        if latency_bound_s is None:
            raise ValueError("P99_BOUNDED needs latency_bound_s")
        if sm <= latency_bound_s:
            return (0, mx, sm)
        return (1, sm, mx)
    return (sm, mx)


@dataclasses.dataclass
class SearchStats:
    i_calls: int = 0
    s_calls: int = 0
    states: int = 0
    pruned_threshold: int = 0
    pruned_halo: int = 0


@dataclasses.dataclass(frozen=True)
class SearchResult:
    plan: Plan
    cost: float
    stats: SearchStats
    #: objective the search optimized (LATENCY for the historical paths)
    objective: Objective = Objective.LATENCY
    #: per-resource-class occupancy of the plan (throughput objectives)
    pipeline: Optional[PipelineCost] = None


def plan_search(graph: ModelGraph, est: CostEstimator, tb: Testbed,
                schemes: Sequence[Scheme] = ALL_SCHEMES,
                max_segment: int = 32,
                allow_fusion: bool = True,
                objective: Objective = Objective.LATENCY,
                latency_bound_s: Optional[float] = None) -> SearchResult:
    """Run DPP from precomputed batched cost tables.  ``allow_fusion=False``
    restricts to all-T plans (the layerwise baseline); ``schemes``
    restricted to one scheme with fusion on gives the fused-layer baseline.
    Dispatches to the per-branch DAG composition when the graph is not a
    chain.  Under the default objective, returns the same plan and cost as
    :func:`plan_search_reference`, bit for bit.

    Throughput objectives (``THROUGHPUT``, ``P99_BOUNDED``) run the exact
    Pareto-frontier DP over (compute, sync) occupancy pairs from the same
    tables (see :func:`pipeline_frontier`); ``cost`` is then the pipeline
    bottleneck time and ``latency_bound_s`` feeds the P99 constraint.

    The batched tables assume the estimator is determined by the feature
    expression (the ``i_cost_batch`` contract).  Estimators that only
    implement the scalar protocol — e.g. oracles keyed on layer *names* —
    run scalar-call providers with identical search semantics."""
    if objective != Objective.LATENCY:
        fr = pipeline_frontier(graph, est, tb, schemes, max_segment,
                               allow_fusion)
        return fr.search_result(objective, latency_bound_s)
    if not hasattr(est, "i_cost_batch"):
        return plan_search_reference(graph, est, tb, schemes, max_segment,
                                     allow_fusion)
    if not graph.is_chain:
        return _dag_plan_search_batched(graph, est, tb, tuple(schemes),
                                        max_segment, allow_fusion)
    return _chain_plan_search_batched(graph, est, tb, tuple(schemes),
                                      max_segment, allow_fusion)


# ---------------------------------------------------------------------------
# Batched chain DP: numpy reductions over the (scheme x segment-length) axes.
# ---------------------------------------------------------------------------

def _chain_plan_search_batched(graph: ModelGraph, est: CostEstimator,
                               tb: Testbed, schemes: Tuple[Scheme, ...],
                               max_segment: int,
                               allow_fusion: bool) -> SearchResult:
    layers = graph.layers
    n = len(layers)
    k = len(schemes)

    builder = CostTableBuilder(est, tb)
    with _obs_trace.span(_obs_trace.PLANNER_TRACK,
                         "plan_search.table_build", cat="planner",
                         graph=graph.name, layers=n) as _sp:
        fin = plan_chain_tables(layers, builder, schemes, max_segment,
                                allow_fusion, tb.nodes, with_final=True)
        tbl = fin(*builder.evaluate())
        _sp.set(i_rows=builder.i_entries, s_rows=builder.s_entries)
    seg = tbl.seg                        # (n, k, cap), +inf = inadmissible
    cap = seg.shape[2]

    with _obs_trace.span(_obs_trace.PLANNER_TRACK,
                         "plan_search.dp_sweep", cat="planner",
                         graph=graph.name):
        S = np.full((n + 1, k), _INF)
        choice_b = np.full((n, k), -1, np.int64)
        choice_q = np.full((n, k), -1, np.int64)
        ks = np.arange(k)
        for i in range(n - 1, -1, -1):
            m = min(cap, n - i)
            # cand[p, L, q] = (seg + boundary s-cost) + suffix — the
            # same float association as the scalar reference, so costs
            # stay bit-identical
            cand = np.full((k, m, k), _INF)
            Lf = n - 1 - i                  # L index of a final segment
            if Lf < m:
                cand[:, Lf, 0] = seg[i, :, Lf] + tbl.s_final
            mn = min(m, Lf)                 # segments with a next layer
            if mn > 0:
                sb = tbl.sbound[i:i + mn].transpose(1, 0, 2)  # (p, L, q)
                cand[:, :mn, :] = (seg[i, :, :mn, None] + sb) \
                    + S[i + 1:i + 1 + mn][None, :, :]
            flat = cand.reshape(k, m * k)
            fi = np.argmin(flat, axis=1)    # first min: b-major, q-minor
            S[i] = flat[ks, fi]             # — the scalar scan order
            Lb = fi // k
            choice_b[i] = i + Lb
            choice_q[i] = np.where(Lb == Lf, -1, fi % k)

        pi = int(np.argmin(S[0]))
        total = float(S[0][pi])

    with _obs_trace.span(_obs_trace.PLANNER_TRACK,
                         "plan_search.reconstruct", cat="planner",
                         graph=graph.name):
        steps: List[Tuple[Scheme, Mode]] = []
        i = 0
        while i < n:
            b, qi = int(choice_b[i][pi]), int(choice_q[i][pi])
            p = schemes[pi]
            for m2 in range(i, b + 1):
                steps.append((p, Mode.NT if m2 < b else Mode.T))
            i = b + 1
            if qi >= 0:
                pi = qi

    stats = SearchStats(
        i_calls=builder.i_entries, s_calls=builder.s_entries,
        states=n * k, pruned_halo=tbl.halo_cuts,
        pruned_threshold=_threshold_prunes(seg, S[:n]))
    return SearchResult(plan=Plan(tuple(steps)), cost=total, stats=stats)


def _threshold_prunes(seg: np.ndarray, S: np.ndarray) -> int:
    """Dynamic-threshold prune counter, derived from the table masks: a
    state (i, p) counts as pruned when some admissible segment's i-cost
    alone already reaches the state's optimal remaining time — exactly the
    candidates the scalar backtrack refuses to extend."""
    with np.errstate(invalid="ignore"):
        hit = (seg != _INF) & (seg >= S[:, :, None]) & \
            np.isfinite(S[:, :, None])
    return int(hit.any(axis=2).sum())


# ---------------------------------------------------------------------------
# Shared per-branch chain DP with pinned boundary layouts (used by both the
# batched and reference DAG drivers — only the cost lookups differ).
# ---------------------------------------------------------------------------

def _pinned_chain_dp(n: int, schemes: Tuple[Scheme, ...],
                     seg_costs: Callable[[int, int], List[Tuple[int, float]]],
                     bound_cost: Callable[[int, int, int], float],
                     stats: SearchStats) -> Dict[Tuple[int, int],
                                                 Tuple[float, tuple]]:
    """Reverse DP over one branch with pinned boundary layouts.

    Returns ``{(head_idx, tail_idx): (cost, steps)}`` — the minimal
    *internal* cost of the branch (i-costs with halos + s-costs at internal
    T boundaries; no entry delivery, no exit delivery/gather) with the first
    segment using ``schemes[head_idx]`` and the last ``schemes[tail_idx]``.
    ``seg_costs(i, pi)`` yields the admissible ``(b, segcost)`` options in
    ascending ``b`` order (already reflecting any head pinning).
    """
    k = len(schemes)
    tables: Dict[Tuple[int, int], Tuple[float, tuple]] = {}
    for ti in range(k):
        S = [[_INF] * k for _ in range(n)]
        choice = [[(-1, -1)] * k for _ in range(n)]
        for i in range(n - 1, -1, -1):
            for pi in range(k):
                best, best_choice = _INF, (-1, -1)
                stats.states += 1
                for b, segcost in seg_costs(i, pi):
                    if segcost >= best:
                        stats.pruned_threshold += 1
                        break
                    if b == n - 1:
                        if pi == ti and segcost < best:
                            best, best_choice = segcost, (b, -1)
                    else:
                        for qi in range(k):
                            if S[b + 1][qi] == _INF:
                                continue
                            c = (segcost + bound_cost(b, pi, qi)
                                 + S[b + 1][qi])
                            if c < best:
                                best, best_choice = c, (b, qi)
                S[i][pi] = best
                choice[i][pi] = best_choice
        for pi in range(k):
            if S[0][pi] == _INF:
                continue
            steps: List[Tuple[Scheme, Mode]] = []
            i, cp = 0, pi
            while i < n:
                b, qi = choice[i][cp]
                p = schemes[cp]
                for m in range(i, b + 1):
                    steps.append((p, Mode.NT if m < b else Mode.T))
                i = b + 1
                if qi >= 0:
                    cp = qi
            tables[(pi, ti)] = (S[0][pi], tuple(steps))
    return tables


def _scalar_chain_tables(ls, icost, scost, schemes, max_segment,
                         allow_fusion, head_solo, nodes, stats):
    """Reference (scalar-call) segment/boundary providers + pinned DP."""
    seg_costs, bound_cost = _scalar_chain_providers(
        ls, icost, scost, schemes, max_segment, allow_fusion, head_solo,
        nodes, stats)
    return _pinned_chain_dp(len(ls), schemes, seg_costs, bound_cost, stats)


def _scalar_chain_providers(ls, icost, scost, schemes, max_segment,
                            allow_fusion, head_solo, nodes, stats):
    """Scalar-call ``(seg_costs, bound_cost)`` providers of one chain —
    the per-query counterpart of :class:`ChainTables` (same admissibility
    rules, same scalar accumulation order), shared by the reference DP and
    the scalar-estimator frontier paths."""
    n = len(ls)

    # Segment and boundary costs are identical across the k tail pins, so
    # compute each once (lazily) and share them between the per-tail DPs.
    seg_cache: Dict[Tuple[int, int], List[Tuple[int, float]]] = {}
    bound_cache: Dict[Tuple[int, int, int], float] = {}

    def seg_costs(i: int, pi: int) -> List[Tuple[int, float]]:
        hit = seg_cache.get((i, pi))
        if hit is not None:
            return hit
        p = schemes[pi]
        out: List[Tuple[int, float]] = []
        seg_hi = min(i + max_segment, n) if allow_fusion else i + 1
        if head_solo and i == 0:
            seg_hi = i + 1
        for b in range(i, seg_hi):
            if b > i and not p.spatial:
                break
            halos = halo_growth(ls[i:b + 1], b - i)
            if b > i and 2 * halos[0] >= min_shard_extent(ls[i], p, nodes):
                stats.pruned_halo += 1
                break
            segcost = 0.0
            for off, m in enumerate(range(i, b + 1)):
                segcost += icost(ls[m], p, halos[off] if b > i else 0)
            out.append((b, segcost))
        seg_cache[(i, pi)] = out
        return out

    def bound_cost(b: int, pi: int, qi: int) -> float:
        key = (b, pi, qi)
        hit = bound_cache.get(key)
        if hit is None:
            hit = scost(ls[b], ls[b + 1], schemes[pi], schemes[qi])
            bound_cache[key] = hit
        return hit

    return seg_costs, bound_cost


# ---------------------------------------------------------------------------
# DAG composition: per-branch chain tables + ladder DP over junctions.
# ---------------------------------------------------------------------------

def _ladder(graph: ModelGraph):
    """Condense the DAG's branches into a spine with parallel bundles.

    Returns ``(branches, spine, bundles)`` where ``spine`` is a list of
    branch indices and ``bundles[t] = (interior_branch_ids, n_direct)``
    describes the parallel branches (plus identity skip edges) between
    ``spine[t]``'s tail (the fork) and ``spine[t+1]``'s head (the merge).
    """
    branches = graph.linearize()
    n_br = len(branches)
    bidx: Dict[int, int] = {}
    for t, br in enumerate(branches):
        for i in br.ids:
            bidx[i] = t
    preds: List[set] = [set() for _ in range(n_br)]
    succs: List[set] = [set() for _ in range(n_br)]
    for i, prods in enumerate(graph.producer_ids):
        for j in prods:
            if j >= 0 and bidx[j] != bidx[i]:
                preds[bidx[i]].add(bidx[j])
                succs[bidx[j]].add(bidx[i])
    sources = [t for t in range(n_br) if not preds[t]]
    if len(sources) != 1:
        raise ValueError(
            f"{graph.name}: plan_search needs a single-source DAG "
            f"(got {len(sources)} source branches)")
    spine = [sources[0]]
    bundles: List[Tuple[List[int], int]] = []
    cur = sources[0]
    used = {cur}
    while succs[cur]:
        interior: List[int] = []
        merges: set = set()
        for b in sorted(succs[cur]):
            if graph.fan_in(branches[b].head) >= 2:
                merges.add(b)
            else:
                interior.append(b)
        for b in interior:
            if preds[b] != {cur} or len(succs[b]) != 1:
                raise ValueError(
                    f"{graph.name}: nested fork at branch {b} — only "
                    f"fork -> parallel branches -> merge ladders are "
                    f"supported by plan_search")
            merges.update(succs[b])
        if len(merges) != 1:
            raise ValueError(
                f"{graph.name}: branches from {branches[cur].tail} do not "
                f"reconverge at a single merge — not a ladder DAG")
        nxt = merges.pop()
        if not preds[nxt] <= set(interior) | {cur}:
            raise ValueError(
                f"{graph.name}: merge at layer "
                f"{graph.layers[branches[nxt].head].name} has inputs from "
                f"outside its bundle — not a ladder DAG")
        n_direct = sum(1 for j in graph.producer_ids[branches[nxt].head]
                       if j == branches[cur].tail)
        bundles.append((interior, n_direct))
        spine.append(nxt)
        used.add(nxt)
        used.update(interior)
        cur = nxt
    if len(used) != n_br:
        raise ValueError(f"{graph.name}: {n_br - len(used)} branches are "
                         f"unreachable along the ladder — unsupported DAG")
    return branches, spine, bundles


def _dag_compose(graph: ModelGraph, schemes: Tuple[Scheme, ...],
                 btable: Callable[[int, bool], Dict],
                 jscost: Callable[[int, Optional[int], int, Optional[int]],
                                  float],
                 stats: SearchStats) -> SearchResult:
    """Ladder DP over junctions, shared by the batched and reference
    drivers.  ``btable(branch, head_solo)`` returns the pinned chain tables
    of one branch; ``jscost(prod_id, cons_id, pi, qi)`` the junction
    delivery s-cost (``cons_id=None``/``qi=None`` is the final gather)."""
    branches, spine, bundles = _ladder(graph)
    layers = graph.layers
    k = len(schemes)
    K = len(spine)

    spine_tab = [btable(s, idx > 0) for idx, s in enumerate(spine)]
    interior_tab = {b: btable(b, False)
                    for ints, _ in bundles for b in ints}

    # min over head schemes of (fork delivery + branch internal cost), per
    # (fork tail scheme, branch tail scheme)
    ib_memo: Dict[Tuple[int, int, int], Tuple[float, int]] = {}

    def ib_entry(b: int, qf_i: int, pt_i: int) -> Tuple[float, int]:
        key = (b, qf_i, pt_i)
        hit = ib_memo.get(key)
        if hit is not None:
            return hit
        fork_id = graph.producer_ids[branches[b].head][0]
        head_id = branches[b].head
        best: Tuple[float, int] = (_INF, -1)
        for ph_i in range(k):
            e = interior_tab[b].get((ph_i, pt_i))
            if e is None:
                continue
            c = jscost(fork_id, head_id, qf_i, ph_i) + e[0]
            if c < best[0]:
                best = (c, ph_i)
        ib_memo[key] = best
        return best

    bundle_memo: Dict[Tuple[int, int, int], Tuple[float, Optional[list]]] = {}

    def bundle_solve(t: int, pt_i: int, qm_i: int):
        """Min cost of delivering the bundle between spine t and t+1, given
        the fork tail scheme and merge head scheme.  Per-branch internal and
        fork-delivery costs sum; merge deliveries combine with max.  Exact:
        enumerate which delivery attains the max, pin it, and let every
        other branch independently take its cheapest option whose delivery
        fits under it.

        The candidate scan is vectorized over the (branch x tail-scheme)
        option tables: one (candidate, branch, scheme) feasibility tensor,
        first-min reductions matching the scalar tie-breaking, and a
        branch-ordered accumulation that keeps totals bit-identical to the
        historical per-candidate loop (matters for wide Inception-style
        bundles, where candidates x branches x schemes dominates)."""
        key = (t, pt_i, qm_i)
        hit = bundle_memo.get(key)
        if hit is not None:
            return hit
        ints, n_direct = bundles[t]
        fork_id = branches[spine[t]].tail
        merge_id = branches[spine[t + 1]].head
        d0 = jscost(fork_id, merge_id, pt_i, qm_i) if n_direct else None
        if not ints:
            res = (d0 if d0 is not None else 0.0, [])
            bundle_memo[key] = res
            return res
        nb = len(ints)
        # option tables, indexed by tail-scheme pti (inf = infeasible)
        C = np.full((nb, k), _INF)    # fork delivery + branch internal cost
        D = np.full((nb, k), _INF)    # merge delivery cost
        PH = np.full((nb, k), -1, np.int64)
        for bi, b in enumerate(ints):
            tail_id = branches[b].tail
            for pti in range(k):
                c, ph_i = ib_entry(b, pt_i, pti)
                if c == _INF:
                    continue
                C[bi, pti] = c
                D[bi, pti] = jscost(tail_id, merge_id, pti, qm_i)
                PH[bi, pti] = ph_i
            if not np.isfinite(C[bi]).any():
                bundle_memo[key] = (_INF, None)
                return (_INF, None)
        # candidates for "which delivery attains the merge max", in the
        # scalar scan order: the direct skip edge first, then options
        # branch-major / scheme-minor
        fbi, foi = np.nonzero(np.isfinite(C))
        m_vec = D[fbi, foi]
        fb = fbi
        fo = foi
        if d0 is not None:
            m_vec = np.concatenate(([d0], m_vec))
            fb = np.concatenate(([-1], fb))
            fo = np.concatenate(([-1], fo))
        feas = D[None, :, :] <= m_vec[:, None, None]
        cm = np.where(feas, C[None, :, :], _INF)
        best_oi = np.argmin(cm, axis=2)               # first min, pti order
        bc = np.take_along_axis(cm, best_oi[:, :, None], 2)[:, :, 0]
        bc_eff = bc.copy()
        rows = np.arange(len(m_vec))
        pin = fb >= 0
        bc_eff[rows[pin], fb[pin]] = C[fb[pin], fo[pin]]
        valid = np.isfinite(bc).all(axis=1)
        if d0 is not None:
            valid &= d0 <= m_vec
        totals = m_vec.copy()
        for bi in range(nb):          # branch order = scalar accumulation
            totals = totals + bc_eff[:, bi]
        totals = np.where(valid, totals, _INF)
        win = int(np.argmin(totals))
        best_total = float(totals[win])
        if best_total == _INF:
            bundle_memo[key] = (_INF, None)
            return (_INF, None)
        best_assign = []
        for bi in range(nb):
            pti = int(fo[win]) if bi == fb[win] else int(best_oi[win, bi])
            best_assign.append((ints[bi], int(PH[bi, pti]), pti))
        bundle_memo[key] = (best_total, best_assign)
        return best_total, best_assign

    # ---- spine DP (reverse) -----------------------------------------------
    # V[t][ph] = (cost from spine t's head onward, tail scheme, next head)
    V: List[Dict[int, Tuple[float, int, int]]] = [dict() for _ in range(K)]
    tail_id = branches[spine[-1]].tail
    for ph_i in range(k):
        best = (_INF, -1, -1)
        for pt_i in range(k):
            e = spine_tab[K - 1].get((ph_i, pt_i))
            if e is None:
                continue
            c = e[0] + jscost(tail_id, None, pt_i, None)
            if c < best[0]:
                best = (c, pt_i, -1)
        if best[0] < _INF:
            V[K - 1][ph_i] = best
    for t in range(K - 2, -1, -1):
        for ph_i in range(k):
            best = (_INF, -1, -1)
            for pt_i in range(k):
                e = spine_tab[t].get((ph_i, pt_i))
                if e is None:
                    continue
                for ph2, (suffix, _, _) in V[t + 1].items():
                    bc, _assign = bundle_solve(t, pt_i, ph2)
                    c = e[0] + bc + suffix
                    if c < best[0]:
                        best = (c, pt_i, ph2)
            if best[0] < _INF:
                V[t][ph_i] = best
    if not V[0]:
        raise RuntimeError(f"{graph.name}: no feasible plan found")
    ph = min(V[0], key=lambda p: V[0][p][0])
    total = V[0][ph][0]

    # ---- reconstruction ---------------------------------------------------
    steps: List[Optional[Tuple[Scheme, Mode]]] = [None] * len(layers)
    for t in range(K):
        _, pt_i, ph_next = V[t][ph]
        for idx, st in zip(branches[spine[t]].ids,
                           spine_tab[t][(ph, pt_i)][1]):
            steps[idx] = st
        if t < K - 1:
            _, assign = bundle_solve(t, pt_i, ph_next)
            for b, ph_b, pt_b in assign:
                for idx, st in zip(branches[b].ids,
                                   interior_tab[b][(ph_b, pt_b)][1]):
                    steps[idx] = st
            ph = ph_next
    return SearchResult(plan=Plan(tuple(steps)), cost=total, stats=stats)


def _dag_plan_search_batched(graph: ModelGraph, est: CostEstimator,
                             tb: Testbed, schemes: Tuple[Scheme, ...],
                             max_segment: int,
                             allow_fusion: bool) -> SearchResult:
    """Batched DAG driver: register every branch segment/boundary and every
    junction delivery with one table builder, evaluate in a single pair of
    batched estimator calls, then run the shared ladder composition from
    the tables."""
    stats = SearchStats()
    layers = graph.layers
    branches = graph.linearize()

    builder = CostTableBuilder(est, tb)
    # geometrically identical branches (resnet101 repeats one bottleneck
    # body 23x) share one table registration and one pinned DP
    bkeys = [tuple(builder.layer_key(layers[i]) for i in br.ids)
             for br in branches]
    uniq: Dict[tuple, int] = {}
    finalizers = []
    for t, key in enumerate(bkeys):
        if key not in uniq:
            uniq[key] = len(finalizers)
            ls = [layers[i] for i in branches[t].ids]
            finalizers.append(plan_chain_tables(
                ls, builder, schemes, max_segment, allow_fusion, tb.nodes,
                with_final=False))

    # junction deliveries: every cross-branch (producer tail, consumer)
    # edge plus the final gather, all (src, dst) scheme pairs
    jidx: Dict[Tuple[int, Optional[int], int, Optional[int]], int] = {}
    for br in branches:
        tail = br.ids[-1]
        consumers = graph.consumer_ids[tail]
        if not consumers:
            for pi, p in enumerate(schemes):
                jidx[(tail, None, pi, None)] = builder.s_index(
                    layers[tail], None, p, None)
        for c in consumers:
            for pi, p in enumerate(schemes):
                for qi, q in enumerate(schemes):
                    jidx[(tail, c, pi, qi)] = builder.s_index(
                        layers[tail], layers[c], p, q)

    ivals, svals = builder.evaluate()
    utables = [fin(ivals, svals) for fin in finalizers]
    stats.i_calls = builder.i_entries
    stats.s_calls = builder.s_entries
    stats.pruned_halo = sum(utables[u].halo_cuts for u in uniq.values())

    dp_memo: Dict[Tuple[int, bool], Dict] = {}

    def btable(t: int, head_solo: bool):
        u = uniq[bkeys[t]]
        hit = dp_memo.get((u, head_solo))
        if hit is not None:
            return hit
        tbl = utables[u]

        def seg_costs(i: int, pi: int):
            return tbl.seg_options(i, pi, head_solo)

        out = _pinned_chain_dp(len(branches[t]), schemes, seg_costs,
                               tbl.bound, stats)
        dp_memo[(u, head_solo)] = out
        return out

    def jscost(prod: int, cons: Optional[int], pi: int,
               qi: Optional[int]) -> float:
        return float(svals[jidx[(prod, cons, pi, qi)]])

    return _dag_compose(graph, schemes, btable, jscost, stats)


# ---------------------------------------------------------------------------
# Reference (scalar-call) driver — kept as the parity/benchmark oracle.
# ---------------------------------------------------------------------------

def plan_search_reference(graph: ModelGraph, est: CostEstimator, tb: Testbed,
                          schemes: Sequence[Scheme] = ALL_SCHEMES,
                          max_segment: int = 32,
                          allow_fusion: bool = True) -> SearchResult:
    """Scalar-call DPP: one ``est.i_cost``/``est.s_cost`` invocation per
    sample.  Semantically identical to :func:`plan_search`; retained as the
    exactness oracle and the benchmark baseline."""
    if not graph.is_chain:
        return _dag_plan_search_reference(graph, est, tb, tuple(schemes),
                                          max_segment, allow_fusion)
    layers = graph.layers
    n = len(layers)
    k = len(schemes)
    stats = SearchStats()

    S: List[List[float]] = [[_INF] * k for _ in range(n + 1)]
    # choice[i][pi] = (segment_end_b, next_scheme_index or -1)
    choice: List[List[Tuple[int, int]]] = [[(-1, -1)] * k for _ in range(n + 1)]

    for i in range(n - 1, -1, -1):
        for pi, p in enumerate(schemes):
            best, best_choice = _INF, (-1, -1)
            stats.states += 1
            seg_hi = min(i + max_segment, n) if allow_fusion else i + 1
            for b in range(i, seg_hi):
                if b > i and not p.spatial:
                    break  # OutC cannot fuse (NT undefined)
                halos = halo_growth(layers[i:b + 1], b - i)
                if b > i and 2 * halos[0] >= min_shard_extent(
                        layers[i], p, tb.nodes):
                    stats.pruned_halo += 1
                    break  # halo degenerated into replication
                segcost = 0.0
                for off, m in enumerate(range(i, b + 1)):
                    segcost += est.i_cost(layers[m], p, tb,
                                          extra_halo=halos[off] if b > i else 0)
                    stats.i_calls += 1
                if segcost >= best:
                    stats.pruned_threshold += 1
                    break  # dynamic threshold: monotone in b
                if b == n - 1:
                    stats.s_calls += 1
                    c = segcost + est.s_cost(layers[b], None, p, None, tb)
                    if c < best:
                        best, best_choice = c, (b, -1)
                else:
                    for qi, q in enumerate(schemes):
                        if S[b + 1][qi] == _INF:
                            continue
                        stats.s_calls += 1
                        c = (segcost
                             + est.s_cost(layers[b], layers[b + 1], p, q, tb)
                             + S[b + 1][qi])
                        if c < best:
                            best, best_choice = c, (b, qi)
            S[i][pi] = best
            choice[i][pi] = best_choice

    pi = min(range(k), key=lambda j: S[0][j])
    total = S[0][pi]
    steps: List[Tuple[Scheme, Mode]] = []
    i = 0
    while i < n:
        b, qi = choice[i][pi]
        p = schemes[pi]
        for m in range(i, b + 1):
            steps.append((p, Mode.NT if m < b else Mode.T))
        i = b + 1
        if qi >= 0:
            pi = qi
    return SearchResult(plan=Plan(tuple(steps)), cost=total, stats=stats)


def _dag_plan_search_reference(graph: ModelGraph, est: CostEstimator,
                               tb: Testbed, schemes: Tuple[Scheme, ...],
                               max_segment: int,
                               allow_fusion: bool) -> SearchResult:
    stats = SearchStats()
    layers = graph.layers

    def icost(l, p, halo=0):
        stats.i_calls += 1
        return est.i_cost(l, p, tb, extra_halo=halo)

    def scost(l, nxt, s, d):
        stats.s_calls += 1
        return est.s_cost(l, nxt, s, d, tb)

    branches = graph.linearize()

    def btable(t: int, head_solo: bool):
        ls = [layers[i] for i in branches[t].ids]
        return _scalar_chain_tables(ls, icost, scost, schemes, max_segment,
                                    allow_fusion, head_solo, tb.nodes, stats)

    def jscost(prod: int, cons: Optional[int], pi: int,
               qi: Optional[int]) -> float:
        return scost(layers[prod], None if cons is None else layers[cons],
                     schemes[pi], None if qi is None else schemes[qi])

    return _dag_compose(graph, schemes, btable, jscost, stats)


# ---------------------------------------------------------------------------
# Pipelined-cost objectives: exact Pareto-frontier DP over (compute, sync)
# occupancy pairs.
#
# Under pipelined serving the two resource classes overlap across requests,
# so a plan's steady-state period is max(sum of segment i-costs, sum of
# sync s-costs) — see ``plan.PipelineCost``.  Both that bottleneck and the
# single-request latency (the sum) are monotone in the pair, and every DP
# composition step (segment extension, boundary crossing, fork delivery,
# merge max, bundle/spine concatenation) is monotone too, so propagating
# nondominated (compute, sync) suffix sets is exact for *any* monotone
# objective of the pair.  One frontier therefore serves THROUGHPUT,
# P99_BOUNDED and latency selection — and the simulator-in-the-loop
# refinement, which only rescales the two axes (``cluster.refine``).
#
# The frontier runs from the same batched cost tables as the latency DP
# (one i_cost_batch + one s_cost_batch call; the per-state merges are
# numpy lexsort/cummin reductions — no scalar estimator fallback).  A
# latency-optimal search seeds the upper bound: any partial pair with a
# coordinate beyond the latency optimum can never win (completions only
# add), which keeps suffix frontiers small.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _FSet:
    """One state's nondominated suffix set: parallel point arrays."""

    a: np.ndarray                  # compute occupancy (sum of i-costs)
    b: np.ndarray                  # sync occupancy (sum of s-costs)
    back: tuple                    # per-point reconstruction payload


def _chain_frontier(n: int, k: int, seg_options, bound, final,
                    ub: float, stats: SearchStats,
                    warm: Optional[Tuple[int, list]] = None):
    """Reverse Pareto DP over one full chain (final gather included).

    ``F[i][pi]`` holds the nondominated (compute, sync) suffix pairs from
    layer ``i`` given segment scheme ``schemes[pi]``; back-pointers are
    ``(segment_end, next_scheme_or_-1, next_point)``.

    ``warm=(start, F_prev)`` warm-starts from surviving suffix frontiers:
    the caller has verified that every table row reachable from layers
    ``>= start`` is unchanged since ``F_prev`` was computed (segment costs,
    boundary syncs and the final gather), so those suffix sets are reused
    verbatim and the reverse DP only recomputes layers ``< start``.
    ``stats.states`` counts recomputed states only.
    """
    start = n if warm is None else warm[0]
    F: List[List[Optional[_FSet]]] = [[None] * k for _ in range(n)]
    if warm is not None:
        for i in range(start, n):
            F[i] = list(warm[1][i])
    for i in range(min(start, n) - 1, -1, -1):
        for pi in range(k):
            As: List[np.ndarray] = []
            Bs: List[np.ndarray] = []
            Eb: List[np.ndarray] = []
            Qs: List[np.ndarray] = []
            Nx: List[np.ndarray] = []
            for bnd, segcost in seg_options(i, pi):
                if bnd == n - 1:
                    As.append(np.asarray([segcost]))
                    Bs.append(np.asarray([final(pi)]))
                    Eb.append(np.asarray([bnd]))
                    Qs.append(np.asarray([-1]))
                    Nx.append(np.asarray([-1]))
                    continue
                for qi in range(k):
                    Fn = F[bnd + 1][qi]
                    if Fn is None:
                        continue
                    m = len(Fn.a)
                    As.append(segcost + Fn.a)
                    Bs.append(bound(bnd, pi, qi) + Fn.b)
                    Eb.append(np.full(m, bnd))
                    Qs.append(np.full(m, qi))
                    Nx.append(np.arange(m))
            if not As:
                continue
            a = np.concatenate(As)
            b = np.concatenate(Bs)
            keep = pareto_front_2d(a, b, ub)
            if not len(keep):
                continue
            stats.states += len(keep)
            F[i][pi] = _FSet(a[keep], b[keep],
                             (np.concatenate(Eb)[keep],
                              np.concatenate(Qs)[keep],
                              np.concatenate(Nx)[keep]))
    return F


def _chain_plan_from(F, schemes: Tuple[Scheme, ...], pi: int,
                     idx: int) -> Plan:
    steps: List[Tuple[Scheme, Mode]] = []
    i = 0
    while True:
        fs = F[i][pi]
        bnd = int(fs.back[0][idx])
        qi = int(fs.back[1][idx])
        nxt = int(fs.back[2][idx])
        p = schemes[pi]
        for m in range(i, bnd + 1):
            steps.append((p, Mode.NT if m < bnd else Mode.T))
        if qi < 0:
            return Plan(tuple(steps))
        i, pi, idx = bnd + 1, qi, nxt


def _pinned_pareto_tables(n: int, schemes: Tuple[Scheme, ...], seg_costs,
                          bound_cost, ub: float, stats: SearchStats) -> Dict:
    """Per-branch Pareto counterpart of :func:`_pinned_chain_dp`.

    Returns ``{(head_idx, tail_idx): (a, b, steps)}`` — the nondominated
    *internal* (compute, sync) pairs of the branch with pinned head/tail
    schemes, with the realizing step tuples materialised per point
    (branches are short, so eager reconstruction is cheap).
    """
    k = len(schemes)
    out: Dict[Tuple[int, int], Tuple[np.ndarray, np.ndarray, list]] = {}
    for ti in range(k):
        F: List[List[Optional[_FSet]]] = [[None] * k for _ in range(n)]
        for i in range(n - 1, -1, -1):
            for pi in range(k):
                As, Bs, Eb, Qs, Nx = [], [], [], [], []
                for bnd, segcost in seg_costs(i, pi):
                    if bnd == n - 1:
                        if pi != ti:
                            continue
                        As.append(np.asarray([segcost]))
                        Bs.append(np.asarray([0.0]))
                        Eb.append(np.asarray([bnd]))
                        Qs.append(np.asarray([-1]))
                        Nx.append(np.asarray([-1]))
                        continue
                    for qi in range(k):
                        Fn = F[bnd + 1][qi]
                        if Fn is None:
                            continue
                        m = len(Fn.a)
                        As.append(segcost + Fn.a)
                        Bs.append(bound_cost(bnd, pi, qi) + Fn.b)
                        Eb.append(np.full(m, bnd))
                        Qs.append(np.full(m, qi))
                        Nx.append(np.arange(m))
                if not As:
                    continue
                a = np.concatenate(As)
                b = np.concatenate(Bs)
                keep = pareto_front_2d(a, b, ub)
                if not len(keep):
                    continue
                stats.states += len(keep)
                F[i][pi] = _FSet(a[keep], b[keep],
                                 (np.concatenate(Eb)[keep],
                                  np.concatenate(Qs)[keep],
                                  np.concatenate(Nx)[keep]))
        for pi in range(k):
            if F[0][pi] is None:
                continue
            fs = F[0][pi]
            steps = [tuple(_chain_plan_from(F, schemes, pi, j).steps)
                     for j in range(len(fs.a))]
            out[(pi, ti)] = (fs.a, fs.b, steps)
    return out


def _dag_pipeline_frontier(graph: ModelGraph, schemes: Tuple[Scheme, ...],
                           ptable, jscost, ub: float, stats: SearchStats):
    """Ladder composition of per-branch Pareto tables.

    Returns ``(points, build_plan)``: the root nondominated set over the
    whole DAG plus a reconstruction callable.  Mirrors ``_dag_compose``
    stage semantics — fork deliveries add to the sync axis, each merge
    contributes the max over its incoming deliveries (one merge stage),
    the spine tail pays the final gather.
    """
    branches, spine, bundles = _ladder(graph)
    k = len(schemes)
    K = len(spine)

    spine_tab = [ptable(s, idx > 0) for idx, s in enumerate(spine)]
    interior_tab = {b: ptable(b, False)
                    for ints, _ in bundles for b in ints}

    bundle_memo: Dict[Tuple[int, int, int], Optional[tuple]] = {}

    def bundle_frontier(t: int, pt_i: int, qm_i: int) -> Optional[tuple]:
        """Nondominated (compute, sync) contributions of bundle ``t`` given
        fork tail / merge head schemes; back payload = per-interior-branch
        ``(branch_id, steps)`` assignments."""
        key = (t, pt_i, qm_i)
        if key in bundle_memo:
            return bundle_memo[key]
        ints, n_direct = bundles[t]
        fork_id = branches[spine[t]].tail
        merge_id = branches[spine[t + 1]].head
        d0 = jscost(fork_id, merge_id, pt_i, qm_i) if n_direct else None
        if not ints:
            res = (np.zeros(1), np.asarray([d0 if d0 is not None else 0.0]),
                   [()])
            bundle_memo[key] = res
            return res
        opts = []
        for b in ints:
            head_id = branches[b].head
            tail_id = branches[b].tail
            fid = graph.producer_ids[head_id][0]
            A, B, D, back = [], [], [], []
            for (ph_i, pti), (aa, bb, steps) in interior_tab[b].items():
                fork = jscost(fid, head_id, pt_i, ph_i)
                d = jscost(tail_id, merge_id, pti, qm_i)
                for j in range(len(aa)):
                    A.append(float(aa[j]))
                    B.append(fork + float(bb[j]))
                    D.append(d)
                    back.append((b, steps[j]))
            if not A:
                bundle_memo[key] = None
                return None
            keep = pareto_front_nd([np.asarray(A), np.asarray(B),
                                    np.asarray(D)])
            opts.append((np.asarray(A)[keep], np.asarray(B)[keep],
                         np.asarray(D)[keep], [back[j] for j in keep]))
        shapes = [len(o[0]) for o in opts]
        grid = np.indices(shapes).reshape(len(opts), -1)
        A = np.zeros(grid.shape[1])
        B = np.zeros(grid.shape[1])
        Ds = []
        for o, g in zip(opts, grid):
            A = A + o[0][g]
            B = B + o[1][g]
            Ds.append(o[2][g])
        D = np.maximum.reduce(Ds)
        if d0 is not None:
            D = np.maximum(D, d0)
        b_tot = B + D
        keep = pareto_front_2d(A, b_tot, ub)
        if not len(keep):
            bundle_memo[key] = None
            return None
        back_out = [tuple(opts[bi][3][int(grid[bi, j])]
                          for bi in range(len(opts))) for j in keep]
        res = (A[keep], b_tot[keep], back_out)
        bundle_memo[key] = res
        return res

    # ---- spine DP (reverse): V[t][ph] = suffix frontier -------------------
    # back payload: (pt, branch_point, bundle_assign, next_head, next_point)
    V: List[Dict[int, tuple]] = [dict() for _ in range(K)]
    tail_id = branches[spine[-1]].tail
    for ph_i in range(k):
        As, Bs, back = [], [], []
        for pt_i in range(k):
            e = spine_tab[K - 1].get((ph_i, pt_i))
            if e is None:
                continue
            gather = jscost(tail_id, None, pt_i, None)
            aa, bb, _steps = e
            for j in range(len(aa)):
                As.append(float(aa[j]))
                Bs.append(float(bb[j]) + gather)
                back.append((pt_i, j, (), -1, -1))
        if not As:
            continue
        a = np.asarray(As)
        b = np.asarray(Bs)
        keep = pareto_front_2d(a, b, ub)
        if len(keep):
            stats.states += len(keep)
            V[K - 1][ph_i] = (a[keep], b[keep], [back[j] for j in keep])
    for t in range(K - 2, -1, -1):
        for ph_i in range(k):
            As, Bs = [], []
            chunks = []           # (offset, pt, ph2, shape, bundle_back)
            total = 0
            for pt_i in range(k):
                e = spine_tab[t].get((ph_i, pt_i))
                if e is None:
                    continue
                ea, eb, _steps = e
                for ph2, (sa, sb, _sback) in V[t + 1].items():
                    bf = bundle_frontier(t, pt_i, ph2)
                    if bf is None:
                        continue
                    ba, bb2, bback = bf
                    A3 = ea[:, None, None] + ba[None, :, None] \
                        + sa[None, None, :]
                    B3 = eb[:, None, None] + bb2[None, :, None] \
                        + sb[None, None, :]
                    As.append(A3.ravel())
                    Bs.append(B3.ravel())
                    chunks.append((total, pt_i, ph2, A3.shape, bback))
                    total += A3.size
            if not As:
                continue
            a = np.concatenate(As)
            b = np.concatenate(Bs)
            keep = pareto_front_2d(a, b, ub)
            if not len(keep):
                continue
            stats.states += len(keep)
            offs = [c[0] for c in chunks]
            back = []
            for j in keep:
                ci = bisect.bisect_right(offs, int(j)) - 1
                off, pt_i, ph2, (m1, m2, m3), bback = chunks[ci]
                e1, rem = divmod(int(j) - off, m2 * m3)
                e2, e3 = divmod(rem, m3)
                back.append((pt_i, e1, bback[e2], ph2, e3))
            V[t][ph_i] = (a[keep], b[keep], back)

    if not V[0]:
        raise RuntimeError(f"{graph.name}: no feasible plan found")

    roots = []                    # (ph, point_idx) per root frontier point
    As, Bs = [], []
    for ph_i, (a, b, _back) in V[0].items():
        for j in range(len(a)):
            As.append(float(a[j]))
            Bs.append(float(b[j]))
            roots.append((ph_i, j))
    a = np.asarray(As)
    b = np.asarray(Bs)
    keep = pareto_front_2d(a, b, ub)
    points = np.stack([a[keep], b[keep]], axis=1)
    kept_roots = [roots[int(j)] for j in keep]

    def build_plan(idx: int) -> Plan:
        ph_i, j = kept_roots[idx]
        steps: List[Optional[Tuple[Scheme, Mode]]] = [None] * len(graph)
        t = 0
        while True:
            _a, _b, back = V[t][ph_i]
            pt_i, e_idx, assign, ph2, nxt = back[j]
            for lid, st in zip(branches[spine[t]].ids,
                               spine_tab[t][(ph_i, pt_i)][2][e_idx]):
                steps[lid] = st
            if ph2 < 0:
                return Plan(tuple(steps))
            for bid, bsteps in assign:
                for lid, st in zip(branches[bid].ids, bsteps):
                    steps[lid] = st
            ph_i, j = ph2, nxt
            t += 1

    return points, build_plan


@dataclasses.dataclass
class PlanFrontier:
    """Latency/throughput Pareto frontier of one planning problem.

    ``points[i] = (compute_s, sync_s)`` — nondominated per-resource-class
    occupancy pairs over valid plans, compute ascending.  Every monotone
    objective of the pair has its optimum on this set, so selection (and
    the simulator-in-the-loop re-weighting, which only scales the axes)
    never rebuilds the tables.

    Built with ``prune_ub=True`` (the ``plan_search`` default) the set is
    additionally trimmed to points whose coordinates stay within the
    latency optimum — exact for the *unscaled* objectives (a coordinate
    beyond the latency optimum can never win ``max(a, b)`` or the bounded
    variants) but potentially missing extreme points that only win under
    strong axis re-weighting; build with ``prune_ub=False`` (what
    ``cluster.refine`` does) when scaled re-selection must be exact over
    the complete set.
    """

    schemes: Tuple[Scheme, ...]
    points: np.ndarray
    stats: SearchStats
    _build: Callable[[int], Plan]

    def __len__(self) -> int:
        return len(self.points)

    def plan(self, idx: int) -> Plan:
        """Materialise the plan realizing ``points[idx]``."""
        return self._build(int(idx))

    def select(self, objective: Objective = Objective.THROUGHPUT,
               latency_bound_s: Optional[float] = None,
               compute_scale: float = 1.0,
               sync_scale: float = 1.0) -> int:
        """Index of the objective-optimal point.  ``compute_scale`` /
        ``sync_scale`` re-weight the two resource classes (the refinement
        loop sets them from simulator occupancy measurements)."""
        best = None
        best_key = None
        for i in range(len(self.points)):
            key = pipeline_objective_key(
                float(self.points[i, 0]) * compute_scale,
                float(self.points[i, 1]) * sync_scale,
                objective, latency_bound_s)
            if best_key is None or key < best_key:
                best, best_key = i, key
        if best is None:
            raise RuntimeError("empty frontier")
        return best

    def search_result(self, objective: Objective,
                      latency_bound_s: Optional[float] = None
                      ) -> SearchResult:
        i = self.select(objective, latency_bound_s)
        a, b = float(self.points[i, 0]), float(self.points[i, 1])
        return SearchResult(plan=self.plan(i), cost=max(a, b),
                            stats=self.stats, objective=objective,
                            pipeline=PipelineCost(a, b))


@dataclasses.dataclass
class FrontierTables:
    """Reusable registration artifacts of one ``pipeline_frontier`` problem.

    Splits the batched frontier build into its three phases so incremental
    replanning (``cluster.elastic``) can redo only what a cluster event
    invalidated:

    1. **register** — enumerate/dedup every admissible segment, boundary
       and junction query (the Python-heavy phase).  Depends only on graph
       geometry and the testbed projection, so any capability change that
       leaves ``cluster.compat_testbed()`` intact reuses it wholesale.
    2. **evaluate** — resolve the registered rows in one
       ``i_cost_batch``/``s_cost_batch`` pair.  ``est`` swaps the
       estimator (same rows, new capabilities); ``ivals``/``svals`` reuse
       a cached side verbatim (a derate dirties only i-rows, a link change
       only s-rows).
    3. **frontier** — assemble tables and run the Pareto DP.  Consecutive
       calls on one instance warm-start from the previous build: chain
       suffix frontiers whose reachable table rows are value-identical are
       reused (``_chain_frontier(warm=...)``); on DAGs, per-unique-branch
       pinned Pareto tables are reused when that branch's seg/bound rows
       are unchanged.  ``last_reuse`` reports what fired.

    ``pipeline_frontier`` routes every batched build through a fresh
    instance, so the one-shot path and the incremental path are the same
    code — a warm rebuild is bit-identical to a scratch build by
    construction (the reused suffix sets are recomputed-value-equal).
    """

    graph: ModelGraph
    tb: Testbed
    schemes: Tuple[Scheme, ...]
    max_segment: int
    allow_fusion: bool
    builder: CostTableBuilder
    _chain_fin: Optional[Callable] = None
    _branches: Optional[list] = None
    _bkeys: Optional[list] = None
    _uniq: Optional[Dict] = None
    _finalizers: Optional[list] = None
    _jidx: Optional[Dict] = None
    #: what the most recent :meth:`frontier` call reused from the previous
    #: build on this instance (empty before the first build)
    last_reuse: Dict = dataclasses.field(default_factory=dict)
    _last: Optional[Dict] = dataclasses.field(default=None, repr=False)

    @classmethod
    def register(cls, graph: ModelGraph, est: CostEstimator, tb: Testbed,
                 schemes: Sequence[Scheme] = ALL_SCHEMES,
                 max_segment: int = 32,
                 allow_fusion: bool = True) -> "FrontierTables":
        """Phase 1: build the query registration for ``graph`` on ``tb``.
        ``est`` must implement the batched protocol; it is only stored as
        the default evaluator (registration never calls it)."""
        with _obs_trace.span(_obs_trace.PLANNER_TRACK,
                             "frontier.register", cat="planner",
                             graph=graph.name):
            return cls._register(graph, est, tb, schemes, max_segment,
                                 allow_fusion)

    @classmethod
    def _register(cls, graph: ModelGraph, est: CostEstimator, tb: Testbed,
                  schemes: Sequence[Scheme] = ALL_SCHEMES,
                  max_segment: int = 32,
                  allow_fusion: bool = True) -> "FrontierTables":
        if not hasattr(est, "i_cost_batch"):
            raise TypeError("FrontierTables requires the batched estimator "
                            "protocol (est.i_cost_batch)")
        schemes_t = tuple(schemes)
        builder = CostTableBuilder(est, tb)
        if graph.is_chain:
            fin = plan_chain_tables(graph.layers, builder, schemes_t,
                                    max_segment, allow_fusion, tb.nodes,
                                    with_final=True)
            return cls(graph, tb, schemes_t, max_segment, allow_fusion,
                       builder, _chain_fin=fin)
        layers = graph.layers
        branches = graph.linearize()
        bkeys = [tuple(builder.layer_key(layers[i]) for i in br.ids)
                 for br in branches]
        uniq: Dict[tuple, int] = {}
        finalizers: List[Callable] = []
        for t, bkey in enumerate(bkeys):
            if bkey not in uniq:
                uniq[bkey] = len(finalizers)
                ls = [layers[i] for i in branches[t].ids]
                finalizers.append(plan_chain_tables(
                    ls, builder, schemes_t, max_segment, allow_fusion,
                    tb.nodes, with_final=False))
        jidx: Dict[Tuple[int, Optional[int], int, Optional[int]], int] = {}
        for br in branches:
            tail = br.ids[-1]
            consumers = graph.consumer_ids[tail]
            if not consumers:
                for pi, p in enumerate(schemes_t):
                    jidx[(tail, None, pi, None)] = builder.s_index(
                        layers[tail], None, p, None)
            for c in consumers:
                for pi, p in enumerate(schemes_t):
                    for qi, q in enumerate(schemes_t):
                        jidx[(tail, c, pi, qi)] = builder.s_index(
                            layers[tail], layers[c], p, q)
        return cls(graph, tb, schemes_t, max_segment, allow_fusion, builder,
                   _branches=branches, _bkeys=bkeys, _uniq=uniq,
                   _finalizers=finalizers, _jidx=jidx)

    def evaluate(self, est: Optional[CostEstimator] = None,
                 ivals: Optional[np.ndarray] = None,
                 svals: Optional[np.ndarray] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Phase 2: resolve the registered rows (see
        :meth:`CostTableBuilder.evaluate` for the reuse semantics)."""
        with _obs_trace.span(_obs_trace.PLANNER_TRACK,
                             "frontier.evaluate", cat="planner",
                             graph=self.graph.name,
                             reuse_ivals=ivals is not None,
                             reuse_svals=svals is not None):
            return self.builder.evaluate(est=est, ivals=ivals,
                                         svals=svals)

    # -- phase 3 ------------------------------------------------------------

    def _chain_suffix_start(self, tbl) -> int:
        """Deepest ``i0`` with every table row reachable from layers
        ``>= i0`` unchanged vs. the previous build (suffix frontiers above
        it survive verbatim); ``tbl.n`` when nothing survives."""
        old = self._last["tbl"]
        n = tbl.n
        if not np.array_equal(tbl.s_final, old.s_final, equal_nan=True):
            return n
        i0 = n
        while i0 > 0:
            i = i0 - 1
            if not np.array_equal(tbl.seg[i], old.seg[i]):
                break
            if i < n - 1 and not np.array_equal(tbl.sbound[i],
                                                old.sbound[i]):
                break
            i0 = i
        return i0

    def frontier(self, ivals: np.ndarray, svals: np.ndarray,
                 ub: float = _INF, warm: bool = True) -> PlanFrontier:
        """Phase 3: assemble tables from the evaluated rows and run the
        Pareto DP, warm-starting from the previous build on this instance
        when ``warm`` (value-equal suffixes/branches only, so the result
        is always bit-identical to a scratch build)."""
        stats = SearchStats(i_calls=self.builder.i_entries,
                            s_calls=self.builder.s_entries)
        with _obs_trace.span(_obs_trace.PLANNER_TRACK, "frontier.dp",
                             cat="planner", graph=self.graph.name,
                             warm=warm) as sp:
            if self._chain_fin is not None:
                fr = self._frontier_chain(ivals, svals, ub, warm, stats)
            else:
                fr = self._frontier_dag(ivals, svals, ub, warm, stats)
            sp.set(points=len(fr.points), **self.last_reuse)
            return fr

    def _frontier_chain(self, ivals, svals, ub, warm, stats):
        schemes_t = self.schemes
        n = len(self.graph)
        k = len(schemes_t)
        tbl = self._chain_fin(ivals, svals)
        stats.pruned_halo = tbl.halo_cuts
        warm_arg = None
        reused = 0
        if warm and self._last is not None and self._last["ub"] == ub:
            i0 = self._chain_suffix_start(tbl)
            if i0 < n:
                warm_arg = (i0, self._last["F"])
                reused = n - i0
        F = _chain_frontier(n, k, tbl.seg_options, tbl.bound, tbl.final,
                            ub, stats, warm=warm_arg)
        self._last = {"tbl": tbl, "F": F, "ub": ub}
        self.last_reuse = {"mode": "chain", "layers": n,
                           "suffix_reused_layers": reused}
        roots = []
        As: List[float] = []
        Bs: List[float] = []
        for pi in range(k):
            if F[0][pi] is None:
                continue
            fs = F[0][pi]
            for j in range(len(fs.a)):
                As.append(float(fs.a[j]))
                Bs.append(float(fs.b[j]))
                roots.append((pi, j))
        if not roots:
            raise RuntimeError(f"{self.graph.name}: no feasible plan found")
        a = np.asarray(As)
        b = np.asarray(Bs)
        keep = pareto_front_2d(a, b, ub)
        points = np.stack([a[keep], b[keep]], axis=1)
        kept = [roots[int(j)] for j in keep]

        def build(idx: int) -> Plan:
            pi, j = kept[idx]
            return _chain_plan_from(F, schemes_t, pi, j)

        return PlanFrontier(schemes_t, points, stats, build)

    def _frontier_dag(self, ivals, svals, ub, warm, stats):
        graph = self.graph
        schemes_t = self.schemes
        branches = self._branches
        bkeys, uniq, jidx = self._bkeys, self._uniq, self._jidx
        utables = [fin(ivals, svals) for fin in self._finalizers]
        stats.pruned_halo = sum(utables[u].halo_cuts for u in uniq.values())
        ptab_memo: Dict[Tuple[int, bool], Dict] = {}
        reused_branches = 0
        if warm and self._last is not None and self._last["ub"] == ub:
            prev_ut = self._last["utables"]
            for u, tblu in enumerate(utables):
                old = prev_ut[u]
                # pinned per-branch tables read seg + internal bounds only
                if np.array_equal(tblu.seg, old.seg) \
                        and np.array_equal(tblu.sbound, old.sbound):
                    for (uu, hs), v in self._last["ptab"].items():
                        if uu == u:
                            ptab_memo[(u, hs)] = v
                    reused_branches += 1

        def ptable(t: int, head_solo: bool):
            u = uniq[bkeys[t]]
            hit = ptab_memo.get((u, head_solo))
            if hit is not None:
                return hit
            tblu = utables[u]

            def seg_costs(i: int, pi: int):
                return tblu.seg_options(i, pi, head_solo)

            out = _pinned_pareto_tables(len(branches[t]), schemes_t,
                                        seg_costs, tblu.bound, ub, stats)
            ptab_memo[(u, head_solo)] = out
            return out

        def jscost(prod: int, cons: Optional[int], pi: int,
                   qi: Optional[int]) -> float:
            return float(svals[jidx[(prod, cons, pi, qi)]])

        points, build = _dag_pipeline_frontier(graph, schemes_t, ptable,
                                               jscost, ub, stats)
        self._last = {"utables": utables, "ptab": ptab_memo, "ub": ub}
        self.last_reuse = {"mode": "dag", "unique_branches": len(utables),
                           "branch_tables_reused": reused_branches}
        return PlanFrontier(schemes_t, points, stats, build)


def pipeline_frontier(graph: ModelGraph, est: CostEstimator, tb: Testbed,
                      schemes: Sequence[Scheme] = ALL_SCHEMES,
                      max_segment: int = 32,
                      allow_fusion: bool = True,
                      ub_cost: Optional[float] = None,
                      prune_ub: bool = True) -> PlanFrontier:
    """Exact (compute, sync) Pareto frontier of all valid plans.

    Batched estimators evaluate through one ``i_cost_batch`` +
    ``s_cost_batch`` table build (the latency DP's tables, reused);
    scalar-only estimators run the same search from per-query providers.

    ``prune_ub=True`` trims partial pairs against the latency optimum —
    exact for the unscaled objectives and what ``plan_search`` uses; pass
    ``ub_cost`` (the latency of any feasible plan under the *same*
    schemes/fusion settings, e.g. a latency ``plan_search`` the caller
    already ran) to skip the internal pre-search.  ``prune_ub=False``
    keeps the complete nondominated set (no pre-search at all) — needed
    when ``select`` will re-weight the axes (see ``cluster.refine``).
    """
    schemes_t = tuple(schemes)
    k = len(schemes_t)
    stats = SearchStats()
    if not prune_ub:
        ub = _INF
    else:
        # Latency optimum: every frontier coordinate is bounded by it
        # (both axes sum to the latency), so it is a valid cutoff.
        if ub_cost is None:
            ub_cost = plan_search(graph, est, tb, schemes_t, max_segment,
                                  allow_fusion).cost
        ub = ub_cost * (1.0 + 1e-12)
    if hasattr(est, "i_cost_batch"):
        # batched estimators route through the registration/evaluation/DP
        # split (one fresh instance here; cluster.elastic holds onto one
        # across cluster events for incremental rebuilds)
        ft = FrontierTables.register(graph, est, tb, schemes_t, max_segment,
                                     allow_fusion)
        return ft.frontier(*ft.evaluate(), ub=ub)

    if graph.is_chain:
        n = len(graph)
        ls = list(graph.layers)

        def icost(l, p, halo=0):
            stats.i_calls += 1
            return est.i_cost(l, p, tb, extra_halo=halo)

        def scost(l, nxt, s, d):
            stats.s_calls += 1
            return est.s_cost(l, nxt, s, d, tb)

        seg_options, bound = _scalar_chain_providers(
            ls, icost, scost, schemes_t, max_segment, allow_fusion,
            False, tb.nodes, stats)
        fin_cache: Dict[int, float] = {}

        def final(pi: int) -> float:
            hit = fin_cache.get(pi)
            if hit is None:
                hit = scost(ls[-1], None, schemes_t[pi], None)
                fin_cache[pi] = hit
            return hit

        F = _chain_frontier(n, k, seg_options, bound, final, ub, stats)
        roots = []
        As, Bs = [], []
        for pi in range(k):
            if F[0][pi] is None:
                continue
            fs = F[0][pi]
            for j in range(len(fs.a)):
                As.append(float(fs.a[j]))
                Bs.append(float(fs.b[j]))
                roots.append((pi, j))
        if not roots:
            raise RuntimeError(f"{graph.name}: no feasible plan found")
        a = np.asarray(As)
        b = np.asarray(Bs)
        keep = pareto_front_2d(a, b, ub)
        points = np.stack([a[keep], b[keep]], axis=1)
        kept = [roots[int(j)] for j in keep]

        def build(idx: int) -> Plan:
            pi, j = kept[idx]
            return _chain_plan_from(F, schemes_t, pi, j)

        return PlanFrontier(schemes_t, points, stats, build)

    # ---- DAG (scalar-only estimators) -------------------------------------
    layers = graph.layers
    branches = graph.linearize()

    def icost(l, p, halo=0):
        stats.i_calls += 1
        return est.i_cost(l, p, tb, extra_halo=halo)

    def scost(l, nxt, s, d):
        stats.s_calls += 1
        return est.s_cost(l, nxt, s, d, tb)

    ptab_memo2: Dict[Tuple[int, bool], Dict] = {}

    def ptable(t: int, head_solo: bool):
        hit = ptab_memo2.get((t, head_solo))
        if hit is not None:
            return hit
        ls = [layers[i] for i in branches[t].ids]
        seg_costs, bound_cost = _scalar_chain_providers(
            ls, icost, scost, schemes_t, max_segment, allow_fusion,
            head_solo, tb.nodes, stats)
        out = _pinned_pareto_tables(len(ls), schemes_t, seg_costs,
                                    bound_cost, ub, stats)
        ptab_memo2[(t, head_solo)] = out
        return out

    def jscost(prod: int, cons: Optional[int], pi: int,
               qi: Optional[int]) -> float:
        return scost(layers[prod],
                     None if cons is None else layers[cons],
                     schemes_t[pi],
                     None if qi is None else schemes_t[qi])

    points, build = _dag_pipeline_frontier(graph, schemes_t, ptable, jscost,
                                           ub, stats)
    return PlanFrontier(schemes_t, points, stats, build)
