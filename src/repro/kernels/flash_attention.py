"""Pallas TPU flash attention (causal / sliding-window), MXU-aligned tiles.

TPU-native adaptation of the streaming-softmax algorithm: the score matrix
never leaves VMEM; q blocks of ``block_q`` rows stream over k/v blocks of
``block_k`` with the online max/sum rescaling.  Block shapes default to 128
— the MXU systolic dimension — and the kv stream is an in-kernel
``fori_loop`` so a q tile's working set is
``block_q*hd + 2*block_k*hd + block_q*block_k`` floats, comfortably inside
the ~16 MiB VMEM for hd <= 256.

Validated on CPU via ``interpret=True`` against ``ref.attention_ref`` (the
container has no TPU); the grid/BlockSpec structure is the TPU deployment
artifact.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                  window: Optional[int], block_q: int, block_k: int,
                  seq_len: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale              # [bq, hd]
    nk = seq_len // block_k

    q_idx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)

    def body(j, carry):
        m, l, acc = carry
        kb = k_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        vb = v_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_idx = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        valid = jnp.ones((block_q, block_k), bool)
        if causal:
            valid &= k_idx <= q_idx
        if window is not None:
            valid &= k_idx > q_idx - window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    hd = q_ref.shape[-1]
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, hd), jnp.float32)
    # causal upper bound: kv blocks beyond the diagonal contribute nothing
    hi = nk if not causal else jnp.minimum(
        nk, ((qi + 1) * block_q + block_k - 1) // block_k)
    # sliding-window lower bound: block j is fully masked when its last key
    # (j+1)*block_k - 1 <= min_q - window, so start at the first block that
    # can reach the tile's earliest query
    lo = 0 if window is None else jnp.maximum(
        0, (qi * block_q - window) // block_k)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_bh(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       causal: bool = True, window: Optional[int] = None,
                       scale: Optional[float] = None, block_q: int = 128,
                       block_k: int = 128,
                       interpret: bool = True) -> jnp.ndarray:
    """q/k/v: [BH, S, hd]; S must be a multiple of the block sizes (the
    public wrapper in ops.py pads)."""
    BH, S, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    grid = (BH, S // block_q)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=S)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
