"""The five baselines of §4 plus FlexPie itself, as planner policies.

  one_dim_inh   — MoDNN / DeepSlicing (One-dim InH/InW, all-T)
  one_dim_outc  — Xenos (One-dim OutC, all-T)
  grid_2d       — DeepThings (2D-grid, all-T)
  layerwise     — DINA / PartialDI (per-layer best scheme, no fusion)
  fused_fixed   — AOFL / EdgeCI (single fixed scheme, fusion T/NT optimized)
  flexpie       — full FCO (schemes x fusion jointly)
"""
from __future__ import annotations

from typing import Dict, Tuple

from .cost import Testbed
from .cost_tables import PrefetchedEstimator
from .dpp import SearchResult, plan_search
from .estimator import CostEstimator
from .graph import ModelGraph
from .partition import ALL_SCHEMES, Scheme
from .plan import Plan, fixed_plan, plan_cost


def one_dim(graph: ModelGraph, est: CostEstimator, tb: Testbed,
            scheme: Scheme) -> Tuple[Plan, float]:
    plan = fixed_plan(graph, scheme)
    # all-T single-scheme plan: prefetch its n i-costs and n-1 s-costs in
    # one batched call instead of 2n-1 scalar ones
    pf = PrefetchedEstimator.for_graph(graph, est, tb, (scheme,),
                                       allow_fusion=False)
    return plan, plan_cost(graph, plan, pf, tb)


def layerwise(graph: ModelGraph, est: CostEstimator,
              tb: Testbed) -> Tuple[Plan, float]:
    res = plan_search(graph, est, tb, schemes=ALL_SCHEMES, allow_fusion=False)
    return res.plan, res.cost


def fused_fixed(graph: ModelGraph, est: CostEstimator, tb: Testbed,
                scheme: Scheme = Scheme.INH) -> Tuple[Plan, float]:
    res = plan_search(graph, est, tb, schemes=(scheme,), allow_fusion=True)
    return res.plan, res.cost


def flexpie(graph: ModelGraph, est: CostEstimator,
            tb: Testbed) -> SearchResult:
    return plan_search(graph, est, tb, schemes=ALL_SCHEMES, allow_fusion=True)


def all_solutions(graph: ModelGraph, est: CostEstimator,
                  tb: Testbed) -> Dict[str, Tuple[Plan, float]]:
    """Every solution's (plan, estimated time) — one row of Fig. 7/9."""
    out: Dict[str, Tuple[Plan, float]] = {}
    out["one_dim_inh"] = one_dim(graph, est, tb, Scheme.INH)
    out["one_dim_outc"] = one_dim(graph, est, tb, Scheme.OUTC)
    out["grid_2d"] = one_dim(graph, est, tb, Scheme.GRID2D)
    out["layerwise"] = layerwise(graph, est, tb)
    out["fused_fixed"] = fused_fixed(graph, est, tb)
    r = flexpie(graph, est, tb)
    out["flexpie"] = (r.plan, r.cost)
    return out


def performance_scores(times: Dict[str, float]) -> Dict[str, float]:
    """§4 Metrics: score_i = min(t_1..t_m) / t_i  (1.0 = best)."""
    best = min(times.values())
    return {k: best / v for k, v in times.items()}
