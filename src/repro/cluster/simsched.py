"""Discrete-event execution simulator for plans on a cluster.

Executes a partition plan as a **pipelined multi-request schedule** over a
:class:`ClusterSpec`: every device runs a compute queue, every physical
link a transfer queue, and a greedy work-conserving scheduler (earlier
request first, then earlier stage) assigns tasks as resources free up.
Requests overlap — while request *r*'s boundary sync is in flight on the
links, the devices already start request *r+1*'s first segment — so the
simulator reports what the analytic per-request cost cannot: steady-state
throughput and the latency distribution under load (p50/p99).

The stage decomposition mirrors ``plan.dag_plan_cost`` exactly:

* one **compute stage** per T-terminated segment, with per-device
  durations summed layer by layer from the capability-weighted shard
  physics (``core.cost.hetero_device_times_s``, halos included);
* one **sync stage** per internal boundary / fork delivery / final gather,
  with per-link durations from the same byte-and-message model the
  analytic s-cost uses (``core.cost.sync_bytes_messages``), evaluated
  against each link's own bandwidth and latency;
* merge deliveries combine into a single stage whose per-link duration is
  the **max** over incoming branch deliveries — the analytic overlap
  semantics.

Because each stage maps one-to-one onto an analytic cost term, a
single-request run on a homogeneous cluster reproduces the analytic plan
cost (up to float summation order, ~1e-12 relative — tested); heterogeneous
or multi-request runs are the independent check the analytic model cannot
provide.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost import hetero_device_times_s, sync_bytes_messages
from repro.core.graph import ModelGraph, halo_growth
from repro.core.plan import Plan, steps_segments
from repro.cluster.spec import ClusterSpec


@dataclasses.dataclass(frozen=True)
class Stage:
    """One pipeline stage of a request: per-resource task durations."""

    kind: str                      # "compute" | "sync"
    durations: Tuple[float, ...]   # per-device (compute) or per-link (sync)
    deps: Tuple[int, ...]          # stage indices this stage waits on
    label: str


@dataclasses.dataclass(frozen=True)
class SimReport:
    """Result of one simulated schedule."""

    n_requests: int
    latencies_s: Tuple[float, ...]      # per request, arrival -> done
    makespan_s: float
    throughput_rps: float               # steady-state completions/second
    p50_latency_s: float
    #: conservative tail: the ``method="higher"`` order statistic (an
    #: observed latency), not a linear interpolation below it
    p99_latency_s: float
    device_busy_s: Tuple[float, ...]
    link_busy_s: Tuple[float, ...]
    #: with ``record_timeline=True``: per-task ``(resource, request,
    #: stage_idx, t_start_s, t_end_s)`` intervals (resource < n_dev is a
    #: device, the rest are links) — the raw material for
    #: :func:`export_sim_trace`
    timeline: Optional[Tuple[Tuple[int, int, int, float, float], ...]] \
        = dataclasses.field(default=None, compare=False)

    @property
    def device_utilization(self) -> Tuple[float, ...]:
        if self.makespan_s <= 0.0:
            return tuple(0.0 for _ in self.device_busy_s)
        return tuple(b / self.makespan_s for b in self.device_busy_s)


def _link_durations(cluster: ClusterSpec, bytes_busiest: float,
                    msgs: int) -> Tuple[float, ...]:
    """Per-link transfer seconds of one sync — ``Testbed.comm_time_s``
    evaluated against each link's own bandwidth/latency (the analytic
    busiest-link bound is the max of this vector when every link carries
    the pattern; contention across requests is the simulator's job)."""
    if bytes_busiest <= 0.0:
        return tuple(0.0 for _ in cluster.links)
    topo = cluster.compat_testbed().topo_factor()
    out = []
    for link in cluster.links:
        bw = link.bandwidth_gbps * 1e9 / 8.0
        out.append(bytes_busiest * topo / bw + msgs * link.latency_us * 1e-6)
    return tuple(out)


def build_stages(graph: ModelGraph, plan: Plan, cluster: ClusterSpec,
                 weighted: bool = True, batch_size: int = 1) -> List[Stage]:
    """Decompose ``plan`` into the per-request stage DAG (shared by every
    request; the scheduler instantiates it once per request).

    ``batch_size`` models request batching at the pipeline head: per-image
    compute and boundary byte volumes scale linearly with the batch, while
    per-message link latency does not (the amortization that makes batching
    win on latency-dominated links — see ``cluster.serving``)."""
    if batch_size < 1:
        raise ValueError("batch_size must be >= 1")
    plan.validate_for(graph)
    tb = cluster.compat_testbed()
    speeds = cluster.speeds_gflops
    derates = cluster.dev_derates
    weights = (cluster.capability_weights if weighted
               else (1.0,) * cluster.n)
    layers = graph.layers
    n = cluster.n

    stages: List[Stage] = []
    # merge head id -> (per-link max durations so far, producer stage deps)
    merge_acc: Dict[int, Tuple[np.ndarray, List[int]]] = {}
    # branch tail layer id -> its last compute stage index
    tail_stage: Dict[int, int] = {}
    # branch head layer id -> delivery/merge stage ids it must wait for
    entry_deps: Dict[int, List[int]] = {}

    def add(kind, durations, deps, label) -> int:
        stages.append(Stage(kind, tuple(float(d) for d in durations),
                            tuple(deps), label))
        return len(stages) - 1

    for br in graph.linearize():
        ids = br.ids
        ls = [layers[i] for i in ids]
        steps = [plan.steps[i] for i in ids]
        head = ids[0]

        deps = list(entry_deps.get(head, []))
        if head in merge_acc:
            durs, prods = merge_acc.pop(head)
            deps.append(add("sync", durs, prods,
                            f"merge->{layers[head].name}"))
        prev: Optional[int] = None
        for (a, b) in steps_segments(steps):
            scheme = steps[a][0]
            halos = halo_growth(ls[a:b + 1], b - a)
            dev = np.zeros(n, np.float64)
            for off, m in enumerate(range(a, b + 1)):
                dev += hetero_device_times_s(
                    ls[m], scheme, tb, speeds, derates, weights,
                    extra_halo=halos[off] if b > a else 0)
            seg_deps = deps if prev is None else [prev]
            prev = add("compute", dev * batch_size, seg_deps,
                       f"seg[{ls[a].name}..{ls[b].name}]")
            if b < len(ids) - 1:
                bb, msgs = sync_bytes_messages(ls[b], ls[b + 1], scheme,
                                               steps[b + 1][0], n)
                prev = add("sync",
                           _link_durations(cluster, bb * batch_size, msgs),
                           [prev], f"bound@{ls[b].name}")
        assert prev is not None
        tail_stage[ids[-1]] = prev

        p_tail = steps[-1][0]
        consumers = graph.consumer_ids[ids[-1]]
        if not consumers:
            bb, msgs = sync_bytes_messages(ls[-1], None, p_tail, None, n)
            add("sync", _link_durations(cluster, bb * batch_size, msgs),
                [prev], "gather")
        for c in consumers:
            bb, msgs = sync_bytes_messages(ls[-1], layers[c], p_tail,
                                           plan.steps[c][0], n)
            durs = np.asarray(_link_durations(cluster, bb * batch_size,
                                              msgs))
            if graph.fan_in(c) >= 2:
                acc = merge_acc.get(c)
                if acc is None:
                    merge_acc[c] = (durs, [prev])
                else:
                    merge_acc[c] = (np.maximum(acc[0], durs),
                                    acc[1] + [prev])
            else:
                entry_deps.setdefault(c, []).append(
                    add("sync", durs, [prev],
                        f"fork->{layers[c].name}"))
    return stages


def simulate(graph: ModelGraph, plan: Plan, cluster: ClusterSpec,
             n_requests: int = 1, arrival_period_s: float = 0.0,
             weighted: bool = True,
             warmup: Optional[int] = None,
             batch_size: int = 1,
             record_timeline: bool = False) -> SimReport:
    """Run ``n_requests`` through the plan's stage DAG on the cluster.

    ``arrival_period_s=0`` is the closed-loop saturation case (all requests
    queued at t=0); a positive period models an open arrival process.
    ``warmup`` requests (default ``n_requests // 4``) are dropped from the
    steady-state throughput estimate.  ``batch_size > 1`` treats each
    simulated request as a batch of that many user requests (compute and
    byte volumes scaled; reported latencies/throughput stay per *batch* —
    ``cluster.serving`` converts to per-request terms).
    ``record_timeline=True`` additionally captures every task's
    ``(resource, request, stage, start, end)`` interval in
    ``SimReport.timeline`` for trace export.
    """
    stages = build_stages(graph, plan, cluster, weighted=weighted,
                          batch_size=batch_size)
    n_stages = len(stages)
    n_dev = cluster.n
    n_link = len(cluster.links)
    n_res = n_dev + n_link

    # dependents[s] = stages waiting on s
    dependents: List[List[int]] = [[] for _ in range(n_stages)]
    for si, st in enumerate(stages):
        for d in st.deps:
            dependents[d].append(si)
    final_stage = n_stages - 1

    def resources(st: Stage) -> range:
        return (range(n_dev) if st.kind == "compute"
                else range(n_dev, n_dev + n_link))

    # per (request, stage): unmet dep count and unfinished task count
    dep_left = np.empty((n_requests, n_stages), np.int64)
    for si, st in enumerate(stages):
        dep_left[:, si] = len(st.deps)
    task_left = np.empty((n_requests, n_stages), np.int64)
    for si, st in enumerate(stages):
        task_left[:, si] = max(len(st.durations), 1)

    ready: List[List[Tuple[int, int, float]]] = [[] for _ in range(n_res)]
    busy = [False] * n_res
    busy_total = [0.0] * n_res
    done_t = np.full(n_requests, np.nan)
    events: List[Tuple[float, int, int, int, int, int]] = []
    seq = 0
    started: Dict[int, float] = {}           # resource -> task start time
    timeline: List[Tuple[int, int, int, float, float]] = []

    def stage_ready(t: float, r: int, si: int) -> None:
        st = stages[si]
        if not st.durations:     # degenerate (no links): completes in place
            stage_done(t, r, si)
            return
        for k, res in enumerate(resources(st)):
            heapq.heappush(ready[res], (r, si, st.durations[k]))

    def try_start(t: float, res: int) -> None:
        nonlocal seq
        if busy[res] or not ready[res]:
            return
        r, si, dur = heapq.heappop(ready[res])
        busy[res] = True
        busy_total[res] += dur
        if record_timeline:
            started[res] = t
        seq += 1
        heapq.heappush(events, (t + dur, seq, 1, res, r, si))

    def stage_done(t: float, r: int, si: int) -> None:
        if si == final_stage:
            done_t[r] = t
        for nxt in dependents[si]:
            dep_left[r, nxt] -= 1
            if dep_left[r, nxt] == 0:
                stage_ready(t, r, nxt)

    roots = [si for si, st in enumerate(stages) if not st.deps]
    for r in range(n_requests):
        seq += 1
        heapq.heappush(events,
                       (r * arrival_period_s, seq, 0, -1, r, -1))

    while events:
        t, _, kind, res, r, si = heapq.heappop(events)
        if kind == 0:            # arrival: root stages become ready
            for root in roots:
                stage_ready(t, r, root)
        else:                    # task finish
            busy[res] = False
            if record_timeline:
                timeline.append((res, r, si, started.pop(res), t))
            task_left[r, si] -= 1
            if task_left[r, si] == 0:
                stage_done(t, r, si)
        for rr in range(n_res):
            try_start(t, rr)

    assert not np.isnan(done_t).any(), "some requests never completed"
    arrivals = np.arange(n_requests) * arrival_period_s
    lat = done_t - arrivals
    makespan = float(done_t.max())
    order = np.sort(done_t)
    if n_requests == 1:
        thr = 1.0 / makespan if makespan > 0 else float("inf")
    else:
        w = n_requests // 4 if warmup is None else warmup
        w = min(max(w, 1), n_requests - 1)
        span = float(order[-1] - order[w - 1])
        thr = (n_requests - w) / span if span > 0 else float("inf")
    return SimReport(
        n_requests=n_requests,
        latencies_s=tuple(float(x) for x in lat),
        makespan_s=makespan,
        throughput_rps=float(thr),
        p50_latency_s=float(np.percentile(lat, 50)),
        # "higher" picks the first order statistic at or above the 99th
        # percentile — a latency a request actually saw.  The default
        # linear interpolation sits *below* the worst observation on small
        # samples, under-reporting the tail a p99 bound gates on.
        p99_latency_s=float(np.percentile(lat, 99, method="higher")),
        device_busy_s=tuple(busy_total[:n_dev]),
        link_busy_s=tuple(busy_total[n_dev:]),
        timeline=tuple(timeline) if record_timeline else None,
    )


def export_sim_trace(stages: List[Stage],
                     timeline: Tuple[Tuple[int, int, int, float, float],
                                     ...],
                     n_dev: int, process: str = "simulated",
                     pid: int = 2):
    """Render a recorded simulator timeline as an ``obs.trace.Tracer``
    in the **same schema the mesh executor emits**: one track per
    device (``dev0..``) plus one per link (``link0..``), every task a
    complete span named by its stage label with ``cat="stage"`` —
    so the predicted timeline and a measured mesh trace land in one
    Perfetto file and diff structurally (``obs.skew.diff_traces``)."""
    from repro.obs.trace import STAGE_CAT, Tracer, device_track, \
        link_track
    tracer = Tracer(process=process, pid=pid)
    for d in range(n_dev):
        tracer.ensure_track(device_track(d))
    for res, r, si, t0, t1 in timeline:
        track = device_track(res) if res < n_dev \
            else link_track(res - n_dev)
        st = stages[si]
        tracer.add_complete(track, st.label, t0 * 1e6,
                            (t1 - t0) * 1e6, cat=STAGE_CAT,
                            args={"kind": st.kind, "request": r})
    return tracer


def simulate_trace(graph: ModelGraph, plan: Plan, cluster: ClusterSpec,
                   n_requests: int = 1, **kwargs):
    """Simulate and export the predicted timeline in one call:
    returns ``(SimReport, Tracer)`` (see :func:`export_sim_trace`)."""
    stages = build_stages(graph, plan, cluster,
                          weighted=kwargs.get("weighted", True),
                          batch_size=kwargs.get("batch_size", 1))
    rep = simulate(graph, plan, cluster, n_requests=n_requests,
                   record_timeline=True, **kwargs)
    return rep, export_sim_trace(stages, rep.timeline, cluster.n)
