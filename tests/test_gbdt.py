"""From-scratch GBDT: regression quality, persistence, estimator loop."""
import numpy as np
import pytest

from repro.gbdt import GBDTRegressor


def _toy(n=4000, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-2, 2, size=(n, 5))
    y = (np.sin(x[:, 0]) + 0.5 * x[:, 1] ** 2 + (x[:, 2] > 0) * x[:, 3]
         + 0.05 * rng.normal(size=n))
    return x, y


def test_gbdt_fits_nonlinear_function():
    x, y = _toy()
    xt, yt = _toy(seed=1)
    m = GBDTRegressor(n_estimators=80, learning_rate=0.2, max_depth=5)
    m.fit(x, y)
    pred = m.predict(xt)
    ss_res = np.sum((pred - yt) ** 2)
    ss_tot = np.sum((yt - yt.mean()) ** 2)
    r2 = 1 - ss_res / ss_tot
    assert r2 > 0.9, r2


def test_gbdt_save_load_roundtrip(tmp_path):
    x, y = _toy(1000)
    m = GBDTRegressor(n_estimators=20, max_depth=4).fit(x, y)
    p = str(tmp_path / "model.npz")
    m.save(p)
    m2 = GBDTRegressor.load(p)
    np.testing.assert_allclose(m.predict(x[:50]), m2.predict(x[:50]),
                               rtol=1e-12)


def test_gbdt_monotone_improvement():
    x, y = _toy(2000)
    errs = []
    for n in (5, 20, 60):
        m = GBDTRegressor(n_estimators=n, max_depth=4, subsample=1.0).fit(x, y)
        errs.append(float(np.mean((m.predict(x) - y) ** 2)))
    assert errs[0] > errs[1] > errs[2]


def test_estimator_training_end_to_end():
    """Traces -> GBDT -> DPP: plan must stay near the analytic optimum."""
    from repro.core import AnalyticEstimator, Testbed
    from repro.core.dpp import plan_search
    from repro.core.plan import plan_cost
    from repro.configs.edge_models import mobilenet_v1
    from repro.sim import TraceConfig, train_estimators

    est = train_estimators(TraceConfig(n_samples=4000, seed=3),
                           gbdt_kwargs=dict(n_estimators=40, max_depth=6))
    g = mobilenet_v1()
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    gbdt_plan = plan_search(g, est, tb).plan
    true_cost = plan_cost(g, gbdt_plan, AnalyticEstimator(), tb)
    opt = plan_search(g, AnalyticEstimator(), tb).cost
    assert true_cost <= opt * 1.30   # within 30% of optimal (small GBDT)
