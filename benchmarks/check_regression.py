"""CI perf-regression gate over the BENCH_*.json artifacts.

Compares a freshly produced benchmark record against the committed
baseline (``benchmarks/baselines/``) and **fails the job** when

* a correctness flag flipped — batched-vs-reference plan mismatch,
  DP-vs-exhaustive parity gap (either objective), weighted-beats-even or
  throughput-beats-latency no longer holding — these are hard failures
  regardless of timing;
* a tracked search/planner time regressed by more than ``--max-ratio``
  (default 2x) against the baseline.  Cells faster than ``--min-us`` in
  the baseline are exempt from the ratio check (micro-timings on shared
  CI runners are noise); the correctness checks never are.

Usage (what the CI jobs run)::

    python -m benchmarks.check_regression --kind search \
        --current BENCH_search.json
    python -m benchmarks.check_regression --kind sweep \
        --current BENCH_sweep.json
    python -m benchmarks.check_regression --kind kernels \
        --current BENCH_kernels.json
    python -m benchmarks.check_regression --kind mesh \
        --current BENCH_mesh.json

``--kind mesh`` gates only the mesh-executor correctness flags
(output equivalence, stats identity, stage-structure agreement with the
simulator) — its timings are advisory on CPU (see ``noise_note`` in
BENCH_mesh.json).

``--kind decode`` gates only the decode-serving correctness flags
(planner head-sharding, sharded-vs-single-device token identity on both
executors and the pallas backend); timings are advisory for the same
reason.

``--kind estimator`` gates the learned-estimator quality flags
(per-preset ``hetero_within_5pct`` / ``hetero_beats_hom`` and the
calibration ``reduced_2x`` flag) — everything is seeded so these are
deterministic; the GBDT training timings are advisory.

``--kind kernels`` additionally hard-fails on a flipped kernel
``conformant`` flag or a pallas/xla engine-equivalence (``agree`` /
``stats_equal``) flag — kernel drift is a correctness bug, not a perf
regression.

Exit code 0 = clean, 1 = regression (violations listed on stderr).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

_BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")

#: parity gaps are float-association noise at worst; anything above this
#: means the DP diverged from the oracle
_PARITY_TOL = 1e-9


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def check_search(current: dict, baseline: dict, max_ratio: float,
                 min_us: float) -> List[str]:
    bad: List[str] = []
    for model, ests in baseline.get("models", {}).items():
        cur_m = current.get("models", {}).get(model)
        if cur_m is None:
            bad.append(f"search/{model}: missing from current record")
            continue
        for est, rec in ests.items():
            cur = cur_m.get(est)
            if cur is None:
                bad.append(f"search/{model}/{est}: missing from current")
                continue
            if not cur.get("match", False):
                bad.append(f"search/{model}/{est}: batched plan_search no "
                           f"longer matches the scalar reference")
            base_us = float(rec["batched_us"])
            cur_us = float(cur["batched_us"])
            if base_us >= min_us and cur_us > max_ratio * base_us:
                bad.append(
                    f"search/{model}/{est}: batched search time "
                    f"{cur_us:.0f}us > {max_ratio:g}x baseline "
                    f"{base_us:.0f}us")
    opt = current.get("optimality_5layer", {})
    if not opt.get("match", False):
        bad.append("search/optimality_5layer: DP no longer matches the "
                   "exhaustive optimum")
    return bad


def check_sweep(current: dict, baseline: dict, max_ratio: float,
                min_us: float) -> List[str]:
    bad: List[str] = []
    # correctness sections are keyed off the BASELINE: a current record
    # that silently drops a parity field must fail, not sail through
    for pname, prec in baseline.get("presets", {}).items():
        cur_oracle = current.get("presets", {}).get(pname,
                                                    {}).get("oracle", {})
        for nodes, base_orec in prec.get("oracle", {}).items():
            orec = cur_oracle.get(nodes)
            if orec is None:
                bad.append(f"sweep/{pname}/n{nodes}: oracle parity record "
                           f"missing from current")
                continue
            for field, label in (("rel_gap", "latency"),
                                 ("rel_gap_throughput", "THROUGHPUT")):
                if field not in base_orec:
                    continue
                gap = orec.get(field)
                if gap is None:
                    bad.append(f"sweep/{pname}/n{nodes}: {label} oracle "
                               f"parity field missing from current")
                elif gap > _PARITY_TOL:
                    bad.append(f"sweep/{pname}/n{nodes}: {label} oracle "
                               f"parity gap {gap:.2e}")
    base_wins = baseline.get("weighted_beats_even_per_model", {})
    wins = current.get("weighted_beats_even_per_model", {})
    for model in base_wins:
        if model not in wins:
            bad.append(f"sweep/{model}: weighted-beats-even flag missing "
                       f"from current")
        elif not wins[model]:
            bad.append(f"sweep/{model}: capability-weighted plans no "
                       f"longer beat even splits")
    tbl = current.get("throughput_beats_latency")
    if tbl is None:
        if "throughput_beats_latency" in baseline:
            bad.append("sweep: throughput_beats_latency record missing "
                       "from current")
    elif tbl.get("best_gain", 0.0) < 1.2:
        bad.append(f"sweep: throughput plans no longer reach 1.2x the "
                   f"latency plan's simulated throughput "
                   f"(best {tbl.get('best_gain')})")
    for pname, prec in baseline.get("presets", {}).items():
        cur_p = current.get("presets", {}).get(pname, {})
        for model, rows in prec.get("models", {}).items():
            cur_rows = cur_p.get("models", {}).get(model)
            if cur_rows is None:
                bad.append(f"sweep/{pname}/{model}: missing from current")
                continue
            for nodes, rec in rows.items():
                cur = cur_rows.get(nodes)
                if cur is None:
                    continue   # grid shrank; the smoke grids must match
                base_us = float(rec["planner_us"])
                cur_us = float(cur["planner_us"])
                if base_us >= min_us and cur_us > max_ratio * base_us:
                    bad.append(
                        f"sweep/{pname}/{model}/n{nodes}: planner time "
                        f"{cur_us:.0f}us > {max_ratio:g}x baseline "
                        f"{base_us:.0f}us")
    return bad


def check_kernels(current: dict, baseline: dict, max_ratio: float,
                  min_us: float) -> List[str]:
    bad: List[str] = []
    for name, rec in baseline.get("kernels", {}).items():
        cur = current.get("kernels", {}).get(name)
        if cur is None:
            bad.append(f"kernels/{name}: missing from current record")
            continue
        if not cur.get("conformant", False):
            bad.append(f"kernels/{name}: Pallas kernel no longer conformant "
                       f"(max_rel_err {cur.get('max_rel_err')})")
        base_us = float(rec["pallas_us"])
        cur_us = float(cur["pallas_us"])
        if base_us >= min_us and cur_us > max_ratio * base_us:
            bad.append(f"kernels/{name}: pallas time {cur_us:.0f}us > "
                       f"{max_ratio:g}x baseline {base_us:.0f}us")
    for model, rec in baseline.get("backend_equiv", {}).items():
        cur = current.get("backend_equiv", {}).get(model)
        if cur is None:
            bad.append(f"kernels/equiv/{model}: missing from current")
            continue
        if not cur.get("agree", False):
            bad.append(f"kernels/equiv/{model}: pallas/xla engine outputs "
                       f"diverged (rel_err {cur.get('rel_err')})")
        if not cur.get("stats_equal", False):
            bad.append(f"kernels/equiv/{model}: ExecStats no longer "
                       f"backend-independent")
    return bad


def check_mesh(current: dict, baseline: dict, max_ratio: float,
               min_us: float) -> List[str]:
    """Mesh-executor gate: every boolean flag is hard — output
    equivalence (``agree``), geometry-accounting identity
    (``stats_equal``) and stage-structure agreement with the simulator
    (``structure_match``).  Timing fields are deliberately NOT gated:
    BENCH_mesh.json's ``noise_note`` documents why CPU host-platform
    fake devices make every duration advisory.  The per-model ``skew``
    summary (measured-vs-simulated stage ratios from
    ``obs.skew.stage_skew``) is surfaced as an advisory ``skew_note``
    on stderr — never a failure, and absent records are fine."""
    bad: List[str] = []
    for model, cur in sorted(current.get("models", {}).items()):
        skew = cur.get("skew") or {}
        med = skew.get("median_ratio")
        if med is not None:
            print(f"# skew_note mesh/{model}: measured/sim median "
                  f"{med:.2f}x over {skew.get('n_paired')} stages "
                  f"(max |log2| {skew.get('max_abs_log2'):.2f}) — "
                  f"advisory, see noise_note", file=sys.stderr)
    # the committed baseline is the full model set; the per-push CI job
    # runs the smoke subset, so only the smoke models are required —
    # any model that IS present gates on its flags
    required = {"mobilenet", "resnet18"}
    for model, rec in baseline.get("models", {}).items():
        cur = current.get("models", {}).get(model)
        if cur is None:
            if model in required:
                bad.append(f"mesh/{model}: missing from current record")
            continue
        if not cur.get("agree", False):
            bad.append(f"mesh/{model}: mesh output diverged from the "
                       f"single-process engine "
                       f"(rel_err {cur.get('rel_err')})")
        if not cur.get("stats_equal", False):
            bad.append(f"mesh/{model}: ExecStats geometry accounting no "
                       f"longer matches the single-process engine")
        if not cur.get("structure_match", False):
            bad.append(f"mesh/{model}: measured stage structure diverged "
                       f"from simsched.build_stages "
                       f"(missing {cur.get('missing')}, "
                       f"extra {cur.get('extra')})")
    return bad


def check_churn(current: dict, baseline: dict, max_ratio: float,
                min_us: float) -> List[str]:
    """Elastic-replanning gate: the per-preset ``wins`` flags are hard —
    incremental replanning must beat both the never-replan and the
    replan-from-scratch baselines on mean time-to-recover AND goodput,
    and must actually exercise its reuse paths.  ALL timings (planner
    wall, recovery seconds) are advisory — a churn replay interleaves
    wall-clock planner time with modeled serving time, so ratio checks
    on shared CPU runners would be pure noise; the seeded win flags
    alone carry the signal (see ``noise_note`` in BENCH_churn.json)."""
    bad: List[str] = []
    for pname, base_p in baseline.get("presets", {}).items():
        cur_p = current.get("presets", {}).get(pname)
        if cur_p is None:
            bad.append(f"churn/{pname}: preset missing from current")
            continue
        for strat in base_p.get("aggregate", {}):
            if strat not in cur_p.get("aggregate", {}):
                bad.append(f"churn/{pname}/{strat}: aggregate missing "
                           f"from current")
        for flag in base_p.get("wins", {}):
            val = cur_p.get("wins", {}).get(flag)
            if val is None:
                bad.append(f"churn/{pname}: win flag {flag!r} missing "
                           f"from current")
            elif not val:
                bad.append(f"churn/{pname}: incremental replanning no "
                           f"longer wins {flag!r}")
    return bad


def check_decode(current: dict, baseline: dict, max_ratio: float,
                 min_us: float) -> List[str]:
    """Decode-serving gate: the boolean flags are hard — the planner must
    keep head-sharding decode (``head_sharded``) and sharded greedy decode
    must stay token-for-token identical to the single-device oracle on the
    local executor, the mesh executor and (full runs) the pallas backend.
    ALL timings (tok/s, step us) are advisory — same CPU-fake-device
    rationale as the mesh gate (see ``noise_note`` in BENCH_decode.json).
    The committed baseline is the full spec×nodes grid; the per-push CI
    job runs the smoke subset, so only the smoke cells are required —
    any cell that IS present gates on its flags."""
    bad: List[str] = []
    required = {("tiny", "2"), ("tiny", "4")}
    for spec, base_rows in baseline.get("specs", {}).items():
        cur_rows = current.get("specs", {}).get(spec, {})
        for nodes, rec in base_rows.items():
            cur = cur_rows.get(nodes)
            if cur is None:
                if (spec, nodes) in required:
                    bad.append(f"decode/{spec}/n{nodes}: missing from "
                               f"current record")
                continue
            if not cur.get("head_sharded", False):
                bad.append(f"decode/{spec}/n{nodes}: planner no longer "
                           f"head-shards the decode graph "
                           f"(schemes {cur.get('schemes')})")
            if not cur.get("tokens_match_local", False):
                bad.append(f"decode/{spec}/n{nodes}: sharded decode "
                           f"tokens diverged from the single-device "
                           f"oracle (local executor, rel_err "
                           f"{cur.get('logits_rel_err')})")
            if cur.get("tokens_match_mesh") is False:
                bad.append(f"decode/{spec}/n{nodes}: sharded decode "
                           f"tokens diverged on the mesh executor")
            if rec.get("tokens_match_pallas") is not None \
                    and cur.get("tokens_match_pallas") is False:
                bad.append(f"decode/{spec}/n{nodes}: pallas decode "
                           f"kernel tokens diverged")
    return bad


def check_estimator(current: dict, baseline: dict, max_ratio: float,
                    min_us: float) -> List[str]:
    """Learned-estimator gate: the seeded quality flags are hard — on
    every baseline preset the hetero-trained GBDT must stay within 5% of
    the analytic oracle's plan cost (``hetero_within_5pct``) and
    strictly beat the homogeneous-trained GBDT (``hetero_beats_hom``),
    and online calibration must keep cutting the predicted-period error
    at least 2x (``reduced_2x``).  Training timings are advisory (see
    ``noise_note``): a slowdown beyond ``--max-ratio`` prints a
    ``timing_note`` on stderr but never fails the job — trace
    generation + GBDT fit wall time on shared CI runners is noise."""
    bad: List[str] = []
    for preset, rec in baseline.get("presets", {}).items():
        cur = current.get("presets", {}).get(preset)
        if cur is None:
            bad.append(f"estimator/{preset}: preset missing from current")
            continue
        if not cur.get("hetero_within_5pct", False):
            bad.append(f"estimator/{preset}: hetero GBDT plan cost "
                       f"{cur.get('hetero_oracle_ratio')}x oracle — "
                       f"no longer within 5%")
        if not cur.get("hetero_beats_hom", False):
            bad.append(f"estimator/{preset}: hetero GBDT "
                       f"({cur.get('hetero_oracle_ratio')}x oracle) no "
                       f"longer beats the homogeneous-trained GBDT "
                       f"({cur.get('hom_oracle_ratio')}x)")
    base_cal = baseline.get("calibration", {})
    cal = current.get("calibration")
    if cal is None:
        if base_cal:
            bad.append("estimator: calibration record missing from current")
    elif not cal.get("reduced_2x", False):
        bad.append(f"estimator: calibration no longer cuts the period "
                   f"error 2x (reduction {cal.get('reduction')})")
    for field in ("train_hetero_us", "train_hom_us"):
        base_us = float(baseline.get(field, 0.0))
        cur_us = float(current.get(field, 0.0))
        if base_us >= min_us and cur_us > max_ratio * base_us:
            print(f"# timing_note estimator/{field}: {cur_us:.0f}us > "
                  f"{max_ratio:g}x baseline {base_us:.0f}us — advisory, "
                  f"see noise_note", file=sys.stderr)
    return bad


_CHECKERS = {"search": check_search, "sweep": check_sweep,
             "estimator": check_estimator,
             "kernels": check_kernels, "mesh": check_mesh,
             "churn": check_churn, "decode": check_decode}


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kind", choices=tuple(_CHECKERS), required=True)
    ap.add_argument("--current", required=True,
                    help="freshly produced BENCH json")
    ap.add_argument("--baseline", default=None,
                    help="baseline json (default: benchmarks/baselines/"
                         "BENCH_<kind>.json)")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="allowed slowdown vs baseline (default 2x)")
    ap.add_argument("--min-us", type=float, default=5000.0,
                    help="baseline cells faster than this skip the ratio "
                         "check (timing noise floor)")
    args = ap.parse_args(argv)
    baseline_path = args.baseline or os.path.join(
        _BASELINE_DIR, f"BENCH_{args.kind}.json")
    current = _load(args.current)
    baseline = _load(baseline_path)
    bad = _CHECKERS[args.kind](current, baseline, args.max_ratio,
                               args.min_us)
    if bad:
        print(f"REGRESSION: {len(bad)} violation(s) vs {baseline_path}",
              file=sys.stderr)
        for line in bad:
            print(f"  - {line}", file=sys.stderr)
        return 1
    print(f"# regression check ({args.kind}) clean vs {baseline_path}",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
