"""Histogram-based regression tree — the weak learner of our GBDT.

A from-scratch, numpy-only stand-in for XGBoost (offline container).  Uses
the standard second-order gain with L2 regularization:

    gain = 1/2 * [ GL^2/(HL+lam) + GR^2/(HR+lam) - G^2/(H+lam) ] - gamma

For squared error, g = (pred - y), h = 1.  Features are pre-binned into
``n_bins`` quantile bins once per GBDT fit; split search is a single
histogram pass per (node, feature).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0     # raw-value threshold (go left if x <= thr)
    left: int = -1
    right: int = -1
    value: float = 0.0
    is_leaf: bool = True


class RegressionTree:
    def __init__(self, max_depth: int = 6, min_child_weight: float = 2.0,
                 reg_lambda: float = 1.0, gamma: float = 0.0):
        self.max_depth = max_depth
        self.min_child_weight = min_child_weight
        self.reg_lambda = reg_lambda
        self.gamma = gamma
        self.nodes: List[_Node] = []

    # binned: (n, d) int32 bin indices; edges: list of per-feature bin edges
    def fit(self, binned: np.ndarray, edges: List[np.ndarray],
            grad: np.ndarray, hess: np.ndarray) -> "RegressionTree":
        self.nodes = []
        idx = np.arange(binned.shape[0])
        self._build(binned, edges, grad, hess, idx, 0)
        return self

    def _leaf_value(self, g: float, h: float) -> float:
        return -g / (h + self.reg_lambda)

    def _build(self, binned, edges, grad, hess, idx, depth) -> int:
        node_id = len(self.nodes)
        self.nodes.append(_Node())
        g_sum = float(grad[idx].sum())
        h_sum = float(hess[idx].sum())
        node = self.nodes[node_id]
        node.value = self._leaf_value(g_sum, h_sum)
        if depth >= self.max_depth or h_sum < 2 * self.min_child_weight \
                or len(idx) < 2:
            return node_id

        best_gain, best_f, best_bin = 0.0, -1, -1
        parent_score = g_sum * g_sum / (h_sum + self.reg_lambda)
        xb = binned[idx]
        gi, hi = grad[idx], hess[idx]
        for f in range(binned.shape[1]):
            nb = len(edges[f]) + 1
            if nb <= 1:
                continue
            gh = np.zeros(nb)
            hh = np.zeros(nb)
            np.add.at(gh, xb[:, f], gi)
            np.add.at(hh, xb[:, f], hi)
            gl = np.cumsum(gh)[:-1]
            hl = np.cumsum(hh)[:-1]
            gr = g_sum - gl
            hr = h_sum - hl
            valid = (hl >= self.min_child_weight) & (hr >= self.min_child_weight)
            if not valid.any():
                continue
            gains = (gl * gl / (hl + self.reg_lambda)
                     + gr * gr / (hr + self.reg_lambda) - parent_score)
            gains = np.where(valid, gains, -np.inf)
            b = int(np.argmax(gains))
            if gains[b] > best_gain + 2 * self.gamma:
                best_gain, best_f, best_bin = float(gains[b]), f, b

        if best_f < 0:
            return node_id

        go_left = xb[:, best_f] <= best_bin
        li, ri = idx[go_left], idx[~go_left]
        node.is_leaf = False
        node.feature = best_f
        node.threshold = float(edges[best_f][best_bin])
        node.left = self._build(binned, edges, grad, hess, li, depth + 1)
        node.right = self._build(binned, edges, grad, hess, ri, depth + 1)
        return node_id

    def predict(self, x: np.ndarray) -> np.ndarray:
        n = x.shape[0]
        out = np.zeros(n)
        stack = [(0, np.arange(n))]
        while stack:
            nid, idx = stack.pop()
            if idx.size == 0:
                continue
            node = self.nodes[nid]
            if node.is_leaf:
                out[idx] = node.value
            else:
                go_left = x[idx, node.feature] <= node.threshold
                stack.append((node.left, idx[go_left]))
                stack.append((node.right, idx[~go_left]))
        return out
