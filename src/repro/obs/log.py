"""Structured logging gated by the ``REPRO_LOG`` environment variable.

Library code calls :func:`log` instead of ``print``; by default
(``REPRO_LOG`` unset/empty/``0``/``off``) nothing is emitted, so
training/serving/fitting loops are quiet and benchmark CLIs keep their
stdout tables clean.  ``REPRO_LOG=1`` (or any other value) emits
human-readable ``[event] k=v ...`` lines; ``REPRO_LOG=json`` emits one
JSON object per line.  Output goes to stderr so it never interleaves
with machine-read stdout.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Any

ENV = "REPRO_LOG"

_OFF = ("", "0", "off", "false")


def enabled() -> bool:
    return os.environ.get(ENV, "").lower() not in _OFF


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def log(event: str, **fields) -> None:
    """Emit one structured log line for ``event`` if logging is on."""
    mode = os.environ.get(ENV, "").lower()
    if mode in _OFF:
        return
    if mode == "json":
        line = json.dumps({"event": event, **fields}, default=str,
                          sort_keys=True)
    else:
        kv = " ".join(f"{k}={_fmt(v)}" for k, v in fields.items())
        line = f"[{event}] {kv}" if kv else f"[{event}]"
    sys.stderr.write(line + "\n")
    sys.stderr.flush()
