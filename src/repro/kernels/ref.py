"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q/k/v: [B, H, S, hd] (same head count; GQA expansion happens in the
    wrapper).  Naive softmax attention with causal / sliding-window mask."""
    B, H, S, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)   # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, *, padding: int = 0
               ) -> jnp.ndarray:
    """x: [H, W, Cin]; w: [K, K, Cin, Cout]; stride 1.  -> [Ho, Wo, Cout]."""
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(1, 1), padding=[(padding, padding)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out[0].astype(x.dtype)
