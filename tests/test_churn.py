"""Fault injection + strategy replay (``cluster.churn``) and the
churn CI gate.

* event/scenario validation and seeded-generator determinism;
* :func:`run_churn` replay structure: the ``never`` strategy's permanent
  outage after a crash, incremental's keep decisions and cache-reuse
  paths, the scratch strategy's cutover stalls;
* the hypothesis property (random churn sequences): every replanned plan
  runs exactly on the registry's live membership and never exceeds the
  surviving devices' memory budgets;
* ``check_regression --kind churn``: win-flag flips and missing sections
  fail, timings never gate.
"""
import copy

import pytest

from benchmarks.check_regression import check_churn
from repro.cluster import (DeviceRegistry, DeviceSpec, DeviceState,
                           ElasticPlanner, MembershipError, mixed_fast_slow,
                           plan_memory_ok, stepped)
from repro.cluster.churn import (CHURN_SCENARIOS, EVENT_KINDS, STRATEGIES,
                                 ChurnEvent, ChurnScenario,
                                 compare_strategies, random_scenario,
                                 run_churn, scenario_flap, scenario_mixed)
from repro.cluster.elastic import PLANNABLE_STATES
from repro.core import ConvT, LayerSpec, chain


def _toy_chain(h=20):
    return chain("toy", [
        LayerSpec("c0", ConvT.CONV, h, h, 3, 8, 3, 1, 1),
        LayerSpec("dw", ConvT.DWCONV, h, h, 8, 8, 3, 1, 1),
        LayerSpec("pw", ConvT.POINTWISE, h, h, 8, 16, 1, 1, 0),
        LayerSpec("c1", ConvT.CONV, h, h, 16, 16, 3, 2, 1),
        LayerSpec("c2", ConvT.CONV, h // 2, h // 2, 16, 8, 3, 1, 1),
    ])


# ---------------------------------------------------------------------------
# events + scenario generators
# ---------------------------------------------------------------------------

def test_event_validation():
    with pytest.raises(ValueError):
        ChurnEvent(t=1.0, kind="explode")
    with pytest.raises(ValueError):
        ChurnEvent(t=1.0, kind="depart")          # needs a device name
    with pytest.raises(ValueError):
        ChurnEvent(t=1.0, kind="arrive")          # needs a DeviceSpec
    ChurnEvent(t=1.0, kind="arrive", spec=DeviceSpec(name="x"))
    ChurnEvent(t=1.0, kind="slowdown", factor=0.5)


def test_scenario_sorts_and_bounds_events():
    e1 = ChurnEvent(t=5.0, kind="depart", device="a")
    e2 = ChurnEvent(t=2.0, kind="derate", device="b", factor=0.5)
    s = ChurnScenario(name="s", horizon_s=10.0, events=(e1, e2))
    assert [e.t for e in s.events] == [2.0, 5.0]
    assert s.n_departures == 1
    with pytest.raises(ValueError):
        ChurnScenario(name="bad", horizon_s=4.0, events=(e1,))


def test_generators_are_seed_deterministic():
    cluster = stepped(4)
    for gen in (*CHURN_SCENARIOS.values(),
                lambda c, seed: random_scenario(c, seed=seed)):
        a = gen(cluster, seed=3)
        b = gen(cluster, seed=3)
        assert a.events == b.events and a.name == b.name
    # the random process actually varies with the seed
    assert random_scenario(cluster, seed=1).events != \
        random_scenario(cluster, seed=2).events


def test_random_scenario_guarantees_a_departure_and_valid_kinds():
    cluster = mixed_fast_slow(4)
    for seed in range(8):
        scen = random_scenario(cluster, seed=seed)
        assert scen.n_departures >= 1
        assert all(e.kind in EVENT_KINDS for e in scen.events)
        assert all(0.0 < e.t < scen.horizon_s for e in scen.events)


# ---------------------------------------------------------------------------
# strategy replay
# ---------------------------------------------------------------------------

def test_run_churn_rejects_unknown_strategy():
    g = _toy_chain()
    cluster = stepped(3)
    scen = scenario_mixed(cluster, seed=0)
    with pytest.raises(ValueError):
        run_churn(g, cluster, scen, "sometimes")


def test_strategy_structure_under_mixed_churn():
    g = _toy_chain()
    cluster = stepped(4)
    scen = scenario_mixed(cluster, seed=0)
    res = compare_strategies(g, cluster, scen)
    assert set(res) == set(STRATEGIES)
    nev, scr, inc = res["never"], res["scratch"], res["incremental"]
    # never: no replans — the crash at 0.55h is a permanent outage, so
    # both replanning strategies dominate its goodput deterministically
    assert nev.n_replans == 0
    assert inc.goodput_rps > nev.goodput_rps
    assert scr.goodput_rps > nev.goodput_rps
    # replans partition into keeps + migrations; scratch never keeps
    # (it re-adopts the frontier best every time) and pays cutover stalls
    assert inc.n_keeps + inc.n_migrations == inc.n_replans
    assert scr.n_keeps == 0 and scr.n_migrations == scr.n_replans
    assert scr.stall_total_s > 0.0
    # incremental exercised at least one reuse path
    assert sum(inc.reuse_counts.values()) > 0
    # every injected fault opened a recovery window
    assert len(nev.recoveries_s) == len(inc.recoveries_s) > 0
    assert inc.mean_recovery_s < nev.mean_recovery_s


def test_flap_hits_the_frontier_cache():
    g = _toy_chain()
    cluster = stepped(4)
    scen = scenario_flap(cluster, seed=0)
    inc = run_churn(g, cluster, scen, "incremental")
    # revisited membership states resolve from the whole-frontier LRU
    assert inc.reuse_counts.get("frontier_cache", 0) >= 2
    nev = run_churn(g, cluster, scen, "never")
    assert inc.goodput_rps > nev.goodput_rps


def test_shared_sim_cache_changes_nothing():
    g = _toy_chain()
    cluster = stepped(3)
    scen = scenario_mixed(cluster, seed=1)
    cache: dict = {}
    a = run_churn(g, cluster, scen, "incremental", sim_cache=cache)
    b = run_churn(g, cluster, scen, "incremental", sim_cache=cache)
    # replays embed real planner wall-clock in the timeline, so outcomes
    # are structurally — not bitwise — reproducible across runs
    assert a.served_requests == pytest.approx(b.served_requests, rel=0.05)
    assert (a.n_replans, a.n_migrations, a.n_keeps) == \
        (b.n_replans, b.n_migrations, b.n_keeps)
    assert len(a.recoveries_s) == len(b.recoveries_s)
    assert len(cache) > 0


# ---------------------------------------------------------------------------
# property: replanned plans live on the surviving membership
# ---------------------------------------------------------------------------

try:        # property test only — the rest of this module runs without it
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                     # pyproject [dev] extra
    HAS_HYPOTHESIS = False


def _apply_event(reg, e, crashed):
    """Project one scenario event onto the registry the way the replay
    loop does (crashes silence heartbeats; everything else is a report)."""
    if e.kind == "depart":
        crashed.add(e.device)
    elif e.kind == "leave":
        if reg.get(e.device) is not None \
                and reg.member(e.device).state in PLANNABLE_STATES:
            reg.leave(e.device, now=e.t)
    elif e.kind == "arrive":
        crashed.discard(e.spec.name)
        m = reg.get(e.spec.name)
        if m is None or m.state in (DeviceState.DEAD, DeviceState.LEFT):
            reg.join(e.spec, now=e.t)
        reg.heartbeat(e.spec.name, now=e.t)
    elif e.kind == "derate":
        if reg.get(e.device) is not None:
            reg.report_derate(e.device, e.factor, now=e.t)
    elif e.kind == "slowdown":
        reg.set_link_factor(e.factor)
    elif e.kind == "recover":
        if e.device is not None and reg.get(e.device) is not None:
            reg.report_derate(e.device, 1.0, now=e.t)
        else:
            reg.set_link_factor(1.0)


def _check_membership_property(seed):
    """Under arbitrary seeded churn, every plan the elastic planner
    returns (1) is planned over exactly the registry's live membership —
    no shard can land on a dead or departed device — and (2) fits every
    surviving device's memory budget."""
    g = _toy_chain()
    cluster = stepped(4)
    scen = random_scenario(cluster, seed=seed, n_events=5)
    reg = DeviceRegistry.from_cluster(cluster, heartbeat_interval_s=1.0,
                                      suspect_misses=1, dead_misses=2)
    planner = ElasticPlanner(g)
    crashed: set = set()
    old_plan = old_cluster = None
    old_period = None
    for e in scen.events:
        # non-crashed members keep their leases current up to the event
        for m in reg.members():
            if m.spec.name in crashed:
                continue
            if m.state in (DeviceState.DEAD, DeviceState.LEFT):
                continue
            reg.heartbeat(m.spec.name, now=e.t)
        _apply_event(reg, e, crashed)
        reg.tick(now=e.t)
        try:
            proj = reg.cluster()
        except MembershipError:
            continue          # nothing live: nothing to plan
        dec = planner.replan(proj, old_plan, old_cluster,
                             old_period_s=old_period)
        live = {m.spec.name for m in reg.live_members()}
        # the plan's cluster is exactly the live membership (positional
        # shards can only land on live devices) ...
        assert {d.name for d in proj.devices} == live
        assert len(proj.devices) == len(reg.live_members())
        # ... and fits every survivor's memory budget
        assert all(plan_memory_ok(g, dec.plan, proj))
        assert dec.period_s > 0.0
        old_plan, old_cluster, old_period = dec.plan, proj, dec.period_s


if HAS_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_replans_respect_membership_and_memory(seed):
        _check_membership_property(seed)
else:
    def test_replans_respect_membership_and_memory():
        pytest.skip("hypothesis not installed (pyproject [dev] extra); "
                    "smoke three fixed seeds instead")


def test_membership_property_fixed_seeds():
    """Deterministic slice of the property: always runs, even without
    hypothesis, so the invariant is never fully unexercised."""
    for seed in (0, 7, 42):
        _check_membership_property(seed)


# ---------------------------------------------------------------------------
# CI gate: check_regression --kind churn
# ---------------------------------------------------------------------------

CHURN = {
    "model": "mobilenet",
    "noise_note": "advisory",
    "presets": {
        "stepped": {
            "aggregate": {
                "never": {"goodput_rps": 26.0, "mean_recovery_s": 7.5,
                          "plan_wall_us": 0.0},
                "scratch": {"goodput_rps": 39.6, "mean_recovery_s": 1.62,
                            "plan_wall_us": 300000.0},
                "incremental": {"goodput_rps": 40.8,
                                "mean_recovery_s": 1.51,
                                "plan_wall_us": 150000.0},
            },
            "wins": {"recovery_beats_scratch": True,
                     "recovery_beats_never": True,
                     "goodput_beats_scratch": True,
                     "goodput_beats_never": True,
                     "incremental_reused": True},
        },
    },
}


def test_churn_clean_record_passes():
    assert check_churn(CHURN, CHURN, 2.0, 5000.0) == []


def test_churn_win_flag_flips_fail():
    for flag in CHURN["presets"]["stepped"]["wins"]:
        cur = copy.deepcopy(CHURN)
        cur["presets"]["stepped"]["wins"][flag] = False
        bad = check_churn(cur, CHURN, 2.0, 5000.0)
        assert len(bad) == 1 and flag in bad[0], (flag, bad)


def test_churn_missing_sections_fail():
    cur = copy.deepcopy(CHURN)
    del cur["presets"]["stepped"]
    assert any("preset missing" in b
               for b in check_churn(cur, CHURN, 2.0, 5000.0))
    cur2 = copy.deepcopy(CHURN)
    del cur2["presets"]["stepped"]["aggregate"]["incremental"]
    assert any("aggregate missing" in b
               for b in check_churn(cur2, CHURN, 2.0, 5000.0))
    cur3 = copy.deepcopy(CHURN)
    del cur3["presets"]["stepped"]["wins"]["goodput_beats_never"]
    assert any("missing" in b
               for b in check_churn(cur3, CHURN, 2.0, 5000.0))


def test_churn_timings_never_gate():
    # a 100x planner-wall blowup alone must NOT fail the gate — churn
    # replays interleave wall clock with modeled time (see noise_note)
    cur = copy.deepcopy(CHURN)
    agg = cur["presets"]["stepped"]["aggregate"]
    agg["incremental"]["plan_wall_us"] = 1.5e7
    agg["incremental"]["mean_recovery_s"] = 150.0
    assert check_churn(cur, CHURN, 2.0, 5000.0) == []
