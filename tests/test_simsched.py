"""Discrete-event cluster simulator: stage decomposition, single-request
agreement with the analytic cost model, and pipelined multi-request
behavior (throughput, latency distribution, link contention)."""
import pytest

from repro.cluster import (asym_uplink, build_stages, cluster_plan_search,
                           homogeneous, mixed_fast_slow, simulate)
from repro.configs.edge_models import EDGE_MODELS
from repro.core import (AnalyticEstimator, ConvT, LayerSpec, ModelGraph,
                        Testbed, chain, fixed_plan, plan_cost, plan_search)
from repro.core.plan import steps_segments

EST = AnalyticEstimator()


def _toy_chain(h=20):
    return chain("toy", [
        LayerSpec("c0", ConvT.CONV, h, h, 3, 8, 3, 1, 1),
        LayerSpec("dw", ConvT.DWCONV, h, h, 8, 8, 3, 1, 1),
        LayerSpec("c1", ConvT.CONV, h, h, 8, 16, 3, 2, 1),
        LayerSpec("c2", ConvT.CONV, h // 2, h // 2, 16, 8, 3, 1, 1),
    ])


def _toy_dag(h=16):
    return ModelGraph(name="rb", layers=(
        LayerSpec("c0", ConvT.CONV, h, h, 3, 8, 3, 1, 1),
        LayerSpec("ba", ConvT.CONV, h, h, 8, 8, 3, 1, 1, inputs=("c0",)),
        LayerSpec("bb", ConvT.CONV, h, h, 8, 8, 3, 1, 1, inputs=("ba",)),
        LayerSpec("add", ConvT.ADD, h, h, 8, 8, inputs=("bb", "c0")),
        LayerSpec("c1", ConvT.CONV, h, h, 8, 8, 3, 1, 1),
    ))


# ---------------------------------------------------------------------------
# Stage decomposition
# ---------------------------------------------------------------------------

def test_chain_stage_structure():
    g = _toy_chain()
    cl = homogeneous(4, bandwidth_gbps=1.0)
    plan = cluster_plan_search(g, cl).plan
    stages = build_stages(g, plan, cl)
    segs = steps_segments(plan.steps)
    # one compute per segment, one sync per internal boundary + gather
    assert sum(s.kind == "compute" for s in stages) == len(segs)
    assert sum(s.kind == "sync" for s in stages) == len(segs)
    assert stages[-1].label == "gather"
    for s in stages:
        n = len(s.durations)
        assert n == (cl.n if s.kind == "compute" else len(cl.links))


def test_dag_stage_structure_has_merge():
    g = _toy_dag()
    cl = homogeneous(4)
    plan = cluster_plan_search(g, cl).plan
    stages = build_stages(g, plan, cl)
    assert any(s.label.startswith("merge->") for s in stages)
    assert stages[-1].label == "gather"


# ---------------------------------------------------------------------------
# Single-request agreement with the analytic model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["mobilenet", "bert"])
@pytest.mark.parametrize("nodes", [2, 3, 4, 5, 8, 13, 16])
def test_single_request_matches_analytic_on_chains(model, nodes):
    g = EDGE_MODELS[model]()
    tb = Testbed(nodes=nodes, bandwidth_gbps=1.0)
    res = plan_search(g, EST, tb)
    rep = simulate(g, res.plan, homogeneous(nodes, bandwidth_gbps=1.0),
                   n_requests=1)
    assert rep.latencies_s[0] == pytest.approx(res.cost, rel=1e-9)
    assert rep.throughput_rps == pytest.approx(1.0 / res.cost, rel=1e-9)


def test_single_request_fixed_plans_match_analytic():
    g = _toy_chain()
    cl = homogeneous(3, bandwidth_gbps=0.5)
    tb = cl.compat_testbed()
    for scheme in (0, 1, 2, 3):
        from repro.core.partition import Scheme
        plan = fixed_plan(g, Scheme(scheme))
        want = plan_cost(g, plan, EST, tb)
        rep = simulate(g, plan, cl, n_requests=1)
        assert rep.latencies_s[0] == pytest.approx(want, rel=1e-9)


def test_dag_single_request_bounded_by_analytic():
    """Branch transfers overlap unrelated compute in the simulator, so the
    DAG latency is <= the fully-serialized analytic sum."""
    g = _toy_dag()
    cl = homogeneous(4, bandwidth_gbps=1.0)
    res = cluster_plan_search(g, cl)
    rep = simulate(g, res.plan, cl, n_requests=1)
    assert rep.latencies_s[0] <= res.cost * (1 + 1e-12)
    assert rep.latencies_s[0] > 0.5 * res.cost


# ---------------------------------------------------------------------------
# Pipelined multi-request behavior
# ---------------------------------------------------------------------------

def test_pipelining_beats_serial_execution():
    g = EDGE_MODELS["mobilenet"]()
    cl = homogeneous(4, bandwidth_gbps=0.5)   # comm-heavy: room to overlap
    res = cluster_plan_search(g, cl)
    rep = simulate(g, res.plan, cl, n_requests=16)
    serial_rate = 1.0 / res.cost
    assert rep.throughput_rps > 1.05 * serial_rate
    assert rep.p99_latency_s >= rep.p50_latency_s
    assert len(rep.latencies_s) == 16


def test_simulation_is_deterministic():
    g = _toy_chain()
    cl = mixed_fast_slow(4)
    plan = cluster_plan_search(g, cl).plan
    a = simulate(g, plan, cl, n_requests=8)
    b = simulate(g, plan, cl, n_requests=8)
    assert a == b


def test_weighted_sharding_helps_on_mixed_cluster():
    g = EDGE_MODELS["mobilenet"]()
    cl = mixed_fast_slow(4)
    plan = cluster_plan_search(g, cl).plan
    rw = simulate(g, plan, cl, n_requests=1, weighted=True)
    re = simulate(g, plan, cl, n_requests=1, weighted=False)
    assert rw.latencies_s[0] < re.latencies_s[0]


def test_slow_uplink_throttles_throughput():
    g = EDGE_MODELS["mobilenet"]()
    fast = homogeneous(4, bandwidth_gbps=5.0)
    slow = asym_uplink(4, slow_bw_gbps=0.2, fast_bw_gbps=5.0)
    plan = cluster_plan_search(g, fast).plan
    rf = simulate(g, plan, fast, n_requests=8)
    rs = simulate(g, plan, slow, n_requests=8)
    assert rs.throughput_rps < rf.throughput_rps
    assert rs.p50_latency_s > rf.p50_latency_s


def test_open_arrivals_keep_latency_flat():
    """Arrivals slower than the bottleneck stage: no queueing, every
    request sees (close to) the single-request latency."""
    g = _toy_chain()
    cl = homogeneous(4, bandwidth_gbps=1.0)
    plan = cluster_plan_search(g, cl).plan
    one = simulate(g, plan, cl, n_requests=1).latencies_s[0]
    rep = simulate(g, plan, cl, n_requests=8, arrival_period_s=2.0 * one)
    assert max(rep.latencies_s) == pytest.approx(one, rel=1e-9)


def test_device_utilization_reported():
    g = _toy_chain()
    cl = homogeneous(4)
    plan = cluster_plan_search(g, cl).plan
    rep = simulate(g, plan, cl, n_requests=4)
    assert len(rep.device_busy_s) == 4
    assert len(rep.link_busy_s) == len(cl.links)
    assert all(0.0 <= u <= 1.0 + 1e-12 for u in rep.device_utilization)
    assert any(b > 0 for b in rep.device_busy_s)
