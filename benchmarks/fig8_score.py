"""Fig. 8 — performance score (min_t / t_i) per solution, aggregated over
models, node counts and bandwidths."""
from __future__ import annotations

from collections import defaultdict

from repro.core import Testbed
from repro.core.baselines import all_solutions, performance_scores
from repro.configs.edge_models import EDGE_MODELS

from .common import EST, emit, time_call


def run() -> None:
    agg = defaultdict(list)
    us_total = 0.0
    for nodes in (4, 3):
        for bw in (5.0, 1.0, 0.5):
            tb = Testbed(nodes=nodes, bandwidth_gbps=bw)
            for model, fn in EDGE_MODELS.items():
                us, sols = time_call(
                    lambda: all_solutions(fn(), EST, tb), repeats=1)
                us_total += us
                scores = performance_scores(
                    {k: v[1] for k, v in sols.items()})
                for k, v in scores.items():
                    agg[k].append(v)
    for k, vals in sorted(agg.items()):
        emit(f"fig8/{k}", us_total / max(len(agg), 1),
             f"mean_score={sum(vals) / len(vals):.3f};"
             f"min={min(vals):.3f};n={len(vals)}")


if __name__ == "__main__":
    run()
