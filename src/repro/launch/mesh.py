"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax use.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
