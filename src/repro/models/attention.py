"""Attention blocks: GQA (dense archs), MLA (DeepSeek-V2), cross-attention
(Whisper), with full/prefill and KV-cache decode paths, causal + sliding
window masks, RoPE / M-RoPE."""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import apply_mrope, apply_rope, dense_init


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def init_attn(cfg, key) -> dict:
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv * hd, dt),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv * hd,), dt)
    return p


def init_mla(cfg, key) -> dict:
    m = cfg.mla
    ks = jax.random.split(key, 7)
    dt = jnp.dtype(cfg.dtype)
    qk_head = m.qk_nope + m.qk_rope
    return {
        "w_dq": dense_init(ks[0], cfg.d_model, m.q_lora, dt),
        "w_uq": dense_init(ks[1], m.q_lora, cfg.n_heads * qk_head, dt),
        "w_dkv": dense_init(ks[2], cfg.d_model, m.kv_lora, dt),
        "w_kr": dense_init(ks[3], cfg.d_model, m.qk_rope, dt),
        # stored [H, qk_nope, kv_lora] for the absorbed decode path
        "w_uk": dense_init(ks[4], m.kv_lora, cfg.n_heads * m.qk_nope,
                           dt).reshape(m.kv_lora, cfg.n_heads, m.qk_nope)
                 .transpose(1, 2, 0),
        "w_uv": dense_init(ks[5], m.kv_lora, cfg.n_heads * m.v_head,
                           dt).reshape(m.kv_lora, cfg.n_heads, m.v_head)
                 .transpose(1, 0, 2),
        "wo": dense_init(ks[6], cfg.n_heads * m.v_head, cfg.d_model, dt),
    }


def init_cross_attn(cfg, key) -> dict:
    return init_attn(cfg, key)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def _causal_window_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                        window: Optional[int]) -> jnp.ndarray:
    """[..., Q, K] boolean mask: causal, optionally sliding-window."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
          mask: Optional[jnp.ndarray], scale: float) -> jnp.ndarray:
    """q [B,K,G,Q,hd], k/v [B,K,S,hd] (grouped-query layout).

    Dots run in the operand dtype (a TPU MXU accumulates bf16 dots in f32
    natively; forcing f32 operands makes XLA materialize an f32 copy of the
    whole KV cache) — only the scores are upcast for the softmax."""
    scores = jnp.einsum("bkgqd,bksd->bkgqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bksd->bkgqd", w, v)


CHUNKED_SEQ_THRESHOLD = 2048   # use online-softmax streaming above this
_KV_CHUNK = 512


def _chunk_kv(k, v, k_pos):
    B, KV, S, dk = k.shape
    dv = v.shape[-1]
    nc = -(-S // _KV_CHUNK)
    pad = nc * _KV_CHUNK - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-10 ** 9)
    kc = k.reshape(B, KV, nc, _KV_CHUNK, dk).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, KV, nc, _KV_CHUNK, dv).transpose(2, 0, 1, 3, 4)
    pc = k_pos.reshape(B, nc, _KV_CHUNK).transpose(1, 0, 2)
    return kc, vc, pc, pad


def _chunk_valid(pb, q_pos, window, causal):
    valid = pb[:, None, None, None, :] >= 0
    if causal:
        valid &= pb[:, None, None, None, :] <= q_pos[:, None, None, :, None]
    if window is not None:
        valid &= pb[:, None, None, None, :] > \
            (q_pos[:, None, None, :, None] - window)
    return valid


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _chunked_sdpa(q, k, v, q_pos, k_pos, window, scale, causal):
    """Streaming attention (the jnp twin of the Pallas flash kernel): scan
    over key chunks with an online softmax; the [Q,S] score matrix is never
    materialized — in the backward either (flash backward via custom_vjp,
    recomputing per-chunk scores from the saved logsumexp).

    q [B,KV,G,Q,dk]; k [B,KV,S,dk]; v [B,KV,S,dv]; q_pos [B,Q]; k_pos [B,S].
    """
    out, _ = _flash_fwd_core(q, k, v, q_pos, k_pos, window, scale, causal)
    return out


def _flash_fwd_core(q, k, v, q_pos, k_pos, window, scale, causal):
    B, KV, G, Q, dk = q.shape
    dv = v.shape[-1]
    kc, vc, pc, _ = _chunk_kv(k, v, k_pos)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        s = jnp.einsum("bkgqd,bkcd->bkgqc", q, kb).astype(jnp.float32) \
            * scale
        s = jnp.where(_chunk_valid(pb, q_pos, window, causal), s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Q), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Q), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Q, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)
    return out, lse


def _flash_fwd(q, k, v, q_pos, k_pos, window, scale, causal):
    out, lse = _flash_fwd_core(q, k, v, q_pos, k_pos, window, scale, causal)
    return out, (q, k, v, q_pos, k_pos, out, lse)


def _flash_bwd(window, scale, causal, res, dout):
    q, k, v, q_pos, k_pos, out, lse = res
    B, KV, G, Q, dkh = q.shape
    kc, vc, pc, pad = _chunk_kv(k, v, k_pos)
    # D = rowsum(dout * out)
    D = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                axis=-1)                                       # [B,KV,G,Q]

    def step(dq, inp):
        kb, vb, pb = inp
        s = jnp.einsum("bkgqd,bkcd->bkgqc", q, kb).astype(jnp.float32) \
            * scale
        s = jnp.where(_chunk_valid(pb, q_pos, window, causal), s, -1e30)
        p = jnp.exp(s - lse[..., None])                        # [B,KV,G,Q,C]
        pq = p.astype(q.dtype)
        dv_b = jnp.einsum("bkgqc,bkgqd->bkcd", pq, dout)
        dp = jnp.einsum("bkgqd,bkcd->bkgqc", dout, vb).astype(jnp.float32)
        ds = (p * (dp - D[..., None]) * scale).astype(q.dtype)
        dq = dq + jnp.einsum("bkgqc,bkcd->bkgqd", ds, kb).astype(jnp.float32)
        dk_b = jnp.einsum("bkgqc,bkgqd->bkcd", ds, q)
        return dq, (dk_b, dv_b)

    dq0 = jnp.zeros(q.shape, jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(step, dq0, (kc, vc, pc))
    nc = kc.shape[0]
    dk = dk_c.transpose(1, 2, 0, 3, 4).reshape(B, KV, nc * _KV_CHUNK, dkh)
    dv = dv_c.transpose(1, 2, 0, 3, 4).reshape(B, KV, nc * _KV_CHUNK,
                                               v.shape[-1])
    if pad:
        dk = dk[:, :, :-pad]
        dv = dv[:, :, :-pad]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_chunked_sdpa.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# GQA full forward (train / prefill / encoder / cross)
# ---------------------------------------------------------------------------

def gqa_full(cfg, p: dict, x: jnp.ndarray, *, causal: bool = True,
             pos: Optional[jnp.ndarray] = None,
             pos3: Optional[jnp.ndarray] = None,
             kv_x: Optional[jnp.ndarray] = None,
             window: Optional[int] = None,
             return_kv: bool = False):
    """x [B,S,d].  ``kv_x`` switches to cross-attention (no mask, no rope on
    encoder side handled by caller convention: rope only when pos given)."""
    B, S, _ = x.shape
    hd = cfg.hd
    H, KV = cfg.n_heads, cfg.n_kv
    G = H // KV
    src = kv_x if kv_x is not None else x
    Skv = src.shape[1]

    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd).transpose(0, 2, 1, 3)       # [B,H,S,hd]
    k = k.reshape(B, Skv, KV, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, Skv, KV, hd).transpose(0, 2, 1, 3)

    if pos is not None and cfg.rope_kind == "rope":
        q = apply_rope(q, pos[:, None, :], cfg.rope_theta)
        k = apply_rope(k, pos[:, None, :], cfg.rope_theta)
    elif pos3 is not None and cfg.rope_kind == "mrope":
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)

    qg = q.reshape(B, KV, G, S, hd)
    if kv_x is None and S >= CHUNKED_SEQ_THRESHOLD:
        qp = pos if pos is not None else \
            jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        out = _chunked_sdpa(qg, k, v, qp, qp, window, 1.0 / math.sqrt(hd),
                            causal)
    else:
        mask = None
        if causal and kv_x is None:
            qp = pos if pos is not None else jnp.arange(S)[None, :]
            mask = _causal_window_mask(qp, qp, window)[:, None, None, :, :]
        out = _sdpa(qg, k, v, mask, 1.0 / math.sqrt(hd))
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    out = out @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# GQA decode with KV cache (ring buffer when cfg.attn_window is set)
# ---------------------------------------------------------------------------

def gqa_cache_init(cfg, batch: int, capacity: int, dtype) -> dict:
    return {
        "k": jnp.zeros((batch, cfg.n_kv, capacity, cfg.hd), dtype),
        "v": jnp.zeros((batch, cfg.n_kv, capacity, cfg.hd), dtype),
    }


def gqa_decode(cfg, p: dict, x: jnp.ndarray, cache: dict,
               t: jnp.ndarray, rope_pos=None) -> Tuple[jnp.ndarray, dict]:
    """One-token step.  x [B,1,d]; ``t`` scalar int32 = cache position;
    ``rope_pos`` overrides the rotary coordinate (VLM text streams are offset
    from cache slots by the vision prefix).  Keys are rope'd before caching,
    so the ring buffer (sliding window) needs only a validity mask — softmax
    is permutation-invariant over slots."""
    B = x.shape[0]
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv
    G = H // KV
    cap = cache["k"].shape[2]

    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, H, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, 1, KV, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, 1, KV, hd).transpose(0, 2, 1, 3)
    if cfg.rope_kind in ("rope", "mrope"):
        # decode treats all streams as text -> plain rope is exact for mrope
        rp = t if rope_pos is None else rope_pos
        posb = jnp.full((B, 1), rp, jnp.int32)
        q = apply_rope(q, posb[:, None, :], cfg.rope_theta)
        k = apply_rope(k, posb[:, None, :], cfg.rope_theta)

    slot = (t % cap if cfg.attn_window is not None else t).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))

    n_valid = jnp.minimum(t + 1, cap)
    valid = (jnp.arange(cap) < n_valid)[None, None, None, None, :]
    qg = q.reshape(B, KV, G, 1, hd)
    out = _sdpa(qg, ck, cv, valid, 1.0 / math.sqrt(hd))
    out = out.reshape(B, H, 1, hd).transpose(0, 2, 1, 3).reshape(B, 1, H * hd)
    return out @ p["wo"], {"k": ck, "v": cv}


def cross_kv(cfg, p: dict, enc: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                      jnp.ndarray]:
    """Precompute cross-attention K/V from encoder output (serve-time cache:
    recomputing these per decode token dominated whisper's memory term)."""
    B, S, _ = enc.shape
    hd, KV = cfg.hd, cfg.n_kv
    k = enc @ p["wk"]
    v = enc @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, KV, hd).transpose(0, 2, 1, 3)
    return k, v


def gqa_cross_cached(cfg, p: dict, x: jnp.ndarray, xk: jnp.ndarray,
                     xv: jnp.ndarray) -> jnp.ndarray:
    """Cross-attention against precomputed K/V.  x [B,Q,d]."""
    B, Q, _ = x.shape
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv
    G = H // KV
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, Q, H, hd).transpose(0, 2, 1, 3).reshape(B, KV, G, Q, hd)
    out = _sdpa(q, xk, xv, None, 1.0 / math.sqrt(hd))
    out = out.reshape(B, H, Q, hd).transpose(0, 2, 1, 3).reshape(B, Q, H * hd)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent KV cache; expanded prefill, absorbed decode
# ---------------------------------------------------------------------------

def mla_full(cfg, p: dict, x: jnp.ndarray, *,
             pos: Optional[jnp.ndarray] = None,
             window: Optional[int] = None) -> jnp.ndarray:
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qk_head = m.qk_nope + m.qk_rope

    q = (x @ p["w_dq"]) @ p["w_uq"]
    q = q.reshape(B, S, H, qk_head).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]

    c_kv = x @ p["w_dkv"]                                  # [B,S,kvl]
    k_rope = x @ p["w_kr"]                                 # [B,S,rope]
    if pos is None:
        pos = jnp.arange(S)[None, :].astype(jnp.int32)
    q_rope = apply_rope(q_rope, pos[:, None, :], cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, None], pos[:, None, :],
                        cfg.rope_theta)[:, 0]

    # expanded prefill: materialize per-head k/v, then shared SDPA paths
    k_nope = jnp.einsum("bsl,hdl->bhsd", c_kv, p["w_uk"])
    v = jnp.einsum("bsl,hlv->bhsv", c_kv, p["w_uv"])
    q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)      # [B,H,S,qk]
    k_eff = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None], k_nope.shape[:-1]
                                  + (m.qk_rope,))], axis=-1)
    scale = 1.0 / math.sqrt(qk_head)
    qg = q_eff[:, :, None]                                  # [B,H,1,S,qk]
    if S >= CHUNKED_SEQ_THRESHOLD:
        out = _chunked_sdpa(qg, k_eff, v, pos, pos, window, scale, True)
    else:
        mask = _causal_window_mask(pos, pos, window)[:, None, None, :, :]
        out = _sdpa(qg, k_eff, v, mask, scale)
    out = out[:, :, 0].transpose(0, 2, 1, 3).reshape(B, S, H * m.v_head)
    return out @ p["wo"]


def mla_cache_init(cfg, batch: int, capacity: int, dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, capacity, m.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, capacity, m.qk_rope), dtype),
    }


def mla_decode(cfg, p: dict, x: jnp.ndarray, cache: dict,
               t: jnp.ndarray, rope_pos=None) -> Tuple[jnp.ndarray, dict]:
    """Absorbed decode: scores and values computed in the latent space —
    the cache stays [B,S,kv_lora+rope], the MLA memory win."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    qk_head = m.qk_nope + m.qk_rope
    cap = cache["c_kv"].shape[1]

    q = (x @ p["w_dq"]) @ p["w_uq"]
    q = q.reshape(B, 1, H, qk_head).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
    posb = jnp.full((B, 1), t if rope_pos is None else rope_pos, jnp.int32)
    q_rope = apply_rope(q_rope, posb[:, None, :], cfg.rope_theta)

    c_new = (x @ p["w_dkv"]).reshape(B, 1, m.kv_lora)
    kr_new = apply_rope((x @ p["w_kr"]).reshape(B, 1, 1, m.qk_rope),
                        posb[:, None, :], cfg.rope_theta).reshape(B, 1,
                                                                  m.qk_rope)
    slot = (t % cap if cfg.attn_window is not None else t).astype(jnp.int32)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new,
                                          (0, slot, 0))

    q_lat = jnp.einsum("bhqd,hdl->bhql", q_nope, p["w_uk"])
    scores = (jnp.einsum("bhql,bsl->bhqs", q_lat, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhqd,bsd->bhqs", q_rope, k_rope,
                           preferred_element_type=jnp.float32))
    scores = scores / math.sqrt(qk_head)
    n_valid = jnp.minimum(t + 1, cap)
    valid = (jnp.arange(cap) < n_valid)[None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhqs,bsl->bhql", w, c_kv)
    out = jnp.einsum("bhql,hlv->bhqv", out_lat, p["w_uv"])
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, H * m.v_head)
    return out @ p["wo"], {"c_kv": c_kv, "k_rope": k_rope}
