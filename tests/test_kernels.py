"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Shape/dtype sweeps per kernel as required: flash attention over sequence
lengths, head dims, GQA ratios, masks and dtypes; conv2d over kernel sizes,
channel counts and paddings.  The in-model jnp flash (custom_vjp) is also
checked against the naive oracle including gradients.
"""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ops import conv2d, flash_attention
from repro.kernels.ref import attention_ref, conv2d_ref


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (2, 4, 2, 256, 64),
    (1, 2, 2, 384, 128),
    (2, 2, 1, 128, 64),
    (1, 8, 8, 512, 64),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_sweep(B, H, KV, S, hd, causal, window):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window)
    kk = jnp.repeat(k, H // KV, axis=1)
    vv = jnp.repeat(v, H // KV, axis=1)
    ref = attention_ref(q, kk, vv, causal=causal, window=window)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("dtype,tol", [("float32", 2e-5), ("bfloat16", 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 4, 256, 64), dtype)
    k = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 256, 64), dtype)
    v = jax.random.normal(jax.random.PRNGKey(5), (2, 4, 256, 64), dtype)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - ref.astype(jnp.float32)))
    assert err < tol


def test_flash_attention_unaligned_seq():
    """S not a multiple of the block size exercises the padding path."""
    q = jax.random.normal(jax.random.PRNGKey(6), (1, 2, 300, 64))
    k = jax.random.normal(jax.random.PRNGKey(7), (1, 2, 300, 64))
    v = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 300, 64))
    out = flash_attention(q, k, v, causal=True, window=48)
    ref = attention_ref(q, k, v, causal=True, window=48)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("H,W,cin,cout,K,p", [
    (16, 16, 8, 16, 3, 1),
    (28, 28, 16, 8, 1, 0),
    (20, 20, 4, 4, 5, 2),
    (14, 14, 32, 32, 3, 1),
])
def test_conv2d_sweep(H, W, cin, cout, K, p):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (H, W, cin))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, K, cin, cout)) * 0.1
    out = conv2d(x, w, padding=p)
    ref = conv2d_ref(x, w, padding=p)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_conv2d_strided_fallback():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 16, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 8)) * 0.1
    out = conv2d(x, w, padding=1, stride=2)
    ref = jax.lax.conv_general_dilated(
        x[None], w, (2, 2), [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_model_flash_custom_vjp_grads():
    """In-model streaming attention: gradients match the naive oracle."""
    from repro.models import attention as A
    B, KV, G, Q, hd = 2, 2, 2, 64, 16
    q = jax.random.normal(jax.random.PRNGKey(1), (B, KV, G, Q, hd))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, KV, Q, hd))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, KV, Q, hd))
    pos = jnp.broadcast_to(jnp.arange(Q)[None], (B, Q))
    scale = 1.0 / math.sqrt(hd)

    def naive(q, k, v):
        mask = A._causal_window_mask(pos, pos, 17)[:, None, None]
        return A._sdpa(q, k, v, mask, scale)

    def flash(q, k, v):
        return A._chunked_sdpa(q, k, v, pos, pos, 17, scale, True)

    o_err = jnp.max(jnp.abs(naive(q, k, v) - flash(q, k, v)))
    assert o_err < 1e-5
    g1 = jax.grad(lambda *a: (naive(*a) ** 2).sum(), (0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (flash(*a) ** 2).sum(), (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert jnp.max(jnp.abs(a - b)) < 1e-4
