"""Scale sweep: nodes x cluster preset x model — plan quality + search time.

The ROADMAP's "search at larger scale" benchmark: for every cluster preset
(uniform, DistrEdge-style mixed fast/slow, stepped capability ramp,
asymmetric uplink) and node count in the grid, run the capability-weighted
DPP on each benchmark model and record

* planner wall time (batched tables end to end),
* plan cost under capability-weighted sharding vs. the best
  homogeneous-assumption even-split plan on the same silicon
  (``even_over_weighted`` >= 1; the capability win),
* Theorem-1 parity vs. the exhaustive oracle on a reduced proxy graph
  (exhaustive on full models is infeasible; the proxy shares the DP
  semantics) — under **both** ``Objective.LATENCY`` and
  ``Objective.THROUGHPUT``,
* latency-vs-throughput **plan pairs**: at every grid cell the analytic
  (compute, sync) occupancy and bottleneck of both objectives' plans; at
  ``pair_sim_nodes`` additionally the simulated steady-state throughput of
  each plan plus the simulator-refined plan
  (``cluster.refine_with_simulator``), with the throughput-over-latency
  gain,
* discrete-event simulator cross-checks at a fixed node count: pipelined
  steady-state throughput, p50/p99 latency, and the single-request
  sim/analytic ratio.

The harness *asserts* oracle parity on every preset (both objectives),
that weighted plans beat even-split plans on at least one heterogeneous
preset per model, and that the throughput objective's plan beats the
latency plan's simulated throughput by >= 1.2x on at least one
(model, heterogeneous-preset) pair.  ``--json [PATH]`` writes
``BENCH_sweep.json`` (the CI artifact); ``--smoke`` shrinks the grid for
the CI smoke job.
"""
from __future__ import annotations

import json
import sys

from repro.cluster import (CLUSTER_PRESETS, ClusterAnalyticEstimator,
                           cluster_pipeline_frontier, cluster_plan_search,
                           refine_with_simulator, simulate)
from repro.configs.edge_models import EDGE_MODELS
from repro.core import Objective, plan_pipeline_cost
from repro.core.exhaustive import exhaustive_search
from repro.core.graph import ConvT, LayerSpec, chain

from .common import emit, time_call


#: proxy graph for the exhaustive oracle (2 * 4**5 plans — tractable)
def _oracle_graph():
    return chain("oracle5", [
        LayerSpec("c0", ConvT.CONV, 24, 24, 3, 8, 3, 1, 1),
        LayerSpec("dw", ConvT.DWCONV, 24, 24, 8, 8, 3, 1, 1),
        LayerSpec("pw", ConvT.POINTWISE, 24, 24, 8, 16, 1, 1, 0),
        LayerSpec("c1", ConvT.CONV, 24, 24, 16, 16, 3, 2, 1),
        LayerSpec("c2", ConvT.CONV, 12, 12, 16, 8, 3, 1, 1),
    ])


def _sim_rec(g, cluster, plan, analytic_cost: float,
             n_requests: int) -> dict:
    one = simulate(g, plan, cluster, n_requests=1)
    many = simulate(g, plan, cluster, n_requests=n_requests)
    return {
        "sim_latency_ms": one.latencies_s[0] * 1e3,
        "sim_over_analytic": one.latencies_s[0] / analytic_cost,
        "throughput_rps": many.throughput_rps,
        "p50_ms": many.p50_latency_s * 1e3,
        "p99_ms": many.p99_latency_s * 1e3,
        "pipeline_speedup": many.throughput_rps * analytic_cost,
    }


def _pair_rec(g, cluster, lat_res, thr_res, simulate_pair: bool,
              refine: bool, n_requests: int, frontier=None) -> dict:
    """Latency-vs-throughput plan pair at one grid cell: analytic always,
    simulated throughput (and the simulator-refined plan) on request.
    ``frontier`` reuses the cell's already-built Pareto frontier for the
    refinement loop instead of rebuilding tables."""
    est = ClusterAnalyticEstimator(cluster)
    lat_pc = plan_pipeline_cost(g, lat_res.plan, est,
                                cluster.compat_testbed())
    rec = {
        "latency_plan": {
            "latency_ms": lat_res.cost * 1e3,
            "bottleneck_ms": lat_pc.bottleneck_s * 1e3,
        },
        "throughput_plan": {
            "bottleneck_ms": thr_res.cost * 1e3,
            "compute_ms": thr_res.pipeline.compute_s * 1e3,
            "sync_ms": thr_res.pipeline.sync_s * 1e3,
            "latency_ms": thr_res.pipeline.latency_s * 1e3,
        },
        "plans_differ": lat_res.plan != thr_res.plan,
    }
    if not simulate_pair:
        return rec
    rl = simulate(g, lat_res.plan, cluster, n_requests=n_requests)
    rt = simulate(g, thr_res.plan, cluster, n_requests=n_requests)
    rec["latency_plan"]["sim_throughput_rps"] = rl.throughput_rps
    rec["throughput_plan"]["sim_throughput_rps"] = rt.throughput_rps
    best_thr = rt.throughput_rps
    if refine:
        rr = refine_with_simulator(g, cluster, n_requests=n_requests,
                                   frontier=frontier)
        rec["refined_plan"] = {
            "sim_throughput_rps": rr.throughput_rps,
            "iters": len(rr.steps),
            "converged": rr.converged,
        }
        best_thr = max(best_thr, rr.throughput_rps)
    rec["throughput_gain"] = best_thr / rl.throughput_rps
    return rec


def run(json_path: str | None = None, smoke: bool = False) -> dict:
    node_grid = [2, 4, 8] if smoke else list(range(2, 17))
    models = (["mobilenet", "resnet18", "inception"] if smoke
              else list(EDGE_MODELS))
    sim_nodes = 4
    pair_sim_nodes = [8] if smoke else [4, 8]
    sim_requests = 8 if smoke else 16
    pair_requests = 16
    oracle = _oracle_graph()

    out: dict = {"grid": {"nodes": node_grid, "models": models,
                          "presets": list(CLUSTER_PRESETS),
                          "pair_sim_nodes": pair_sim_nodes},
                 "presets": {}}
    weighted_wins: dict = {m: False for m in models}
    best_gain = (0.0, None)      # (gain, "preset/model/nodes")

    for pname, mk in CLUSTER_PRESETS.items():
        prec: dict = {"oracle": {}, "models": {}}
        out["presets"][pname] = prec

        # Theorem-1 parity vs the exhaustive oracle, every node count,
        # under both the latency and the pipelined-throughput objective
        for nodes in node_grid:
            cl = mk(nodes)
            est = ClusterAnalyticEstimator(cl)
            tb = cl.compat_testbed()
            res = cluster_plan_search(oracle, cl)
            _, ex_cost = exhaustive_search(oracle, est, tb)
            gap = abs(res.cost - ex_cost) / ex_cost
            assert gap < 1e-12, (
                f"{pname}/n{nodes}: DPP missed the oracle optimum "
                f"({res.cost} vs {ex_cost})")
            tres = cluster_plan_search(oracle, cl,
                                       objective=Objective.THROUGHPUT)
            _, tex_cost = exhaustive_search(
                oracle, est, tb, objective=Objective.THROUGHPUT)
            tgap = abs(tres.cost - tex_cost) / tex_cost
            assert tgap < 1e-9, (
                f"{pname}/n{nodes}: THROUGHPUT DP missed the oracle "
                f"optimum ({tres.cost} vs {tex_cost})")
            prec["oracle"][nodes] = {
                "dp_cost_ms": res.cost * 1e3,
                "exhaustive_cost_ms": ex_cost * 1e3,
                "rel_gap": gap,
                "dp_bottleneck_ms": tres.cost * 1e3,
                "exhaustive_bottleneck_ms": tex_cost * 1e3,
                "rel_gap_throughput": tgap,
            }

        for model in models:
            g = EDGE_MODELS[model]()
            rows = {}
            for nodes in node_grid:
                cl = mk(nodes)
                # best-of-3 even on the smoke grid: the 2x CI gate needs
                # scheduler-noise-free timings, and the latency DP is ms
                us, res = time_call(
                    lambda cl=cl: cluster_plan_search(g, cl))
                even = cluster_plan_search(g, cl, weighted=False)
                ratio = even.cost / res.cost
                assert ratio >= 1.0 - 1e-12, (
                    f"{pname}/{model}/n{nodes}: weighted plan worse than "
                    f"even split ({res.cost} vs {even.cost})")
                if pname != "uniform" and ratio > 1.0 + 1e-9:
                    weighted_wins[model] = True
                # one frontier build serves the throughput-plan selection
                # AND the refinement loop at sim cells; prune_ub=False
                # keeps the complete set (exact under refinement's axis
                # re-weighting) and skips the latency pre-search
                fr = cluster_pipeline_frontier(g, cl, prune_ub=False)
                thr = fr.search_result(Objective.THROUGHPUT)
                rows[nodes] = {
                    "planner_us": round(us, 1),
                    "weighted_cost_ms": res.cost * 1e3,
                    "even_cost_ms": even.cost * 1e3,
                    "even_over_weighted": round(ratio, 4),
                    "i_rows": res.stats.i_calls,
                    "s_rows": res.stats.s_calls,
                    "memory_ok": all(cl.memory_ok(g)),
                    "pair": _pair_rec(
                        g, cl, res, thr,
                        simulate_pair=nodes in pair_sim_nodes,
                        refine=not g.is_chain, n_requests=pair_requests,
                        frontier=fr),
                }
                gain = rows[nodes]["pair"].get("throughput_gain")
                if gain is not None and pname != "uniform" \
                        and gain > best_gain[0]:
                    best_gain = (gain, f"{pname}/{model}/n{nodes}")
                if nodes == sim_nodes:
                    rows[nodes].update(_sim_rec(g, cl, res.plan, res.cost,
                                                sim_requests))
            prec["models"][model] = rows
            mid = sim_nodes if sim_nodes in rows else node_grid[0]
            emit(f"sweep/{pname}/{model}", rows[mid]["planner_us"],
                 f"nodes={mid};even_over_weighted="
                 f"{rows[mid]['even_over_weighted']};"
                 f"throughput_rps={rows[mid].get('throughput_rps', 0):.1f}")

    assert all(weighted_wins.values()), (
        f"capability-weighted plans never beat even splits for "
        f"{[m for m, w in weighted_wins.items() if not w]}")
    out["weighted_beats_even_per_model"] = weighted_wins

    assert best_gain[0] >= 1.2, (
        f"throughput plans never reached 1.2x the latency plan's simulated "
        f"throughput on a heterogeneous preset (best {best_gain[0]:.3f} at "
        f"{best_gain[1]})")
    out["throughput_beats_latency"] = {"best_gain": round(best_gain[0], 4),
                                       "where": best_gain[1]}
    emit("sweep/throughput-gain", 0.0,
         f"best_gain={best_gain[0]:.3f};where={best_gain[1]}")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)
    return out


if __name__ == "__main__":
    from .common import json_arg
    argv = sys.argv[1:]
    run(json_path=json_arg(argv, default="BENCH_sweep.json"),
        smoke="--smoke" in argv)
