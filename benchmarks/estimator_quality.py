"""CE quality — GBDT i-/s-Estimator held-out accuracy and the end-to-end
plan-quality gap of data-driven FCO vs the analytic oracle (§3.2)."""
from __future__ import annotations

import numpy as np

from repro.core import AnalyticEstimator, Testbed
from repro.core.dpp import plan_search
from repro.core.plan import plan_cost
from repro.configs.edge_models import mobilenet_v1
from repro.sim import TraceConfig, generate_i_traces, train_estimators

from .common import emit, time_call


def run(n_samples: int = 12_000, trees: int = 60) -> None:
    cfg = TraceConfig(n_samples=n_samples, seed=0)
    us, est = time_call(lambda: train_estimators(
        cfg, gbdt_kwargs=dict(n_estimators=trees, max_depth=7)), repeats=1)

    held = TraceConfig(n_samples=2000, seed=99)
    xi, yi = generate_i_traces(held)
    rel = np.exp(np.abs(est.i_model.predict(xi) - yi)) - 1
    emit("ce/i-estimator", us,
         f"samples={n_samples};trees={trees};"
         f"median_rel_err={np.median(rel) * 100:.1f}%;"
         f"p90_rel_err={np.percentile(rel, 90) * 100:.1f}%")

    g = mobilenet_v1()
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    us2, plan = time_call(lambda: plan_search(g, est, tb).plan, repeats=1)
    true_cost = plan_cost(g, plan, AnalyticEstimator(), tb)
    opt = plan_search(g, AnalyticEstimator(), tb).cost
    emit("ce/plan-gap", us2,
         f"gbdt_plan_true_cost={true_cost * 1e3:.2f}ms;"
         f"oracle_optimal={opt * 1e3:.2f}ms;"
         f"gap={(true_cost / opt - 1) * 100:.1f}%")


if __name__ == "__main__":
    run()
