"""Heterogeneous edge-cluster description — devices, links, presets.

The homogeneous :class:`repro.core.cost.Testbed` describes the paper's SRIO
DSP cluster: one ``device_gflops``, one per-link bandwidth.  Real edge
deployments are uneven — DistrEdge-style mixes of fast and slow boards,
asymmetric uplinks — and that unevenness is where capability-proportional
partitioning wins or loses.  :class:`ClusterSpec` carries the full
description: per-device compute capability (gflops, kernel-efficiency
derate, memory) and a per-edge link graph (bandwidth + latency per link,
edge set defined by the topology).

Compatibility contract: ``ClusterSpec.compat_testbed()`` projects the
cluster onto a ``Testbed`` (node count, topology, *bottleneck* link
bandwidth / latency, scheme efficiencies), so every existing call site —
feature extraction, cost tables, DPP — keeps working unchanged.  A
homogeneous cluster's costs through ``ClusterAnalyticEstimator`` are
bit-identical to the historical ``Testbed`` path (tested).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core.cost import Testbed, Topology
from repro.core.graph import ModelGraph
from repro.core.partition import DTYPE_BYTES


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One edge device: sustained compute rate, memory, kernel efficiency.

    ``eff_derate`` multiplies the testbed's scheme efficiency on this device
    (e.g. a board whose DSP intrinsics vectorize worse); capability weights
    are proportional to ``gflops * eff_derate``.
    """

    name: str = "dev"
    gflops: float = 16.0          # sustained fp32 GFLOP/s
    mem_mb: float = 512.0
    eff_derate: float = 1.0

    def __post_init__(self) -> None:
        if self.gflops <= 0.0 or self.eff_derate <= 0.0:
            raise ValueError(f"{self.name}: gflops and eff_derate must be "
                             f"positive")


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One physical link of the cluster interconnect."""

    bandwidth_gbps: float = 5.0
    latency_us: float = 10.0

    def __post_init__(self) -> None:
        if self.bandwidth_gbps <= 0.0 or self.latency_us < 0.0:
            raise ValueError("link bandwidth must be positive, latency "
                             "non-negative")


def topology_edges(nodes: int, topology: Topology) -> Tuple[Tuple[int, int],
                                                            ...]:
    """Undirected edge set of each supported interconnect topology."""
    if nodes <= 1:
        return ()
    if topology == Topology.RING:
        if nodes == 2:
            return ((0, 1),)
        return tuple((i, (i + 1) % nodes) for i in range(nodes))
    if topology == Topology.PS:
        return tuple((0, i) for i in range(1, nodes))
    if topology == Topology.MESH:
        return tuple((i, j) for i in range(nodes) for j in range(i + 1,
                                                                 nodes))
    raise ValueError(topology)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A (possibly heterogeneous) edge cluster: devices + link graph.

    ``links[k]`` is the :class:`LinkSpec` of ``topology_edges(n,
    topology)[k]`` — the edge set is fixed by the topology, the per-edge
    capabilities are free.  Scheme efficiencies (``eff_*``) are
    cluster-wide, matching ``Testbed``; per-device variation goes through
    ``DeviceSpec.eff_derate``.
    """

    name: str
    devices: Tuple[DeviceSpec, ...]
    links: Tuple[LinkSpec, ...]
    topology: Topology = Topology.RING
    eff_inh: float = 0.90
    eff_inw: float = 0.80
    eff_outc: float = 0.85
    eff_grid: float = 0.82

    def __post_init__(self) -> None:
        if not self.devices:
            raise ValueError(f"{self.name}: cluster needs >= 1 device")
        n_edges = len(topology_edges(self.n, self.topology))
        if len(self.links) != n_edges:
            raise ValueError(
                f"{self.name}: {self.topology.name} over {self.n} nodes has "
                f"{n_edges} links, got {len(self.links)}")

    # ---- structure --------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.devices)

    @property
    def edges(self) -> Tuple[Tuple[int, int], ...]:
        return topology_edges(self.n, self.topology)

    @property
    def speeds_gflops(self) -> Tuple[float, ...]:
        return tuple(d.gflops for d in self.devices)

    @property
    def dev_derates(self) -> Tuple[float, ...]:
        return tuple(d.eff_derate for d in self.devices)

    @property
    def capability_weights(self) -> Tuple[float, ...]:
        """Shard-fraction weights: effective throughput per device."""
        return tuple(d.gflops * d.eff_derate for d in self.devices)

    @property
    def is_homogeneous(self) -> bool:
        return (all(d == self.devices[0] for d in self.devices)
                and all(l == self.links[0] for l in self.links))

    # ---- Testbed projection ----------------------------------------------
    @property
    def bottleneck_bw_gbps(self) -> float:
        """Slowest link — the busiest-link bound the analytic s-cost uses."""
        return min((l.bandwidth_gbps for l in self.links), default=5.0)

    @property
    def max_latency_us(self) -> float:
        return max((l.latency_us for l in self.links), default=10.0)

    def compat_testbed(self) -> Testbed:
        """Project onto the homogeneous ``Testbed`` the feature expression
        and cost tables consume: node count, topology, bottleneck link.
        ``device_gflops`` is the lead device's rate (representative only —
        the cluster estimator never reads it)."""
        return Testbed(nodes=self.n,
                       bandwidth_gbps=self.bottleneck_bw_gbps,
                       topology=self.topology,
                       device_gflops=self.devices[0].gflops,
                       link_latency_us=self.max_latency_us,
                       eff_inh=self.eff_inh, eff_inw=self.eff_inw,
                       eff_outc=self.eff_outc, eff_grid=self.eff_grid)

    @classmethod
    def from_testbed(cls, tb: Testbed, name: str = "testbed") -> \
            "ClusterSpec":
        """Lift a homogeneous ``Testbed`` into the cluster IR (the inverse
        of :meth:`compat_testbed` on homogeneous clusters)."""
        dev = DeviceSpec(name="dev", gflops=tb.device_gflops)
        link = LinkSpec(bandwidth_gbps=tb.bandwidth_gbps,
                        latency_us=tb.link_latency_us)
        n_edges = len(topology_edges(tb.nodes, tb.topology))
        return cls(name=name, devices=(dev,) * tb.nodes,
                   links=(link,) * n_edges, topology=tb.topology,
                   eff_inh=tb.eff_inh, eff_inw=tb.eff_inw,
                   eff_outc=tb.eff_outc, eff_grid=tb.eff_grid)

    # ---- memory feasibility ----------------------------------------------
    def memory_ok(self, graph: ModelGraph) -> Tuple[bool, ...]:
        """Rough per-device fit check: full weight set (spatial schemes
        replicate weights) plus the largest capability-weighted activation
        shard (in + out feature maps).  Advisory — the sweep reports it, the
        planner does not enforce it."""
        w_bytes = sum(l.weight_elems() for l in graph.layers) * DTYPE_BYTES
        total = float(np.sum(self.capability_weights))
        out = []
        for d, w in zip(self.devices, self.capability_weights):
            frac = w / total
            act = max((l.in_elems() + l.out_elems()) * DTYPE_BYTES * frac
                      for l in graph.layers)
            out.append((w_bytes + act) <= d.mem_mb * 1e6)
        return tuple(out)


# ---------------------------------------------------------------------------
# Presets — the sweep's cluster zoo, parameterized by node count.
# ---------------------------------------------------------------------------

def homogeneous(nodes: int, bandwidth_gbps: float = 5.0,
                topology: Topology = Topology.RING,
                device_gflops: float = 16.0,
                latency_us: float = 10.0) -> ClusterSpec:
    """Uniform cluster — must reproduce ``Testbed`` costs bit-identically."""
    return ClusterSpec.from_testbed(
        Testbed(nodes=nodes, bandwidth_gbps=bandwidth_gbps,
                topology=topology, device_gflops=device_gflops,
                link_latency_us=latency_us), name=f"uniform{nodes}")


def mixed_fast_slow(nodes: int, n_fast: int = 2, fast_gflops: float = 32.0,
                    slow_gflops: float = 8.0,
                    bandwidth_gbps: float = 5.0) -> ClusterSpec:
    """DistrEdge-style mixed cluster: a few fast boards + many slow ones
    (default shape 2 fast + rest slow, a 4x capability gap)."""
    n_fast = min(n_fast, nodes)
    devs = tuple(DeviceSpec(name=f"fast{i}", gflops=fast_gflops, mem_mb=2048)
                 for i in range(n_fast)) + \
        tuple(DeviceSpec(name=f"slow{i}", gflops=slow_gflops, mem_mb=512)
              for i in range(nodes - n_fast))
    n_edges = len(topology_edges(nodes, Topology.RING))
    return ClusterSpec(name=f"mixed{nodes}", devices=devs,
                       links=(LinkSpec(bandwidth_gbps=bandwidth_gbps),)
                       * n_edges)


def stepped(nodes: int, top_gflops: float = 24.0,
            bottom_gflops: float = 6.0) -> ClusterSpec:
    """Graded capability ramp (every device different — the general case
    for weighted-fraction geometry)."""
    if nodes == 1:
        gf = [top_gflops]
    else:
        step = (top_gflops - bottom_gflops) / (nodes - 1)
        gf = [top_gflops - i * step for i in range(nodes)]
    devs = tuple(DeviceSpec(name=f"d{i}", gflops=g)
                 for i, g in enumerate(gf))
    n_edges = len(topology_edges(nodes, Topology.RING))
    return ClusterSpec(name=f"stepped{nodes}", devices=devs,
                       links=(LinkSpec(),) * n_edges)


def asym_uplink(nodes: int, slow_bw_gbps: float = 0.5,
                fast_bw_gbps: float = 5.0) -> ClusterSpec:
    """Uniform devices, one congested link — the busiest-link bound (and
    the simulator's per-link queues) gate every sync on the slow edge."""
    n_edges = len(topology_edges(nodes, Topology.RING))
    links = (LinkSpec(bandwidth_gbps=slow_bw_gbps),) + \
        (LinkSpec(bandwidth_gbps=fast_bw_gbps),) * max(n_edges - 1, 0)
    return ClusterSpec(name=f"asym{nodes}",
                       devices=(DeviceSpec(),) * nodes,
                       links=links[:n_edges])


#: preset registry for sweeps: name -> (nodes -> ClusterSpec).  Every entry
#: except ``uniform`` is heterogeneous (device- or link-skewed).
CLUSTER_PRESETS: Dict[str, object] = {
    "uniform": homogeneous,
    "mixed_fast_slow": mixed_fast_slow,
    "stepped": stepped,
    "asym_uplink": asym_uplink,
}
