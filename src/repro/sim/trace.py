"""Trace generation + estimator training (§3.2, "330K pieces of trace data").

On the paper's testbed the traces are wall-clock measurements; here they are
drawn from the analytic testbed physics (``core/cost.py``) with multiplicative
log-normal measurement noise — the same role, no hardware.  The GBDT
estimators are then trained on (features -> log seconds) pairs and plugged
into DPP, giving the full data-driven FCO loop end to end.

Heterogeneous traces: a config with ``cluster_presets`` set additionally
samples ``repro.cluster`` presets (``mixed_fast_slow``, ``stepped``,
``asym_uplink``); those rows carry the per-cluster capability summary
columns (``core.estimator.hetero_summary``) after the exact homogeneous
prefix and are labeled by the heterogeneous batched physics
(``hetero_compute_time_batch_s`` straggler maxes; sync against the
bottleneck-projected compat testbed).  The default (empty-preset) config
is **draw-for-draw identical** to the historical homogeneous stream —
same RNG consumption, same 17/20-column matrices, same labels.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost import (Testbed, Topology, compute_time_batch_s,
                             hetero_compute_time_batch_s, sync_time_batch_s)
from repro.core.estimator import (GBDTEstimator, hetero_summary, i_features,
                                  s_features, testbed_summary)
from repro.core.graph import ConvT, LayerSpec
from repro.core.partition import Scheme
from repro.gbdt import GBDTRegressor

#: the heterogeneous presets a hetero trace config samples by default
HETERO_PRESETS: Tuple[str, ...] = ("mixed_fast_slow", "stepped",
                                   "asym_uplink")


@dataclasses.dataclass
class TraceConfig:
    n_samples: int = 330_000
    noise_sigma: float = 0.05       # log-normal measurement noise
    seed: int = 0
    node_choices: Tuple[int, ...] = (3, 4, 5, 6)
    bw_choices: Tuple[float, ...] = (0.5, 1.0, 5.0)
    topo_choices: Tuple[Topology, ...] = (Topology.RING, Topology.PS,
                                          Topology.MESH)
    #: ``repro.cluster.CLUSTER_PRESETS`` names to sample heterogeneous
    #: rows from.  Empty (the default) keeps the historical homogeneous
    #: stream and the 17/20-column layout; non-empty widens every row by
    #: the capability-summary columns (homogeneous rows carry the uniform
    #: summary) and labels preset rows with the hetero physics.
    cluster_presets: Tuple[str, ...] = ()
    #: fraction of samples drawn on a sampled preset (only consulted when
    #: ``cluster_presets`` is non-empty)
    hetero_fraction: float = 0.5


def hetero_trace_config(**overrides) -> TraceConfig:
    """A :class:`TraceConfig` sampling all heterogeneous presets (the
    config the hetero-trained planner estimator is built from)."""
    kw = dict(cluster_presets=HETERO_PRESETS)
    kw.update(overrides)
    return TraceConfig(**kw)


def _random_layer(rng: np.random.Generator) -> LayerSpec:
    t = ConvT(rng.choice([0, 1, 2, 3, 4, 5, 6],
                         p=[0.33, 0.14, 0.24, 0.08, 0.11, 0.05, 0.05]))
    if t == ConvT.FC:
        seq = int(rng.choice([1, 64, 128, 256, 512]))
        return LayerSpec("t", t, seq, 1, int(rng.choice([256, 512, 768, 1024,
                                                         2048, 3072])),
                         int(rng.choice([256, 512, 768, 1000, 3072])))
    h = int(rng.choice([7, 14, 28, 56, 112, 224]))
    cin = int(rng.choice([3, 16, 32, 64, 128, 256, 512, 1024]))
    if t == ConvT.DWCONV:
        cout, k, s, p = cin, 3, int(rng.choice([1, 2])), 1
    elif t == ConvT.POINTWISE:
        cout, k, s, p = int(rng.choice([16, 32, 64, 128, 256, 512, 1024])), 1, 1, 0
    elif t == ConvT.POOL:
        cout, k, s, p = cin, int(rng.choice([2, 3])), 2, 0
    elif t in (ConvT.ADD, ConvT.CONCAT):
        # multi-input merge: the fan-in feature comes from len(inputs);
        # the dummy producer names never resolve (features only)
        fan = int(rng.integers(2, 5))
        cout, k, s, p = cin, 1, 1, 0
        return LayerSpec("t", t, h, h, cin, cout, k, s, p,
                         inputs=tuple(f"in{j}" for j in range(fan)))
    else:
        cout = int(rng.choice([16, 32, 64, 128, 256, 512]))
        k = int(rng.choice([3, 5, 7]))
        s = int(rng.choice([1, 2]))
        p = k // 2
    if h + 2 * p < k:
        k = 1
        p = 0
    return LayerSpec("t", t, h, h, cin, cout, k, s, p)


def _random_testbed(rng: np.random.Generator, cfg: TraceConfig) -> Testbed:
    return Testbed(nodes=int(rng.choice(cfg.node_choices)),
                   bandwidth_gbps=float(rng.choice(cfg.bw_choices)),
                   topology=Topology(int(rng.choice(cfg.topo_choices))))


def _sample_cluster(rng: np.random.Generator, cfg: TraceConfig,
                    cache: Dict[tuple, object]) -> tuple:
    """Draw one heterogeneous cluster (preset name x node count); clusters
    are memoized so label batching can group rows by cluster key."""
    from repro.cluster.spec import CLUSTER_PRESETS   # lazy: keep the
    # homogeneous import path free of the cluster subsystem
    name = cfg.cluster_presets[int(rng.integers(0,
                                                len(cfg.cluster_presets)))]
    nodes = int(rng.choice(cfg.node_choices))
    key = (name, nodes)
    if key not in cache:
        cache[key] = CLUSTER_PRESETS[name](nodes)
    return key


def _cluster_summary(cluster) -> List[float]:
    return hetero_summary(cluster.capability_weights,
                          [link.bandwidth_gbps for link in cluster.links],
                          cluster.max_latency_us)


def _hetero_i_labels(X: np.ndarray, factors: np.ndarray,
                     keys: List[Optional[tuple]],
                     clusters: Dict[tuple, object]) -> np.ndarray:
    """Batched ground-truth compute times: homogeneous rows through one
    ``compute_time_batch_s`` call, each preset group through one
    ``hetero_compute_time_batch_s`` call (straggler max under the
    cluster's capability weights — exactly what
    ``ClusterAnalyticEstimator.i_cost_batch`` computes)."""
    t = np.empty(len(X), np.float64)
    key_arr = np.asarray(_index(keys))
    hom = key_arr < 0
    if hom.any():
        t[hom] = compute_time_batch_s(X[hom], Testbed(), factors[hom])
    for gi, (key, cl) in enumerate(clusters.items()):
        m = key_arr == gi
        if not m.any():
            continue
        t[m] = hetero_compute_time_batch_s(
            X[m], cl.compat_testbed(),
            np.asarray(cl.speeds_gflops), np.asarray(cl.dev_derates),
            np.asarray(cl.capability_weights), factors[m])
    return t


def _index(keys: List[Optional[tuple]]) -> List[int]:
    """Group index per row: position of the row's cluster key in
    first-seen order (-1 entries are handled by the caller's mask)."""
    order: Dict[tuple, int] = {}
    out = []
    for k in keys:
        if k is None:
            out.append(-1)
        else:
            out.append(order.setdefault(k, len(order)))
    return out


def generate_i_traces(cfg: TraceConfig) -> Tuple[np.ndarray, np.ndarray]:
    """i-Estimator traces: features -> log(compute seconds).

    Sampling stays scalar (it drives the RNG stream, kept draw-for-draw
    identical to the historical loop under the default config), but the
    tens of thousands of ground-truth times come from batched physics
    calls — one per cluster group.  A spatial scheme is required for a
    nonzero halo, so every sampled configuration is valid by construction.
    """
    rng = np.random.default_rng(cfg.seed)
    xs: List[List[float]] = []
    factors: List[float] = []
    noise: List[float] = []
    keys: List[Optional[tuple]] = []
    clusters: Dict[tuple, object] = {}
    while len(xs) < cfg.n_samples:
        layer = _random_layer(rng)
        if cfg.cluster_presets and rng.random() < cfg.hetero_fraction:
            key = _sample_cluster(rng, cfg, clusters)
            cl = clusters[key]
            tb = cl.compat_testbed()
            summary = _cluster_summary(cl)
        else:
            key = None
            tb = _random_testbed(rng, cfg)
            summary = testbed_summary(tb) if cfg.cluster_presets else None
        scheme = Scheme(int(rng.integers(0, 4)))
        halo = 0
        if scheme.spatial and rng.random() < 0.4:
            halo = int(rng.integers(1, 5))
        noise.append(float(np.exp(rng.normal(0.0, cfg.noise_sigma))))
        xs.append(i_features(layer, scheme, tb, halo, hetero=summary))
        factors.append(layer.extra_flop_factor)
        keys.append(key)
    X = np.asarray(xs)
    t = _hetero_i_labels(X, np.asarray(factors), keys, clusters) \
        * np.asarray(noise)
    return X, np.log(np.maximum(t, 1e-9))


def generate_s_traces(cfg: TraceConfig) -> Tuple[np.ndarray, np.ndarray]:
    """s-Estimator traces: features -> log(sync seconds).  Same structure
    as :func:`generate_i_traces`: scalar sampling, batched
    ``sync_time_batch_s`` evaluation per cluster group (heterogeneous
    rows are priced against the bottleneck-projected compat testbed —
    bandwidth/topology travel in the feature columns, the projected link
    latency in ``tb``)."""
    rng = np.random.default_rng(cfg.seed + 1)
    xs: List[List[float]] = []
    noise: List[float] = []
    keys: List[Optional[tuple]] = []
    clusters: Dict[tuple, object] = {}
    while len(xs) < cfg.n_samples:
        layer = _random_layer(rng)
        if cfg.cluster_presets and rng.random() < cfg.hetero_fraction:
            key = _sample_cluster(rng, cfg, clusters)
            cl = clusters[key]
            tb = cl.compat_testbed()
            summary = _cluster_summary(cl)
        else:
            key = None
            tb = _random_testbed(rng, cfg)
            summary = testbed_summary(tb) if cfg.cluster_presets else None
        src = Scheme(int(rng.integers(0, 4)))
        if rng.random() < 0.1:
            nxt, dst = None, None
        else:
            nxt = _random_layer(rng)
            dst = Scheme(int(rng.integers(0, 4)))
        noise.append(float(np.exp(rng.normal(0.0, cfg.noise_sigma))))
        xs.append(s_features(layer, nxt, src, dst, tb, hetero=summary))
        keys.append(key)
    X = np.asarray(xs)
    t = np.empty(len(X), np.float64)
    key_arr = np.asarray(_index(keys))
    hom = key_arr < 0
    if hom.any():
        t[hom] = sync_time_batch_s(X[hom], Testbed())
    for gi, (key, cl) in enumerate(clusters.items()):
        m = key_arr == gi
        if m.any():
            t[m] = sync_time_batch_s(X[m], cl.compat_testbed())
    t *= np.asarray(noise)
    return X, np.log(np.maximum(t, 1e-9))


def train_estimators(cfg: Optional[TraceConfig] = None,
                     gbdt_kwargs: Optional[dict] = None,
                     verbose: bool = False) -> GBDTEstimator:
    """End-to-end: sample traces from the simulator, fit both GBDTs."""
    cfg = cfg or TraceConfig()
    kw = dict(n_estimators=120, learning_rate=0.15, max_depth=7)
    kw.update(gbdt_kwargs or {})
    xi, yi = generate_i_traces(cfg)
    xs, ys = generate_s_traces(cfg)
    i_model = GBDTRegressor(**kw, seed=cfg.seed).fit(
        xi, yi, verbose_every=40 if verbose else 0)
    s_model = GBDTRegressor(**kw, seed=cfg.seed + 7).fit(
        xs, ys, verbose_every=40 if verbose else 0)
    return GBDTEstimator(i_model, s_model)
