"""Request batching at the pipeline head — serving policy over the
discrete-event simulator.

The planner's throughput objectives fix the *plan*; this module fixes the
*operating point*: at a given request arrival rate, how many requests
should the pipeline head batch per inference pass?  Larger batches
amortize per-message link latency and raise pipeline capacity, but every
request in a batch waits for the batch to fill — the head-of-batch
request waits ``(batch-1)/rate`` before the pass even starts — so tail
latency pays for what throughput gains.

``sweep_serving`` runs the simulator's multi-request schedule across an
arrival-rate grid and a batch-size grid, scores each cell as *goodput*
(arrival rate served within the p99 bound, zero when the bound breaks or
the pipeline is unstable), and ``choose_batch`` picks the winning batch
size per rate.  Everything is simulator-measured — queueing delay under
the open arrival process is exactly what the analytic model cannot see.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.graph import ModelGraph
from repro.core.plan import Plan

from .simsched import simulate
from .spec import ClusterSpec


@dataclasses.dataclass(frozen=True)
class ServingPoint:
    """One (arrival rate, batch size) operating point, simulator-scored."""

    arrival_rate_rps: float
    batch_size: int
    capacity_rps: float        # closed-loop pipeline capacity at this batch
    stable: bool               # capacity >= arrival rate
    p50_latency_s: float       # per-request, batching wait included
    p99_latency_s: float
    goodput_rps: float         # rate served within the bound, else 0.0
    feasible: bool             # stable and p99 within bound


def serve_point(graph: ModelGraph, plan: Plan, cluster: ClusterSpec,
                arrival_rate_rps: float, batch_size: int,
                p99_bound_s: float, n_batches: int = 32,
                weighted: bool = True) -> ServingPoint:
    """Simulate one operating point.

    Batches of ``batch_size`` requests depart every ``batch/rate`` seconds
    (the fill time of an evenly-paced arrival stream); per-request latency
    adds the fill wait of the *first* request of the batch — the
    conservative (worst-member) accounting, which is what a p99 bound
    should see.  The p99 itself is conservative too: ``SimReport``
    reports the ``method="higher"`` order statistic, an observed latency
    rather than an interpolation below it.  Capacity comes from a
    closed-loop run of the same batched
    stage DAG; an unstable point (arrivals outrun capacity) is infeasible
    regardless of the simulated window.
    """
    if arrival_rate_rps <= 0.0:
        raise ValueError("arrival rate must be positive")
    cap = simulate(graph, plan, cluster, n_requests=max(8, n_batches // 2),
                   weighted=weighted, batch_size=batch_size)
    capacity_rps = cap.throughput_rps * batch_size
    stable = capacity_rps >= arrival_rate_rps * (1.0 - 1e-9)
    period = batch_size / arrival_rate_rps
    rep = simulate(graph, plan, cluster, n_requests=n_batches,
                   arrival_period_s=period, weighted=weighted,
                   batch_size=batch_size)
    fill_wait = (batch_size - 1) / arrival_rate_rps
    p50 = rep.p50_latency_s + fill_wait
    p99 = rep.p99_latency_s + fill_wait
    feasible = stable and p99 <= p99_bound_s
    return ServingPoint(
        arrival_rate_rps=arrival_rate_rps, batch_size=batch_size,
        capacity_rps=capacity_rps, stable=stable,
        p50_latency_s=p50, p99_latency_s=p99,
        goodput_rps=arrival_rate_rps if feasible else 0.0,
        feasible=feasible)


def choose_batch(graph: ModelGraph, plan: Plan, cluster: ClusterSpec,
                 arrival_rate_rps: float, p99_bound_s: float,
                 batch_sizes: Sequence[int] = (1, 2, 4, 8),
                 n_batches: int = 32,
                 weighted: bool = True
                 ) -> Tuple[ServingPoint, List[ServingPoint]]:
    """Best batch size at one arrival rate: max goodput, ties to the lower
    p99 (and then the smaller batch).  Returns ``(best, all_points)``;
    when no batch size meets the bound, ``best`` is the point closest to
    meeting it (min p99 among stable points, else max capacity)."""
    pts = [serve_point(graph, plan, cluster, arrival_rate_rps, b,
                       p99_bound_s, n_batches, weighted)
           for b in batch_sizes]
    feas = [p for p in pts if p.feasible]
    if feas:
        best = min(feas, key=lambda p: (-p.goodput_rps, p.p99_latency_s,
                                        p.batch_size))
    else:
        stable = [p for p in pts if p.stable]
        best = (min(stable, key=lambda p: (p.p99_latency_s, p.batch_size))
                if stable else
                max(pts, key=lambda p: (p.capacity_rps, -p.batch_size)))
    return best, pts


def sweep_serving(graph: ModelGraph, plan: Plan, cluster: ClusterSpec,
                  arrival_rates_rps: Sequence[float], p99_bound_s: float,
                  batch_sizes: Sequence[int] = (1, 2, 4, 8),
                  n_batches: int = 32,
                  weighted: bool = True) -> List[dict]:
    """Arrival-rate sweep: per rate, the chosen batch size and its scores
    (JSON-ready rows — the BENCH_serving record format)."""
    rows: List[dict] = []
    for rate in arrival_rates_rps:
        best, pts = choose_batch(graph, plan, cluster, rate, p99_bound_s,
                                 batch_sizes, n_batches, weighted)
        rows.append({
            "arrival_rate_rps": rate,
            "batch_size": best.batch_size,
            "goodput_rps": best.goodput_rps,
            "feasible": best.feasible,
            "capacity_rps": best.capacity_rps,
            "p50_ms": best.p50_latency_s * 1e3,
            "p99_ms": best.p99_latency_s * 1e3,
            "per_batch": {p.batch_size: {
                "goodput_rps": p.goodput_rps,
                "capacity_rps": p.capacity_rps,
                "p99_ms": p.p99_latency_s * 1e3,
                "stable": p.stable,
            } for p in pts},
        })
    return rows


@dataclasses.dataclass(frozen=True)
class DecodeServingReport:
    """Continuous-batching decode serving at one operating point."""

    prefill_s: float           # one prompt pass (planned prefill graph)
    decode_step_s: float       # one token step for the whole batch
    tokens_per_s: float        # generated tokens / makespan
    p50_latency_s: float       # per-request: arrival -> last token
    p99_latency_s: float
    mean_batch: float          # decode-batch occupancy over all steps
    makespan_s: float
    n_requests: int
    prefill_schemes: Tuple[str, ...]
    decode_schemes: Tuple[str, ...]


def plan_decode_serving(spec, cluster: ClusterSpec, prompt_len: int,
                        n_new: int, weighted: bool = True):
    """Split planning for autoregressive serving: one searched plan for
    the compute-bound prefill pass (``seq_len`` queries) and a separate
    one for the latency-bound decode step (one query against the full
    KV length).  The two phases have opposite arithmetic intensity, so a
    single plan systematically mis-serves one of them — this is the
    prefill/decode split every LLM-serving stack performs.  Returns the
    ``(prefill, decode)`` :class:`SearchResult` pair."""
    from repro.cluster import cluster_plan_search
    from repro.runtime.decode import decode_graph, prefill_graph
    pre = cluster_plan_search(prefill_graph(spec, prompt_len), cluster,
                              weighted=weighted)
    dec = cluster_plan_search(decode_graph(spec, prompt_len + n_new),
                              cluster, weighted=weighted)
    return pre, dec


def serve_decode(spec, cluster: ClusterSpec, *, prompt_len: int,
                 n_new: int, arrival_rate_rps: float, n_requests: int = 32,
                 max_batch: int = 8,
                 weighted: bool = True) -> DecodeServingReport:
    """Continuous decode-step batching over the prefill/decode split.

    Deterministic event loop (evenly-paced arrivals at
    ``arrival_rate_rps``): a request is prefilled as soon as the decode
    batch has a free slot — prefill blocks the batch for one
    ``prefill_s`` pass (prefill-priority admission) — then joins the
    running batch, where every decode step emits one token for *all*
    active requests and completed requests leave immediately.  This is
    the vLLM-style iteration-level scheduling policy: no request waits
    for a batch-mate to finish its full generation.  Step times come
    from the split plans of :func:`plan_decode_serving`; a decode step
    is priced independently of batch occupancy (decode is
    bandwidth-bound on the weights, which are read once per step
    regardless of batch size — the standard continuous-batching
    economy)."""
    if arrival_rate_rps <= 0.0:
        raise ValueError("arrival rate must be positive")
    if n_requests < 1 or n_new < 1 or max_batch < 1:
        raise ValueError(f"bad decode serving point: n_requests="
                         f"{n_requests}, n_new={n_new}, "
                         f"max_batch={max_batch}")
    pre, dec = plan_decode_serving(spec, cluster, prompt_len, n_new,
                                   weighted)
    prefill_s, decode_s = pre.cost, dec.cost
    arrivals = [i / arrival_rate_rps for i in range(n_requests)]
    waiting: List[int] = []
    active: dict = {}
    latencies = [0.0] * n_requests
    t, nxt, done, tokens = 0.0, 0, 0, 0
    occupancy: List[int] = []
    while done < n_requests:
        while nxt < n_requests and arrivals[nxt] <= t + 1e-12:
            waiting.append(nxt)
            nxt += 1
        if not active and not waiting:
            t = arrivals[nxt]           # idle until the next arrival
            continue
        if waiting and len(active) < max_batch:
            r = waiting.pop(0)
            t += prefill_s
            active[r] = n_new
            continue
        occupancy.append(len(active))
        t += decode_s
        tokens += len(active)
        for r in list(active):
            active[r] -= 1
            if active[r] == 0:
                del active[r]
                latencies[r] = t - arrivals[r]
                done += 1
    import numpy as np
    return DecodeServingReport(
        prefill_s=prefill_s, decode_step_s=decode_s,
        tokens_per_s=tokens / t,
        p50_latency_s=float(np.percentile(latencies, 50)),
        # conservative tail: an observed latency, never an interpolation
        # below the worst request (matches SimReport.p99_latency_s)
        p99_latency_s=float(np.percentile(latencies, 99, method="higher")),
        mean_batch=float(np.mean(occupancy)) if occupancy else 0.0,
        makespan_s=t, n_requests=n_requests,
        prefill_schemes=tuple(s.name for s, _ in pre.plan.steps),
        decode_schemes=tuple(s.name for s, _ in dec.plan.steps))


def max_goodput(graph: ModelGraph, plan: Plan, cluster: ClusterSpec,
                arrival_rates_rps: Sequence[float], p99_bound_s: float,
                batch_sizes: Sequence[int] = (1, 2, 4, 8),
                n_batches: int = 32,
                weighted: bool = True) -> Tuple[float, Optional[dict]]:
    """Highest feasible goodput across the rate grid (the serving-capacity
    headline number for one plan) and its sweep row."""
    rows = sweep_serving(graph, plan, cluster, arrival_rates_rps,
                         p99_bound_s, batch_sizes, n_batches, weighted)
    best_row = None
    best = 0.0
    for row in rows:
        if row["feasible"] and row["goodput_rps"] > best:
            best, best_row = row["goodput_rps"], row
    return best, best_row
