"""The consolidated public API surface.

Two contracts: (a) ``repro`` / ``repro.runtime`` export exactly their
documented ``__all__`` — every name importable, no private leakage — and
(b) the historical ``run_partitioned`` entry point survives as a working
shim that warns ``DeprecationWarning`` and returns bit-identical results
to the :class:`Session` it wraps.
"""
import dataclasses
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
import repro.cluster
import repro.core
import repro.runtime
from repro.core import AnalyticEstimator, ConvT, LayerSpec, Testbed, chain
from repro.core.dpp import plan_search
from repro.runtime.engine import init_weights, run_partitioned
from repro.runtime.session import ExecConfig, Session


def _toy():
    g = chain("toy", [
        LayerSpec("c0", ConvT.CONV, 16, 16, 3, 8, 3, 1, 1),
        LayerSpec("pw", ConvT.POINTWISE, 16, 16, 8, 16, 1, 1, 0),
        LayerSpec("c1", ConvT.CONV, 16, 16, 16, 8, 3, 1, 1),
    ])
    key = jax.random.PRNGKey(0)
    return g, init_weights(g, key), jax.random.normal(key, (16, 16, 3))


# ---------------------------------------------------------------------------
# curated surface
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mod", ["repro", "repro.runtime", "repro.core",
                                 "repro.cluster"])
def test_all_names_importable(mod):
    m = importlib.import_module(mod)
    assert m.__all__ == sorted(set(m.__all__), key=m.__all__.index)
    for name in m.__all__:
        assert not name.startswith("_"), name
        assert hasattr(m, name), f"{mod}.__all__ lists missing {name!r}"


def test_top_level_covers_plan_then_run():
    """The README quickstart works off `import repro` alone."""
    for name in ("plan_search", "Testbed", "AnalyticEstimator", "chain",
                 "Session", "ExecConfig", "init_weights",
                 "DecodeSession", "TransformerSpec", "plan_decode",
                 "PagedKVCache", "cluster_plan_search", "homogeneous"):
        assert name in repro.__all__, name


def test_no_private_leakage():
    """`from repro import *` must not drag in submodules or internals."""
    ns = {}
    exec("from repro import *", ns)
    public = {k for k in ns if not k.startswith("__")}
    assert public == set(repro.__all__)
    import types
    leaked = [k for k, v in ns.items() if isinstance(v, types.ModuleType)]
    assert not leaked, leaked


# ---------------------------------------------------------------------------
# ExecConfig
# ---------------------------------------------------------------------------

def test_exec_config_validates():
    with pytest.raises(ValueError, match="backend"):
        ExecConfig(backend="cuda")
    with pytest.raises(ValueError, match="executor"):
        ExecConfig(executor="ray")
    with pytest.raises(ValueError, match="fallback"):
        ExecConfig(fallback="retry")
    with pytest.raises(ValueError, match="stage_retries"):
        ExecConfig(stage_retries=-1)
    with pytest.raises(ValueError, match="stage_timeout_s"):
        ExecConfig(stage_timeout_s=0.0)


def test_exec_config_frozen_hashable_policy():
    cfg = ExecConfig(backend="pallas", instrument=True)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.backend = "xla"
    assert cfg == ExecConfig(backend="pallas", instrument=True)
    assert len({cfg, ExecConfig(backend="pallas", instrument=True),
                ExecConfig()}) == 2  # hashable policy, usable as cache key
    # replace() is the supported way to derive variants
    assert dataclasses.replace(cfg, backend="xla") == \
        ExecConfig(instrument=True)


def test_session_validates_binding():
    g, ws, _ = _toy()
    res = plan_search(g, AnalyticEstimator(), Testbed(nodes=4))
    with pytest.raises(ValueError, match="nodes"):
        Session(g, ws, res.plan, 0)
    short = chain("short", list(g.layers[:1]))
    with pytest.raises(ValueError, match="length"):
        Session(short, ws, res.plan, 4)


# ---------------------------------------------------------------------------
# run_partitioned shim
# ---------------------------------------------------------------------------

def test_run_partitioned_warns_and_matches_session():
    g, ws, x = _toy()
    res = plan_search(g, AnalyticEstimator(), Testbed(nodes=4))
    sess_out, _ = Session(g, ws, res.plan, 4).run(x)
    with pytest.warns(DeprecationWarning, match="Session"):
        shim_out, stats = run_partitioned(g, ws, x, res.plan, 4)
    np.testing.assert_array_equal(np.asarray(shim_out),
                                  np.asarray(sess_out))
    assert stats is not None
    # kwargs still thread through (and still get validated)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="backend"):
            run_partitioned(g, ws, x, res.plan, 4, backend="cuda")


def test_session_reuse_across_inputs():
    g, ws, _ = _toy()
    res = plan_search(g, AnalyticEstimator(), Testbed(nodes=2))
    sess = Session(g, ws, res.plan, 2)
    rng = np.random.default_rng(1)
    for _ in range(3):
        x = jnp.asarray(rng.normal(size=(16, 16, 3)), jnp.float32)
        out = sess(x)  # __call__ sugar drops the stats
        ref, _ = sess.run(x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
