"""Deliverable (g) — roofline table over all (arch x shape) dry-run records
(single-pod mesh).  Reads experiments/dryrun/*.json produced by
``python -m repro.launch.dryrun --all``."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def rows(mesh: str = "16x16"):
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") == mesh:
            out.append(rec)
    return out


def run() -> None:
    recs = rows()
    if not recs:
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return
    for rec in recs:
        coll = sum(rec.get("coll_bytes", {}).values())
        emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
             f"bneck={rec['bottleneck']};"
             f"t_comp={rec['t_compute_s']:.4g}s;"
             f"t_mem={rec['t_memory_s']:.4g}s;"
             f"t_coll={rec['t_collective_s']:.4g}s;"
             f"useful={rec['useful_ratio']:.3f};"
             f"coll_GB={coll / 1e9:.2f}")


if __name__ == "__main__":
    run()
