"""Per-architecture smoke tests (reduced configs) + decode consistency.

Smoke: instantiate the REDUCED variant of each assigned architecture
(<=2 layers, d_model<=256, <=4 experts), run one forward and one train step
on CPU, assert output shapes and no NaNs.  Consistency: token-by-token
decode with the KV/state cache must reproduce the teacher-forced forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.transformer import Model
from repro.optim import adamw_init, adamw_update

B, S = 2, 16


def _batch(cfg, key, seq=S):
    toks = jax.random.randint(key, (B, seq), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)

    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    loss0 = model.loss(params, batch)
    assert jnp.isfinite(loss0)

    opt = adamw_init(params)
    grads = jax.grad(lambda p: model.loss(p, batch))(params)
    params2, opt = adamw_update(grads, opt, params, lr=1e-2)
    loss1 = model.loss(params2, batch)
    assert jnp.isfinite(loss1)
    # one step on the same batch should not increase loss materially
    assert float(loss1) < float(loss0) + 0.05


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe:   # drop-free routing so teacher forcing == decode
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    if cfg.family == "vlm":   # decode continues the text stream
        cfg = dataclasses.replace(cfg, vision_tokens=0)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    seq = 10
    batch = _batch(cfg, key, seq=seq)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros((B, 0, cfg.d_model))
    full_logits, _ = model.forward(params, batch)

    cache = model.cache_init(B, capacity=cfg.attn_window or seq)
    if cfg.family == "encdec":
        cache["xlayers"] = model.encode_cross(params, batch["audio_embeds"])
    step = jax.jit(model.decode_step)
    toks = batch["tokens"]
    errs = []
    for t in range(seq):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(logits[:, 0]
                                          - full_logits[:, t]))))
    assert max(errs) < 1e-3, errs


@pytest.mark.parametrize("arch", ["llama3-8b", "zamba2-1.2b"])
def test_sliding_window_decode_ring_buffer(arch):
    """Positions beyond the window must not influence decode logits."""
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype="float32", attn_window=4)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    seq = 12
    toks = jax.random.randint(key, (B, seq), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    full_logits, _ = model.forward(params, batch)
    cache = model.cache_init(B, capacity=4)
    step = jax.jit(model.decode_step)
    for t in range(seq):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
    # ring-buffer decode at the last position == teacher-forced windowed
    assert float(jnp.max(jnp.abs(logits[:, 0] - full_logits[:, -1]))) < 1e-3


def test_train_loss_decreases_over_steps():
    """A few optimizer steps on repeated data descend (llama reduced)."""
    cfg = get_config("llama3-8b").reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = adamw_init(params)
    batch = _batch(cfg, key)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(params)
        params, opt = adamw_update(grads, opt, params, lr=5e-3)
        return params, opt, loss

    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_vlm_uses_vision_embeddings():
    cfg = get_config("qwen2-vl-7b").reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    l1, _ = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["vision_embeds"] = batch["vision_embeds"] + 1.0
    l2, _ = model.forward(params, batch2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4


def test_whisper_uses_audio():
    cfg = get_config("whisper-small").reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    l1, _ = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["audio_embeds"] = batch["audio_embeds"] + 1.0
    l2, _ = model.forward(params, batch2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4
