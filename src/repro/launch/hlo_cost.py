"""Loop-aware HLO cost analyzer — the dry-run "profiler".

``compiled.cost_analysis()`` counts a ``while`` body once regardless of trip
count (verified in-repo), which under-reports every scanned layer stack and
every chunked-attention/SSM time loop.  This module parses the optimized
post-SPMD HLO text and walks the computation graph hierarchically:

  * ``while``  -> body and condition costs x ``known_trip_count`` (from
    ``backend_config``)
  * ``fusion`` -> one kernel: HBM bytes = operands + result of the *fusion*
    (not its internals — that's exactly what fusion means), FLOPs = inner
    dots + one flop per output element for the elementwise work
  * ``dot``    -> 2 * prod(result dims) * prod(contracting dims)
  * collectives -> result bytes, multiplied through enclosing loops

All shapes are post-partitioning, so every quantity is **per device**.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\(")
_ARRAY = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'known_trip_count[\\":{]+n[\\":]+(\d+)')
_CALL_ATTR = re.compile(r"(?:calls|body|to_apply)=%([\w.\-]+)")
_COND_ATTR = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES = re.compile(r"(?:branch_computations|true_computation|"
                       r"false_computation)=\{?%([\w.\-, %]+)\}?")
_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute", "ragged-all-to-all")

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy", "after-all", "partition-id", "replica-id"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


class _Instr:
    __slots__ = ("name", "type_str", "opcode", "line")

    def __init__(self, name, type_str, opcode, line):
        self.name = name
        self.type_str = type_str
        self.opcode = opcode
        self.line = line


class HloCostAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[_Instr]] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, Dict[str, float]] = {}
        self.entry: Optional[str] = self._entry

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        self._entry = None
        for line in text.splitlines():
            if line.endswith("{") and ("->" in line) and not \
                    line.lstrip().startswith("%param"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.strip().startswith("ENTRY"):
                        self._entry = cur
                    continue
            if line.strip() == "}":
                continue
            if cur is None:
                continue
            m = _INSTR.match(line)
            if m:
                self.comps[cur].append(
                    _Instr(m.group(1), m.group(2), m.group(3), line))

    # ------------------------------------------------------------------
    def _dot_flops(self, ins: _Instr, shapes: Dict[str, str]) -> float:
        out_elems = _type_elems(ins.type_str)
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
        if not cm:
            return 2.0 * out_elems
        # lhs operand shape (operands are printed as "f32[64,64]{1,0} %name",
        # so resolve the first %name token, not a raw "%"-prefixed string)
        opm = _OPERANDS.search(ins.line[ins.line.index(ins.opcode + "("):])
        contract = 1
        if opm:
            names = _OPERAND_NAME.findall(opm.group(1))
            if names:
                lhs_type = shapes.get(names[0], "")
                dims_m = _ARRAY.search(lhs_type)
                if dims_m and dims_m.group(2):
                    dims = [int(d) for d in dims_m.group(2).split(",") if d]
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            contract *= dims[int(ci)]
        return 2.0 * out_elems * contract

    def _operand_bytes_list(self, ins: _Instr,
                            shapes: Dict[str, str]) -> List[int]:
        start = ins.line.find(ins.opcode + "(")
        if start < 0:
            return []
        opm = _OPERANDS.search(ins.line[start:])
        if not opm:
            return []
        return [_type_bytes(shapes[nm])
                for nm in _OPERAND_NAME.findall(opm.group(1))
                if nm in shapes]

    def _operand_bytes(self, ins: _Instr, shapes: Dict[str, str]) -> int:
        return sum(self._operand_bytes_list(ins, shapes))

    def _smallest_operand_bytes(self, ins: _Instr,
                                shapes: Dict[str, str]) -> int:
        lst = [b for b in self._operand_bytes_list(ins, shapes) if b > 0]
        return min(lst) if lst else 0

    def _root_opcode(self, comp: str) -> str:
        for ins in self.comps.get(comp, ()):
            if "ROOT" in ins.line:
                return ins.opcode
        return ""

    def _contains_op(self, comp: str, opcode: str) -> bool:
        return any(i.opcode == opcode for i in self.comps.get(comp, ()))

    def comp_cost(self, comp: str) -> Dict[str, float]:
        if comp in self._memo:
            return self._memo[comp]
        cost: Dict[str, float] = {"flops": 0.0, "bytes": 0.0}
        self._memo[comp] = cost      # break cycles defensively
        shapes: Dict[str, str] = {}
        for ins in self.comps.get(comp, ()):
            shapes[ins.name] = ins.type_str
        for ins in self.comps.get(comp, ()):
            op = ins.opcode
            if op == "while":
                tm = _TRIP.search(ins.line)
                trips = float(tm.group(1)) if tm else 1.0
                bm = _CALL_ATTR.search(ins.line)
                cm = _COND_ATTR.search(ins.line)
                for sub in filter(None, (bm and bm.group(1),
                                         cm and cm.group(1))):
                    for k, v in self.comp_cost(sub).items():
                        cost[k] = cost.get(k, 0.0) + trips * v
                continue
            if op == "conditional":
                brs = re.findall(r"%([\w.\-]+)", ins.line.split(
                    "conditional(")[-1])
                sub_costs = [self.comp_cost(b) for b in brs
                             if b in self.comps]
                if sub_costs:
                    keys = set().union(*[set(c) for c in sub_costs])
                    for k in keys:
                        cost[k] = cost.get(k, 0.0) + max(
                            c.get(k, 0.0) for c in sub_costs)
                continue
            if op == "fusion":
                cm2 = _CALL_ATTR.search(ins.line)
                root_op = ""
                if cm2:
                    inner = self.comp_cost(cm2.group(1))
                    cost["flops"] += inner["flops"] + _type_elems(
                        ins.type_str)
                    for k, v in inner.items():
                        if k.startswith("coll:"):
                            cost[k] = cost.get(k, 0.0) + v
                    root_op = self._root_opcode(cm2.group(1))
                result_b = _type_bytes(ins.type_str)
                ops = self._operand_bytes_list(ins, shapes)
                big = max(ops) if ops else 0
                dus_inside = cm2 and self._contains_op(cm2.group(1),
                                                       "dynamic-update-slice")
                if root_op == "dynamic-update-slice" or (
                        dus_inside and big >= result_b):
                    # in-place fused slice update (possibly wrapped in the
                    # CPU backend's bf16<->f32 legalization converts, which a
                    # TPU build would not emit): the big buffer is aliased;
                    # traffic = the non-aliased operands, twice (read+write)
                    cost["bytes"] += 2.0 * (sum(ops) - big)
                else:
                    cost["bytes"] += result_b + self._operand_bytes(
                        ins, shapes)
                continue
            if op in ("call", "async-start"):
                cm2 = _CALL_ATTR.search(ins.line)
                if cm2:
                    for k, v in self.comp_cost(cm2.group(1)).items():
                        cost[k] = cost.get(k, 0.0) + v
                continue
            is_coll = False
            for cop in _COLL_OPS:
                if op == cop or op == cop + "-start":
                    b = _type_bytes(ins.type_str)
                    cost[f"coll:{cop}"] = cost.get(f"coll:{cop}", 0.0) + b
                    cost["bytes"] += b + self._operand_bytes(ins, shapes)
                    is_coll = True
                    break
            if is_coll:
                continue
            if op.endswith("-done") or op in _SKIP_BYTES:
                continue
            if op == "convert":
                # standalone dtype converts: on TPU these fuse into the
                # producing/consuming op; the CPU backend's bf16->f32
                # legalization also fabricates cache-sized converts that a
                # TPU build would not emit.  Count nothing.
                continue
            if op == "dynamic-update-slice":
                # in-place slice write: traffic = read+write of the update
                # region, not the whole buffer
                upd = self._smallest_operand_bytes(ins, shapes)
                cost["bytes"] += 2.0 * upd
                cost["flops"] += _type_elems(ins.type_str) * 0  # no math
                continue
            if op == "dynamic-slice":
                cost["bytes"] += 2.0 * _type_bytes(ins.type_str)
                continue
            if op == "dot":
                cost["flops"] += self._dot_flops(ins, shapes)
            elif op == "convolution":
                # not used by the zoo (conv frontends are stubs); count IO
                cost["flops"] += 2.0 * _type_elems(ins.type_str)
            elif op in ("reduce", "reduce-window", "sort", "scatter",
                        "gather", "dynamic-slice", "dynamic-update-slice",
                        "select-and-scatter", "iota", "broadcast", "reshape",
                        "transpose", "convert", "slice", "pad", "concatenate",
                        "add", "multiply", "subtract", "divide", "exponential",
                        "compare", "select", "maximum", "minimum", "rsqrt",
                        "tanh", "negate", "log", "custom-call", "rng",
                        "rng-bit-generator", "clamp", "and", "or", "xor"):
                cost["flops"] += _type_elems(ins.type_str)
            cost["bytes"] += _type_bytes(ins.type_str) \
                + self._operand_bytes(ins, shapes)
        self._memo[comp] = cost
        return cost

    def totals(self) -> Dict[str, float]:
        if not self.entry:
            return {"flops": 0.0, "bytes": 0.0}
        return dict(self.comp_cost(self.entry))

    # -- debugging / perf iteration: where do the bytes come from? ---------
    def top_instructions(self, n: int = 20, key: str = "bytes"):
        """(contribution, comp, opcode, line) weighted by loop trip counts."""
        mult: Dict[str, float] = {}
        if not self.entry:
            return []

        def mark(comp: str, m: float):
            if comp in mult:
                mult[comp] += m
                return
            mult[comp] = m
            for ins in self.comps.get(comp, ()):
                if ins.opcode == "while":
                    tm = _TRIP.search(ins.line)
                    trips = float(tm.group(1)) if tm else 1.0
                    bm = _CALL_ATTR.search(ins.line)
                    cm = _COND_ATTR.search(ins.line)
                    for sub in filter(None, (bm and bm.group(1),
                                             cm and cm.group(1))):
                        mark(sub, m * trips)
                elif ins.opcode in ("fusion", "call"):
                    cm2 = _CALL_ATTR.search(ins.line)
                    if cm2:
                        mark(cm2.group(1), m)
        mark(self.entry, 1.0)

        rows = []
        for comp, instrs in self.comps.items():
            m = mult.get(comp, 0.0)
            if m == 0.0:
                continue
            shapes = {i.name: i.type_str for i in instrs}
            for ins in instrs:
                if ins.opcode in _SKIP_BYTES or ins.opcode == "while":
                    continue
                if key == "bytes":
                    if ins.opcode == "fusion":
                        val = _type_bytes(ins.type_str) + self._operand_bytes(
                            ins, shapes)
                    elif ins.opcode in ("dynamic-update-slice",):
                        val = 2 * self._smallest_operand_bytes(ins, shapes)
                    else:
                        val = _type_bytes(ins.type_str) + self._operand_bytes(
                            ins, shapes)
                else:
                    val = self._dot_flops(ins, shapes) \
                        if ins.opcode == "dot" else 0.0
                if val * m > 0:
                    rows.append((val * m, comp, ins.opcode,
                                 ins.line.strip()[:140]))
        rows.sort(reverse=True)
        return rows[:n]


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    return HloCostAnalyzer(hlo_text).totals()
