"""Fault injection for simsched — churn scenarios and strategy replay.

The counterpart of :mod:`cluster.elastic`: this module *produces* the
cluster events (device arrivals, departures, capability derates,
interconnect slowdowns) as seedable scenario timelines, and replays a
serving horizon under one of three replanning strategies:

* ``never`` — plan once at t=0, never react (the static-planner
  baseline: a crash of any plan member is a permanent outage);
* ``scratch`` — on every detected membership/capability change, rebuild
  the Pareto frontier from a cold planner and always cut over to the
  frontier optimum (correct but pays full re-registration wall time
  plus a drain+copy stall on every event);
* ``incremental`` — one persistent :class:`ElasticPlanner`: cached
  registrations / sync rows / frontiers are reused across events, and
  the keep-vs-migrate score can rationally leave a mildly degraded plan
  in place instead of stalling the fleet.

The replay is a discrete-event simulation at heartbeat resolution with
an explicit detection model: a crash is only *detected* after
``dead_misses`` missed heartbeats, a derate when the next heartbeat
carries the capability report — so time-to-recover honestly includes
detection delay + planner wall time + cutover (weight copy + in-flight
drain) stalls.  Serving rate between events comes from the closed-loop
:func:`cluster.simsched.simulate` throughput of the *current plan on the
true cluster state* — an undetected derate degrades the measured rate
before any planner notices.

Definitions used by the benchmark gates (``benchmarks/churn_bench.py``):

* **goodput** — requests served over the whole horizon / horizon
  seconds, counting outage and cutover-stall windows at rate zero;
* **time-to-recover** — per injected fault (departure / leave / derate /
  slowdown): time from the true fault instant until the system is back
  in steady state — serving at a nonzero rate with no replan or
  migration pending.  A strategy that never reacts "recovers" instantly
  from a derate (it is steady, just degraded — the penalty shows up in
  goodput) but never recovers from a member crash (recovery = remaining
  horizon).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dpp import Objective
from repro.core.graph import ModelGraph
from repro.core.plan import Plan
from repro.obs import trace as _obs_trace

from .elastic import (CapacityError, DeviceRegistry, ElasticPlanner,
                      MembershipError)
from .simsched import simulate
from .spec import ClusterSpec, DeviceSpec

#: event kinds understood by the replayer
EVENT_KINDS = ("depart", "leave", "arrive", "derate", "slowdown", "recover")

#: replanning strategies understood by :func:`run_churn`
STRATEGIES = ("never", "scratch", "incremental")

#: fault kinds that open a time-to-recover measurement
FAULT_KINDS = ("depart", "leave", "derate", "slowdown")


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    """One injected cluster event at simulated time ``t``.

    ``depart`` — hard crash: the device stops serving *and* stops
    heartbeating at ``t`` (detected only after the lease expires).
    ``leave`` — graceful departure: announced, detected immediately.
    ``arrive`` — ``spec`` joins the fleet (detected at its first
    heartbeat).  ``derate`` — capability multiplier ``factor`` applied to
    ``device`` (reported with the next heartbeat).  ``slowdown`` —
    fleet-wide link bandwidth multiplier ``factor``.  ``recover`` —
    clears the device's derate (or the slowdown when ``device`` is None).
    """

    t: float
    kind: str
    device: Optional[str] = None
    factor: float = 1.0
    spec: Optional[DeviceSpec] = None

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown churn event kind {self.kind!r}")
        if self.kind == "arrive" and self.spec is None:
            raise ValueError("arrive events need a DeviceSpec")
        if self.kind in ("depart", "leave", "derate") and not self.device:
            raise ValueError(f"{self.kind} events need a device name")


@dataclasses.dataclass(frozen=True)
class ChurnScenario:
    name: str
    horizon_s: float
    events: Tuple[ChurnEvent, ...]

    def __post_init__(self):
        object.__setattr__(self, "events",
                           tuple(sorted(self.events, key=lambda e: e.t)))
        for e in self.events:
            if not (0.0 < e.t < self.horizon_s):
                raise ValueError(
                    f"event at t={e.t} outside (0, {self.horizon_s})")

    @property
    def n_departures(self) -> int:
        return sum(1 for e in self.events
                   if e.kind in ("depart", "leave"))


# ---------------------------------------------------------------------------
# scenario generators (seedable)
# ---------------------------------------------------------------------------

def scenario_mixed(cluster: ClusterSpec, seed: int = 0,
                   horizon_s: float = 40.0) -> ChurnScenario:
    """Derates + a link slowdown + one crash + a recovery: the general
    churn mix.  Derate targets/magnitudes are seeded; the crash victim is
    the last device (never the lead, so the survivor set stays planable
    on 2-device clusters)."""
    rng = np.random.default_rng(seed)
    names = [d.name for d in cluster.devices]
    d_derate = names[int(rng.integers(0, max(1, len(names) - 1)))]
    f1 = float(rng.uniform(0.4, 0.7))
    events = [
        ChurnEvent(t=horizon_s * 0.12, kind="derate", device=d_derate,
                   factor=f1),
        ChurnEvent(t=horizon_s * 0.30, kind="slowdown",
                   factor=float(rng.uniform(0.5, 0.8))),
        ChurnEvent(t=horizon_s * 0.45, kind="recover", device=d_derate),
        ChurnEvent(t=horizon_s * 0.55, kind="depart", device=names[-1]),
        ChurnEvent(t=horizon_s * 0.80, kind="recover"),
    ]
    return ChurnScenario(name=f"mixed-s{seed}", horizon_s=horizon_s,
                         events=tuple(events))


def scenario_flap(cluster: ClusterSpec, seed: int = 0,
                  horizon_s: float = 60.0) -> ChurnScenario:
    """One device repeatedly crashes and rejoins — the membership state
    sequence revisits itself, which is exactly what the incremental
    planner's frontier cache exploits."""
    rng = np.random.default_rng(seed)
    victim = cluster.devices[-1]
    jitter = float(rng.uniform(0.0, 0.02 * horizon_s))
    events = []
    for i, frac in enumerate((0.10, 0.40, 0.70)):
        t = horizon_s * frac + jitter
        events.append(ChurnEvent(t=t, kind="depart", device=victim.name))
        events.append(ChurnEvent(t=t + horizon_s * 0.15, kind="arrive",
                                 spec=victim))
    return ChurnScenario(name=f"flap-s{seed}", horizon_s=horizon_s,
                         events=tuple(events))


def scenario_crash_only(cluster: ClusterSpec, seed: int = 0,
                        horizon_s: float = 40.0) -> ChurnScenario:
    """Staggered hard crashes with no soft events — the pure outage
    case (needs >= 3 devices so one survives planning)."""
    rng = np.random.default_rng(seed)
    names = [d.name for d in cluster.devices]
    n_crash = min(2, len(names) - 1)
    victims = list(rng.choice(names[1:], size=n_crash, replace=False))
    events = [ChurnEvent(t=horizon_s * (0.25 + 0.35 * i), kind="depart",
                         device=str(v))
              for i, v in enumerate(victims)]
    return ChurnScenario(name=f"crash-s{seed}", horizon_s=horizon_s,
                         events=tuple(events))


CHURN_SCENARIOS: Dict[str, Callable[..., ChurnScenario]] = {
    "mixed": scenario_mixed,
    "flap": scenario_flap,
    "crash_only": scenario_crash_only,
}


def random_scenario(cluster: ClusterSpec, seed: int,
                    horizon_s: float = 40.0, n_events: int = 6,
                    ensure_departure: bool = True) -> ChurnScenario:
    """Seeded random churn timeline: arrival/departure/derate/slowdown
    processes with uniform event times.  At most ``n - 1`` distinct
    devices ever crash or leave, so the registry always keeps at least
    one live member; with ``ensure_departure`` the timeline contains at
    least one hard crash (the benchmark gate requires a real outage)."""
    rng = np.random.default_rng(seed)
    names = [d.name for d in cluster.devices]
    gone: set = set()
    events: List[ChurnEvent] = []
    times = np.sort(rng.uniform(0.05 * horizon_s, 0.95 * horizon_s,
                                size=n_events))
    fresh = itertools.count()
    for t in times:
        t = float(t)
        kind = str(rng.choice(["depart", "derate", "derate", "slowdown",
                               "arrive", "recover"]))
        if kind == "depart":
            alive = [n for n in names if n not in gone]
            if len(alive) <= 1:
                kind = "derate"
            else:
                victim = str(rng.choice(alive[1:]))
                gone.add(victim)
                events.append(ChurnEvent(t=t, kind="depart",
                                         device=victim))
                continue
        if kind == "arrive":
            if gone:
                back = sorted(gone)[0]
                gone.discard(back)
                spec = next(d for d in cluster.devices if d.name == back)
            else:
                spec = DeviceSpec(name=f"x{next(fresh)}",
                                  gflops=float(rng.uniform(4.0, 24.0)),
                                  mem_mb=1024)
                names.append(spec.name)
            events.append(ChurnEvent(t=t, kind="arrive", spec=spec))
            continue
        if kind == "derate":
            alive = [n for n in names if n not in gone]
            events.append(ChurnEvent(
                t=t, kind="derate", device=str(rng.choice(alive)),
                factor=float(rng.uniform(0.3, 0.9))))
            continue
        if kind == "slowdown":
            events.append(ChurnEvent(
                t=t, kind="slowdown",
                factor=float(rng.uniform(0.4, 0.9))))
            continue
        events.append(ChurnEvent(t=t, kind="recover",
                                 device=None))
    if ensure_departure and not any(e.kind in ("depart", "leave")
                                    for e in events):
        alive = [n for n in names if n not in gone]
        victim = alive[-1] if len(alive) > 1 else names[-1]
        events.append(ChurnEvent(t=float(0.5 * horizon_s), kind="depart",
                                 device=victim))
    return ChurnScenario(name=f"random-s{seed}", horizon_s=horizon_s,
                         events=tuple(events))


# ---------------------------------------------------------------------------
# strategy replay
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChurnRunResult:
    """Outcome of replaying one scenario under one strategy."""

    strategy: str
    scenario: str
    horizon_s: float
    served_requests: float
    goodput_rps: float
    recoveries_s: Tuple[float, ...]       # one per injected fault
    mean_recovery_s: float
    max_recovery_s: float
    n_replans: int
    n_migrations: int                     # replans that changed the plan
    n_keeps: int                          # replans that kept the old plan
    plan_wall_total_s: float
    stall_total_s: float                  # cutover windows at rate zero
    reuse_counts: Dict[str, int]
    timeline: List[Dict]


def _fold_derate(spec: DeviceSpec, derate: float) -> DeviceSpec:
    if derate == 1.0:
        return spec
    return dataclasses.replace(spec,
                               eff_derate=spec.eff_derate * derate)


def run_churn(graph: ModelGraph, cluster: ClusterSpec,
              scenario: ChurnScenario, strategy: str, *,
              objective: Objective = Objective.THROUGHPUT,
              heartbeat_interval_s: float = 1.0, suspect_misses: int = 2,
              dead_misses: int = 3, horizon_requests: float = 300.0,
              inflight: int = 4, n_sim_requests: int = 12,
              weighted: bool = True, max_segment: int = 32,
              sim_cache: Optional[Dict] = None) -> ChurnRunResult:
    """Replay ``scenario`` on ``cluster`` under ``strategy`` (see module
    docstring for the strategies, the detection model, and the metric
    definitions)."""
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}")
    hb = heartbeat_interval_s
    reg = DeviceRegistry.from_cluster(
        cluster, heartbeat_interval_s=hb, suspect_misses=suspect_misses,
        dead_misses=dead_misses)
    base_specs: Dict[str, DeviceSpec] = {d.name: d for d in cluster.devices}
    true_alive: Dict[str, bool] = {d.name: True for d in cluster.devices}
    true_derate: Dict[str, float] = {}
    true_link = 1.0
    sim_cache = {} if sim_cache is None else sim_cache

    def sim_rate(plan: Plan, plan_cluster: ClusterSpec) -> float:
        """Closed-loop throughput of ``plan`` on the TRUE capabilities of
        its device set (zero if any member is truly down)."""
        devs = []
        for d in plan_cluster.devices:
            if not true_alive.get(d.name, False):
                return 0.0
            devs.append(_fold_derate(base_specs[d.name],
                                     true_derate.get(d.name, 1.0)))
        links = tuple(dataclasses.replace(
            l, bandwidth_gbps=l.bandwidth_gbps * true_link)
            for l in plan_cluster.links)
        true_cl = dataclasses.replace(plan_cluster, devices=tuple(devs),
                                      links=links)
        key = (graph.name, plan.steps,
               ElasticPlanner.cluster_signature(true_cl, weighted),
               n_sim_requests)
        if key not in sim_cache:
            sim_cache[key] = simulate(
                graph, plan, true_cl,
                n_requests=n_sim_requests).throughput_rps
        return float(sim_cache[key])

    planner = ElasticPlanner(
        graph, weighted=weighted, max_segment=max_segment,
        horizon_requests=horizon_requests, inflight=inflight)
    plan_cluster = reg.cluster()
    d0 = planner.replan(plan_cluster, objective=objective)
    plan, cur_period = d0.plan, d0.period_s
    planned_sig = reg.signature()

    # -- event loop state --------------------------------------------------
    cur_t = 0.0
    served = 0.0
    stalled = False
    stall_total = 0.0
    rate = sim_rate(plan, plan_cluster)
    open_faults: List[float] = []
    recoveries: List[float] = []
    n_replans = n_migrations = n_keeps = 0
    wall_total = 0.0
    reuse_counts: Dict[str, int] = {
        "frontier_cache": 0, "registration": 0, "svals": 0, "rescale": 0,
        "suffix_reused_layers": 0, "branch_tables_reused": 0}
    timeline: List[Dict] = []
    pending_id = 0
    pending_live = False

    SEQ = itertools.count()
    heap: List[tuple] = []

    def push(t: float, kind: str, payload=None) -> None:
        heapq.heappush(heap, (t, next(SEQ), kind, payload))

    for e in scenario.events:
        push(e.t, "true", e)
    k = 1
    while k * hb <= scenario.horizon_s:
        push(k * hb, "tick", None)
        k += 1
    push(scenario.horizon_s, "end", None)

    def advance(to_t: float) -> None:
        nonlocal cur_t, served, stall_total
        dt = to_t - cur_t
        if dt > 0.0:
            eff = 0.0 if stalled else rate
            served += eff * dt
            if stalled:
                stall_total += dt
            cur_t = to_t

    def refresh_rate() -> None:
        nonlocal rate
        rate = sim_rate(plan, plan_cluster)
        # "never" is back in steady state as soon as it serves again; a
        # replanning strategy recovers only when its response deploys
        if (strategy == "never" and rate > 0.0 and not stalled
                and not pending_live):
            while open_faults:
                recoveries.append(cur_t - open_faults.pop())

    def begin_replan(now: float) -> None:
        """Plan for the newly detected cluster and schedule the cutover.
        Old plan keeps serving during the (off-critical-path) solve; the
        cutover itself is a stop-the-world stall of the migration time."""
        nonlocal n_replans, n_migrations, n_keeps, wall_total
        nonlocal pending_id, pending_live, stalled
        stalled = False      # a newer decision aborts a stale cutover
        try:
            det = reg.cluster()
        except MembershipError:
            return          # nothing live to plan on — faults stay open
        if strategy == "scratch":
            solver = ElasticPlanner(
                graph, weighted=weighted, max_segment=max_segment,
                horizon_requests=horizon_requests, inflight=inflight)
            dec = solver.replan(det, old_plan=plan,
                                old_cluster=plan_cluster,
                                objective=objective, consider_keep=False,
                                old_period_s=cur_period)
        else:
            dec = planner.replan(det, old_plan=plan,
                                 old_cluster=plan_cluster,
                                 objective=objective,
                                 old_period_s=cur_period)
        n_replans += 1
        wall_total += dec.plan_wall_s
        for key, val in dec.reuse.items():
            if key == "rescale":
                reuse_counts["rescale"] += int(val is not None)
            else:
                reuse_counts[key] += int(val)
        changed = dec.plan is not plan
        if changed:
            n_migrations += 1
        else:
            n_keeps += 1
        cutover = dec.migration.total_s if (changed
                                            or dec.migration.bytes_moved
                                            > 0.0) else 0.0
        pending_id += 1
        pending_live = True
        t_solved = now + dec.plan_wall_s
        if cutover > 0.0:
            push(t_solved, "stall_on", pending_id)
        push(t_solved + cutover, "deploy",
             (pending_id, dec.plan, det, changed, dec.period_s))
        timeline.append(dict(t=now, what="replan", strategy=strategy,
                             changed=changed, wall_s=dec.plan_wall_s,
                             cutover_s=cutover, reuse=dec.reuse))

    while heap:
        t, _, kind, payload = heapq.heappop(heap)
        t = min(t, scenario.horizon_s)
        advance(t)
        if kind == "end":
            break
        if kind == "true":
            e: ChurnEvent = payload
            if e.kind == "depart":
                true_alive[e.device] = False
            elif e.kind == "leave":
                true_alive[e.device] = False
                if e.device in {m.spec.name for m in reg.live_members()}:
                    reg.leave(e.device, now=t)
            elif e.kind == "arrive":
                base_specs[e.spec.name] = e.spec
                true_alive[e.spec.name] = True
                true_derate.pop(e.spec.name, None)
            elif e.kind == "derate":
                true_derate[e.device] = e.factor
            elif e.kind == "slowdown":
                true_link = e.factor
            elif e.kind == "recover":
                if e.device is not None:
                    true_derate.pop(e.device, None)
                else:
                    true_link = 1.0
            if e.kind in FAULT_KINDS:
                in_plan = any(d.name == e.device
                              for d in plan_cluster.devices)
                if e.kind in ("derate",) and not in_plan:
                    pass        # derating an unused device is a non-event
                elif strategy == "never" and e.kind in ("derate",
                                                        "slowdown"):
                    recoveries.append(0.0)   # steady (degraded) already
                else:
                    open_faults.append(t)
            refresh_rate()
            timeline.append(dict(t=t, what=f"true:{e.kind}",
                                 device=e.device, rate=rate))
        elif kind == "tick":
            for name, alive in true_alive.items():
                if not alive:
                    continue
                m = reg.get(name)
                if m is None or m.state.value in ("dead", "left"):
                    reg.join(base_specs[name], now=t)
                reg.heartbeat(name, now=t,
                              derate=true_derate.get(name, 1.0))
            reg.set_link_factor(true_link)
            reg.tick(now=t)
            try:
                sig = reg.signature()
            except MembershipError:
                sig = None
            if sig != planned_sig and strategy != "never":
                planned_sig = sig
                # detection instant: the membership/capability change
                # was noticed on this heartbeat tick (sim time in args)
                _obs_trace.instant(_obs_trace.PLANNER_TRACK, "detect",
                                   cat="planner", t_sim=t,
                                   strategy=strategy)
                begin_replan(t)
        elif kind == "stall_on":
            if payload == pending_id:
                stalled = True
        elif kind == "deploy":
            did, new_plan, new_cluster, changed, new_period = payload
            if did != pending_id:
                continue        # superseded by a newer replan
            plan, plan_cluster, cur_period = (new_plan, new_cluster,
                                              new_period)
            stalled = False
            pending_live = False
            refresh_rate()
            if rate > 0.0:
                while open_faults:
                    recoveries.append(t - open_faults.pop())
            timeline.append(dict(t=t, what="deploy", changed=changed,
                                 rate=rate))
    advance(scenario.horizon_s)
    while open_faults:
        recoveries.append(scenario.horizon_s - open_faults.pop())

    rec = tuple(recoveries)
    return ChurnRunResult(
        strategy=strategy, scenario=scenario.name,
        horizon_s=scenario.horizon_s, served_requests=served,
        goodput_rps=served / scenario.horizon_s,
        recoveries_s=rec,
        mean_recovery_s=float(np.mean(rec)) if rec else 0.0,
        max_recovery_s=float(np.max(rec)) if rec else 0.0,
        n_replans=n_replans, n_migrations=n_migrations, n_keeps=n_keeps,
        plan_wall_total_s=wall_total, stall_total_s=stall_total,
        reuse_counts=reuse_counts, timeline=timeline)


def compare_strategies(graph: ModelGraph, cluster: ClusterSpec,
                       scenario: ChurnScenario,
                       **kwargs) -> Dict[str, ChurnRunResult]:
    """All three strategies on one scenario, sharing the simulator
    memo (rates are modeling, not measurement — sharing is fair and
    keeps the sweep fast)."""
    sim_cache: Dict = {}
    return {s: run_churn(graph, cluster, scenario, s,
                         sim_cache=sim_cache, **kwargs)
            for s in STRATEGIES}
