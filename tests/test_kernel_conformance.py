"""Kernel conformance grid + halo property tests (the Pallas proof
obligation).

Three layers of evidence that ``backend="pallas"`` is safe on the engine
hot path, all in interpret mode on CPU:

1. **Geometry grid** — every distinct ``(ConvT, k, s, padding)`` occurring
   in any ``EDGE_MODELS`` graph, crossed with every shard zero-pad
   signature (``shard_halo_pads``) a spatial split can produce, runs the
   shard kernel against the jnp oracle.  A guard test asserts the grid IS
   the full geometry union, so adding a model layer with a new geometry
   fails CI until the grid covers it.
2. **Engine backend equivalence** — each edge model (test-scaled) runs the
   planner's plan under both backends: outputs agree within 1e-4 of the
   output scale and ``ExecStats`` are identical field for field (stats
   accounting is geometry-derived, never backend-derived).
3. **Halo property tests** (hypothesis) — sharded-execute-then-reassemble
   equals the unsharded forward for arbitrary valid shard counts and
   random T/NT plans, on random chains and on fork/merge DAGs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.edge_models import EDGE_MODELS
from repro.core import AnalyticEstimator, Testbed, chain
from repro.core.dpp import plan_search
from repro.core.graph import (ConvT, LayerSpec, ModelGraph, conv_geometries,
                              shard_halo_pads)
from repro.core.partition import ALL_SCHEMES, Mode, Scheme
from repro.core.plan import Plan, fixed_plan, plan_feasible
from repro.kernels.conv2d import (UnsupportedGeometry, conv2d_shard,
                                  shard_out_shape)
from repro.kernels.ops import matmul_tiled
from repro.kernels.ref import conv2d_shard_ref, matmul_ref
from repro.runtime.engine import (_apply_record, _apply_record_b,
                                  init_weights, run_reference)
from repro.runtime.session import ExecConfig, Session

EST = AnalyticEstimator()

#: test-scale constructor kwargs per edge model (full-resolution interpret
#: runs are minutes each; geometry keys (k, s, p) are size-independent)
MODEL_TEST_KW = {
    "mobilenet": dict(width=32),
    "resnet18": dict(width=32),
    "resnet101": dict(width=32),
    "inception": dict(width=32),
    "bert": dict(seq=16, d=32, n_layers=1, d_ff=64),
}

#: conv-family types the Pallas shard kernel must lower
_CONV_TYPES = (ConvT.CONV, ConvT.DWCONV, ConvT.POINTWISE)


def _edge_model_geometries():
    """Union of (ConvT, k, s, p) keys over all EDGE_MODELS at full scale
    (geometry keys don't depend on the test-scale kwargs except the global
    avgpools, which track input size — include both scales)."""
    geoms = set()
    for name, f in EDGE_MODELS.items():
        geoms.update(conv_geometries(f()))
        geoms.update(conv_geometries(f(**MODEL_TEST_KW[name])))
    return sorted(geoms)


ALL_GEOMS = _edge_model_geometries()
CONV_GEOMS = [g for g in ALL_GEOMS if g[0] in _CONV_TYPES]
OTHER_GEOMS = [g for g in ALL_GEOMS if g[0] not in _CONV_TYPES]


def _rel_err(a: jnp.ndarray, b: jnp.ndarray) -> float:
    """Max abs deviation normalized by the reference scale (unnormalized
    random-weight nets grow activations; f32 agreement is relative)."""
    scale = max(1.0, float(jnp.max(jnp.abs(b))) if b.size else 1.0)
    if a.size == 0:
        return 0.0 if a.shape == b.shape else float("inf")
    return float(jnp.max(jnp.abs(a - b))) / scale


def test_grid_is_complete():
    """The parametrized grid below is computed from EDGE_MODELS at import
    (a new model layer geometry automatically becomes a grid case), so the
    falsifiable content here is (a) the extraction isn't silently losing
    the known hot geometries and (b) every conv-family key is actually
    kernel-lowerable on a full-map shard."""
    must_have = {
        (ConvT.CONV, 3, 1, 1),        # resnet body
        (ConvT.CONV, 3, 2, 1),        # resnet downsampling
        (ConvT.CONV, 7, 2, 3),        # resnet stem
        (ConvT.CONV, 5, 1, 2),        # inception 5x5 branch
        (ConvT.DWCONV, 3, 1, 1),      # mobilenet depthwise
        (ConvT.DWCONV, 3, 2, 1),      # mobilenet strided depthwise
        (ConvT.POINTWISE, 1, 1, 0),   # pointwise / bottleneck 1x1
        (ConvT.POINTWISE, 1, 2, 0),   # strided projection skip
    }
    missing = must_have - set(CONV_GEOMS)
    assert not missing, f"geometry extraction lost hot keys: {missing}"
    assert any(t == ConvT.FC for t, *_ in OTHER_GEOMS)     # bert / heads
    assert any(t == ConvT.POOL for t, *_ in OTHER_GEOMS)   # fallback axis
    # every conv-family key must be kernel-lowerable on a full-map shard
    for (t, k, s, p) in CONV_GEOMS:
        h = w = k + 3 * s + 1
        oh, ow = shard_out_shape(h, w, k, s, (p, p, p, p))
        assert oh >= 1 and ow >= 1, (t, k, s, p)


@pytest.mark.parametrize("t,k,s,p", CONV_GEOMS,
                         ids=[f"{t.name}-k{k}-s{s}-p{p}"
                              for t, k, s, p in CONV_GEOMS])
def test_conv_grid_all_halo_pads(t, k, s, p):
    """Shard kernel vs oracle on every zero-pad signature of this geometry:
    top/bottom/left/right map-edge shards and the all-halo interior shard
    (whose padding is real neighbor rows already inside the slice)."""
    key = jax.random.PRNGKey(k * 100 + s * 10 + p)
    cin = 5
    cout = cin if t == ConvT.DWCONV else 7
    dw = t == ConvT.DWCONV
    wshape = (k, k, 1, cin) if dw else (k, k, cin, cout)
    w = jax.random.normal(jax.random.PRNGKey(1), wshape) * 0.2
    for pads in shard_halo_pads(p):
        # shard big enough for >= 2 output rows/cols at every pad signature
        h = k + 3 * s + 1 - pads[0] - pads[1]
        wdt = k + 3 * s + 1 - pads[2] - pads[3]
        x = jax.random.normal(key, (h, wdt, cin))
        out = conv2d_shard(x, w, pads=pads, stride=s, depthwise=dw,
                           tile_h=2)
        ref = conv2d_shard_ref(x, w, pads=pads, stride=s, depthwise=dw)
        assert out.shape == ref.shape
        assert _rel_err(out, ref) < 1e-4, (pads,)


@pytest.mark.parametrize("t,k,s,p", OTHER_GEOMS,
                         ids=[f"{t.name}-k{k}-s{s}-p{p}"
                              for t, k, s, p in OTHER_GEOMS])
def test_non_conv_grid_falls_back_identically(t, k, s, p):
    """POOL/FC/ADD/CONCAT records: the pallas backend's per-record dispatch
    must agree exactly with the XLA record path (POOL via the automatic
    fallback, FC via the matmul kernel, merges via slicing)."""
    key = jax.random.PRNGKey(0)
    if t == ConvT.FC:
        cin, cout, seq = 24, 10, max(1, k)
        rec = (int(t), 1, 1, None, None, (0, cout))
        w = jax.random.normal(key, (cin, cout)) * 0.1
        x = jax.random.normal(jax.random.PRNGKey(1), (seq, 1, cin))
    elif t in (ConvT.ADD, ConvT.CONCAT):
        rec = (int(t), k, s, None, None, (1, 5))
        w = None
        x = jax.random.normal(key, (6, 6, 8))
    else:   # POOL
        h = max(k + s, 2 * s + k)
        rec = (int(t), k, s, (p, p, p, p), (0, h, 0, h), (0, 6))
        w = None
        x = jax.random.normal(key, (h, h, 6))
    out_p = _apply_record_b(rec, w, x, "pallas")
    out_x = _apply_record(rec, w, x)
    assert out_p.shape == out_x.shape
    assert _rel_err(out_p, out_x) < 1e-5


def test_fc_matmul_grid():
    """Row-tiled matmul over the engine's FC shard shapes: channel-sliced
    widths, row counts off the tile multiple, tiny and tall cases."""
    for (m, cin, cout, tile_m) in [(16, 32, 96, 8), (1, 32, 10, 128),
                                   (37, 16, 100, 16), (128, 64, 3, 128),
                                   (300, 7, 9, 64)]:
        x = jax.random.normal(jax.random.PRNGKey(m), (m, cin))
        w = jax.random.normal(jax.random.PRNGKey(cin), (cin, cout)) * 0.1
        out = matmul_tiled(x, w, tile_m=tile_m)
        assert _rel_err(out, matmul_ref(x, w)) < 1e-5


def test_unsupported_geometries_raise_and_fall_back():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 4))
    with pytest.raises(UnsupportedGeometry):
        conv2d_shard(x, w)                  # out_h == 0
    with pytest.raises(UnsupportedGeometry):
        conv2d_shard(x[:, :2], w)           # out_w == 0
    with pytest.raises(UnsupportedGeometry):
        matmul_tiled(jnp.zeros((0, 4)), jnp.zeros((4, 3)))
    # the engine record path must absorb these into the XLA lowering:
    # a POOL record has no pallas kernel at all
    rec = (int(ConvT.POOL), 2, 2, (0, 0, 0, 0), (0, 4, 0, 4), (0, 4))
    xp = jax.random.normal(jax.random.PRNGKey(2), (4, 4, 4))
    assert _rel_err(_apply_record_b(rec, None, xp, "pallas"),
                    _apply_record(rec, None, xp)) == 0.0


# ---------------------------------------------------------------------------
# Engine backend equivalence on every edge model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(EDGE_MODELS))
def test_engine_backend_equivalence(name):
    """The planner's plan for each edge model runs under both backends:
    outputs agree within 1e-4 of the output scale, ExecStats identical."""
    g = EDGE_MODELS[name](**MODEL_TEST_KW[name])
    key = jax.random.PRNGKey(0)
    ws = init_weights(g, key)
    l0 = g.layers[0]
    x = jax.random.normal(key, (l0.in_h, l0.in_w, l0.in_c))
    plan = plan_search(g, EST, Testbed(nodes=4, bandwidth_gbps=0.5)).plan
    out_x, st_x = Session(g, ws, plan, 4, ExecConfig(backend="xla")).run(x)
    out_p, st_p = Session(g, ws, plan, 4,
                          ExecConfig(backend="pallas")).run(x)
    assert _rel_err(out_p, out_x) < 1e-4
    assert st_x == st_p                     # satellite: ExecStats identical
    ref = run_reference(g, ws, x)
    assert _rel_err(out_p, ref) < 1e-4


def test_engine_backend_rejects_unknown():
    g = EDGE_MODELS["bert"](**MODEL_TEST_KW["bert"])
    ws = init_weights(g, jax.random.PRNGKey(0))
    x = jnp.zeros((16, 1, 32))
    with pytest.raises(ValueError, match="backend"):
        Session(g, ws, fixed_plan(g, Scheme.OUTC), 2,
                ExecConfig(backend="cuda")).run(x)


# ---------------------------------------------------------------------------
# Halo property tests (hypothesis)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:        # property tests only; see pyproject [dev]
    _HAVE_HYPOTHESIS = False

pytestmark_hyp = pytest.mark.skipif(not _HAVE_HYPOTHESIS,
                                    reason="hypothesis not installed")


def _random_chain(draw) -> ModelGraph:
    """2-4 conv-family layers with random geometry over a small map."""
    h = w = draw(st.integers(12, 20))
    cin = draw(st.integers(2, 4))
    layers = []
    for i in range(draw(st.integers(2, 4))):
        t = draw(st.sampled_from([ConvT.CONV, ConvT.DWCONV, ConvT.POINTWISE,
                                  ConvT.POOL]))
        if t == ConvT.POINTWISE:
            k, p = 1, 0
        else:
            k = draw(st.sampled_from([3, 5]))
            p = draw(st.integers(0, (k - 1) // 2))
        s = draw(st.sampled_from([1, 1, 2]))
        cout = cin if t in (ConvT.DWCONV, ConvT.POOL) \
            else draw(st.integers(2, 6))
        l = LayerSpec(f"l{i}", t, h, w, cin, cout, k, s, p)
        if l.out_h < 4 or l.out_w < 4:
            break
        layers.append(l)
        h, w, cin = l.out_h, l.out_w, cout
    if not layers:
        layers = [LayerSpec("l0", ConvT.CONV, h, w, cin, 4, 3, 1, 1)]
    return chain("prop_chain", layers)


def _random_plan(draw, g: ModelGraph, nodes: int) -> Plan:
    """Random T/NT steps made segment-uniform, filtered to feasible."""
    n = len(g)
    steps = []
    for i in range(n):
        scheme = draw(st.sampled_from(list(ALL_SCHEMES)))
        mode = Mode.T if i == n - 1 else draw(st.sampled_from(
            [Mode.T, Mode.NT]))
        steps.append((scheme, mode))
    for i in range(n - 2, -1, -1):
        if steps[i][1] == Mode.NT:
            nxt = steps[i + 1][0]
            if not nxt.spatial:
                steps[i + 1] = (Scheme.INH, steps[i + 1][1])
                nxt = Scheme.INH
            steps[i] = (nxt, Mode.NT)
    plan = Plan(tuple(steps))
    plan.validate()
    return plan


if _HAVE_HYPOTHESIS:

    @pytestmark_hyp
    @settings(max_examples=12, deadline=None)
    @given(st.data())
    def test_property_chain_pallas_reassembly(data):
        """Arbitrary chain x shard count x valid random plan: pallas
        sharded-execute-then-reassemble == unsharded forward."""
        draw = data.draw
        g = _random_chain(draw)
        nodes = draw(st.integers(2, 5))
        plan = _random_plan(draw, g, nodes)
        if not plan_feasible(g, plan, nodes):
            plan = fixed_plan(g, Scheme.INH)
            if not plan_feasible(g, plan, nodes):
                return   # degenerate split; geometry too small for nodes
        key = jax.random.PRNGKey(draw(st.integers(0, 2 ** 16)))
        ws = init_weights(g, key)
        x = jax.random.normal(key, (g.layers[0].in_h, g.layers[0].in_w,
                                    g.layers[0].in_c))
        ref = run_reference(g, ws, x)
        out, _ = Session(g, ws, plan, nodes,
                         ExecConfig(backend="pallas")).run(x)
        assert _rel_err(out, ref) < 1e-4

    @pytestmark_hyp
    @settings(max_examples=8, deadline=None)
    @given(st.data())
    def test_property_dag_pallas_reassembly(data):
        """Residual fork/merge DAG x shard count: pallas execution
        reassembles to the reference across merge boundaries."""
        draw = data.draw
        h = w = draw(st.integers(12, 18))
        cin = draw(st.integers(2, 4))
        cout = draw(st.integers(3, 6))
        s = draw(st.sampled_from([1, 2]))
        layers = [
            LayerSpec("a", ConvT.CONV, h, w, cin, cout, 3, s, 1,
                      inputs=("@input",)),
        ]
        oh, ow = layers[0].out_h, layers[0].out_w
        layers.append(LayerSpec("b", ConvT.CONV, oh, ow, cout, cout, 3, 1, 1,
                                inputs=("a",)))
        layers.append(LayerSpec("sk", ConvT.POINTWISE, h, w, cin, cout, 1, s,
                                0, inputs=("@input",)))
        layers.append(LayerSpec("add", ConvT.ADD, oh, ow, cout, cout,
                                inputs=("b", "sk")))
        layers.append(LayerSpec("c", ConvT.CONV, oh, ow, cout, 4, 3, 1, 1,
                                inputs=("add",)))
        g = ModelGraph(name="prop_dag", layers=tuple(layers))
        nodes = draw(st.integers(2, 4))
        scheme = draw(st.sampled_from([Scheme.INH, Scheme.INW,
                                       Scheme.GRID2D]))
        plan = fixed_plan(g, scheme)
        if not plan_feasible(g, plan, nodes):
            return
        key = jax.random.PRNGKey(draw(st.integers(0, 2 ** 16)))
        ws = init_weights(g, key)
        x = jax.random.normal(key, (h, w, cin))
        ref = run_reference(g, ws, x)
        out, _ = Session(g, ws, plan, nodes,
                         ExecConfig(backend="pallas")).run(x)
        assert _rel_err(out, ref) < 1e-4
