"""Measured-vs-simulated skew: structural trace diffs + per-stage ratios.

Two entry points:

* :func:`stage_skew` consumes the per-stage
  ``{"kind", "label", "sim_s", "measured_s"}`` pairing produced by
  ``runtime.mesh_exec.validate_stage_decomposition`` and reduces it to
  per-stage ``measured/sim`` ratios plus summary statistics — the
  advisory ``skew`` record in ``BENCH_mesh.json``;
* :func:`diff_traces` structurally diffs two Perfetto traces in the
  shared schema (a measured mesh trace vs the exported simulated
  timeline): same ``cat="stage"`` span names in the same order, with
  paired durations.

Ratios are **advisory by construction** on CPU CI — the measured side
runs on XLA host-platform fakes, the simulated side on the analytic
edge-silicon model — so the summary favours shape-robust statistics
(median ratio, max |log2 ratio|) over means.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence

from . import trace as _trace


def stage_skew(stages: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Per-stage measured/simulated skew from validated stage pairs.

    Stages where either side is missing or non-positive pair as
    ``ratio: None`` and are excluded from the summary (a zero-cost sync
    on one side carries no timing signal)."""
    per: List[Dict[str, Any]] = []
    ratios: List[float] = []
    for st in stages:
        sim = st.get("sim_s")
        meas = st.get("measured_s")
        ratio: Optional[float] = None
        if sim and meas and sim > 0.0 and meas > 0.0:
            ratio = float(meas) / float(sim)
            ratios.append(ratio)
        per.append({"kind": st.get("kind"), "label": st.get("label"),
                    "sim_s": sim, "measured_s": meas, "ratio": ratio})
    summary: Dict[str, Any] = {"n_stages": len(per),
                               "n_paired": len(ratios)}
    if ratios:
        s = sorted(ratios)
        mid = len(s) // 2
        median = s[mid] if len(s) % 2 else 0.5 * (s[mid - 1] + s[mid])
        summary.update(
            median_ratio=float(median),
            min_ratio=float(s[0]),
            max_ratio=float(s[-1]),
            max_abs_log2=float(max(abs(math.log2(r)) for r in ratios)))
    else:
        summary.update(median_ratio=None, min_ratio=None,
                       max_ratio=None, max_abs_log2=None)
    return {"per_stage": per, **summary}


def diff_traces(measured: Dict[str, Any], simulated: Dict[str, Any],
                cat: str = _trace.STAGE_CAT,
                measured_pid: Optional[int] = None,
                simulated_pid: Optional[int] = None) -> Dict[str, Any]:
    """Structural diff of two loaded traces sharing the span schema.

    Compares the ordered ``cat`` span-name sequences (deduplicated to
    first occurrence per name so per-device repetitions of one stage
    collapse) and pairs durations by name.  ``structure_match`` is True
    when both traces contain exactly the same stage names in the same
    first-occurrence order."""
    def names_and_durs(trace_obj, pid):
        evs = _trace.span_events(trace_obj, cat=cat, pid=pid)
        order: List[str] = []
        durs: Dict[str, float] = {}
        for ev in evs:
            n = ev["name"]
            if n not in durs:
                order.append(n)
                durs[n] = 0.0
            durs[n] = max(durs[n], float(ev.get("dur", 0.0)))
        return order, durs

    m_order, m_durs = names_and_durs(measured, measured_pid)
    s_order, s_durs = names_and_durs(simulated, simulated_pid)
    only_measured = [n for n in m_order if n not in s_durs]
    only_simulated = [n for n in s_order if n not in m_durs]
    pairs = [{"name": n, "measured_us": m_durs[n],
              "simulated_us": s_durs[n],
              "ratio": (m_durs[n] / s_durs[n]
                        if s_durs[n] > 0.0 and m_durs[n] > 0.0
                        else None)}
             for n in m_order if n in s_durs]
    return {
        "structure_match": m_order == s_order,
        "only_measured": only_measured,
        "only_simulated": only_simulated,
        "pairs": pairs,
    }
