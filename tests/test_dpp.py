"""DPP vs exhaustive oracle (Theorem 1) + baseline dominance properties,
plus bit-parity of the batched planner against the scalar reference."""
import random

import pytest

from repro.core import (ALL_SCHEMES, AnalyticEstimator, Scheme, Testbed,
                        Topology, chain, plan_cost, plan_search,
                        plan_search_reference)
from repro.core.baselines import all_solutions, performance_scores
from repro.core.exhaustive import exhaustive_search
from repro.core.graph import ConvT, LayerSpec
from repro.configs.edge_models import EDGE_MODELS

EST = AnalyticEstimator()


def _rand_graph(rng, n):
    layers = []
    h = rng.choice([14, 28, 56])
    c = rng.choice([16, 32, 64])
    for i in range(n):
        t = rng.choice([ConvT.CONV, ConvT.POINTWISE, ConvT.DWCONV])
        k, s, p = {ConvT.CONV: (3, 1, 1), ConvT.POINTWISE: (1, 1, 0),
                   ConvT.DWCONV: (3, 1, 1)}[t]
        cout = c if t == ConvT.DWCONV else rng.choice([c, 2 * c,
                                                       max(16, c // 2)])
        l = LayerSpec(f"l{i}", t, h, h, c, cout, k, s, p)
        layers.append(l)
        h, c = l.out_h, cout
    return chain("rand", layers)


@pytest.mark.parametrize("seed", range(8))
def test_dpp_matches_exhaustive(seed):
    """Theorem 1: with a correct cost oracle DPP is optimal."""
    rng = random.Random(seed)
    g = _rand_graph(rng, rng.randint(2, 6))
    tb = Testbed(nodes=rng.choice([3, 4, 5]),
                 bandwidth_gbps=rng.choice([0.5, 1.0, 5.0]),
                 topology=Topology(rng.randint(0, 2)))
    _, best = exhaustive_search(g, EST, tb)
    res = plan_search(g, EST, tb)
    assert res.cost == pytest.approx(best, rel=1e-12)
    # the returned plan's independently-evaluated cost equals the DP value
    assert plan_cost(g, res.plan, EST, tb) == pytest.approx(res.cost,
                                                            rel=1e-9)


@pytest.mark.parametrize("seed", range(4))
def test_flexpie_dominates_baselines(seed):
    """FlexPie searches a superset space: it can never lose to a baseline."""
    rng = random.Random(100 + seed)
    g = _rand_graph(rng, rng.randint(4, 10))
    tb = Testbed(nodes=4, bandwidth_gbps=rng.choice([0.5, 5.0]))
    sols = all_solutions(g, EST, tb)
    flex = sols["flexpie"][1]
    for name, (_, cost) in sols.items():
        assert flex <= cost + 1e-12, (name, cost, flex)
    scores = performance_scores({k: v[1] for k, v in sols.items()})
    assert scores["flexpie"] == pytest.approx(1.0)


def test_pruning_reduces_calls():
    rng = random.Random(7)
    g = _rand_graph(rng, 10)
    tb = Testbed(nodes=4)
    res = plan_search(g, EST, tb)
    # exhaustive space is (k*2)^(n-1)*k ~ 8^9; DPP must stay polynomial
    assert res.stats.i_calls + res.stats.s_calls < 20_000
    assert res.stats.pruned_threshold + res.stats.pruned_halo > 0


@pytest.mark.parametrize("model", list(EDGE_MODELS))
def test_batched_search_bit_matches_reference(model):
    """The batched table-driven DP returns the exact plan and cost of the
    scalar reference on every benchmark model (chain and DAG)."""
    g = EDGE_MODELS[model]()
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    res = plan_search(g, EST, tb)
    ref = plan_search_reference(g, EST, tb)
    assert res.plan == ref.plan
    assert res.cost == ref.cost
    # batching collapses duplicate queries: never more estimator rows than
    # the reference makes scalar calls
    assert res.stats.i_calls <= ref.stats.i_calls
    assert res.stats.s_calls <= ref.stats.s_calls


@pytest.mark.parametrize("seed", range(6))
def test_batched_search_matches_reference_random(seed):
    """Parity under random graphs, node counts, topologies and the
    restricted search modes the baselines use."""
    rng = random.Random(1000 + seed)
    g = _rand_graph(rng, rng.randint(2, 12))
    tb = Testbed(nodes=rng.choice([1, 3, 4, 5]),
                 bandwidth_gbps=rng.choice([0.5, 1.0, 5.0]),
                 topology=Topology(rng.randint(0, 2)))
    for kw in ({}, {"allow_fusion": False}, {"schemes": (Scheme.INH,)},
               {"schemes": (Scheme.OUTC,)}, {"max_segment": 3}):
        res = plan_search(g, EST, tb, **kw)
        ref = plan_search_reference(g, EST, tb, **kw)
        assert res.plan == ref.plan, kw
        assert res.cost == ref.cost, kw


def test_batched_stats_stay_meaningful():
    """SearchStats under the batched path: counters derived from the table
    masks keep their roles (states enumerated, entries evaluated, both
    prune families firing on a fusion-heavy conv chain)."""
    rng = random.Random(7)
    g = _rand_graph(rng, 10)
    tb = Testbed(nodes=4)
    st = plan_search(g, EST, tb).stats
    assert st.states == len(g) * len(ALL_SCHEMES)
    assert 0 < st.i_calls and 0 < st.s_calls
    assert st.pruned_halo > 0
    ref = plan_search_reference(g, EST, tb).stats
    assert st.i_calls <= ref.i_calls and st.s_calls <= ref.s_calls


def test_layerwise_beats_fixed_on_heterogeneous_graph():
    """Layers with different shapes prefer different schemes (paper Fig. 2)."""
    layers = [
        LayerSpec("big_spatial", ConvT.CONV, 56, 56, 16, 16, 3, 1, 1),
        LayerSpec("deep_channel", ConvT.POINTWISE, 56, 56, 16, 512, 1, 1, 0),
        LayerSpec("deep_channel2", ConvT.POINTWISE, 56, 56, 512, 512, 1, 1, 0),
    ]
    g = chain("hetero", layers)
    tb = Testbed(nodes=4, bandwidth_gbps=5.0)
    sols = all_solutions(g, EST, tb)
    assert sols["layerwise"][1] <= min(sols["one_dim_inh"][1],
                                       sols["one_dim_outc"][1]) + 1e-12
