"""Thread-safe span tracer with Chrome/Perfetto trace-event export.

Zero-dependency observability spine: a :class:`Tracer` records
**complete spans** (``ph: "X"``) and **instant events** (``ph: "i"``)
on named *tracks* (one Perfetto thread row per track — by convention
one per planned device, ``dev0..devN-1``, plus :data:`PLANNER_TRACK`
and :data:`CONTROL_TRACK`), timestamped in microseconds on the
monotonic clock relative to the tracer's epoch.

Tracing is **off by default** and strictly zero-overhead when off:
:func:`span` returns the module-level :data:`NULL_SPAN` singleton (no
per-call allocation, no recording), and hot paths that cannot afford
even that call cache :func:`get_tracer` once and skip instrumentation
entirely when it is ``None``.  Install a tracer with
:func:`set_tracer`; every recorded span carries ``(track, name, cat,
t0_us, dur_us, depth, args)`` and exports to the Chrome trace-event
JSON schema (``ph``/``ts``/``pid``/``tid``/``name`` — load the file at
https://ui.perfetto.dev).  The same schema is used for the *simulated*
timeline (``cluster.simsched.export_sim_trace``), so a measured mesh
trace and its prediction diff structurally (``obs.skew``).
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

#: canonical track names (Perfetto thread rows)
PLANNER_TRACK = "planner"
CONTROL_TRACK = "control"

#: span categories with gate semantics: ``cat="stage"`` spans on the
#: control track are the ones contracted to match
#: ``ExecStats.stage_times`` 1:1
STAGE_CAT = "stage"


def device_track(i: int) -> str:
    """Track name for planned device ``i``."""
    return f"dev{i}"


def link_track(i: int) -> str:
    """Track name for cluster link ``i`` (simulated timelines)."""
    return f"link{i}"


class _NullSpan:
    """Inert span: the disabled-tracing fast path.  A single module
    level instance is returned by :func:`span` for every call, so the
    no-op path allocates nothing per call."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> None:
        pass

    def event(self, name: str, **args) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    """One open span; use as a context manager.  ``set(**args)`` attaches
    arguments; ``event(name)`` drops an instant event on the span's
    track while it is open."""

    __slots__ = ("_tracer", "track", "name", "cat", "args",
                 "_t0", "depth")

    def __init__(self, tracer: "Tracer", track: str, name: str,
                 cat: str, args: Dict[str, Any]) -> None:
        self._tracer = tracer
        self.track = track
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0
        self.depth = 0

    def __enter__(self) -> "Span":
        self.depth = self._tracer._enter(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self._tracer._exit(self, self._t0, t1, failed=exc[0] is not None)
        return False

    def set(self, **args) -> None:
        self.args.update(args)

    def event(self, name: str, **args) -> None:
        self._tracer.instant(self.track, name, **args)


class Tracer:
    """Collects span/instant records; thread safe; exports Perfetto
    trace-event JSON via :meth:`to_perfetto` / :func:`write_trace`.

    ``pid``/``process`` name the Perfetto process row — measured traces
    use ``(1, "measured")``, simulated timelines ``(2, "simulated")``,
    so both fit in one file and line up vertically.
    """

    def __init__(self, process: str = "measured", pid: int = 1) -> None:
        self.process = process
        self.pid = pid
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._records: List[Dict[str, Any]] = []
        self._tracks: Dict[str, int] = {}
        self._tls = threading.local()

    # -- clock -------------------------------------------------------------

    def now_us(self) -> float:
        """Microseconds since this tracer's epoch (monotonic)."""
        return (time.perf_counter() - self._epoch) * 1e6

    # -- recording ---------------------------------------------------------

    def ensure_track(self, track: str) -> int:
        """tid of ``track``, assigning the next id on first use."""
        with self._lock:
            tid = self._tracks.get(track)
            if tid is None:
                tid = len(self._tracks) + 1
                self._tracks[track] = tid
            return tid

    def span(self, track: str, name: str, cat: str = "span",
             **args) -> Span:
        return Span(self, track, name, cat, args)

    def instant(self, track: str, name: str, cat: str = "event",
                **args) -> None:
        self.ensure_track(track)
        rec = {"ph": "i", "track": track, "name": name, "cat": cat,
               "ts": self.now_us(), "args": args}
        with self._lock:
            self._records.append(rec)

    def add_complete(self, track: str, name: str, t0_us: float,
                     dur_us: float, cat: str = "span", depth: int = 0,
                     args: Optional[Dict[str, Any]] = None) -> None:
        """Record an externally-timed complete span (e.g. a mesh stage
        whose wall time was measured by the executor itself)."""
        self.ensure_track(track)
        rec = {"ph": "X", "track": track, "name": name, "cat": cat,
               "ts": float(t0_us), "dur": float(dur_us), "depth": depth,
               "args": dict(args) if args else {}}
        with self._lock:
            self._records.append(rec)

    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _enter(self, sp: Span) -> int:
        self.ensure_track(sp.track)
        st = self._stack()
        depth = len(st)
        st.append(sp)
        return depth

    def _exit(self, sp: Span, t0: float, t1: float,
              failed: bool = False) -> None:
        st = self._stack()
        if st and st[-1] is sp:
            st.pop()
        if failed:
            sp.args.setdefault("error", True)
        rec = {"ph": "X", "track": sp.track, "name": sp.name,
               "cat": sp.cat, "ts": (t0 - self._epoch) * 1e6,
               "dur": (t1 - t0) * 1e6, "depth": sp.depth,
               "args": sp.args}
        with self._lock:
            self._records.append(rec)

    # -- introspection -----------------------------------------------------

    def spans(self, cat: Optional[str] = None,
              track: Optional[str] = None) -> List[Dict[str, Any]]:
        """Recorded complete spans (``ph == "X"``), in start order,
        optionally filtered by category and/or track."""
        with self._lock:
            recs = list(self._records)
        out = [r for r in recs if r["ph"] == "X"
               and (cat is None or r["cat"] == cat)
               and (track is None or r["track"] == track)]
        out.sort(key=lambda r: r["ts"])
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    # -- export ------------------------------------------------------------

    def to_perfetto(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object (``{"traceEvents": [...]}``)
        with process/thread-name metadata for every track."""
        with self._lock:
            recs = list(self._records)
            tracks = dict(self._tracks)
        events: List[Dict[str, Any]] = [{
            "ph": "M", "pid": self.pid, "tid": 0,
            "name": "process_name", "args": {"name": self.process}}]
        for track, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
            events.append({"ph": "M", "pid": self.pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": track}})
        for r in sorted(recs, key=lambda r: r["ts"]):
            ev: Dict[str, Any] = {
                "ph": r["ph"], "ts": r["ts"], "pid": self.pid,
                "tid": tracks[r["track"]], "name": r["name"],
                "cat": r["cat"]}
            if r["ph"] == "X":
                ev["dur"] = r["dur"]
            elif r["ph"] == "i":
                ev["s"] = "t"
            if r.get("args"):
                ev["args"] = r["args"]
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_trace(path: str, *tracers: Tracer) -> str:
    """Merge one or more tracers into a single Perfetto trace file
    (distinct ``pid`` per tracer keeps their tracks separate rows)."""
    events: List[Dict[str, Any]] = []
    for t in tracers:
        events.extend(t.to_perfetto()["traceEvents"])
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f,
                  indent=1, sort_keys=True)
    return path


def load_trace(path: str) -> Dict[str, Any]:
    """Load a trace file written by :func:`write_trace`."""
    with open(path) as f:
        return json.load(f)


def span_events(trace: Dict[str, Any], cat: Optional[str] = None,
                pid: Optional[int] = None,
                track: Optional[str] = None) -> List[Dict[str, Any]]:
    """Complete-span events of a loaded trace in timestamp order,
    with their track names resolved from the thread-name metadata."""
    names: Dict[Tuple[int, int], str] = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    out = []
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        if pid is not None and ev.get("pid") != pid:
            continue
        if cat is not None and ev.get("cat") != cat:
            continue
        ev = dict(ev)
        ev["track"] = names.get((ev.get("pid"), ev.get("tid")),
                                str(ev.get("tid")))
        if track is not None and ev["track"] != track:
            continue
        out.append(ev)
    out.sort(key=lambda e: e["ts"])
    return out


# ---------------------------------------------------------------------------
# global tracer (None by default — tracing is opt-in)
# ---------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def get_tracer() -> Optional[Tracer]:
    """The installed tracer, or ``None`` (the default: tracing off).
    Hot paths cache this once per run and skip instrumentation when it
    is ``None`` — that is the strictly-zero-overhead contract."""
    return _TRACER


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or, with ``None``, remove) the process-wide tracer."""
    global _TRACER
    _TRACER = tracer
    return tracer


def span(track: str, name: str, cat: str = "span", **args):
    """Open a span on the installed tracer — or return the shared
    :data:`NULL_SPAN` (no allocation, nothing recorded) when tracing is
    off."""
    t = _TRACER
    if t is None:
        return NULL_SPAN
    return t.span(track, name, cat, **args)


def instant(track: str, name: str, **args) -> None:
    """Drop an instant event on the installed tracer, if any."""
    t = _TRACER
    if t is not None:
        t.instant(track, name, **args)
