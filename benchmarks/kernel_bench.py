"""Per-layer shard-kernel timings: Pallas vs the XLA lowering.

For each representative shard geometry of the edge benchmarks (conv /
strided conv / stem / depthwise / pointwise on an INH shard slice with
halo rows, plus the FC matmul tile) this times the jitted Pallas path
against the jitted XLA path on one node's halo-extended input, checks
conformance (scale-normalized max error), and records everything into
``BENCH_kernels.json``:

* ``kernels.<name>``: ``{pallas_us, xla_us, ratio, max_rel_err,
  conformant}``
* ``backend_equiv.<model>``: engine-level ``backend="pallas"`` vs
  ``backend="xla"`` on the planner's plan — ``{rel_err, stats_equal,
  agree}``

``benchmarks/check_regression.py --kind kernels`` gates CI on the
committed baseline: a flipped ``conformant``/``agree``/``stats_equal``
flag always fails; timing ratios follow the usual 2x / noise-floor rule.
In this container Pallas runs in interpret mode, so ``pallas_us`` is an
emulation number — the conformance flags are the point; on a TPU the same
record tracks real kernel time.
"""
from __future__ import annotations

import json
import sys

import jax
import jax.numpy as jnp

from repro.configs.edge_models import EDGE_MODELS
from repro.core import Testbed
from repro.core.dpp import plan_search
from repro.kernels.conv2d import conv2d_shard
from repro.kernels.ops import matmul_tiled
from repro.kernels.ref import conv2d_shard_ref, matmul_ref
from repro.runtime.engine import init_weights
from repro.runtime.session import ExecConfig, Session

from .common import EST, emit, json_arg, time_call

#: (name, kind, geometry) — shard shapes of the edge models' hot layers
#: on one of 4 INH nodes (height quarter + halo), channel counts trimmed
#: so interpret-mode timing stays tractable
_SHARD_CASES = [
    # name, (Hl, Wl, cin, cout, k, s, pads)
    ("conv3x3_s1_interior", (16, 56, 32, 32, 3, 1, (0, 0, 1, 1))),
    ("conv3x3_s2_down", (16, 56, 32, 64, 3, 2, (0, 0, 1, 1))),
    ("stem7x7_s2_top", (31, 56, 3, 32, 7, 2, (3, 0, 3, 3))),
    ("dw3x3_s1_interior", (16, 56, 64, 64, 3, 1, (0, 0, 1, 1))),
    ("dw3x3_s2_down", (16, 56, 64, 64, 3, 2, (0, 0, 1, 1))),
    ("pw1x1_s1", (14, 56, 64, 128, 1, 1, (0, 0, 0, 0))),
]

_FC_CASES = [
    ("fc_seq128", (128, 256, 256)),
    ("fc_head", (1, 512, 1000)),
]

#: engine equivalence models (test scale; see tests/test_kernel_conformance)
_EQUIV_MODELS = {
    "resnet18": dict(width=32),
    "inception": dict(width=32),
}

_REL_TOL = 1e-4


def _rel_err(a, b) -> float:
    scale = max(1.0, float(jnp.max(jnp.abs(b))))
    return float(jnp.max(jnp.abs(a - b))) / scale


def _bench_shard(name: str, geo) -> dict:
    Hl, Wl, cin, cout, k, s, pads = geo
    dw = name.startswith("dw")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (Hl, Wl, cin))
    wshape = (k, k, 1, cin) if dw else (k, k, cin, cout)
    w = jax.random.normal(jax.random.PRNGKey(1), wshape) * 0.1

    pall = jax.jit(lambda a, b: conv2d_shard(
        a, b, pads=pads, stride=s, depthwise=dw))
    xla = jax.jit(lambda a, b: conv2d_shard_ref(
        a, b, pads=pads, stride=s, depthwise=dw))
    out_p = pall(x, w).block_until_ready()      # compile outside the timer
    out_x = xla(x, w).block_until_ready()
    us_p, _ = time_call(lambda: pall(x, w).block_until_ready())
    us_x, _ = time_call(lambda: xla(x, w).block_until_ready())
    err = _rel_err(out_p, out_x)
    return {
        "pallas_us": round(us_p, 1),
        "xla_us": round(us_x, 1),
        "ratio": round(us_p / max(us_x, 1e-9), 2),
        "max_rel_err": err,
        "conformant": bool(err < _REL_TOL),
    }


def _bench_fc(geo) -> dict:
    m, cin, cout = geo
    x = jax.random.normal(jax.random.PRNGKey(2), (m, cin))
    w = jax.random.normal(jax.random.PRNGKey(3), (cin, cout)) * 0.1
    pall = jax.jit(lambda a, b: matmul_tiled(a, b))
    xla = jax.jit(matmul_ref)
    out_p = pall(x, w).block_until_ready()
    out_x = xla(x, w).block_until_ready()
    us_p, _ = time_call(lambda: pall(x, w).block_until_ready())
    us_x, _ = time_call(lambda: xla(x, w).block_until_ready())
    err = _rel_err(out_p, out_x)
    return {
        "pallas_us": round(us_p, 1),
        "xla_us": round(us_x, 1),
        "ratio": round(us_p / max(us_x, 1e-9), 2),
        "max_rel_err": err,
        "conformant": bool(err < _REL_TOL),
    }


def _bench_equiv(model: str, kw: dict) -> dict:
    g = EDGE_MODELS[model](**kw)
    key = jax.random.PRNGKey(0)
    ws = init_weights(g, key)
    l0 = g.layers[0]
    x = jax.random.normal(key, (l0.in_h, l0.in_w, l0.in_c))
    plan = plan_search(g, EST, Testbed(nodes=4, bandwidth_gbps=0.5)).plan
    out_x, st_x = Session(g, ws, plan, 4, ExecConfig(backend="xla")).run(x)
    out_p, st_p = Session(g, ws, plan, 4,
                          ExecConfig(backend="pallas")).run(x)
    err = _rel_err(out_p, out_x)
    return {
        "rel_err": err,
        "stats_equal": bool(st_x == st_p),
        "agree": bool(err < _REL_TOL),
    }


def run(json_path: str | None = None) -> dict:
    out: dict = {"interpret": jax.default_backend() != "tpu",
                 "kernels": {}, "backend_equiv": {}}
    for name, geo in _SHARD_CASES:
        rec = _bench_shard(name, geo)
        out["kernels"][name] = rec
        emit(f"kernel/{name}", rec["pallas_us"],
             f"xla_us={rec['xla_us']};ratio={rec['ratio']};"
             f"conformant={rec['conformant']}")
    for name, geo in _FC_CASES:
        rec = _bench_fc(geo)
        out["kernels"][name] = rec
        emit(f"kernel/{name}", rec["pallas_us"],
             f"xla_us={rec['xla_us']};ratio={rec['ratio']};"
             f"conformant={rec['conformant']}")
    for model, kw in _EQUIV_MODELS.items():
        rec = _bench_equiv(model, kw)
        out["backend_equiv"][model] = rec
        emit(f"kernel/equiv_{model}", rec["rel_err"] * 1e6,
             f"stats_equal={rec['stats_equal']};agree={rec['agree']}")
        assert rec["agree"] and rec["stats_equal"], (
            f"{model}: pallas/xla engine divergence {rec}")
    bad = [n for n, r in out["kernels"].items() if not r["conformant"]]
    assert not bad, f"non-conformant kernels: {bad}"
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
        print(f"# wrote {json_path}", file=sys.stderr)
    return out


if __name__ == "__main__":
    run(json_path=json_arg(sys.argv[1:], default="BENCH_kernels.json"))
