"""Assigned architecture pool — exact configs with source citations.

Every entry follows the assignment block verbatim; bracketed citations are
the public sources.  ``get_config(arch_id)`` is the single lookup the
launcher, dry-run and smoke tests all use (``--arch <id>``).
"""
from __future__ import annotations

from typing import Callable, Dict

from .base import MLAConfig, MoEConfig, ModelConfig, SSMConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def _register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


@_register
def zamba2_1p2b() -> ModelConfig:
    # [arXiv:2411.15242] Mamba2 backbone + shared attention block
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
        n_heads=32, n_kv=32, d_ff=8192, vocab=32000,
        ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2,
                      head_dim=64),
        hybrid_attn_every=6, attn_window=4096)


@_register
def granite_moe() -> ModelConfig:
    # [hf:ibm-granite/granite-3.0-1b-a400m-base] scaled per assignment line
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
        n_heads=24, n_kv=8, d_ff=512, vocab=49155,
        moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512))


@_register
def deepseek_v2() -> ModelConfig:
    # [arXiv:2405.04434] MLA kv_lora=512, 2 shared + 160 routed top-6
    return ModelConfig(
        name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
        n_heads=128, n_kv=128, d_ff=1536, vocab=102400, head_dim=128,
        mla=MLAConfig(kv_lora=512, q_lora=1536, qk_nope=128, qk_rope=64,
                      v_head=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
                      first_dense=1, d_ff_dense=12288))


@_register
def whisper_small() -> ModelConfig:
    # [arXiv:2212.04356] enc-dec; conv frontend is a stub (frame embeddings)
    return ModelConfig(
        name="whisper-small", family="encdec", n_layers=12, d_model=768,
        n_heads=12, n_kv=12, d_ff=3072, vocab=51865, norm="layernorm",
        act="gelu", rope_kind="none", n_enc_layers=12, enc_seq=1500)


@_register
def qwen2_72b() -> ModelConfig:
    # [arXiv:2407.10671] GQA with QKV bias
    return ModelConfig(
        name="qwen2-72b", family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv=8, d_ff=29568, vocab=152064, qkv_bias=True)


@_register
def qwen2p5_14b() -> ModelConfig:
    # [hf:Qwen/Qwen2.5-0.5B family] GQA, QKV bias
    return ModelConfig(
        name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
        n_heads=40, n_kv=8, d_ff=13824, vocab=152064, qkv_bias=True)


@_register
def qwen2_vl_7b() -> ModelConfig:
    # [arXiv:2409.12191] M-RoPE; ViT frontend is a stub (patch embeddings)
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm", n_layers=28, d_model=3584,
        n_heads=28, n_kv=4, d_ff=18944, vocab=152064, qkv_bias=True,
        rope_kind="mrope", mrope_sections=(16, 24, 24), vision_tokens=1024)


@_register
def llama3_8b() -> ModelConfig:
    # [arXiv:2407.21783] GQA, 128k vocab
    return ModelConfig(
        name="llama3-8b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv=8, d_ff=14336, vocab=128256, rope_theta=500000.0)


@_register
def olmo_1b() -> ModelConfig:
    # [arXiv:2402.00838] non-parametric LayerNorm
    return ModelConfig(
        name="olmo-1b", family="dense", n_layers=16, d_model=2048,
        n_heads=16, n_kv=16, d_ff=8192, vocab=50304, norm="nonparam_ln",
        tie_embeddings=True)


@_register
def rwkv6_3b() -> ModelConfig:
    # [arXiv:2404.05892] Finch: data-dependent decay, attention-free
    return ModelConfig(
        name="rwkv6-3b", family="ssm", n_layers=32, d_model=2560,
        n_heads=0, n_kv=0, d_ff=8960, vocab=65536, rope_kind="none",
        ssm=SSMConfig(kind="rwkv6", head_dim=64))


ARCH_IDS = tuple(sorted(_REGISTRY))


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    return _REGISTRY[arch_id]()
