"""Heterogeneous cluster subsystem: spec validation, capability-weighted
shard geometry, scalar/batch parity, homogeneous Testbed bit-parity, and
Theorem-1 on heterogeneous clusters."""
import dataclasses
import random

import numpy as np
import pytest

from repro.cluster import (CLUSTER_PRESETS, ClusterAnalyticEstimator,
                           ClusterSpec, DeviceSpec, LinkSpec, asym_uplink,
                           cluster_plan_search, homogeneous, mixed_fast_slow,
                           stepped, topology_edges)
from repro.core import (AnalyticEstimator, ConvT, LayerSpec, ModelGraph,
                        Scheme, Testbed, Topology, chain, plan_search)
from repro.core.cost import hetero_compute_time_batch_s, hetero_compute_time_s
from repro.core.dpp import plan_search_reference
from repro.core.estimator import i_features
from repro.core.exhaustive import exhaustive_search
from repro.core.partition import (ALL_SCHEMES, hetero_shard_work, shard_work,
                                  split_sizes, weighted_split_batch,
                                  weighted_split_sizes)

EST = AnalyticEstimator()

HETERO_PRESETS = [mixed_fast_slow, stepped, asym_uplink]


def _toy_chain(h=20):
    return chain("toy", [
        LayerSpec("c0", ConvT.CONV, h, h, 3, 8, 3, 1, 1),
        LayerSpec("dw", ConvT.DWCONV, h, h, 8, 8, 3, 1, 1),
        LayerSpec("pw", ConvT.POINTWISE, h, h, 8, 16, 1, 1, 0),
        LayerSpec("c1", ConvT.CONV, h, h, 16, 16, 3, 2, 1),
        LayerSpec("c2", ConvT.CONV, h // 2, h // 2, 16, 8, 3, 1, 1),
    ])


def _toy_dag(h=16):
    return ModelGraph(name="rb", layers=(
        LayerSpec("c0", ConvT.CONV, h, h, 3, 8, 3, 1, 1),
        LayerSpec("ba", ConvT.CONV, h, h, 8, 8, 3, 1, 1, inputs=("c0",)),
        LayerSpec("bb", ConvT.CONV, h, h, 8, 8, 3, 1, 1, inputs=("ba",)),
        LayerSpec("add", ConvT.ADD, h, h, 8, 8, inputs=("bb", "c0")),
        LayerSpec("c1", ConvT.CONV, h, h, 8, 8, 3, 1, 1),
    ))


# ---------------------------------------------------------------------------
# Spec validation & adapters
# ---------------------------------------------------------------------------

def test_topology_edge_sets():
    assert topology_edges(2, Topology.RING) == ((0, 1),)
    assert len(topology_edges(6, Topology.RING)) == 6
    assert topology_edges(4, Topology.PS) == ((0, 1), (0, 2), (0, 3))
    assert len(topology_edges(5, Topology.MESH)) == 10
    assert topology_edges(1, Topology.RING) == ()


def test_spec_validation():
    with pytest.raises(ValueError):
        DeviceSpec(gflops=0.0)
    with pytest.raises(ValueError):
        LinkSpec(bandwidth_gbps=-1.0)
    with pytest.raises(ValueError):
        ClusterSpec(name="bad", devices=(DeviceSpec(),) * 4,
                    links=(LinkSpec(),) * 3)  # ring of 4 needs 4 links


def test_testbed_round_trip():
    tb = Testbed(nodes=5, bandwidth_gbps=2.0, topology=Topology.PS,
                 device_gflops=12.0, link_latency_us=7.0)
    cl = ClusterSpec.from_testbed(tb)
    assert cl.is_homogeneous
    assert cl.compat_testbed() == tb


def test_preset_shapes():
    cl = mixed_fast_slow(6)
    assert cl.n == 6 and not cl.is_homogeneous
    assert cl.devices[0].gflops > cl.devices[-1].gflops
    cl = asym_uplink(4)
    assert cl.bottleneck_bw_gbps == 0.5
    assert all(d == cl.devices[0] for d in cl.devices)
    for mk in CLUSTER_PRESETS.values():
        assert mk(3).n == 3


# ---------------------------------------------------------------------------
# Weighted shard-fraction geometry
# ---------------------------------------------------------------------------

def test_weighted_split_uniform_matches_balanced():
    for total in (1, 3, 7, 28, 224, 1000):
        for parts in (1, 2, 3, 4, 7, 16):
            assert weighted_split_sizes(total, [1.0] * parts) == \
                split_sizes(total, parts)
            assert weighted_split_sizes(total, [16.0] * parts) == \
                split_sizes(total, parts)


def test_weighted_split_proportional_and_edge_cases():
    assert weighted_split_sizes(100, [3.0, 1.0]) == [75, 25]
    # one dominant device takes (almost) everything
    assert weighted_split_sizes(10, [1000.0, 1.0, 1.0]) == [10, 0, 0]
    # zero weight -> zero-work shard
    assert weighted_split_sizes(9, [2.0, 0.0, 1.0]) == [6, 0, 3]
    # conservation under awkward fractions
    for seed in range(20):
        rng = random.Random(seed)
        w = [rng.uniform(0.0, 8.0) for _ in range(rng.randint(2, 9))]
        if sum(w) == 0.0:
            continue
        total = rng.randint(1, 300)
        s = weighted_split_sizes(total, w)
        assert sum(s) == total and all(x >= 0 for x in s)
    with pytest.raises(ValueError):
        weighted_split_sizes(10, [-1.0, 2.0])
    with pytest.raises(ValueError):
        weighted_split_sizes(10, [0.0, 0.0])


def test_weighted_split_batch_matches_scalar():
    rng = np.random.default_rng(0)
    for _ in range(10):
        w = rng.uniform(0.0, 8.0, size=rng.integers(2, 9))
        if w.sum() == 0.0:
            continue
        totals = rng.integers(1, 300, size=40)
        got = weighted_split_batch(totals, w)
        for row, t in zip(got, totals):
            assert list(row) == weighted_split_sizes(int(t), list(w))


def test_hetero_shard_work_uniform_bitwise():
    ls = _toy_chain().layers
    for l in ls:
        for scheme in ALL_SCHEMES:
            for nodes in (2, 3, 4, 7):
                for halo in (0, 1, 2):
                    if halo and not scheme.spatial:
                        continue
                    ref = shard_work(l, scheme, nodes, extra_halo=halo)
                    got = hetero_shard_work(l, scheme, [1.0] * nodes,
                                            extra_halo=halo)
                    assert got == ref


def test_hetero_shard_work_skew():
    l = _toy_chain().layers[0]
    w = hetero_shard_work(l, Scheme.INH, [3.0, 1.0])
    assert w.flops_per_node[0] == 3 * w.flops_per_node[1]
    # zero-weight device does no T-mode work
    z = hetero_shard_work(l, Scheme.INH, [1.0, 0.0, 1.0])
    assert z.flops_per_node[1] == 0.0 and z.out_bytes_per_node[1] == 0.0
    with pytest.raises(ValueError):
        hetero_shard_work(l, Scheme.OUTC, [1.0, 2.0], extra_halo=1)


# ---------------------------------------------------------------------------
# Scalar / batch parity of the hetero cost physics
# ---------------------------------------------------------------------------

def test_hetero_compute_batch_bit_parity():
    rng = np.random.default_rng(1)
    cl = stepped(5)
    tb = cl.compat_testbed()
    speeds = np.asarray(cl.speeds_gflops)
    derates = np.asarray(cl.dev_derates)
    weights = np.asarray(cl.capability_weights)
    rows, factors, want = [], [], []
    for l in _toy_chain().layers + _toy_dag().layers:
        for scheme in ALL_SCHEMES:
            halo = int(rng.integers(0, 3)) if scheme.spatial else 0
            rows.append(i_features(l, scheme, tb, halo))
            factors.append(l.extra_flop_factor)
            want.append(hetero_compute_time_s(
                l, scheme, tb, speeds, derates, weights, extra_halo=halo))
    got = hetero_compute_time_batch_s(np.asarray(rows), tb, speeds, derates,
                                      weights, np.asarray(factors))
    assert np.array_equal(got, np.asarray(want))


def test_cluster_estimator_batch_protocol():
    cl = mixed_fast_slow(4)
    est = ClusterAnalyticEstimator(cl)
    tb = cl.compat_testbed()
    l = _toy_chain().layers[0]
    rows = [i_features(l, s, tb, 0) for s in ALL_SCHEMES]
    got = est.i_cost_batch(np.asarray(rows), tb)
    want = [est.i_cost(l, s, tb) for s in ALL_SCHEMES]
    assert np.array_equal(got, np.asarray(want))
    with pytest.raises(ValueError):
        est.i_cost(l, Scheme.INH, Testbed(nodes=7))


# ---------------------------------------------------------------------------
# Homogeneous clusters == historical Testbed, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nodes", [2, 3, 4, 5, 8, 13, 16])
def test_homogeneous_cluster_bit_parity(nodes):
    from repro.configs.edge_models import EDGE_MODELS
    g = EDGE_MODELS["mobilenet"]()
    tb = Testbed(nodes=nodes, bandwidth_gbps=1.0)
    cl = homogeneous(nodes, bandwidth_gbps=1.0)
    ref = plan_search(g, EST, tb)
    got = cluster_plan_search(g, cl)
    assert got.plan == ref.plan
    assert got.cost == ref.cost


def test_homogeneous_scalar_costs_bitwise():
    cl = homogeneous(4, bandwidth_gbps=1.0)
    est = ClusterAnalyticEstimator(cl)
    tb = cl.compat_testbed()
    ls = _toy_chain().layers
    for l, nxt in zip(ls, list(ls[1:]) + [None]):
        for s in ALL_SCHEMES:
            assert est.i_cost(l, s, tb) == EST.i_cost(l, s, tb)
            for d in ALL_SCHEMES:
                if nxt is not None:
                    assert est.s_cost(l, nxt, s, d, tb) == \
                        EST.s_cost(l, nxt, s, d, tb)
            assert est.s_cost(l, None, s, None, tb) == \
                EST.s_cost(l, None, s, None, tb)


# ---------------------------------------------------------------------------
# Theorem-1 on heterogeneous clusters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mk", HETERO_PRESETS, ids=lambda f: f.__name__)
@pytest.mark.parametrize("nodes", [2, 3, 4, 6])
def test_hetero_dp_matches_exhaustive_chain(mk, nodes):
    g = _toy_chain()
    cl = mk(nodes)
    est = ClusterAnalyticEstimator(cl)
    tb = cl.compat_testbed()
    res = cluster_plan_search(g, cl)
    ref = plan_search_reference(g, est, tb)
    assert res.plan == ref.plan and res.cost == ref.cost
    _, ex_cost = exhaustive_search(g, est, tb)
    assert abs(res.cost - ex_cost) < 1e-15


@pytest.mark.parametrize("mk", HETERO_PRESETS, ids=lambda f: f.__name__)
def test_hetero_dp_matches_exhaustive_dag(mk):
    g = _toy_dag()
    cl = mk(4)
    est = ClusterAnalyticEstimator(cl)
    tb = cl.compat_testbed()
    res = cluster_plan_search(g, cl)
    ref = plan_search_reference(g, est, tb)
    assert res.plan == ref.plan and res.cost == ref.cost
    _, ex_cost = exhaustive_search(g, est, tb)
    assert abs(res.cost - ex_cost) / ex_cost < 1e-12


# ---------------------------------------------------------------------------
# Capability weighting beats the homogeneous-assumption baseline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["mobilenet", "resnet18", "inception",
                                   "bert"])
def test_weighted_beats_even_split_on_mixed(model):
    from repro.configs.edge_models import EDGE_MODELS
    g = EDGE_MODELS[model]()
    cl = mixed_fast_slow(4)
    rw = cluster_plan_search(g, cl, weighted=True)
    re = cluster_plan_search(g, cl, weighted=False)
    assert rw.cost < re.cost


def test_memory_check_flags_small_devices():
    from repro.configs.edge_models import EDGE_MODELS
    g = EDGE_MODELS["resnet18"]()
    big = homogeneous(4)
    assert all(big.memory_ok(g))
    tiny = dataclasses.replace(
        big, devices=tuple(dataclasses.replace(d, mem_mb=1.0)
                           for d in big.devices))
    assert not any(tiny.memory_ok(g))
