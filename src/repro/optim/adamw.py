"""AdamW, pytree-native (no optax in the offline container).

Moments are kept in fp32 regardless of param dtype; the update is computed
in fp32 and cast back — standard mixed-precision training practice.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, lr, *, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1

    # global-norm clip (fp32)
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
