"""Partition-geometry edge cases (no hypothesis needed).

Covers the degenerate single-node cluster, halo-free K=1 layers (ADD/FC),
and the paper's 3-node 2D-grid round-robin imbalance observation.
"""
import pytest

from repro.core.graph import ConvT, LayerSpec
from repro.core.partition import (ALL_SCHEMES, Scheme,
                                  boundary_bytes_same_scheme, grid_dims,
                                  relayout_bytes, shard_work)


def _conv(h=28, c=16, k=3):
    return LayerSpec("c", ConvT.CONV, h, h, c, c, k, 1, k // 2)


def test_single_node_has_zero_comm():
    l, nxt = _conv(), _conv()
    for src in ALL_SCHEMES:
        for dst in ALL_SCHEMES:
            assert relayout_bytes(l, src, dst, nodes=1) == 0.0
    for s in (Scheme.INH, Scheme.INW, Scheme.GRID2D):
        assert boundary_bytes_same_scheme(l, nxt, s, nodes=1) == 0.0


def test_k1_layers_need_no_halo_exchange():
    """ADD and FC have K=1: a same-scheme T boundary moves zero bytes."""
    prev = _conv()
    add = LayerSpec("add", ConvT.ADD, 28, 28, 16, 16, inputs=("a", "b"))
    fc = LayerSpec("fc", ConvT.FC, 28, 1, 16, 10)
    for s in (Scheme.INH, Scheme.INW, Scheme.GRID2D):
        assert boundary_bytes_same_scheme(prev, add, s, nodes=4) == 0.0
        assert boundary_bytes_same_scheme(prev, fc, s, nodes=4) == 0.0
    # and their shard workloads carry no halo notion: exact split only
    w = shard_work(add, Scheme.INH, 4)
    assert sum(w.flops_per_node) == pytest.approx(add.flops(), rel=1e-9)


def test_grid_3_nodes_round_robin_imbalance():
    """grid_dims(3) -> 2x2 cells round-robined onto 3 nodes: one node owns
    two cells and carries ~2x the per-cell work (paper's 3-node case)."""
    assert grid_dims(3) == (2, 2)
    l = _conv(h=28)
    w = shard_work(l, Scheme.GRID2D, 3)
    assert len(w.flops_per_node) == 3
    assert sum(w.flops_per_node) == pytest.approx(l.flops(), rel=1e-9)
    # node 0 owns cells 0 and 3 -> twice the work of the single-cell nodes
    assert w.imbalance == pytest.approx(1.5, rel=0.05)
    assert max(w.flops_per_node) == pytest.approx(
        2 * min(w.flops_per_node), rel=0.05)


def test_relayout_outc_destination_costliest():
    """Gather-to-full for an OutC consumer dominates spatial re-shards."""
    l = _conv()
    to_outc = relayout_bytes(l, Scheme.INH, Scheme.OUTC, 4)
    spatial = relayout_bytes(l, Scheme.INH, Scheme.INW, 4)
    assert to_outc > spatial > 0.0
    assert relayout_bytes(l, Scheme.INH, Scheme.INH, 4) == 0.0
