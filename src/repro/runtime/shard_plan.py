"""Sharding plans: FlexPie's scheme alphabet mapped onto the TPU mesh.

The edge planner chooses (partition scheme, T/NT) per layer; here the same
decision surfaces as a :class:`Strategy` per block-class:

  * ``attn``: ``"tp"`` (shard head projections over ``model`` — the OutC
    analogue) or ``"sp"`` (replicate weights, shard activations by sequence —
    the InH analogue).
  * ``ffn``:  ``"tp"`` or ``"sp"`` likewise for the MLP.
  * ``moe``:  ``"ep"`` (experts over ``model`` — expert parallel) or
    ``"tp"`` (expert FFN dim over ``model``).
  * ``fsdp``: shard every weight over the data axes as well (ZeRO-3); the
    per-layer weight all-gather is the T-mode re-layout of the TPU mapping.

Every rule is divisibility-checked against the mesh; infeasible choices fall
back (e.g. 40 heads on a 16-way model axis -> flattened-dim sharding or
replication), mirroring the paper's observation that scheme feasibility
depends on the layer/testbed pair.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Strategy:
    attn: str = "tp"        # tp | sp
    ffn: str = "tp"         # tp | sp
    moe: str = "ep"         # ep | tp
    fsdp: bool = True
    # decode: resident TP weights (no data-axis sharding) when the model fits
    decode_resident: bool = False


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> bool:
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape)
                                                         - len(spec))):
        if axes is None:
            continue
        if dim % _axis_size(mesh, axes) != 0:
            return False
    return True


def _pick(shape, mesh: Mesh, *candidates: P) -> P:
    """First candidate whose named axes all divide; else fully replicated."""
    for c in candidates:
        if _fits(shape, c, mesh):
            return c
    return P()


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

def _leaf_spec(path: str, shape: Tuple[int, ...], mesh: Mesh, st: Strategy,
               mode: str) -> P:
    """Sharding rule for one parameter leaf.  ``path`` is a '/'-joined key
    path; stacked block params carry a leading layer dim (detected by the
    'blocks' path component) which is never sharded."""
    stacked = "blocks" in path or "attn_layers" in path
    rank = len(shape)
    core = shape[1:] if stacked else shape
    fsdp = data_axes(mesh) if (st.fsdp and not (mode != "train"
                                                and st.decode_resident)) \
        else None

    def wrap(spec: P) -> P:
        if stacked:
            return P(None, *spec)
        return spec

    name = path.split("/")[-1]

    # ---- scalars / vectors -------------------------------------------------
    if len(core) == 1:
        if name in ("bq", "bk", "bv") and st.attn == "tp":
            return wrap(_pick(core, mesh, P("model")))
        return wrap(P())

    # ---- embeddings / heads -----------------------------------------------
    if name == "tok_emb":
        return _pick(core, mesh, P("model", fsdp), P(None, "model"), P())
    if name == "lm_head":
        return _pick(core, mesh, P(fsdp, "model"), P("model", None), P())

    # ---- MoE ----------------------------------------------------------------
    if name == "router":
        return wrap(_pick(core, mesh, P(fsdp, None)))
    if len(core) == 3 and name in ("w_gate", "w_up", "w_down"):
        # expert weights [E, d, f] / [E, f, d]
        if st.moe == "ep":
            cand = [P("model", fsdp, None), P(None, fsdp, "model"),
                    P(None, "model", fsdp)]
        else:
            cand = [P(None, fsdp, "model"), P(None, "model", fsdp),
                    P("model", fsdp, None)]
        return wrap(_pick(core, mesh, *cand))

    # ---- MLA ----------------------------------------------------------------
    if name in ("w_uk", "w_uv"):          # [H, a, b]
        return wrap(_pick(core, mesh, P("model", None, None), P()))
    if name in ("w_dq", "w_dkv", "w_kr"):
        return wrap(_pick(core, mesh, P(fsdp, None)))
    if name == "w_uq":
        if st.attn == "tp":
            return wrap(_pick(core, mesh, P(fsdp, "model"), P(fsdp, None)))
        return wrap(_pick(core, mesh, P(fsdp, None)))

    # ---- attention ----------------------------------------------------------
    if name in ("wq", "wk", "wv"):
        if st.attn == "tp":
            return wrap(_pick(core, mesh, P(fsdp, "model"), P(fsdp, None)))
        return wrap(_pick(core, mesh, P(fsdp, None)))
    if name == "wo":
        if st.attn == "tp":
            return wrap(_pick(core, mesh, P("model", fsdp), P(None, fsdp)))
        return wrap(_pick(core, mesh, P(None, fsdp)))

    # ---- dense MLP / rwkv channel-mix ---------------------------------------
    if name in ("w_gate", "w_up", "cm_k"):
        if st.ffn == "tp":
            return wrap(_pick(core, mesh, P(fsdp, "model"), P(fsdp, None)))
        return wrap(_pick(core, mesh, P(fsdp, None)))
    if name in ("w_down", "cm_v"):
        if st.ffn == "tp":
            return wrap(_pick(core, mesh, P("model", fsdp), P(None, fsdp)))
        return wrap(_pick(core, mesh, P(None, fsdp)))
    if name in ("b_up", "b_down"):
        return wrap(P())

    # ---- mamba2 / rwkv6 -----------------------------------------------------
    if name in ("w_z", "w_x"):
        return wrap(_pick(core, mesh, P(fsdp, "model"), P(fsdp, None)))
    if name in ("w_b", "w_c", "w_dt"):
        return wrap(_pick(core, mesh, P(fsdp, None)))
    if name == "conv_w":
        return wrap(_pick(core, mesh, P(None, "model"), P()))
    if name in ("w_r", "w_k", "w_v", "w_g", "w_decay"):
        return wrap(_pick(core, mesh, P(fsdp, "model"), P(fsdp, None)))
    if name == "w_out":
        return wrap(_pick(core, mesh, P("model", fsdp), P(None, fsdp)))

    # ---- default: FSDP on dim 0 --------------------------------------------
    if len(core) >= 2:
        return wrap(_pick(core, mesh, P(fsdp, None), P()))
    return wrap(P())


def _paths_tree(tree) -> Any:
    """pytree of '/'-joined path strings matching ``tree``'s structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    def pstr(kp):
        parts = []
        for p in kp:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        return "/".join(parts)
    return treedef.unflatten([pstr(kp) for kp, _ in flat])


def param_specs(params_shape, mesh: Mesh, st: Strategy,
                mode: str = "train"):
    paths = _paths_tree(params_shape)
    return jax.tree.map(
        lambda pth, leaf: _leaf_spec(pth, tuple(leaf.shape), mesh, st, mode),
        paths, params_shape)


# ---------------------------------------------------------------------------
# Batch / cache / optimizer sharding
# ---------------------------------------------------------------------------

def batch_specs(batch_shape, mesh: Mesh) -> Any:
    dp = data_axes(mesh)

    def spec(leaf):
        shape = tuple(leaf.shape)
        if shape and shape[0] % _axis_size(mesh, dp) == 0:
            return P(dp, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))
    return jax.tree.map(spec, batch_shape)


def cache_specs(cache_shape, mesh: Mesh, st: Strategy) -> Any:
    """KV caches / SSM states (per-layer pages, batch-first): batch over the
    data axes; the largest remaining divisible dim (kv-heads, sequence or
    features) over ``model`` — flash-decode style sequence sharding falls
    out naturally when kv-heads don't divide the model axis."""
    dp = data_axes(mesh)
    dpn = _axis_size(mesh, dp)
    msize = mesh.shape["model"]

    def spec(leaf) -> P:
        shape = tuple(leaf.shape)
        dims: list = [None] * len(shape)
        if shape and shape[0] % dpn == 0 and shape[0] > 1:
            dims[0] = dp
        best, best_dim = 0, -1
        for i in range(1, len(shape)):
            if shape[i] % msize == 0 and shape[i] > best:
                best, best_dim = shape[i], i
        if best_dim >= 0:
            dims[best_dim] = "model"
        return P(*dims)

    return jax.tree.map(spec, cache_shape)


def opt_specs(param_spec_tree, params_shape) -> Dict[str, Any]:
    """AdamW moments inherit their parameter's sharding; step is replicated."""
    return {"m": param_spec_tree, "v": param_spec_tree, "step": P()}


def named(tree_of_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))
