"""Mesh-executor benchmark: sequential vs mesh wall time, measured vs
simulated stage times -> ``BENCH_mesh.json``.

For each edge model (test scale, searched 4-node plan) this runs the
single-process engine and the mesh executor (4 fake host devices) and
records:

* ``agree`` / ``rel_err`` — mesh output vs the single-process path
  (PR 5 scale-normalized tolerance);
* ``stats_equal`` — ``ExecStats`` geometry accounting identical;
* ``structure_match`` — the measured stage multiset
  (``instrument=True, overlap=False``) equals
  ``simsched.build_stages`` 1:1 (post-merge boundaries subsumed by the
  merge gather — see ``runtime.mesh_exec.validate_stage_decomposition``);
* ``local_us`` / ``mesh_wall_us`` / ``dev_occupancy_us`` /
  ``link_occupancy_us`` — warm wall times and measured occupancy;
* ``stages`` — per-stage ``{kind, label, sim_s, measured_s}`` pairs;
* ``skew`` — per-stage measured/simulated ratios plus a
  ``median_ratio`` / ``max_abs_log2`` summary (``obs.skew.stage_skew``);
  advisory, surfaced by ``check_regression --kind mesh`` as a note.

With ``--trace-dir PATH`` (or ``run(trace_dir=...)``) each model's warm
staged run is captured by ``repro.obs.Tracer`` and written together with
the simulator's timeline (same Perfetto schema, pid 2) to
``PATH/mesh_<model>.trace.json``, plus a ``mesh_metrics.json`` counter
snapshot — open the trace files at https://ui.perfetto.dev.

``check_regression.py --kind mesh`` gates the flags **hard**; every
timing field is **advisory**: the "devices" are XLA host-platform fakes
sharing one CPU's cores, so per-stage durations carry scheduling noise
far above any regression signal (the per-device completion times are an
upper envelope — shards are blocked on in mesh order) and sim-vs-measured
ratios reflect the analytic Testbed's modeled edge silicon, not this
host.  The flags are the contract; the times are the trajectory record
(see ``noise_note`` in the JSON).

The bench needs >= 4 devices: when the current process has fewer it
respawns itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (jax device count
is fixed at init, so the flag cannot be applied in-process).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit, json_arg, trace_dir_arg

NODES = 4

#: test-scale constructor kwargs (interpret-mode full scale is minutes)
MODEL_KW = {
    "mobilenet": dict(width=32),
    "resnet18": dict(width=32),
    "resnet101": dict(width=32),
    "inception": dict(width=32),
    "bert": dict(seq=16, d=32, n_layers=1, d_ff=64),
}

SMOKE_MODELS = ("mobilenet", "resnet18")

NOISE_NOTE = (
    "All *_us / *_s fields are advisory on CPU CI: the mesh 'devices' are "
    "XLA host-platform fakes time-sharing one CPU, so stage durations "
    "include scheduler noise well above 2x and sim_s comes from the "
    "analytic edge-silicon Testbed, not this host. Only the boolean "
    "flags (agree/stats_equal/structure_match) are gated.")

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bench_model(name: str, trace_dir: str | None = None) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.cluster import build_stages, homogeneous, simulate_trace
    from repro.configs.edge_models import EDGE_MODELS
    from repro.core import Testbed
    from repro.core.dpp import plan_search
    from repro.obs import Tracer, set_tracer, write_trace
    from repro.obs.skew import stage_skew
    from repro.runtime.engine import init_weights
    from repro.runtime.session import ExecConfig, Session
    from repro.runtime.mesh_exec import validate_stage_decomposition

    from .common import EST, time_call

    g = EDGE_MODELS[name](**MODEL_KW[name])
    w = init_weights(g, jax.random.PRNGKey(0))
    l0 = g.layers[0]
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (l0.in_h, l0.in_w, l0.in_c))
    plan = plan_search(g, EST,
                       Testbed(nodes=NODES, bandwidth_gbps=0.5)).plan

    local_sess = Session(g, w, plan, NODES)
    local_us, (ref, s_ref) = time_call(
        lambda: local_sess.run(x), repeats=2)

    mesh_sess = Session(g, w, plan, NODES,
                        ExecConfig(executor="mesh", instrument=True))
    def mesh_run():
        return mesh_sess.run(x)
    mesh_run()                                   # warm-up: compile
    mesh_us, (out, s_mesh) = time_call(mesh_run, repeats=2)
    occ = s_mesh.to_occupancy()

    scale = max(1.0, float(jnp.max(jnp.abs(ref))))
    rel_err = float(jnp.max(jnp.abs(out - ref))) / scale

    # staged (overlap=False) run against the simulator's stage DAG;
    # two runs so the measured one is warm (only the warm run is traced)
    staged_sess = Session(g, w, plan, NODES,
                          ExecConfig(executor="mesh", instrument=True,
                                     overlap=False))
    _, s_staged = staged_sess.run(x)
    tr = Tracer() if trace_dir else None
    set_tracer(tr)
    try:
        _, s_staged = staged_sess.run(x)
    finally:
        set_tracer(None)
    cl = homogeneous(NODES, bandwidth_gbps=0.5)
    v = validate_stage_decomposition(s_staged, build_stages(g, plan, cl))

    if trace_dir:
        _, sim_tr = simulate_trace(g, plan, cl)
        write_trace(os.path.join(trace_dir, f"mesh_{name}.trace.json"),
                    tr, sim_tr)

    return {
        "skew": stage_skew(v["stages"]),
        "rel_err": rel_err,
        "agree": rel_err < 1e-4,
        "stats_equal": s_ref == s_mesh,
        "structure_match": v["structure_match"],
        "missing": [list(m) for m in v["missing"]],
        "extra": [list(m) for m in v["extra"]],
        "subsumed": [list(m) for m in v["subsumed"]],
        "local_us": local_us,
        "mesh_wall_us": mesh_us,
        "dev_occupancy_us": occ.dev_occupancy_s * 1e6,
        "link_occupancy_us": occ.link_occupancy_s * 1e6,
        "stages": v["stages"],
    }


def _run_inner(json_path: str | None, smoke: bool,
               trace_dir: str | None = None) -> dict:
    import jax
    assert len(jax.devices()) >= NODES, jax.devices()
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
        from repro.obs import Metrics, set_metrics
        set_metrics(Metrics())
    models = SMOKE_MODELS if smoke else tuple(MODEL_KW)
    record = {"nodes": NODES, "devices": len(jax.devices()),
              "noise_note": NOISE_NOTE, "models": {}}
    for name in models:
        rec = _bench_model(name, trace_dir=trace_dir)
        record["models"][name] = rec
        flags = "ok" if (rec["agree"] and rec["stats_equal"]
                         and rec["structure_match"]) else "FLAG"
        emit(f"mesh_{name}", rec["mesh_wall_us"],
             f"local={rec['local_us']:.0f}us rel_err={rec['rel_err']:.1e} "
             f"{flags}")
    if trace_dir:
        from repro.obs import get_metrics, set_metrics
        get_metrics().export(os.path.join(trace_dir, "mesh_metrics.json"))
        set_metrics(None)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
    return record


def run(json_path: str | None = None, smoke: bool = False,
        trace_dir: str | None = None) -> dict:
    """Entry point used by ``benchmarks.run``: respawns in a subprocess
    with forced host devices when this process is short of them."""
    import jax
    if len(jax.devices()) >= NODES:
        return _run_inner(json_path, smoke, trace_dir=trace_dir)
    out_path = os.path.abspath(json_path) if json_path else \
        os.path.join(_ROOT, "BENCH_mesh.json")
    cmd = [sys.executable, "-m", "benchmarks.mesh_bench",
           "--json", out_path]
    if smoke:
        cmd.append("--smoke")
    if trace_dir:
        cmd += ["--trace-dir", os.path.abspath(trace_dir)]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    try:
        r = subprocess.run(cmd, env=env, cwd=_ROOT, capture_output=True,
                           text=True, timeout=3600)
    except subprocess.TimeoutExpired as exc:
        raise RuntimeError(
            "mesh_bench subprocess exceeded 3600s — on the CPU host "
            "platform this is the known thread-pool starvation: all fake "
            "devices share one dispatch pool, so threads parked in one "
            "stage module's collective rendezvous can starve another "
            "module's participants (XLA logs 'collective_ops_utils ... "
            "may be stuck'). Reduce "
            "XLA_FLAGS=--xla_force_host_platform_device_count, run with "
            "--smoke, or arm run_partitioned_mesh(stage_timeout_s=...) "
            "to fail the single wedged stage instead of the whole "
            f"sweep.\npartial stdout: {exc.stdout!r}") from exc
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr)
    if r.returncode != 0:
        raise RuntimeError("mesh_bench subprocess failed")
    with open(out_path) as f:
        return json.load(f)


if __name__ == "__main__":
    argv = sys.argv[1:]
    run(json_path=json_arg(argv, default="BENCH_mesh.json"),
        smoke="--smoke" in argv, trace_dir=trace_dir_arg(argv))
