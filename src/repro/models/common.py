"""Shared model building blocks (pure-functional JAX, params as pytrees)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, weight: Optional[jnp.ndarray],
            eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if weight is not None:
        x = x * weight.astype(jnp.float32)
    return x.astype(dt)


def layernorm(x: jnp.ndarray, weight: Optional[jnp.ndarray],
              bias: Optional[jnp.ndarray], eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        x = x * weight.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def apply_norm(cfg, x: jnp.ndarray, p: Optional[dict]) -> jnp.ndarray:
    """Dispatch on cfg.norm; ``nonparam_ln`` (OLMo) has no params at all."""
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["w"] if p else None)
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"] if p else None, p.get("b") if p else None)
    if cfg.norm == "nonparam_ln":
        return layernorm(x, None, None)
    raise ValueError(cfg.norm)


def norm_params(cfg, key, d: int, dtype) -> Optional[dict]:
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {}  # nonparam_ln: empty (keeps pytree structure stable)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE + sinusoidal abs-pos for whisper)
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, hd]; pos: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    ang = pos[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, pos3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.  x: [B, H, S, hd]; ``pos3``: [B, 3, S]
    (temporal, height, width coordinate streams).  ``sections`` partition the
    hd/2 frequency slots among the 3 streams; text tokens carry identical
    coords in all three streams, making this exactly standard RoPE for text."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    # which position stream drives each frequency slot
    sel = np.concatenate([np.full((s,), i) for i, s in enumerate(sections)])
    pos_sel = jnp.take(pos3.transpose(0, 2, 1), jnp.asarray(sel),
                       axis=-1)                        # [B, S, hd/2]
    ang = pos_sel.astype(jnp.float32)[:, None, :, :] * freqs  # [B,1,S,hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_at(t: jnp.ndarray, d: int) -> jnp.ndarray:
    """Sinusoidal embedding [d] for a single (traced) position scalar."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = t.astype(jnp.float32) / (10000 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def sinusoidal_pos(seq: int, d: int) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def act_fn(name: str):
    if name == "swiglu":
        raise ValueError("swiglu is handled inside the MLP (two inputs)")
    if name == "gelu":
        return jax.nn.gelu
    if name == "silu":
        return jax.nn.silu
    raise ValueError(name)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token CE in fp32. logits [..., V], labels [...] int.

    The gold logit is extracted with a masked reduction (iota compare), not
    a gather — gathers over a vocab-sharded axis force an all-gather of the
    full logits under SPMD; the masked sum partitions cleanly.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (1,) * (labels.ndim) + (vocab,), labels.ndim)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
