"""Chunk-parallel SSM forms vs the exact per-token recurrences.

The §Perf A hillclimb replaced the recurrent RWKV-6/Mamba2 scans with
chunked forms (121x/116x memory-term wins); these tests pin their
exactness — forward and gradients — across chunk sizes, sequence lengths
that don't divide the chunk, and random decay magnitudes.
"""

import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests only; see pyproject [dev]
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import ssm as S


def _rwkv_cfg(chunk=0):
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=64,
                       n_heads=0, n_kv=0, d_ff=128, vocab=64,
                       dtype="float32",
                       ssm=SSMConfig(kind="rwkv6", head_dim=32, chunk=chunk))


def _mamba_cfg(chunk=0):
    return ModelConfig(name="t", family="hybrid", n_layers=1, d_model=64,
                       n_heads=4, n_kv=4, d_ff=128, vocab=64,
                       dtype="float32",
                       ssm=SSMConfig(kind="mamba2", d_state=16, head_dim=32,
                                     chunk=chunk))


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("seq", [50, 64, 33])
def test_rwkv6_chunked_matches_recurrent(chunk, seq):
    cfg = _rwkv_cfg()
    key = jax.random.PRNGKey(0)
    p = S.init_rwkv6(cfg, key)
    x = jax.random.normal(key, (2, seq, cfg.d_model)) * 0.5
    ref = S.rwkv6_time_mix(cfg, p, x)
    out = S.rwkv6_time_mix(_rwkv_cfg(chunk), p, x)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("seq", [50, 64, 33])
def test_mamba2_chunked_matches_recurrent(chunk, seq):
    cfg = _mamba_cfg()
    key = jax.random.PRNGKey(0)
    p = S.init_mamba2(cfg, key)
    x = jax.random.normal(key, (2, seq, cfg.d_model)) * 0.5
    ref = S.mamba2_full(cfg, p, x)
    out = S.mamba2_full(_mamba_cfg(chunk), p, x)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_chunked_gradients_match():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (2, 40, 64)) * 0.5
    for base, opt, init, fwd in [
            (_rwkv_cfg(), _rwkv_cfg(16), S.init_rwkv6, S.rwkv6_time_mix),
            (_mamba_cfg(), _mamba_cfg(16), S.init_mamba2, S.mamba2_full)]:
        p = init(base, key)
        g1 = jax.grad(lambda xx: (fwd(base, p, xx) ** 2).sum())(x)
        g2 = jax.grad(lambda xx: (fwd(opt, p, xx) ** 2).sum())(x)
        assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-4


@given(seed=st.integers(0, 10 ** 6), scale=st.floats(0.1, 2.0))
@settings(max_examples=10, deadline=None)
def test_rwkv6_chunked_random_decays(seed, scale):
    """Strong random decays (deep underflow territory for naive 1/P
    rescaling) stay exact — the pairwise-ratio form never exponentiates a
    positive number."""
    cfg = _rwkv_cfg()
    key = jax.random.PRNGKey(seed)
    p = S.init_rwkv6(cfg, key)
    # push the decay projection to extremes
    p = dict(p)
    p["decay_bias"] = p["decay_bias"] + scale
    x = jax.random.normal(key, (1, 37, cfg.d_model)) * scale
    ref = S.rwkv6_time_mix(cfg, p, x)
    out = S.rwkv6_time_mix(_rwkv_cfg(8), p, x)
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
