"""Observability spine (`repro.obs`): tracer schema + Perfetto export,
disabled-tracing zero-overhead contract, metrics registry, flight
recorder + postmortem artifacts, REPRO_LOG gating, and the
measured-vs-simulated skew helpers.

No jax imports here — the obs layer is dependency-free by design and
these tests must stay cheap enough for any tier-1 run.
"""
from __future__ import annotations

import json
import threading

import pytest

from repro.obs import (CONTROL_TRACK, NULL_SPAN, PLANNER_TRACK, STAGE_CAT,
                       FlightRecorder, Metrics, Tracer, device_track,
                       diff_traces, dump_postmortem, get_flight,
                       get_metrics, get_tracer, link_track, load_trace,
                       postmortem_dir, set_metrics, set_postmortem_dir,
                       set_tracer, span, span_events, stage_skew,
                       write_trace)
import importlib

from repro.obs import metrics as obsmetrics
from repro.obs import trace as obstrace

# ``from .log import log`` in the package shadows the submodule
# attribute with the function — go through importlib for the module
obslog = importlib.import_module("repro.obs.log")


@pytest.fixture(autouse=True)
def _clean_obs_globals():
    """Every test starts and ends with observability uninstalled."""
    set_tracer(None)
    set_metrics(None)
    set_postmortem_dir(None)
    get_flight().clear()
    yield
    set_tracer(None)
    set_metrics(None)
    set_postmortem_dir(None)
    get_flight().clear()


# ---------------------------------------------------------------------------
# disabled-tracing contract
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_null_singleton():
    """With no tracer installed, span() returns THE module singleton —
    no per-call allocation — and the null span absorbs the full API."""
    assert get_tracer() is None
    a = span(CONTROL_TRACK, "stage-a", cat=STAGE_CAT)
    b = span(PLANNER_TRACK, "anything-else")
    assert a is NULL_SPAN and b is NULL_SPAN
    with a as sp:
        assert sp is NULL_SPAN
        sp.set(answer=42)
        sp.event("marker", detail="ignored")
    # instants are equally inert
    obstrace.instant(CONTROL_TRACK, "nothing")


def test_null_span_has_no_instance_dict():
    """__slots__ = () — the singleton cannot accumulate per-call state,
    which is what makes sharing it safe."""
    assert not hasattr(NULL_SPAN, "__dict__")
    with pytest.raises(AttributeError):
        NULL_SPAN.leak = 1


def test_set_tracer_roundtrip():
    tr = Tracer()
    assert set_tracer(tr) is tr
    assert get_tracer() is tr
    assert set_tracer(None) is None
    assert get_tracer() is None


# ---------------------------------------------------------------------------
# recording + nesting invariants
# ---------------------------------------------------------------------------

def test_span_records_complete_event():
    tr = Tracer()
    with tr.span(CONTROL_TRACK, "work", cat="phase", graph="g"):
        pass
    (rec,) = tr.spans()
    assert rec["ph"] == "X" and rec["name"] == "work"
    assert rec["cat"] == "phase" and rec["track"] == CONTROL_TRACK
    assert rec["dur"] >= 0.0 and rec["ts"] >= 0.0
    assert rec["args"] == {"graph": "g"}


def test_nesting_depth_and_ordering():
    tr = Tracer()
    with tr.span(PLANNER_TRACK, "outer") as outer:
        with tr.span(PLANNER_TRACK, "inner") as inner:
            assert outer.depth == 0 and inner.depth == 1
        with tr.span(PLANNER_TRACK, "inner2") as inner2:
            assert inner2.depth == 1
    recs = tr.spans()
    # spans() sorts by start time: outer opened first
    assert [r["name"] for r in recs] == ["outer", "inner", "inner2"]
    assert [r["depth"] for r in recs] == [0, 1, 1]
    # children nest inside the parent interval
    t0, t1 = recs[0]["ts"], recs[0]["ts"] + recs[0]["dur"]
    for child in recs[1:]:
        assert t0 <= child["ts"]
        assert child["ts"] + child["dur"] <= t1


def test_nesting_is_per_thread():
    tr = Tracer()
    depths = []

    def worker():
        with tr.span("dev0", "t") as sp:
            depths.append(sp.depth)

    with tr.span("dev0", "main-open"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    # the worker thread starts its own stack: depth 0, not 1
    assert depths == [0]


def test_span_exit_on_exception_marks_error():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span(CONTROL_TRACK, "boom"):
            raise RuntimeError("x")
    (rec,) = tr.spans()
    assert rec["args"].get("error") is True


def test_add_complete_and_filtering():
    tr = Tracer()
    tr.add_complete(CONTROL_TRACK, "seg[a..b]", 10.0, 5.0, cat=STAGE_CAT)
    tr.add_complete(device_track(0), "seg[a..b]", 10.0, 4.0, cat="device")
    tr.add_complete(link_track(1), "xfer", 15.0, 1.0, cat="link")
    assert len(tr.spans(cat=STAGE_CAT)) == 1
    assert len(tr.spans(track=device_track(0))) == 1
    assert len(tr.spans()) == 3


def test_track_tids_assigned_in_first_use_order():
    tr = Tracer()
    assert tr.ensure_track("dev1") == 1
    assert tr.ensure_track("dev0") == 2
    assert tr.ensure_track("dev1") == 1


# ---------------------------------------------------------------------------
# Perfetto export schema
# ---------------------------------------------------------------------------

def _sample_tracer():
    tr = Tracer()
    with tr.span(CONTROL_TRACK, "stage-a", cat=STAGE_CAT):
        pass
    tr.instant(PLANNER_TRACK, "detect", cat="planner")
    tr.add_complete(device_track(0), "stage-a", 1.0, 2.0, cat="device")
    return tr


def test_perfetto_event_fields():
    doc = _sample_tracer().to_perfetto()
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert events, "empty export"
    metas = [e for e in events if e["ph"] == "M"]
    assert {"process_name"} | {"thread_name"} == {m["name"] for m in metas}
    for ev in events:
        assert ev["ph"] in ("M", "X", "i")
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert isinstance(ev["name"], str)
        if ev["ph"] == "X":
            assert isinstance(ev["ts"], float) and ev["ts"] >= 0.0
            assert isinstance(ev["dur"], float) and ev["dur"] >= 0.0
        if ev["ph"] == "i":
            assert ev["s"] == "t"
    # non-meta events sorted by ts
    ts = [e["ts"] for e in events if e["ph"] != "M"]
    assert ts == sorted(ts)


def test_write_trace_roundtrip(tmp_path):
    path = str(tmp_path / "t.trace.json")
    tr = _sample_tracer()
    assert write_trace(path, tr) == path
    loaded = load_trace(path)
    assert loaded == tr.to_perfetto()
    # valid JSON on disk, not just via load_trace
    with open(path) as f:
        json.load(f)


def test_write_trace_merges_distinct_pids(tmp_path):
    measured = _sample_tracer()
    sim = Tracer(process="simulated", pid=2)
    sim.add_complete(device_track(0), "stage-a", 0.0, 3.0, cat=STAGE_CAT)
    path = str(tmp_path / "merged.trace.json")
    write_trace(path, measured, sim)
    loaded = load_trace(path)
    pids = {e["pid"] for e in loaded["traceEvents"]}
    assert pids == {1, 2}
    names = {e["args"]["name"] for e in loaded["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert names == {"measured", "simulated"}


def test_span_events_resolves_tracks(tmp_path):
    path = str(tmp_path / "t.trace.json")
    write_trace(path, _sample_tracer())
    loaded = load_trace(path)
    evs = span_events(loaded, cat=STAGE_CAT, pid=1)
    assert [e["name"] for e in evs] == ["stage-a"]
    assert evs[0]["track"] == CONTROL_TRACK
    assert span_events(loaded, track=device_track(0))[0]["cat"] == "device"


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_counters_and_labels():
    m = Metrics()
    m.inc("hits")
    m.inc("hits", 2.0)
    m.inc("hits", table="i")
    assert m.counter_value("hits") == 3.0
    assert m.counter_value("hits", table="i") == 1.0
    snap = m.snapshot()
    assert snap["counters"]["hits"] == 3.0
    assert snap["counters"]['hits{table="i"}'] == 1.0


def test_metrics_gauge_overwrites():
    m = Metrics()
    m.gauge("beta", 0.5, graph="g")
    m.gauge("beta", 0.7, graph="g")
    assert m.gauge_value("beta", graph="g") == 0.7
    assert m.gauge_value("beta") is None


def test_metrics_histogram_buckets():
    m = Metrics()
    for v in (0.5, 1.0, 3.0, 3.0):
        m.observe("lat", v)
    h = m.snapshot()["histograms"]["lat"]
    assert h["count"] == 4
    assert h["sum"] == pytest.approx(7.5)
    assert h["min"] == 0.5 and h["max"] == 3.0
    # 0.5 -> le_2^-1, 1.0 -> le_2^0, 3.0 -> le_2^2 (twice)
    assert h["buckets"] == {"le_2^-1": 1, "le_2^0": 1, "le_2^2": 2}


def test_metrics_export(tmp_path):
    m = Metrics()
    m.inc("n", 5.0)
    path = str(tmp_path / "metrics.json")
    assert m.export(path) == path
    with open(path) as f:
        assert json.load(f)["counters"]["n"] == 5.0


def test_free_functions_noop_until_installed():
    assert get_metrics() is None
    obsmetrics.inc("ghost")
    obsmetrics.gauge("ghost", 1.0)
    obsmetrics.observe("ghost", 1.0)
    m = set_metrics(Metrics())
    obsmetrics.inc("real")
    assert m.counter_value("real") == 1.0
    assert m.counter_value("ghost") == 0.0


# ---------------------------------------------------------------------------
# flight recorder + postmortems
# ---------------------------------------------------------------------------

def test_flight_ring_bounds_and_eviction():
    fr = FlightRecorder(capacity=3)
    for i in range(5):
        fr.record("tick", i=i)
    assert len(fr) == 3
    assert fr.total_recorded == 5
    assert [e["i"] for e in fr.events()] == [2, 3, 4]
    assert all(e["kind"] == "tick" and e["t_us"] >= 0.0
               for e in fr.events())
    fr.clear()
    assert len(fr) == 0 and fr.total_recorded == 5


def test_flight_capacity_validated():
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)


def test_postmortem_noop_without_directory(monkeypatch):
    monkeypatch.delenv("REPRO_POSTMORTEM_DIR", raising=False)
    assert postmortem_dir() is None
    assert dump_postmortem("unit_test") is None


def test_postmortem_dump_contents(tmp_path):
    set_postmortem_dir(str(tmp_path))
    get_flight().record("stage_dispatch", label="seg[a..b]", attempt=0)
    tr = set_tracer(Tracer())
    with tr.span(CONTROL_TRACK, "seg[a..b]", cat=STAGE_CAT):
        pass
    path = dump_postmortem("stage_timeout",
                           context={"label": "seg[a..b]", "timeout_s": 1.0})
    assert path is not None and path.startswith(str(tmp_path))
    with open(path) as f:
        doc = json.load(f)
    assert doc["reason"] == "stage_timeout"
    assert doc["context"]["label"] == "seg[a..b]"
    assert any(e["kind"] == "stage_dispatch" for e in doc["events"])
    assert [s["name"] for s in doc["spans"]] == ["seg[a..b]"]


def test_postmortem_env_var(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_POSTMORTEM_DIR", str(tmp_path))
    assert postmortem_dir() == str(tmp_path)
    path = dump_postmortem("refine_oscillation", context={"cycle": [1, 2]})
    assert path is not None
    with open(path) as f:
        assert json.load(f)["context"]["cycle"] == [1, 2]
    # explicit dir overrides env; None defers back
    set_postmortem_dir(str(tmp_path / "sub"))
    assert postmortem_dir() == str(tmp_path / "sub")
    set_postmortem_dir(None)
    assert postmortem_dir() == str(tmp_path)


# ---------------------------------------------------------------------------
# REPRO_LOG gating
# ---------------------------------------------------------------------------

def test_log_quiet_by_default(monkeypatch, capsys):
    for off in ("", "0", "off", "false", "OFF"):
        monkeypatch.setenv("REPRO_LOG", off)
        assert not obslog.enabled()
        obslog.log("train.step", step=1, loss=0.5)
    monkeypatch.delenv("REPRO_LOG")
    obslog.log("train.step", step=1)
    out = capsys.readouterr()
    assert out.out == "" and out.err == ""


def test_log_human_mode(monkeypatch, capsys):
    monkeypatch.setenv("REPRO_LOG", "1")
    assert obslog.enabled()
    obslog.log("train.step", step=3, loss=0.25)
    out = capsys.readouterr()
    assert out.out == ""
    assert out.err == "[train.step] step=3 loss=0.25\n"


def test_log_json_mode(monkeypatch, capsys):
    monkeypatch.setenv("REPRO_LOG", "json")
    obslog.log("serve.timing", batch=4, prefill_ms=1.5)
    line = capsys.readouterr().err.strip()
    assert json.loads(line) == {"event": "serve.timing", "batch": 4,
                                "prefill_ms": 1.5}


# ---------------------------------------------------------------------------
# skew helpers
# ---------------------------------------------------------------------------

def test_stage_skew_ratios_and_summary():
    stages = [
        {"kind": "compute", "label": "seg[a..b]",
         "sim_s": 1.0, "measured_s": 2.0},
        {"kind": "sync", "label": "bound@b",
         "sim_s": 0.5, "measured_s": 0.25},
        {"kind": "sync", "label": "gather",
         "sim_s": 0.0, "measured_s": 0.1},      # unpaired: sim zero
        {"kind": "compute", "label": "seg[c..c]",
         "sim_s": 1.0, "measured_s": None},     # unpaired: missing
    ]
    skew = stage_skew(stages)
    assert skew["n_stages"] == 4 and skew["n_paired"] == 2
    ratios = [p["ratio"] for p in skew["per_stage"]]
    assert ratios == [2.0, 0.5, None, None]
    assert skew["median_ratio"] == pytest.approx(1.25)
    assert skew["min_ratio"] == 0.5 and skew["max_ratio"] == 2.0
    assert skew["max_abs_log2"] == pytest.approx(1.0)


def test_stage_skew_empty():
    skew = stage_skew([])
    assert skew["n_stages"] == 0 and skew["n_paired"] == 0
    assert skew["median_ratio"] is None
    assert skew["max_abs_log2"] is None


def _stage_trace(pid, names_durs, process):
    tr = Tracer(process=process, pid=pid)
    t = 0.0
    for name, dur in names_durs:
        tr.add_complete(CONTROL_TRACK, name, t, dur, cat=STAGE_CAT)
        t += dur
    return tr.to_perfetto()


def test_diff_traces_match():
    m = _stage_trace(1, [("a", 2.0), ("b", 1.0)], "measured")
    s = _stage_trace(2, [("a", 1.0), ("b", 1.0)], "simulated")
    d = diff_traces(m, s)
    assert d["structure_match"]
    assert d["only_measured"] == [] and d["only_simulated"] == []
    assert [(p["name"], p["ratio"]) for p in d["pairs"]] == \
        [("a", 2.0), ("b", 1.0)]


def test_diff_traces_mismatch():
    m = _stage_trace(1, [("a", 1.0), ("x", 1.0)], "measured")
    s = _stage_trace(2, [("a", 1.0), ("b", 1.0)], "simulated")
    d = diff_traces(m, s)
    assert not d["structure_match"]
    assert d["only_measured"] == ["x"]
    assert d["only_simulated"] == ["b"]
    assert [p["name"] for p in d["pairs"]] == ["a"]
