"""Flight recorder: a bounded ring of recent events + postmortem dumps.

The recorder is always on (one deque append per recorded event — it
never touches numerics, so instrumented and uninstrumented runs stay
bit-identical) and bounded (``capacity`` events, oldest evicted), so
it can ride along every mesh dispatch, replan, and refine iteration at
negligible cost.  When a failure fires (``StageFailure`` / stage
watchdog timeout / ``RefineOscillationError``), :func:`dump_postmortem`
writes a JSON artifact with the failure context, the recent ring, and
— when a tracer is installed — the tail of its recorded spans, to the
directory configured by :func:`set_postmortem_dir` or the
``REPRO_POSTMORTEM_DIR`` environment variable.  With no directory
configured the dump is a no-op returning ``None`` (the default:
failures raise exactly as before, just without the artifact).
"""
from __future__ import annotations

import collections
import itertools
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

from . import trace as _trace

#: environment variable naming the postmortem output directory
POSTMORTEM_ENV = "REPRO_POSTMORTEM_DIR"

#: how many trailing tracer spans a postmortem captures
SPAN_TAIL = 64


class FlightRecorder:
    """Bounded ring buffer of ``{"t_us", "kind", ...fields}`` events."""

    def __init__(self, capacity: int = 512) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        self._epoch = time.perf_counter()
        self._total = 0

    def record(self, kind: str, **fields) -> None:
        ev = {"t_us": (time.perf_counter() - self._epoch) * 1e6,
              "kind": kind}
        ev.update(fields)
        with self._lock:
            self._buf.append(ev)
            self._total += 1

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    @property
    def total_recorded(self) -> int:
        """Events recorded over the recorder's lifetime (>= ``len``
        once the ring has wrapped)."""
        with self._lock:
            return self._total

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)


_FLIGHT = FlightRecorder()
_DIR: Optional[str] = None
_SEQ = itertools.count()


def get_flight() -> FlightRecorder:
    """The process-wide flight recorder (always available)."""
    return _FLIGHT


def set_postmortem_dir(path: Optional[str]) -> None:
    """Configure where :func:`dump_postmortem` writes (overrides the
    ``REPRO_POSTMORTEM_DIR`` environment variable; ``None`` defers back
    to it)."""
    global _DIR
    _DIR = path


def postmortem_dir() -> Optional[str]:
    return _DIR if _DIR is not None else \
        (os.environ.get(POSTMORTEM_ENV) or None)


def dump_postmortem(reason: str,
                    context: Optional[Dict[str, Any]] = None,
                    directory: Optional[str] = None) -> Optional[str]:
    """Write a postmortem artifact and return its path — or ``None``
    when no output directory is configured.

    The artifact carries ``reason``, the caller's ``context`` (for a
    stage failure: the failing stage's kind/label/timeout — its span
    context), the flight ring, and the last :data:`SPAN_TAIL` spans of
    the installed tracer, if any."""
    d = directory if directory is not None else postmortem_dir()
    if d is None:
        return None
    os.makedirs(d, exist_ok=True)
    tracer = _trace.get_tracer()
    spans: List[Dict[str, Any]] = []
    if tracer is not None:
        spans = tracer.spans()[-SPAN_TAIL:]
    doc = {
        "reason": reason,
        "context": dict(context) if context else {},
        "events": _FLIGHT.events(),
        "spans": spans,
    }
    path = os.path.join(
        d, f"postmortem-{os.getpid()}-{next(_SEQ)}-{reason}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, default=str)
    return path
