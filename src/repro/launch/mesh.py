"""Mesh construction — the one mesh-building path.

Every mesh in the repo (the partitioned-inference ``nodes`` mesh of the
mesh executor, the small local test meshes, the production TPU meshes of
the dry-run) is built through :func:`_grid`, which validates the device
count and raises an actionable error naming the ``XLA_FLAGS`` host-device
override when the host platform is short of devices.

Functions (not module-level constants) so importing this module never
touches jax device state — callers set ``XLA_FLAGS`` (e.g.
``--xla_force_host_platform_device_count=8``) before any jax use and the
first ``jax.devices()`` call here sees it.
"""
from __future__ import annotations

import math
import os
import re
from typing import Optional, Sequence, Tuple


def requested_host_devices() -> Optional[int]:
    """Host-device count requested via ``XLA_FLAGS``, if any.  Parsed from
    the environment (not from jax) so it reflects what *was asked for* even
    when jax initialized before the flag was set."""
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)",
                  os.environ.get("XLA_FLAGS", ""))
    return int(m.group(1)) if m else None


def _grid(shape: Tuple[int, ...], axes: Tuple[str, ...], devices=None):
    """Build a mesh of ``shape`` over the first ``prod(shape)`` devices."""
    import jax

    n = math.prod(shape)
    devs = list(jax.devices()) if devices is None else list(devices)
    if len(devs) < n:
        req = requested_host_devices()
        hint = (f"XLA_FLAGS requested {req} host devices but jax "
                f"initialized before the flag was set"
                if req is not None and req >= n else
                f"set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{n} before importing jax to fake host devices")
        raise RuntimeError(
            f"mesh {axes}={shape} needs {n} devices, found {len(devs)} "
            f"({hint})")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_nodes_mesh(nodes: int, devices: Optional[Sequence] = None):
    """1-D mesh over the planned edge nodes — the mesh executor's axis.

    One device per plan node; CPU CI fakes the devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    if nodes < 1:
        raise ValueError(f"nodes must be >= 1, got {nodes}")
    return _grid((nodes,), ("nodes",), devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _grid(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    import jax

    n = len(jax.devices())
    assert n % model_axis == 0
    return _grid((n // model_axis, model_axis), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
