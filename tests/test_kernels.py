"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Shape/dtype sweeps per kernel as required: flash attention over sequence
lengths, head dims, GQA ratios, masks, dtypes and block shapes — including
a direct ``flash_attention_bh`` comparison against an inline jnp softmax
reference (independent of ``ref.attention_ref``); conv2d over kernel
sizes, strides, channel counts and paddings, plus the degenerate-geometry
fallback regressions.  The in-model jnp flash (custom_vjp) is also checked
against the naive oracle including gradients.
"""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention_bh
from repro.kernels.ops import conv2d, dwconv2d, flash_attention
from repro.kernels.ref import attention_ref, conv2d_ref, dwconv2d_ref


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (2, 4, 2, 256, 64),
    (1, 2, 2, 384, 128),
    (2, 2, 1, 128, 64),
    (1, 8, 8, 512, 64),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_sweep(B, H, KV, S, hd, causal, window):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window)
    kk = jnp.repeat(k, H // KV, axis=1)
    vv = jnp.repeat(v, H // KV, axis=1)
    ref = attention_ref(q, kk, vv, causal=causal, window=window)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("dtype,tol", [("float32", 2e-5), ("bfloat16", 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 4, 256, 64), dtype)
    k = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 256, 64), dtype)
    v = jax.random.normal(jax.random.PRNGKey(5), (2, 4, 256, 64), dtype)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - ref.astype(jnp.float32)))
    assert err < tol


def test_flash_attention_unaligned_seq():
    """S not a multiple of the block size exercises the padding path."""
    q = jax.random.normal(jax.random.PRNGKey(6), (1, 2, 300, 64))
    k = jax.random.normal(jax.random.PRNGKey(7), (1, 2, 300, 64))
    v = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 300, 64))
    out = flash_attention(q, k, v, causal=True, window=48)
    ref = attention_ref(q, k, v, causal=True, window=48)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("H,W,cin,cout,K,p", [
    (16, 16, 8, 16, 3, 1),
    (28, 28, 16, 8, 1, 0),
    (20, 20, 4, 4, 5, 2),
    (14, 14, 32, 32, 3, 1),
])
def test_conv2d_sweep(H, W, cin, cout, K, p):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (H, W, cin))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, K, cin, cout)) * 0.1
    out = conv2d(x, w, padding=p)
    ref = conv2d_ref(x, w, padding=p)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("H,W,cin,cout,K,s,p", [
    (16, 16, 8, 8, 3, 2, 1),     # resnet/mobilenet downsampling
    (23, 23, 3, 16, 7, 2, 3),    # resnet stem
    (15, 17, 4, 4, 1, 2, 0),     # strided pointwise (projection skip)
    (14, 14, 6, 5, 5, 2, 2),
])
def test_conv2d_strided(H, W, cin, cout, K, s, p):
    """Strided convs now run the Pallas shard kernel, not the XLA
    fallback."""
    x = jax.random.normal(jax.random.PRNGKey(0), (H, W, cin))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, K, cin, cout)) * 0.1
    out = conv2d(x, w, padding=p, stride=s)
    ref = conv2d_ref(x, w, padding=p, stride=s)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("H,W,c,K,s,p", [
    (16, 16, 8, 3, 1, 1),
    (15, 17, 6, 3, 2, 1),        # mobilenet strided depthwise
    (9, 9, 4, 5, 1, 2),
])
def test_dwconv2d_sweep(H, W, c, K, s, p):
    x = jax.random.normal(jax.random.PRNGKey(0), (H, W, c))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, K, 1, c)) * 0.3
    out = dwconv2d(x, w, padding=p, stride=s)
    ref = dwconv2d_ref(x, w, padding=p, stride=s)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("shape,K,p,case", [
    ((8, 2, 4), 3, 0, "out_w==0"),       # W < K: zero-width output
    ((2, 8, 4), 3, 0, "out_h==0"),       # H < K: zero-height output
    ((6, 6, 4), 3, 0, "tile_h>out_h"),   # one short tile
    ((3, 3, 4), 3, 0, "1x1 output"),
])
def test_conv2d_degenerate_geometries(shape, K, p, case):
    """Regression (satellite): tile_h > out_h and empty outputs fall back
    cleanly to the XLA result instead of raising shape errors."""
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, K, shape[2], 4)) * 0.1
    out = conv2d(x, w, padding=p, tile_h=8)
    ref = conv2d_ref(x, w, padding=p)
    assert out.shape == ref.shape, case
    if ref.size:
        assert jnp.max(jnp.abs(out - ref)) < 1e-4, case


def _softmax_attention_inline(q, k, v, *, causal, window, scale):
    """Inline jnp softmax reference (independent of ref.attention_ref):
    q/k/v [BH, S, hd] — the kernel's own layout."""
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).swapaxes(-1, -2)
         ) * scale
    S = q.shape[1]
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("BH,S,hd,bq,bk", [
    (4, 256, 64, 128, 128),
    (2, 256, 32, 64, 128),       # block_q != block_k
    (2, 384, 64, 128, 64),
    (1, 128, 128, 32, 32),       # many small blocks
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 48),
                                           (False, None), (False, 96)])
def test_flash_attention_bh_conformance(BH, S, hd, bq, bk, causal, window):
    """Direct kernel entry point vs the inline softmax reference — covers
    the causal upper-bound and sliding-window lower-bound block skips on
    every block-shape combination."""
    q = jax.random.normal(jax.random.PRNGKey(0), (BH, S, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (BH, S, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (BH, S, hd))
    out = flash_attention_bh(q, k, v, causal=causal, window=window,
                             block_q=bq, block_k=bk)
    ref = _softmax_attention_inline(q, k, v, causal=causal, window=window,
                                    scale=1.0 / math.sqrt(hd))
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("S", [100, 130, 257])
@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 32)])
def test_flash_attention_nonmultiple_seq(S, bq, bk):
    """Sequence lengths off every block multiple exercise the wrapper's
    padding path; padded keys must not leak into real rows."""
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 2, S, 64))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 2, S, 64))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 2, S, 64))
    out = flash_attention(q, k, v, causal=True, window=40,
                          block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=True, window=40)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_model_flash_custom_vjp_grads():
    """In-model streaming attention: gradients match the naive oracle."""
    from repro.models import attention as A
    B, KV, G, Q, hd = 2, 2, 2, 64, 16
    q = jax.random.normal(jax.random.PRNGKey(1), (B, KV, G, Q, hd))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, KV, Q, hd))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, KV, Q, hd))
    pos = jnp.broadcast_to(jnp.arange(Q)[None], (B, Q))
    scale = 1.0 / math.sqrt(hd)

    def naive(q, k, v):
        mask = A._causal_window_mask(pos, pos, 17)[:, None, None]
        return A._sdpa(q, k, v, mask, scale)

    def flash(q, k, v):
        return A._chunked_sdpa(q, k, v, pos, pos, 17, scale, True)

    o_err = jnp.max(jnp.abs(naive(q, k, v) - flash(q, k, v)))
    assert o_err < 1e-5
    g1 = jax.grad(lambda *a: (naive(*a) ** 2).sum(), (0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (flash(*a) ** 2).sum(), (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert jnp.max(jnp.abs(a - b)) < 1e-4
