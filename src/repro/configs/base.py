"""Model configuration schema for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0             # always-on shared experts (DeepSeek)
    first_dense: int = 0          # leading dense layers (DeepSeek layer 0)
    d_ff_dense: int = 0           # d_ff of the leading dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 1536
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"          # "mamba2" | "rwkv6"
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # SSM head size
    # chunk-parallel scan (0 = exact per-token recurrence).  The chunked
    # form trades per-token state IO for intra-chunk matmuls — the
    # §Perf hillclimb for the SSM/hybrid architectures.
    chunk: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm | nonparam_ln
    act: str = "swiglu"           # swiglu | gelu
    rope_kind: str = "rope"       # rope | mrope | none | sinusoidal
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    attn_window: Optional[int] = None   # sliding-window width (decode paths)
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every N ssm blocks
    hybrid_attn_every: int = 0
    # encoder-decoder (whisper): encoder depth + fixed source length
    n_enc_layers: int = 0
    enc_seq: int = 1500
    # vlm (qwen2-vl): number of stub vision tokens prepended
    vision_tokens: int = 0
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: <=2 layers, d_model<=512,
        <=4 experts — runs a real forward/train step on CPU."""
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv, n_heads)) if n_heads else 0
        d_model = min(self.d_model, 256)
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_model=d_model,
            n_heads=n_heads, n_kv=n_kv,
            head_dim=d_model // n_heads if n_heads else 0,
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            attn_window=min(self.attn_window, 64) if self.attn_window else None,
            mrope_sections=(16, 24, 24) if self.rope_kind == "mrope" else self.mrope_sections,
        )
        if self.rope_kind == "mrope":
            # sections must sum to hd/2
            hd = kw["d_model"] // kw["n_heads"]
            kw["mrope_sections"] = (hd // 2 - 2 * (hd // 6), hd // 6, hd // 6)
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 128),
                n_shared=min(self.moe.n_shared, 1),
                first_dense=min(self.moe.first_dense, 1),
                d_ff_dense=min(self.moe.d_ff_dense, 256) if self.moe.d_ff_dense else 0)
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora=64, q_lora=64, qk_nope=32,
                                  qk_rope=16, v_head=32)
            kw["head_dim"] = 32
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=32)
        if self.hybrid_attn_every:
            kw["hybrid_attn_every"] = 1
        if self.n_enc_layers:
            kw["n_enc_layers"] = 1
            kw["enc_seq"] = 16
        if self.vision_tokens:
            kw["vision_tokens"] = 8
        return dataclasses.replace(self, **kw)
