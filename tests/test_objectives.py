"""Serving objectives: Theorem-1 parity for the pipelined-cost DP, the
frontier's structural invariants, and the analytic-vs-simulated
bottleneck-time tolerance contract."""
import numpy as np
import pytest

from repro.cluster import (CLUSTER_PRESETS, ClusterAnalyticEstimator,
                           cluster_plan_search, homogeneous, simulate)
from repro.configs.edge_models import EDGE_MODELS
from repro.core import (AnalyticEstimator, Objective, Testbed,
                        exhaustive_search, pipeline_frontier,
                        pipeline_objective_key, plan_pipeline_cost,
                        plan_search)
from repro.core.graph import ConvT, LayerSpec, ModelGraph, chain

EST = AnalyticEstimator()


def oracle_chain():
    return chain("oracle5", [
        LayerSpec("c0", ConvT.CONV, 24, 24, 3, 8, 3, 1, 1),
        LayerSpec("dw", ConvT.DWCONV, 24, 24, 8, 8, 3, 1, 1),
        LayerSpec("pw", ConvT.POINTWISE, 24, 24, 8, 16, 1, 1, 0),
        LayerSpec("c1", ConvT.CONV, 24, 24, 16, 16, 3, 2, 1),
        LayerSpec("c2", ConvT.CONV, 12, 12, 16, 8, 3, 1, 1),
    ])


def res_block_dag():
    return ModelGraph(name="resblock", layers=(
        LayerSpec("c0", ConvT.CONV, 16, 16, 3, 8, 3, 1, 1),
        LayerSpec("a", ConvT.CONV, 16, 16, 8, 8, 3, 1, 1, inputs=("c0",)),
        LayerSpec("b", ConvT.CONV, 16, 16, 8, 8, 3, 1, 1, inputs=("a",)),
        LayerSpec("add", ConvT.ADD, 16, 16, 8, 8, inputs=("b", "c0")),
        LayerSpec("c1", ConvT.CONV, 16, 16, 8, 8, 3, 1, 1,
                  inputs=("add",)),
    ))


def inception_dag():
    return ModelGraph(name="tinyinc", layers=(
        LayerSpec("stem", ConvT.CONV, 16, 16, 3, 8, 3, 1, 1),
        LayerSpec("b1", ConvT.POINTWISE, 16, 16, 8, 4, 1, 1, 0,
                  inputs=("stem",)),
        LayerSpec("b2a", ConvT.POINTWISE, 16, 16, 8, 4, 1, 1, 0,
                  inputs=("stem",)),
        LayerSpec("b2b", ConvT.CONV, 16, 16, 4, 4, 3, 1, 1,
                  inputs=("b2a",)),
        LayerSpec("cat", ConvT.CONCAT, 16, 16, 8, 8,
                  inputs=("b1", "b2b")),
        LayerSpec("c1", ConvT.CONV, 16, 16, 8, 8, 3, 1, 1,
                  inputs=("cat",)),
    ))


GRAPHS = {"chain": oracle_chain, "resblock": res_block_dag,
          "inception": inception_dag}


# ---------------------------------------------------------------------------
# Theorem-1 parity under Objective.THROUGHPUT across every cluster preset.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", list(CLUSTER_PRESETS))
@pytest.mark.parametrize("gname", list(GRAPHS))
def test_throughput_dp_matches_exhaustive(preset, gname):
    g = GRAPHS[gname]()
    for nodes in (2, 4):
        cl = CLUSTER_PRESETS[preset](nodes)
        est = ClusterAnalyticEstimator(cl)
        tb = cl.compat_testbed()
        res = cluster_plan_search(g, cl, objective=Objective.THROUGHPUT)
        _, ex_cost = exhaustive_search(g, est, tb,
                                       objective=Objective.THROUGHPUT)
        assert abs(res.cost - ex_cost) / ex_cost < 1e-9
        # the returned plan must realize the claimed (compute, sync) pair
        pc = plan_pipeline_cost(g, res.plan, est, tb)
        assert abs(pc.bottleneck_s - res.cost) / res.cost < 1e-9
        assert res.pipeline is not None
        assert abs(pc.compute_s - res.pipeline.compute_s) \
            <= 1e-9 * pc.compute_s
        assert abs(pc.sync_s - res.pipeline.sync_s) \
            <= 1e-9 * max(pc.sync_s, 1e-30)


@pytest.mark.parametrize("gname", ["chain", "resblock"])
@pytest.mark.parametrize("mult", [1.5, 1.02, 0.5])
def test_p99_bounded_dp_matches_exhaustive(gname, mult):
    g = GRAPHS[gname]()
    for preset in ("uniform", "asym_uplink"):
        cl = CLUSTER_PRESETS[preset](4)
        est = ClusterAnalyticEstimator(cl)
        tb = cl.compat_testbed()
        bound = cluster_plan_search(g, cl).cost * mult
        res = cluster_plan_search(g, cl, objective=Objective.P99_BOUNDED,
                                  latency_bound_s=bound)
        _, ex_cost = exhaustive_search(g, est, tb,
                                       objective=Objective.P99_BOUNDED,
                                       latency_bound_s=bound)
        assert abs(res.cost - ex_cost) / max(ex_cost, 1e-30) < 1e-9


def test_p99_infeasible_bound_degrades_to_latency_optimum():
    g = oracle_chain()
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    lat = plan_search(g, EST, tb)
    res = plan_search(g, EST, tb, objective=Objective.P99_BOUNDED,
                      latency_bound_s=lat.cost * 0.5)   # unreachable
    assert res.pipeline is not None
    assert abs(res.pipeline.latency_s - lat.cost) / lat.cost < 1e-9


def test_p99_requires_bound():
    g = oracle_chain()
    tb = Testbed(nodes=2)
    with pytest.raises(ValueError):
        plan_search(g, EST, tb, objective=Objective.P99_BOUNDED)
    with pytest.raises(ValueError):
        pipeline_objective_key(1.0, 1.0, Objective.P99_BOUNDED)


# ---------------------------------------------------------------------------
# Frontier invariants.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname", list(GRAPHS))
def test_frontier_is_nondominated_and_contains_latency_optimum(gname):
    g = GRAPHS[gname]()
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    fr = pipeline_frontier(g, EST, tb)
    pts = fr.points
    assert len(pts) >= 1
    # sorted by compute ascending, sync strictly descending (nondominated)
    assert np.all(np.diff(pts[:, 0]) > 0) or len(pts) == 1
    assert np.all(np.diff(pts[:, 1]) < 0) or len(pts) == 1
    # the latency optimum is a frontier point (sum is monotone in the pair)
    lat = plan_search(g, EST, tb)
    sums = pts.sum(axis=1)
    assert abs(sums.min() - lat.cost) / lat.cost < 1e-9
    # every point's plan realizes its coordinates
    for i in range(len(pts)):
        pc = plan_pipeline_cost(g, fr.plan(i), EST, tb)
        assert abs(pc.compute_s - pts[i, 0]) <= 1e-9 * pts[i, 0]
        assert abs(pc.sync_s - pts[i, 1]) <= 1e-9 * max(pts[i, 1], 1e-30)


def test_scalar_estimator_frontier_matches_batched():
    class ScalarOnly:
        def i_cost(self, *a, **k):
            return EST.i_cost(*a, **k)

        def s_cost(self, *a, **k):
            return EST.s_cost(*a, **k)

    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    for gname in ("chain", "resblock"):
        g = GRAPHS[gname]()
        fb = pipeline_frontier(g, EST, tb)
        fs = pipeline_frontier(g, ScalarOnly(), tb)
        assert fb.points.shape == fs.points.shape
        assert np.allclose(fb.points, fs.points, rtol=1e-12, atol=0)


def test_throughput_never_worse_than_latency_plan_bottleneck():
    for gname in GRAPHS:
        g = GRAPHS[gname]()
        for preset in ("uniform", "asym_uplink"):
            cl = CLUSTER_PRESETS[preset](4)
            est = ClusterAnalyticEstimator(cl)
            tb = cl.compat_testbed()
            lat = cluster_plan_search(g, cl)
            thr = cluster_plan_search(g, cl,
                                      objective=Objective.THROUGHPUT)
            lat_pc = plan_pipeline_cost(g, lat.plan, est, tb)
            assert thr.cost <= lat_pc.bottleneck_s * (1 + 1e-12)


def test_frontier_ub_variants_agree_on_unscaled_optimum():
    """prune_ub=False keeps a superset of points; ub_cost reproduces the
    internally-seeded cutoff; all three agree on the unscaled optimum."""
    g = oracle_chain()
    for bw in (5.0, 0.3):
        tb = Testbed(nodes=4, bandwidth_gbps=bw)
        lat = plan_search(g, EST, tb)
        fp = pipeline_frontier(g, EST, tb)
        fu = pipeline_frontier(g, EST, tb, prune_ub=False)
        fc = pipeline_frontier(g, EST, tb, ub_cost=lat.cost)
        assert np.allclose(fp.points, fc.points, rtol=0, atol=0)
        assert len(fu.points) >= len(fp.points)
        ref = fp.search_result(Objective.THROUGHPUT).cost
        for fr in (fu, fc):
            assert fr.search_result(Objective.THROUGHPUT).cost \
                == pytest.approx(ref, rel=1e-12)


def test_frontier_select_scaling_picks_extremes():
    g = oracle_chain()
    tb = Testbed(nodes=4, bandwidth_gbps=0.3)   # comm-heavy: rich frontier
    fr = pipeline_frontier(g, EST, tb)
    if len(fr.points) < 2:
        pytest.skip("degenerate frontier")
    # huge sync weight -> pick the sync-minimal (last) point; huge compute
    # weight -> the compute-minimal (first) point
    assert fr.select(Objective.THROUGHPUT, sync_scale=1e9) \
        == len(fr.points) - 1
    assert fr.select(Objective.THROUGHPUT, compute_scale=1e9) == 0


# ---------------------------------------------------------------------------
# Analytic bottleneck vs simulated steady-state inter-departure time.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["mobilenet", "bert"])
@pytest.mark.parametrize("nodes", [2, 4])
def test_analytic_bottleneck_matches_sim_on_homogeneous_chains(model,
                                                               nodes):
    g = EDGE_MODELS[model]()
    cl = homogeneous(nodes, bandwidth_gbps=2.0)
    est = ClusterAnalyticEstimator(cl)
    tb = cl.compat_testbed()
    for objective in (Objective.LATENCY, Objective.THROUGHPUT):
        res = plan_search(g, est, tb, objective=objective)
        pc = plan_pipeline_cost(g, res.plan, est, tb)
        rep = simulate(g, res.plan, cl, n_requests=64)
        period = 1.0 / rep.throughput_rps
        assert abs(period - pc.bottleneck_s) / pc.bottleneck_s < 0.05


def test_objective_threads_through_tpu_planner_proxy():
    """choose_strategy's scalar roofline estimator runs the frontier path
    (chain, scalar providers) — THROUGHPUT must match its own oracle."""
    from repro.runtime.planner import TpuRooflineEstimator, _proxy_graph
    from repro.configs.registry import get_config

    cfg = get_config("olmo-1b")
    graph, div, kv = _proxy_graph(cfg, 4096, 4)
    est = TpuRooflineEstimator(4, div, kv)
    from repro.core.partition import Scheme
    from repro.launch.mesh import ICI_BW
    tb = Testbed(nodes=4, bandwidth_gbps=ICI_BW * 8 / 1e9)
    schemes = (Scheme.INH, Scheme.OUTC)
    res = plan_search(graph, est, tb, schemes=schemes,
                      objective=Objective.THROUGHPUT)
    _, ex = exhaustive_search(graph, est, tb, schemes=schemes,
                              objective=Objective.THROUGHPUT)
    assert abs(res.cost - ex) / ex < 1e-9


@pytest.mark.parametrize("preset", ["mixed_fast_slow", "stepped"])
def test_hetero_analytic_bottleneck_upper_bounds_sim(preset):
    """On heterogeneous clusters the analytic occupancy sums are upper
    bounds (straggler may move between layers; the schedule can only do
    better) — but stay within a loose band of the simulator."""
    g = EDGE_MODELS["mobilenet"]()
    cl = CLUSTER_PRESETS[preset](4)
    est = ClusterAnalyticEstimator(cl)
    tb = cl.compat_testbed()
    res = plan_search(g, est, tb, objective=Objective.THROUGHPUT)
    pc = plan_pipeline_cost(g, res.plan, est, tb)
    rep = simulate(g, res.plan, cl, n_requests=64)
    period = 1.0 / rep.throughput_rps
    assert period <= pc.bottleneck_s * 1.05
    assert period >= pc.bottleneck_s * 0.5
