"""Cost estimator (CE) interface — i-Estimator and s-Estimator (§3.2).

Two implementations:

* :class:`AnalyticEstimator` — wraps the closed-form testbed model
  (``core/cost.py``).  Used as the Theorem-1 oracle and as the label source
  for trace generation.
* :class:`GBDTEstimator` — the paper-faithful data-driven estimator: two
  from-scratch histogram GBDT regressors (``repro/gbdt``) trained on traces
  sampled from the simulator (``repro/sim/trace.py``).  Predicts log-time.

Feature expression (Fig. 4, extended with the planner's decision variables,
the DAG fan-in so the estimators see merge structure, and the ATTN head
count so they see head-granular OutC geometry):
``[InH, InW, InC, OutH, OutW, OutC, K, S, P, ConvT, FanIn, Heads,
bandwidth, topology]`` plus ``nodes, scheme, halo`` for i- and ``nodes,
src, dst, next_K, next_fan_in, next_conv_t`` for s-.
"""
from __future__ import annotations

from typing import List, Optional, Protocol

import numpy as np

from .cost import (Testbed, compute_time_batch_s, compute_time_s,
                   sync_time_batch_s, sync_time_s)
from .graph import LayerSpec
from .partition import Scheme


class CostEstimator(Protocol):
    """Scalar estimator protocol — the minimum every estimator provides.

    Estimators may additionally implement :class:`BatchedCostEstimator`;
    consumers feature-test with ``hasattr(est, "i_cost_batch")`` and fall
    back to scalar-call paths otherwise (scalar-only estimators may depend
    on information outside the feature expression, e.g. layer names)."""

    def i_cost(self, layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int = 0) -> float: ...

    def s_cost(self, layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed) -> float: ...


class BatchedCostEstimator(CostEstimator, Protocol):
    """Batched extension: costs are determined by the feature expression
    alone, and whole query matrices evaluate in one call, bit-identical to
    the scalar protocol row for row."""

    def i_cost_batch(self, X: np.ndarray, tb: Testbed,
                     flop_factor: Optional[np.ndarray] = None
                     ) -> np.ndarray:
        """Vector i-Estimator over a stacked ``(n, 17)`` matrix of
        :func:`i_features` rows.  Row ``j`` must equal
        ``i_cost(layer_j, scheme_j, tb_j, halo_j)`` exactly.
        ``flop_factor`` carries ``extra_flop_factor`` per row for estimators
        that read the analytic physics (it is not a learned feature)."""
        ...

    def s_cost_batch(self, X: np.ndarray, tb: Testbed) -> np.ndarray:
        """Vector s-Estimator over stacked ``(n, 20)`` :func:`s_features`
        rows (``Dst = -1`` marks the final gather)."""
        ...


class AnalyticEstimator:
    """Oracle estimator: reads the simulated testbed physics directly."""

    def i_cost(self, layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int = 0) -> float:
        return compute_time_s(layer, scheme, tb, extra_halo=extra_halo)

    def s_cost(self, layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed) -> float:
        return sync_time_s(layer, nxt, src, dst, tb)

    def i_cost_batch(self, X: np.ndarray, tb: Testbed,
                     flop_factor: Optional[np.ndarray] = None
                     ) -> np.ndarray:
        return compute_time_batch_s(X, tb, flop_factor)

    def s_cost_batch(self, X: np.ndarray, tb: Testbed) -> np.ndarray:
        return sync_time_batch_s(X, tb)


# ---------------------------------------------------------------------------
# Feature extraction shared by trace generation and GBDT inference.
# ---------------------------------------------------------------------------

def i_features(layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int) -> List[float]:
    return [*layer.feature_vector(), tb.bandwidth_gbps, float(tb.topology),
            float(tb.nodes), float(scheme), float(extra_halo)]


def s_features(layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed) -> List[float]:
    return [*layer.feature_vector(), tb.bandwidth_gbps, float(tb.topology),
            float(tb.nodes), float(src),
            -1.0 if dst is None else float(dst),
            0.0 if nxt is None else float(nxt.k),
            0.0 if nxt is None else float(nxt.fan_in),
            0.0 if nxt is None else float(nxt.conv_t)]


I_FEATURE_NAMES = ["InH", "InW", "InC", "OutH", "OutW", "OutC", "K", "S", "P",
                   "ConvT", "FanIn", "Heads", "BW", "Topo", "Nodes", "Scheme",
                   "Halo"]
S_FEATURE_NAMES = ["InH", "InW", "InC", "OutH", "OutW", "OutC", "K", "S", "P",
                   "ConvT", "FanIn", "Heads", "BW", "Topo", "Nodes", "Src",
                   "Dst", "NextK", "NextFanIn", "NextConvT"]


class GBDTEstimator:
    """Data-driven CE backed by two trained GBDT regressors (log-seconds)."""

    def __init__(self, i_model, s_model):
        self.i_model = i_model
        self.s_model = s_model
        self._i_cache: dict = {}
        self._s_cache: dict = {}

    def i_cost(self, layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int = 0) -> float:
        key = (layer, scheme, tb, extra_halo)
        hit = self._i_cache.get(key)
        if hit is None:
            x = np.asarray([i_features(layer, scheme, tb, extra_halo)],
                           dtype=np.float64)
            hit = float(np.exp(self.i_model.predict(x)[0]))
            self._i_cache[key] = hit
        return hit

    def s_cost(self, layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed) -> float:
        key = (layer,
               None if nxt is None else (nxt.k, nxt.fan_in, nxt.conv_t),
               src, dst, tb)
        hit = self._s_cache.get(key)
        if hit is None:
            x = np.asarray([s_features(layer, nxt, src, dst, tb)],
                           dtype=np.float64)
            hit = float(np.exp(self.s_model.predict(x)[0]))
            self._s_cache[key] = hit
        return hit

    def i_cost_batch(self, X: np.ndarray, tb: Testbed,
                     flop_factor: Optional[np.ndarray] = None
                     ) -> np.ndarray:
        """One forest pass for the whole matrix (``flop_factor`` is not part
        of the learned feature expression and is ignored, exactly as the
        scalar path ignores it)."""
        return np.exp(self.i_model.predict(np.asarray(X, np.float64)))

    def s_cost_batch(self, X: np.ndarray, tb: Testbed) -> np.ndarray:
        return np.exp(self.s_model.predict(np.asarray(X, np.float64)))
