"""Execution runtime: the consolidated Session API, decode serving
primitives, and the underlying executors.

Public surface:

* :class:`ExecConfig` / :class:`Session` — how to run a plan (policy) and
  a plan bound for repeated execution (state).  This is the front door;
  ``engine.run_partitioned`` is a deprecated shim over it.
* :class:`DecodeSession` + :class:`TransformerSpec` and the decode-graph
  helpers — autoregressive transformer decode with the distributed paged
  KV cache.
* :class:`PagedKVCache` — head-owner page placement for decode.
* ``init_weights`` / ``run_reference`` / :class:`ExecStats` — model
  setup and the unpartitioned oracle from the engine.
"""
from .engine import ExecStats, init_weights, run_reference
from .session import ExecConfig, Session
from .kv_cache import PagedKVCache
from .decode import (DecodeSession, TransformerSpec, decode_graph,
                     greedy_decode, init_transformer, plan_decode,
                     prefill_graph, reference_decode)

__all__ = [
    "ExecConfig", "Session", "ExecStats", "init_weights", "run_reference",
    "PagedKVCache", "DecodeSession", "TransformerSpec", "decode_graph",
    "prefill_graph", "init_transformer", "reference_decode",
    "greedy_decode", "plan_decode",
]
