"""Perf hillclimbing driver (§Perf of EXPERIMENTS.md).

Three chosen pairs from the 40-pair baseline roofline table:

  A. rwkv6-3b   x train_4k — WORST roofline fraction (useful 0.069,
     t_memory 3050s): per-token state IO of the recurrent scan.
     Iterations: chunk-parallel linear attention (chunk 32/64/128).
  B. granite-moe x train_4k — MOST collective-bound (t_coll/t_mem ~ 2).
     Iterations: MoE EP vs TP sharding, attn scheme, no-fsdp.
  C. deepseek-v2 x train_4k — most REPRESENTATIVE of the paper's
     technique (per-class scheme choice: MoE EP/TP x attn SP/TP).

Bonus D: qwen2-72b decode_32k (memory-bound decode): resident-TP weights
vs ZeRO-style gathered weights.

Each iteration = explicit FCO decision variables (Strategy / chunk
schedule), recompiled, re-measured with the loop-aware HLO profiler.

Run: PYTHONPATH=src python experiments/hillclimb.py [A|B|C|D|all]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
import json
import sys

from repro.launch.dryrun import run_one
from repro.runtime.shard_plan import Strategy

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "hillclimb")


def _chunk(n):
    def tf(cfg):
        return dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=n))
    return tf


EXPERIMENTS = {
    "A": [
        ("rwkv6-3b", "train_4k", "baseline recurrent scan", None, None),
        ("rwkv6-3b", "train_4k", "chunked C=32", None, _chunk(32)),
        ("rwkv6-3b", "train_4k", "chunked C=64", None, _chunk(64)),
        ("rwkv6-3b", "train_4k", "chunked C=128", None, _chunk(128)),
        ("zamba2-1.2b", "train_4k", "zamba2 baseline recurrent", None, None),
        ("zamba2-1.2b", "train_4k", "zamba2 chunked C=64", None, _chunk(64)),
    ],
    "B": [
        ("granite-moe-3b-a800m", "train_4k", "baseline planner", None, None),
        ("granite-moe-3b-a800m", "train_4k", "moe=tp attn=tp",
         Strategy(attn="tp", ffn="tp", moe="tp"), None),
        ("granite-moe-3b-a800m", "train_4k", "moe=tp attn=sp",
         Strategy(attn="sp", ffn="sp", moe="tp"), None),
        ("granite-moe-3b-a800m", "train_4k", "no-fsdp (replicated weights)",
         Strategy(attn="tp", ffn="tp", moe="tp", fsdp=False), None),
    ],
    "C": [
        ("deepseek-v2-236b", "train_4k", "baseline planner", None, None),
        ("deepseek-v2-236b", "train_4k", "moe=tp attn=sp",
         Strategy(attn="sp", ffn="tp", moe="tp"), None),
        ("deepseek-v2-236b", "train_4k", "moe=ep attn=tp",
         Strategy(attn="tp", ffn="tp", moe="ep"), None),
        ("deepseek-v2-236b", "train_4k", "moe=ep attn=sp",
         Strategy(attn="sp", ffn="sp", moe="ep"), None),
    ],
    "D": [
        ("qwen2-72b", "decode_32k", "baseline planner", None, None),
        ("qwen2-72b", "decode_32k", "ZeRO-inference (fsdp gathered)",
         Strategy(attn="tp", ffn="tp", fsdp=True,
                  decode_resident=False), None),
        ("qwen2-72b", "decode_32k", "resident TP weights",
         Strategy(attn="tp", ffn="tp", fsdp=False,
                  decode_resident=True), None),
    ],
}


def run(which: str) -> None:
    os.makedirs(OUT, exist_ok=True)
    targets = EXPERIMENTS if which == "all" else {which: EXPERIMENTS[which]}
    for exp, rows_spec in targets.items():
        print(f"=== hillclimb {exp} ===", flush=True)
        rows = []
        for arch, shape, label, st, tf in rows_spec:
            try:
                rec = run_one(arch, shape, strategy=st, cfg_transform=tf,
                              verbose=False)
            except Exception as e:  # record failures, keep climbing
                print(f"  {label:40s} FAILED {type(e).__name__}: "
                      f"{str(e)[:160]}", flush=True)
                continue
            rec["label"] = label
            rows.append(rec)
            print(f"  {label:40s} comp={rec['t_compute_s']:9.4g}s "
                  f"mem={rec['t_memory_s']:9.4g}s "
                  f"coll={rec['t_collective_s']:9.4g}s "
                  f"bneck={rec['bottleneck']:10s} "
                  f"useful={rec['useful_ratio']:.3f} "
                  f"temp={(rec['mem_per_device']['temp_size_bytes'] or 0) / 1e9:.1f}GB",
                  flush=True)
        with open(os.path.join(OUT, f"{exp}.json"), "w") as f:
            json.dump(rows, f, indent=1, default=str)


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "all")
