"""Mesh executor: multi-device `Session(..., ExecConfig(executor="mesh"))`.

Two tiers, following the repo's multi-device convention
(``test_multidevice.py``): the main test process keeps jax at 1 device,
so everything that needs a real device mesh runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

In-process (1 device):
  * degenerate 1-node plans bypass collectives — the mesh path must run
    (and match the local executor bit-exactly) with a single device and
    no mesh;
  * argument validation, ``to_occupancy`` arithmetic and the
    stage-decomposition validator as pure functions;
  * ``refine_with_simulator(occupancy_fn=...)`` consumes measured
    occupancy in place of the simulator.

Subprocess (8 fake devices, ``slow``):
  * equivalence vs the single-process path on every ``EDGE_MODELS`` entry
    (chains and branched DAGs) at node counts 2/4/8 with searched plans,
    scale-normalized tolerance as in PR 5, plus exact ``ExecStats``
    geometry equality;
  * ``backend="pallas"`` slots into the per-device programs unchanged;
  * measured stage structure (``instrument=True, overlap=False``)
    matches ``simsched.build_stages`` 1:1 and compute stages carry
    per-device completion times;
  * the overlapped (double-buffered) halo path on an NT plan matches;
  * the refine loop closes against *measured* mesh occupancy.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.edge_models import EDGE_MODELS
from repro.core import AnalyticEstimator, Testbed
from repro.core.dpp import plan_search
from repro.core.partition import Mode, Scheme
from repro.core.plan import Plan
from repro.runtime.engine import (EXECUTORS, ExecStats, MeasuredOccupancy,
                                  StageTime, init_weights)
from repro.runtime.mesh_exec import validate_stage_decomposition
from repro.runtime.session import ExecConfig, Session

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EST = AnalyticEstimator()


def run_partitioned(g, w, x, plan, nodes, **cfg):
    """Session-API positional sugar for this module's config sweeps."""
    return Session(g, w, plan, nodes, ExecConfig(**cfg)).run(x)

MODEL_TEST_KW = {
    "mobilenet": dict(width=32),
    "resnet18": dict(width=32),
    "resnet101": dict(width=32),
    "inception": dict(width=32),
    "bert": dict(seq=16, d=32, n_layers=1, d_ff=64),
}


#: hard wall limit for one mesh subprocess — generous for compile-heavy
#: 8-device runs, small enough that a wedged collective fails the test
#: instead of hanging the whole suite until the CI job limit
SUBPROC_TIMEOUT_S = 1200

_STARVATION_MSG = (
    "mesh subprocess exceeded {limit}s — on the CPU host platform this "
    "is the known thread-pool starvation: all fake devices share one "
    "dispatch pool, so threads parked in one stage module's collective "
    "rendezvous can starve another module's participants (XLA logs "
    "'collective_ops_utils ... may be stuck'). Reduce "
    "XLA_FLAGS=--xla_force_host_platform_device_count, keep the "
    "executor's serialized CPU dispatch enabled (_MeshRun.serialize), "
    "or arm run_partitioned_mesh(stage_timeout_s=...) to fail the "
    "single wedged stage instead of the whole process.")


def _run(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    try:
        return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                              capture_output=True, text=True, env=env,
                              timeout=SUBPROC_TIMEOUT_S)
    except subprocess.TimeoutExpired as exc:
        pytest.fail(_STARVATION_MSG.format(limit=SUBPROC_TIMEOUT_S)
                    + f"\npartial stdout: {exc.stdout!r}"
                    + f"\npartial stderr: {exc.stderr!r}")


def _model_io(name, seed=0):
    g = EDGE_MODELS[name](**MODEL_TEST_KW[name])
    w = init_weights(g, jax.random.PRNGKey(seed))
    l0 = g.layers[0]
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (l0.in_h, l0.in_w, l0.in_c))
    return g, w, x


# ---------------------------------------------------------------------------
# in-process: degenerate 1-node path + validation
# ---------------------------------------------------------------------------

def test_executors_constant():
    assert EXECUTORS == ("local", "mesh")


@pytest.mark.parametrize("name", ["mobilenet", "resnet18"])
def test_one_node_plan_bypasses_collectives(name):
    """nodes=1 must work in a 1-device process: no mesh is built and no
    collective is traced — output and stats are bit-identical to the
    local executor."""
    g, w, x = _model_io(name)
    plan = plan_search(g, EST, Testbed(nodes=1, bandwidth_gbps=0.5)).plan
    ref, s_ref = run_partitioned(g, w, x, plan, nodes=1)
    out, s = run_partitioned(g, w, x, plan, nodes=1, executor="mesh")
    assert float(jnp.max(jnp.abs(out - ref))) == 0.0
    assert s == s_ref


def test_one_node_instrumented_stats():
    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    _, s = run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                           instrument=True)
    assert s.stage_times and s.wall_s > 0.0
    kinds = {st.kind for st in s.stage_times}
    assert kinds == {"compute", "sync"}
    occ = s.to_occupancy()
    assert occ.period_s == max(occ.dev_occupancy_s, occ.link_occupancy_s)
    assert occ.latency_s >= 0.0


def test_executor_validation():
    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    with pytest.raises(ValueError, match="executor"):
        run_partitioned(g, w, x, plan, nodes=1, executor="bogus")
    with pytest.raises(ValueError, match="backend"):
        run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                        backend="bogus")
    with pytest.raises(ValueError, match="nodes"):
        run_partitioned(g, w, x, plan, nodes=0, executor="mesh")


def test_mesh_needs_devices():
    """Asking for more nodes than devices raises the actionable
    XLA_FLAGS hint (this process has 1 device)."""
    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        run_partitioned(g, w, x, plan, nodes=4, executor="mesh")


# ---------------------------------------------------------------------------
# in-process: fault handling (1-node plans need no mesh; the shrink
# precheck *wants* a device-starved process)
# ---------------------------------------------------------------------------

def test_fault_knob_validation():
    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    with pytest.raises(ValueError, match="fallback"):
        run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                        fallback="shrug")
    with pytest.raises(ValueError, match="stage_retries"):
        run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                        stage_retries=-1)
    with pytest.raises(ValueError, match="stage_timeout_s"):
        run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                        stage_timeout_s=0.0)


def test_transient_fault_is_retried():
    """Every stage dispatch fails once: with stage_retries=1 the run
    completes, matches the local executor, and counts every re-attempt
    (failure_count > 0 marks the occupancy sample untrusted for
    refine)."""
    from repro.runtime.mesh_exec import run_partitioned_mesh

    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    ref, s_ref = run_partitioned(g, w, x, plan, nodes=1)
    failed = set()

    def hook(kind, label, attempt):
        if (kind, label) not in failed:
            failed.add((kind, label))
            raise OSError(f"injected transient fault at {label}")

    out, s = run_partitioned_mesh(g, w, x, plan, nodes=1,
                                  stage_retries=1, fault_hook=hook)
    assert float(jnp.max(jnp.abs(out - ref))) == 0.0
    assert s.retries == len(failed) > 0
    assert s.timeouts == 0 and s.fallbacks == 0
    assert s.failure_count == s.retries
    # retries are advisory: stats still equal the clean run's geometry
    assert s == s_ref


def test_persistent_fault_exhausts_retries():
    from repro.runtime.mesh_exec import (StageDispatchError,
                                         run_partitioned_mesh)

    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))

    def hook(kind, label, attempt):
        raise OSError("injected persistent fault")

    with pytest.raises(StageDispatchError,
                       match=r"failed after 3 attempt\(s\)"):
        run_partitioned_mesh(g, w, x, plan, nodes=1, stage_retries=2,
                             fault_hook=hook)


def test_persistent_fault_degrades_to_local():
    from repro.runtime.mesh_exec import run_partitioned_mesh

    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    ref, _ = run_partitioned(g, w, x, plan, nodes=1)

    def hook(kind, label, attempt):
        raise OSError("injected persistent fault")

    out, s = run_partitioned_mesh(g, w, x, plan, nodes=1, stage_retries=1,
                                  fallback="local", fault_hook=hook)
    assert float(jnp.max(jnp.abs(out - ref))) == 0.0
    assert s.fallbacks == 1 and s.retries >= 1
    assert s.failure_count >= 2


def test_timeout_is_never_retried():
    """An injected StageTimeoutError must go straight to the fallback —
    re-dispatching a wedged collective just stacks another stuck module
    on the thread pool (see _timeout_message)."""
    from repro.runtime.mesh_exec import (StageTimeoutError,
                                         run_partitioned_mesh)

    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    ref, _ = run_partitioned(g, w, x, plan, nodes=1)

    def hook(kind, label, attempt):
        raise StageTimeoutError(f"injected timeout at {label}")

    out, s = run_partitioned_mesh(g, w, x, plan, nodes=1,
                                  stage_retries=5, fallback="local",
                                  fault_hook=hook)
    assert float(jnp.max(jnp.abs(out - ref))) == 0.0
    assert s.timeouts == 1
    assert s.retries == 0          # stage_retries never applied
    assert s.fallbacks == 1
    # and without a fallback the timeout propagates
    with pytest.raises(StageTimeoutError, match="injected timeout"):
        run_partitioned_mesh(g, w, x, plan, nodes=1, stage_retries=5,
                             fault_hook=hook)


def test_real_watchdog_fires_with_actionable_message():
    """An unmeetable stage_timeout_s trips the watchdog on the first
    (compiling) stage; the message names the known CPU thread-pool
    starvation and its remedies."""
    from repro.runtime.mesh_exec import StageTimeoutError

    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    with pytest.raises(StageTimeoutError, match="starvation"):
        run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                        stage_timeout_s=1e-4)


def test_generous_timeout_counts_nothing():
    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    ref, s_ref = run_partitioned(g, w, x, plan, nodes=1)
    out, s = run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                             stage_timeout_s=300.0, stage_retries=2)
    assert float(jnp.max(jnp.abs(out - ref))) == 0.0
    assert s == s_ref
    assert s.failure_count == 0


def test_mesh_shrink_degrades_to_local():
    """A 4-node plan in this 1-device process: with fallback='local' the
    precheck degrades to the single-process engine instead of raising
    the XLA_FLAGS hint (cf. test_mesh_needs_devices)."""
    g, w, x = _model_io("mobilenet")
    plan = plan_search(g, EST, Testbed(nodes=4, bandwidth_gbps=0.5)).plan
    ref, _ = run_partitioned(g, w, x, plan, nodes=4)
    out, s = run_partitioned(g, w, x, plan, nodes=4, executor="mesh",
                             fallback="local")
    assert float(jnp.max(jnp.abs(out - ref))) == 0.0
    assert s.fallbacks == 1 and s.failure_count == 1


def test_failure_counters_break_stats_trust_not_equality():
    """ExecStats equality compares geometry only — failure counters are
    excluded (a retried run still validates against the clean baseline)
    but failure_count drives refine's trusted-sample logic."""
    a, b = ExecStats(), ExecStats()
    a.retries, a.timeouts, a.fallbacks = 2, 1, 1
    assert a == b
    assert a.failure_count == 4 and b.failure_count == 0


def test_to_occupancy_arithmetic():
    s = ExecStats()
    with pytest.raises(ValueError, match="instrument"):
        s.to_occupancy()
    s.stage_times = [
        StageTime("compute", "seg[a..b]", 0.5, (0.2, 0.5)),
        StageTime("compute", "seg[c..c]", 0.3, (0.3, 0.1)),
        StageTime("sync", "bound@b", 0.05),
        StageTime("sync", "gather", 0.1),
    ]
    s.wall_s = 0.95
    occ = s.to_occupancy()
    assert isinstance(occ, MeasuredOccupancy)
    # per-device sums: dev0 = 0.5, dev1 = 0.6 -> straggler 0.6
    assert occ.dev_occupancy_s == pytest.approx(0.6)
    assert occ.link_occupancy_s == pytest.approx(0.15)
    assert occ.period_s == pytest.approx(0.6)
    assert occ.latency_s == pytest.approx(0.95)


def test_to_occupancy_error_names_mesh_executor():
    """The empty-stats message must tell the caller exactly which
    executor/flag combination produces measured stages."""
    with pytest.raises(ValueError, match=r'executor="mesh"'):
        ExecStats().to_occupancy()


# ---------------------------------------------------------------------------
# observability: stage spans, postmortems, disabled-tracing contract
# ---------------------------------------------------------------------------

def test_stage_spans_match_stage_times_one_to_one():
    """With a tracer installed, the control-track ``cat="stage"`` spans
    are the observability mirror of ``ExecStats.stage_times``: same
    count, same labels, same order, same kinds, same wall times."""
    from repro.obs import CONTROL_TRACK, STAGE_CAT, Tracer, set_tracer

    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    tr = Tracer()
    set_tracer(tr)
    try:
        _, s = run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                               instrument=True)
    finally:
        set_tracer(None)
    spans = tr.spans(cat=STAGE_CAT, track=CONTROL_TRACK)
    assert len(spans) == len(s.stage_times) > 0
    assert [sp["name"] for sp in spans] == \
        [st.label for st in s.stage_times]
    assert [sp["args"]["kind"] for sp in spans] == \
        [st.kind for st in s.stage_times]
    for sp, st in zip(spans, s.stage_times):
        assert sp["dur"] == pytest.approx(st.wall_s * 1e6)
    # per-device spans mirror the compute stages' completion tuples
    # (empty here: the 1-node path measures no per-shard times)
    n_dev_expected = sum(len(st.device_done_s) for st in s.stage_times)
    assert len(tr.spans(cat="device")) == n_dev_expected


def test_tracing_disabled_is_bit_identical():
    """The default (no tracer) and traced runs agree bit-exactly on
    outputs and on the ExecStats geometry contract — instrumentation
    must never perturb the numerics."""
    from repro.obs import Tracer, get_tracer, set_tracer

    assert get_tracer() is None        # tier-1 default: tracing off
    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    ref, s_ref = run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                                 instrument=True)
    set_tracer(Tracer())
    try:
        out, s = run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                                 instrument=True)
    finally:
        set_tracer(None)
    assert float(jnp.max(jnp.abs(out - ref))) == 0.0
    assert s == s_ref
    assert [st.label for st in s.stage_times] == \
        [st.label for st in s_ref.stage_times]


def test_watchdog_timeout_dumps_postmortem(tmp_path):
    """A tripped stage watchdog leaves a postmortem artifact carrying
    the failing stage's span context (kind/label/timeout) and the
    recent flight-ring events, including that stage's dispatch."""
    from repro.obs import get_flight, set_postmortem_dir
    from repro.runtime.mesh_exec import StageTimeoutError

    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    get_flight().clear()
    set_postmortem_dir(str(tmp_path))
    try:
        with pytest.raises(StageTimeoutError):
            run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                            stage_timeout_s=1e-4)
    finally:
        set_postmortem_dir(None)
    dumps = sorted(tmp_path.glob("postmortem-*-stage_timeout.json"))
    assert len(dumps) == 1
    doc = json.loads(dumps[0].read_text())
    assert doc["reason"] == "stage_timeout"
    ctx = doc["context"]
    assert ctx["timeout_s"] == pytest.approx(1e-4)
    assert ctx["kind"] in ("compute", "sync") and ctx["label"]
    # the ring shows the failing stage being dispatched, then timing out
    kinds = [(e["kind"], e.get("label")) for e in doc["events"]]
    assert ("stage_dispatch", ctx["label"]) in kinds
    assert ("stage_timeout", ctx["label"]) in kinds


def test_no_postmortem_dir_means_no_artifact(tmp_path, monkeypatch):
    """Without a configured directory the watchdog failure raises
    exactly as before — no artifact side effects anywhere."""
    from repro.obs import postmortem_dir
    from repro.runtime.mesh_exec import StageTimeoutError

    monkeypatch.delenv("REPRO_POSTMORTEM_DIR", raising=False)
    assert postmortem_dir() is None
    g, w, x = _model_io("mobilenet")
    plan = Plan([(Scheme.INH, Mode.T)] * len(g))
    with pytest.raises(StageTimeoutError):
        run_partitioned(g, w, x, plan, nodes=1, executor="mesh",
                        stage_timeout_s=1e-4)
    assert list(tmp_path.glob("postmortem-*")) == []


def test_validate_stage_decomposition_pure():
    from repro.cluster.simsched import Stage

    def sim(kind, label):
        return Stage(kind, (1.0,), (), label)

    stats = ExecStats()
    stats.stage_times = [
        StageTime("compute", "seg[a..b]", 0.1, (0.1,)),
        StageTime("sync", "bound@b", 0.01),
        StageTime("compute", "seg[c..d]", 0.2, (0.2,)),
        StageTime("sync", "reshard", 0.0),
        StageTime("sync", "gather", 0.02),
    ]
    stages = [sim("compute", "seg[a..b]"), sim("sync", "bound@b"),
              sim("compute", "seg[c..d]"), sim("sync", "gather")]
    v = validate_stage_decomposition(stats, stages)
    assert v["structure_match"] and not v["missing"] and not v["extra"]
    assert len(v["stages"]) == 4
    assert all(r["measured_s"] is not None for r in v["stages"])
    # a sim-only stage is missing; a measured-only stage is extra
    v2 = validate_stage_decomposition(
        stats, stages + [sim("sync", "fork->x")])
    assert not v2["structure_match"]
    assert v2["missing"] == [("sync", "fork->x")]
    # post-merge bound@ subsumed by the measured merge-> gather
    stats3 = ExecStats()
    stats3.stage_times = [StageTime("sync", "merge->m", 0.01),
                          StageTime("compute", "seg[m..m]", 0.1, (0.1,))]
    stages3 = [sim("sync", "merge->m"), sim("compute", "seg[m..m]"),
               sim("sync", "bound@m")]
    v3 = validate_stage_decomposition(stats3, stages3)
    assert v3["structure_match"]
    assert v3["subsumed"] == [("sync", "bound@m")]


def test_refine_accepts_measured_occupancy():
    """occupancy_fn replaces the simulator as the occupancy source: the
    fixed-point loop runs on measured numbers and report is None."""
    from repro.cluster import homogeneous, refine_with_simulator

    g = EDGE_MODELS["mobilenet"](**MODEL_TEST_KW["mobilenet"])
    cl = homogeneous(2, bandwidth_gbps=1.0)
    calls = []

    def occupancy_fn(plan):
        calls.append(plan)
        return MeasuredOccupancy(dev_occupancy_s=2e-3,
                                 link_occupancy_s=1e-3,
                                 period_s=2e-3, latency_s=3e-3)

    rr = refine_with_simulator(g, cl, max_iters=3,
                               occupancy_fn=occupancy_fn)
    assert calls and rr.report is None
    assert rr.throughput_rps == pytest.approx(500.0)
    assert all(s.dev_occupancy_s == pytest.approx(2e-3) for s in rr.steps)
    # constant measurements -> constant reweighting -> fixed point
    assert rr.converged


# ---------------------------------------------------------------------------
# subprocess: real 8-device mesh
# ---------------------------------------------------------------------------

_PRELUDE = """
    import numpy as np, jax, jax.numpy as jnp
    assert len(jax.devices()) == 8, jax.devices()
    from repro.configs.edge_models import EDGE_MODELS
    from repro.core import AnalyticEstimator, Testbed
    from repro.core.dpp import plan_search
    from repro.runtime.engine import init_weights
    from repro.runtime.session import ExecConfig, Session
    EST = AnalyticEstimator()
    KW = %r

    def run_partitioned(g, w, x, plan, nodes, **cfg):
        return Session(g, w, plan, nodes, ExecConfig(**cfg)).run(x)

    def model_io(name, seed=0):
        g = EDGE_MODELS[name](**KW[name])
        w = init_weights(g, jax.random.PRNGKey(seed))
        l0 = g.layers[0]
        x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (l0.in_h, l0.in_w, l0.in_c))
        return g, w, x

    def rel_err(a, b):
        return float(jnp.max(jnp.abs(a - b)) / jnp.maximum(
            1.0, jnp.max(jnp.abs(b))))
""" % (MODEL_TEST_KW,)


@pytest.mark.slow
@pytest.mark.parametrize("nodes", [2, 4, 8])
def test_mesh_equivalence_all_models(nodes):
    """Mesh vs single-process equivalence, searched plans, xla backend."""
    r = _run(_PRELUDE + f"""
    nodes = {nodes}
    for name in KW:
        g, w, x = model_io(name)
        plan = plan_search(g, EST,
                           Testbed(nodes=nodes, bandwidth_gbps=0.5)).plan
        ref, s_ref = run_partitioned(g, w, x, plan, nodes=nodes)
        out, s = run_partitioned(g, w, x, plan, nodes=nodes,
                                 executor='mesh')
        e = rel_err(out, ref)
        assert e < 1e-4, (name, e)
        assert s == s_ref, (name, s, s_ref)
        print('EQ_OK', name)
    print('ALL_EQ_OK')
    """)
    assert "ALL_EQ_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_mesh_equivalence_pallas():
    """The Pallas shard kernels run unchanged inside the per-device
    programs (the collective assembles the halo-extended slice the
    kernel consumes)."""
    r = _run(_PRELUDE + """
    for name in ('mobilenet', 'resnet18', 'bert'):
        g, w, x = model_io(name)
        plan = plan_search(g, EST,
                           Testbed(nodes=4, bandwidth_gbps=0.5)).plan
        ref, s_ref = run_partitioned(g, w, x, plan, nodes=4,
                                     backend='pallas')
        out, s = run_partitioned(g, w, x, plan, nodes=4,
                                 backend='pallas', executor='mesh')
        e = rel_err(out, ref)
        assert e < 1e-4, (name, e)
        assert s == s_ref, (name,)
        print('PALLAS_OK', name)
    print('ALL_PALLAS_OK')
    """)
    assert "ALL_PALLAS_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_mesh_stage_structure_matches_simulator():
    """instrument=True, overlap=False: the measured stage multiset equals
    simsched.build_stages 1:1 and every multi-node compute stage carries
    per-device completion times."""
    r = _run(_PRELUDE + """
    from repro.cluster import build_stages, homogeneous
    from repro.runtime.mesh_exec import validate_stage_decomposition
    cl = homogeneous(4, bandwidth_gbps=0.5)
    for name in KW:
        g, w, x = model_io(name)
        plan = plan_search(g, EST,
                           Testbed(nodes=4, bandwidth_gbps=0.5)).plan
        out, s = run_partitioned(g, w, x, plan, nodes=4, executor='mesh',
                                 instrument=True, overlap=False)
        v = validate_stage_decomposition(s, build_stages(g, plan, cl))
        assert v['structure_match'], (name, v['missing'], v['extra'])
        n_dev = [len(st.device_done_s) for st in s.stage_times
                 if st.kind == 'compute'
                 and len(st.device_done_s) > 0]
        assert n_dev and all(k == 4 for k in n_dev), (name, n_dev)
        print('STRUCT_OK', name)
    print('ALL_STRUCT_OK')
    """)
    assert "ALL_STRUCT_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_mesh_overlapped_halo_exchange():
    """Same-scheme boundaries take the double-buffered ppermute path:
    on a constant-resolution conv chain (every boundary is
    permute-eligible) overlap=True fuses all exchanges into the
    producing compute stages, overlap=False dispatches each as its own
    sync stage.  On mobilenet at test scale the deep tail shrinks to
    <1 row per node, so ineligible boundaries must *fall back* to the
    gather path and still match."""
    r = _run(_PRELUDE + """
    from repro.core.graph import ConvT, LayerSpec, ModelGraph, chain
    from repro.core.partition import Mode, Scheme
    from repro.core.plan import Plan
    # constant-resolution chain: 6x conv3x3 s1 p1 over 24x24 rows ->
    # 6 rows/node at 4 nodes, 1-2 halo rows per 2-layer segment
    convs = [LayerSpec(f'c{i}', ConvT.CONV, 24, 24, 8, 8, 3, 1, 1)
             for i in range(6)]
    g = chain('flatchain', convs)
    w = init_weights(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 24, 8))
    steps = [(Scheme.INH, Mode.T if i % 2 == 1 else Mode.NT)
             for i in range(len(g))]
    plan = Plan(steps)
    ref, s_ref = run_partitioned(g, w, x, plan, nodes=4)
    for overlap in (True, False):
        out, s = run_partitioned(g, w, x, plan, nodes=4, executor='mesh',
                                 instrument=True, overlap=overlap)
        e = rel_err(out, ref)
        assert e < 1e-4, (overlap, e)
        assert s == s_ref
        syncs = [st.label for st in s.stage_times if st.kind == 'sync']
        bounds = [l for l in syncs if l.startswith('bound@')]
        if overlap:
            # every exchange fused into the producing compute stage
            assert not bounds, syncs
        else:
            assert bounds == ['bound@c1', 'bound@c3'], syncs
    # mobilenet, T every 3rd layer: the high-res boundaries fuse, the
    # deep ineligible ones fall back to gather (labelled bound@) —
    # overlap=True must still strictly reduce the sync-stage count
    g, w, x = model_io('mobilenet')
    steps = [(Scheme.INH, Mode.T if (i % 3 == 2) else Mode.NT)
             for i in range(len(g))]
    steps[-1] = (Scheme.INH, Mode.T)
    plan = Plan(steps)
    ref, s_ref = run_partitioned(g, w, x, plan, nodes=4)
    n_bounds = {}
    for overlap in (True, False):
        out, s = run_partitioned(g, w, x, plan, nodes=4, executor='mesh',
                                 instrument=True, overlap=overlap)
        assert rel_err(out, ref) < 1e-4
        assert s == s_ref
        n_bounds[overlap] = sum(
            1 for st in s.stage_times
            if st.kind == 'sync' and st.label.startswith('bound@'))
    assert n_bounds[True] < n_bounds[False], n_bounds
    print('OVERLAP_OK')
    """)
    assert "OVERLAP_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_refine_on_measured_mesh_occupancy():
    """Close the planner loop against the machine: refine re-selects on
    occupancy measured by warm instrumented mesh runs."""
    r = _run(_PRELUDE + """
    from repro.cluster import homogeneous, refine_with_simulator
    g, w, x = model_io('mobilenet')
    cl = homogeneous(2, bandwidth_gbps=1.0)

    def occupancy_fn(plan):
        run = lambda: run_partitioned(g, w, x, plan, nodes=2,
                                      executor='mesh', instrument=True)
        run()                       # warm-up: compile
        _, s = run()
        return s.to_occupancy()

    rr = refine_with_simulator(g, cl, max_iters=2,
                               occupancy_fn=occupancy_fn)
    assert rr.report is None
    assert rr.steps and rr.throughput_rps > 0.0
    assert all(s.dev_occupancy_s > 0.0 for s in rr.steps)
    print('REFINE_MEASURED_OK')
    """)
    assert "REFINE_MEASURED_OK" in r.stdout, r.stdout + r.stderr
