"""Unit + property tests for partition geometry (core/partition.py)."""

import pytest

pytest.importorskip("hypothesis")  # property tests only; see pyproject [dev]
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.graph import ConvT, LayerSpec, halo_growth
from repro.core.partition import (ALL_SCHEMES, Scheme, grid_dims,
                                  min_shard_extent, shard_work, split_sizes)


def test_split_sizes_balanced():
    assert split_sizes(14, 4) == [4, 4, 3, 3]
    assert split_sizes(512, 4) == [128] * 4
    assert sum(split_sizes(17, 5)) == 17


def test_grid_dims():
    assert grid_dims(4) == (2, 2)
    assert grid_dims(9) == (3, 3)
    gh, gw = grid_dims(3)
    assert gh * gw >= 3


@given(total=st.integers(1, 500), parts=st.integers(1, 8))
def test_split_sizes_props(total, parts):
    s = split_sizes(total, parts)
    assert sum(s) == total and len(s) == parts
    assert max(s) - min(s) <= 1    # balanced


def _layer(h=28, c=64, k=3, s=1, t=ConvT.CONV):
    return LayerSpec("l", t, h, h, c, c, k, s, k // 2)


@given(h=st.sampled_from([7, 14, 28, 56]),
       nodes=st.integers(2, 6),
       scheme=st.sampled_from(list(ALL_SCHEMES)))
@settings(max_examples=60, deadline=None)
def test_shard_work_covers_layer(h, nodes, scheme):
    l = _layer(h=h)
    w = shard_work(l, scheme, nodes)
    assert len(w.flops_per_node) == nodes
    # without halo, shard flops sum to the full layer's flops
    assert sum(w.flops_per_node) == pytest.approx(l.flops(), rel=1e-6)
    assert w.straggler_flops >= l.flops() / nodes - 1e-6


def test_halo_monotone_in_extra():
    l = _layer()
    base = shard_work(l, Scheme.INH, 4).straggler_flops
    prev = base
    for h in range(1, 5):
        cur = shard_work(l, Scheme.INH, 4, extra_halo=h).straggler_flops
        assert cur >= prev
        prev = cur


def test_outc_rejects_halo():
    with pytest.raises(ValueError):
        shard_work(_layer(), Scheme.OUTC, 4, extra_halo=1)


def test_halo_growth_receptive_field():
    # two 3x3 stride-1 convs: fusing the 2nd needs 2 extra rows at the 1st
    ls = [_layer(k=3), _layer(k=3), _layer(k=3)]
    h = halo_growth(ls, 2)
    assert h == [4, 2, 0]
    # pointwise layers grow no halo
    ls2 = [_layer(k=3), _layer(k=1, t=ConvT.POINTWISE)]
    assert halo_growth(ls2, 1) == [0, 0]
    # stride amplifies downstream needs
    ls3 = [_layer(k=3), LayerSpec("s2", ConvT.CONV, 28, 28, 64, 64, 3, 2, 1),
           _layer(h=14, k=3)]
    h3 = halo_growth(ls3, 2)
    assert h3[0] == 2 * 2 + 2 and h3[1] == 2 and h3[2] == 0


def test_min_shard_extent():
    l = _layer(h=14)
    assert min_shard_extent(l, Scheme.INH, 4) == 3   # 14 -> [4,4,3,3]
    assert min_shard_extent(l, Scheme.OUTC, 4) == 1
