"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Two modes:
  * default        — run real optimizer steps (reduced or full config) on
                     the local devices with the production sharding rules;
  * ``--dry-run``  — lower + compile the production-mesh train step only
                     (delegates to launch.dryrun; no execution).

On this CPU container only reduced configs run in real mode; the full
configs are exercised through the dry-run path (the same code a TPU pod
would execute).
"""
import argparse
import sys
import time

from repro.obs.log import log


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full config (default: reduced smoke variant)")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun
        return dryrun.main(["--arch", args.arch, "--shape", args.shape])

    import jax
    import jax.numpy as jnp
    from repro.checkpoint import save_pytree
    from repro.configs.registry import get_config
    from repro.data import SyntheticLMDataset
    from repro.models.transformer import Model
    from repro.optim import adamw_init
    from repro.runtime.steps import make_train_step

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n = sum(x.size for x in jax.tree.leaves(params))
    log("train.start", arch=cfg.name, params_m=n / 1e6,
        devices=len(jax.devices()))

    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq,
                            global_batch=args.batch, seed=0)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, total=args.steps,
                                   warmup=max(1, args.steps // 10),
                                   accum=args.accum))
    t0 = time.time()
    for i, batch in zip(range(args.steps), ds):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        if cfg.family == "encdec":
            batch["audio_embeds"] = jnp.zeros(
                (args.batch, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        params, opt, m = step(params, opt, batch)
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            log("train.step", step=i, loss=float(m["loss"]),
                elapsed_s=time.time() - t0)
    if args.ckpt:
        save_pytree(params, args.ckpt)
        log("train.checkpoint", path=args.ckpt)
    return 0


if __name__ == "__main__":
    sys.exit(main())
