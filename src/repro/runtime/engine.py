"""Distributed edge-inference engine: executes a FlexPie Plan on real
tensors, node by node, and verifies exact reassembly.

Each simulated edge node computes only from data it actually holds: the
engine backward-chains the receptive field from the node's exact output
shard at the segment end (T layer) through every NT-fused layer, slices
that input region once at the segment entry (counting the bytes the node
did not own — the measured communication), then runs the whole segment
locally.  This exercises the paper's core mechanics end to end: halo
growth, redundant computation, scheme-dependent re-layout.

Branched graphs execute branch by branch (``ModelGraph.linearize()``):
every branch is a chain run through the same segment machinery, fork
outputs are read by each consuming branch, and merge layers (ADD/CONCAT)
reassemble their incoming branch shards at a forced sync point before the
next branch continues.

Correctness contract (tested): for ANY valid plan — chain or DAG — the
reassembled output is identical to the unpartitioned reference inference.

Backends: ``run_partitioned(..., backend="pallas")`` dispatches every
NT-fused segment layer to the Pallas shard kernels (``repro.kernels``) —
conv/depthwise/pointwise shards consume their halo-extended local slice
directly (zero padding applied in VMEM, no re-materialized padded copy per
segment layer) and FC layers run the row-tiled MXU matmul.  Geometries the
kernels cannot lower (POOL, degenerate shard outputs) fall back to the XLA
path per layer record automatically; ``backend="xla"`` (default) is the
historical ``lax.conv_general_dilated`` lowering.  The backend is part of
the compiled-segment cache key, so both backends stay jit-cached side by
side.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import ConvT, LayerSpec, ModelGraph
from repro.core.partition import (DTYPE_BYTES, Mode, Scheme, grid_dims,
                                  split_sizes)
from repro.core.plan import Plan, steps_segments
from repro.kernels.conv2d import UnsupportedGeometry, conv2d_shard
from repro.kernels.ops import matmul_tiled

Rect = Tuple[Tuple[int, int], Tuple[int, int], Tuple[int, int]]

BACKENDS = ("xla", "pallas")
EXECUTORS = ("local", "mesh")


def _pallas_interpret() -> bool:
    """Interpret-mode Pallas everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Reference (unpartitioned) inference
# ---------------------------------------------------------------------------

def init_weights(graph: ModelGraph, key) -> List[Optional[jnp.ndarray]]:
    ws: List[Optional[jnp.ndarray]] = []
    for l in graph.layers:
        if l.conv_t in (ConvT.CONV, ConvT.POINTWISE):
            key, k = jax.random.split(key)
            ws.append(jax.random.normal(k, (l.k, l.k, l.in_c, l.out_c),
                                        jnp.float32)
                      / np.sqrt(l.k * l.k * l.in_c))
        elif l.conv_t == ConvT.DWCONV:
            key, k = jax.random.split(key)
            ws.append(jax.random.normal(k, (l.k, l.k, 1, l.in_c), jnp.float32)
                      / np.sqrt(l.k * l.k))
        elif l.conv_t == ConvT.FC:
            key, k = jax.random.split(key)
            ws.append(jax.random.normal(k, (l.in_c, l.out_c), jnp.float32)
                      / np.sqrt(l.in_c))
        else:
            ws.append(None)
    return ws


def apply_layer(l: LayerSpec, w, x: jnp.ndarray) -> jnp.ndarray:
    """Full-tensor layer application. x: [H, W, C] (FC: [seq, 1, C])."""
    out = _conv_region(l, w, x, pads=((l.p, l.p), (l.p, l.p)))
    return out


def _conv_region(l: LayerSpec, w, x: jnp.ndarray, pads) -> jnp.ndarray:
    return _conv_region_p(l.conv_t, l.k, l.s, w, x, pads)


def _conv_region_p(conv_t: ConvT, k: int, s: int, w, x: jnp.ndarray,
                   pads) -> jnp.ndarray:
    """Parameter form of :func:`_conv_region` — shared with the jitted
    segment programs, whose cache keys are name-blind geometry tuples."""
    if conv_t in (ConvT.CONV, ConvT.POINTWISE):
        return jax.lax.conv_general_dilated(
            x[None], w, (s, s), list(pads),
            dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    if conv_t == ConvT.DWCONV:
        return jax.lax.conv_general_dilated(
            x[None], w, (s, s), list(pads),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=x.shape[-1])[0]
    if conv_t == ConvT.POOL:
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (k, k, 1), (s, s, 1),
            [tuple(pads[0]), tuple(pads[1]), (0, 0)])
    if conv_t == ConvT.FC:
        return (x.reshape(x.shape[0], x.shape[-1]) @ w).reshape(
            x.shape[0], 1, -1)
    if conv_t in (ConvT.ADD, ConvT.CONCAT):
        return x   # single-input (chain-compat) merge is the identity
    raise ValueError(conv_t)


def merge_tensors(l: LayerSpec, inputs: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Combine the producer tensors of a multi-input merge layer."""
    if len(inputs) == 1:
        return inputs[0]
    if l.conv_t == ConvT.ADD:
        out = inputs[0]
        for t in inputs[1:]:
            out = out + t
        return out
    if l.conv_t == ConvT.CONCAT:
        return jnp.concatenate(list(inputs), axis=-1)
    raise ValueError(f"{l.name}: only ADD/CONCAT layers can merge")


def run_reference(graph: ModelGraph, weights, x: jnp.ndarray) -> jnp.ndarray:
    if graph.is_chain:
        for l, w in zip(graph.layers, weights):
            x = apply_layer(l, w, x)
        return x
    outs: Dict[int, jnp.ndarray] = {-1: x}
    for i, (l, w) in enumerate(zip(graph.layers, weights)):
        prods = graph.producer_ids[i]
        if len(prods) >= 2:
            outs[i] = merge_tensors(l, [outs[p] for p in prods])
        else:
            outs[i] = apply_layer(l, w, outs[prods[0]])
    return outs[len(graph) - 1]


# ---------------------------------------------------------------------------
# Shard geometry
# ---------------------------------------------------------------------------

def _ranges(total: int, parts: int) -> List[Tuple[int, int]]:
    sizes = split_sizes(total, parts)
    out, a = [], 0
    for s in sizes:
        out.append((a, a + s))
        a += s
    return out


def exact_regions(l: LayerSpec, scheme: Scheme,
                  nodes: int) -> List[List[Rect]]:
    """Per-node exact (halo-free) output cells of layer ``l``.  One cell per
    node for the 1-D schemes; round-robin cell assignment for 2D-grid on
    non-square node counts (the paper's 3-node imbalance case)."""
    oh, ow, oc = l.out_h, l.out_w, l.out_c
    if scheme == Scheme.INH:
        return [[((r0, r1), (0, ow), (0, oc))]
                for r0, r1 in _ranges(oh, nodes)]
    if scheme == Scheme.INW:
        return [[((0, oh), (c0, c1), (0, oc))]
                for c0, c1 in _ranges(ow, nodes)]
    if scheme == Scheme.OUTC:
        return [[((0, oh), (0, ow), (k0, k1))]
                for k0, k1 in _ranges(oc, nodes)]
    if scheme == Scheme.GRID2D:
        gh, gw = grid_dims(nodes)
        cells = [((r0, r1), (c0, c1), (0, oc))
                 for r0, r1 in _ranges(oh, gh) for c0, c1 in _ranges(ow, gw)]
        per_node: List[List[Rect]] = [[] for _ in range(nodes)]
        for i, cell in enumerate(cells):
            per_node[i % nodes].append(cell)
        return per_node
    raise ValueError(scheme)


def in_rows(l: LayerSpec, out_r: Tuple[int, int], dim: int
            ) -> Tuple[int, int]:
    """Unclipped input range needed for an output range along H (dim=0,
    bound l.in_h) or W (dim=1, bound l.in_w).  FC/ADD/CONCAT are 1:1."""
    if l.conv_t in (ConvT.FC, ConvT.ADD, ConvT.CONCAT):
        return out_r
    r0 = out_r[0] * l.s - l.p
    r1 = (out_r[1] - 1) * l.s - l.p + l.k
    return (r0, r1)


def _clip(r: Tuple[int, int], bound: int) -> Tuple[int, int]:
    return (max(0, r[0]), min(bound, r[1]))


# ---------------------------------------------------------------------------
# Plan execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageTime:
    """Measured wall time of one dispatched pipeline stage (mesh executor,
    ``instrument=True``).  ``device_done_s`` holds per-device completion
    offsets of a compute stage's output shards, measured by blocking on
    the shards in mesh order — on shared-core host platforms the values
    are an upper envelope (a shard that finished before an earlier shard
    in the blocking order reports that earlier shard's completion time)."""

    kind: str                            # "compute" | "sync"
    label: str                           # simsched stage label convention
    wall_s: float
    device_done_s: Tuple[float, ...] = ()


@dataclasses.dataclass(frozen=True)
class MeasuredOccupancy:
    """Per-request resource-class occupancy measured from a real run —
    the drop-in counterpart of the simulator occupancy that
    ``cluster.refine`` extracts from a :class:`~repro.cluster.simsched.
    SimReport` (``occupancy_fn`` protocol)."""

    dev_occupancy_s: float     # max over devices of summed compute time
    link_occupancy_s: float    # summed sync-stage wall time
    period_s: float            # pipelined steady-state period estimate
    latency_s: float           # single-request wall time
    #: dispatch failures behind the measurement (retries + timeouts +
    #: degraded fallbacks) — ``cluster.refine`` treats any nonzero value
    #: as an untrusted sample and keeps its previous axis weights
    failures: int = 0


@dataclasses.dataclass
class ExecStats:
    sync_points: int = 0
    bytes_received: float = 0.0      # across all nodes/boundaries (fp32)
    redundant_elems: float = 0.0     # halo outputs computed more than once
    #: executed T-terminated segments — the plan's compute-stage count,
    #: matching ``plan.plan_stage_counts`` and the simulator's stage DAG
    #: (pipeline metadata: serving reads it to align engine runs with
    #: ``cluster.simsched`` schedules)
    compute_stages: int = 0
    #: measured pipeline stages (mesh executor with ``instrument=True``).
    #: Excluded from equality: geometry accounting is executor- and
    #: backend-independent by contract, wall times never are.
    stage_times: List[StageTime] = dataclasses.field(
        default_factory=list, compare=False, repr=False)
    #: end-to-end wall seconds of the run (mesh executor only)
    wall_s: float = dataclasses.field(default=0.0, compare=False)
    #: stage dispatches re-attempted after a failure (mesh executor with
    #: ``stage_retries > 0``).  Excluded from equality with the same
    #: rationale as wall times: failure incidence is environmental, the
    #: geometry accounting above is the executor contract.
    retries: int = dataclasses.field(default=0, compare=False)
    #: stage dispatches that exceeded ``stage_timeout_s``
    timeouts: int = dataclasses.field(default=0, compare=False)
    #: runs completed by the degraded single-process fallback
    fallbacks: int = dataclasses.field(default=0, compare=False)

    @property
    def failure_count(self) -> int:
        """Total faults observed while producing this run's numbers."""
        return self.retries + self.timeouts + self.fallbacks

    def to_occupancy(self) -> MeasuredOccupancy:
        """Fold the measured stage times into per-resource-class occupancy
        for ``cluster.refine`` (replacing sim-only occupancy when real
        measurements exist).  Device occupancy is the straggler device's
        summed compute time; link occupancy sums the sync-stage walls; the
        period is the busier class (the ``PipelineCost`` bottleneck
        semantics applied to measurements)."""
        if not self.stage_times:
            raise ValueError(
                "no measured stages — run with "
                'run_partitioned(..., executor="mesh", instrument=True) '
                "(only the mesh executor measures stage times)")
        per_dev: Dict[int, float] = {}
        sync = 0.0
        for st in self.stage_times:
            if st.kind == "compute":
                if st.device_done_s:
                    for d, t in enumerate(st.device_done_s):
                        per_dev[d] = per_dev.get(d, 0.0) + t
                else:
                    per_dev[0] = per_dev.get(0, 0.0) + st.wall_s
            else:
                sync += st.wall_s
        dev = max(per_dev.values()) if per_dev else 0.0
        return MeasuredOccupancy(
            dev_occupancy_s=dev, link_occupancy_s=sync,
            period_s=max(dev, sync), latency_s=self.wall_s,
            failures=self.failure_count)


def _rect_elems(r: Rect) -> int:
    return max(0, r[0][1] - r[0][0]) * max(0, r[1][1] - r[1][0]) \
        * max(0, r[2][1] - r[2][0])


def _rect_isect(a: Rect, b: Rect) -> Rect:
    return tuple((max(x[0], y[0]), min(x[1], y[1]))
                 for x, y in zip(a, b))  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# Compiled shard segment programs.
#
# One jitted program per *name-blind segment signature*: the per-layer conv
# parameters plus the static pad/slice/channel arithmetic of this cell's
# backward-chained regions.  Identical cells — every interior node of a
# balanced split, and every repetition of a ResNet bottleneck across blocks
# and planner sweeps — share one compiled executable; weights and the input
# tensor are traced arguments, so reuse survives weight changes.
# ---------------------------------------------------------------------------

def backward_chain(layers: Sequence[LayerSpec], a: int, b: int,
                   reg_b: Rect) -> Tuple[Dict[int, Rect], Rect]:
    """Backward-chain the receptive field of output region ``reg_b`` of
    layer ``b`` through segment ``[a..b]``: the per-layer needed output
    regions (clipped to each layer's bounds) and the clipped input rect at
    the segment entry.  Shared by the local executor, which slices the
    rect from the host-resident full tensor, and the mesh executor, which
    assembles it from collectives."""
    need: Dict[int, Rect] = {b: reg_b}
    rows, cols = reg_b[0], reg_b[1]
    for li in range(b, a, -1):
        rows = _clip(in_rows(layers[li], rows, 0), layers[li].in_h)
        cols = _clip(in_rows(layers[li], cols, 1), layers[li].in_w)
        need[li - 1] = (rows, cols, (0, layers[li - 1].out_c))
    l_in = layers[a]
    in_r = _clip(in_rows(l_in, need[a][0], 0), l_in.in_h)
    in_c = _clip(in_rows(l_in, need[a][1], 1), l_in.in_w)
    return need, (in_r, in_c, (0, l_in.in_c))


#: per-layer static record: (conv_t, k, s, pads(pt,pb,pl,pr) | None,
#: slices(r0,r1,c0,c1) | None, chans(c0,c1))
_SegRec = Tuple[int, int, int, Optional[Tuple[int, int, int, int]],
                Optional[Tuple[int, int, int, int]], Tuple[int, int]]


def _segment_records(layers: Sequence[LayerSpec], a: int, b: int,
                     need: Dict[int, Rect],
                     in_rect: Rect) -> Tuple[_SegRec, ...]:
    """Resolve the cell's per-layer slice/pad arithmetic into a static
    signature (the jit cache key; also the full program spec)."""
    recs: List[_SegRec] = []
    origin = (in_rect[0][0], in_rect[1][0])
    extent = (in_rect[0][1] - in_rect[0][0], in_rect[1][1] - in_rect[1][0])
    for li in range(a, b + 1):
        l = layers[li]
        rows, cols, chans = need[li]
        if l.conv_t in (ConvT.FC, ConvT.ADD, ConvT.CONCAT):
            recs.append((int(l.conv_t), l.k, l.s, None, None, chans))
        else:
            nr = in_rows(l, rows, 0)
            nc = in_rows(l, cols, 1)
            pads = (max(0, -nr[0]), max(0, nr[1] - l.in_h),
                    max(0, -nc[0]), max(0, nc[1] - l.in_w))
            sl = (max(0, nr[0]) - origin[0], min(l.in_h, nr[1]) - origin[0],
                  max(0, nc[0]) - origin[1], min(l.in_w, nc[1]) - origin[1])
            assert sl[0] >= 0 and sl[2] >= 0 \
                and sl[1] <= extent[0] and sl[3] <= extent[1], (
                    "local slice does not cover the needed region", l.name)
            recs.append((int(l.conv_t), l.k, l.s, pads, sl, chans))
        origin = (rows[0], cols[0])
        extent = (rows[1] - rows[0], cols[1] - cols[0])
    return tuple(recs)


def _apply_record(rec: _SegRec, w, x: jnp.ndarray) -> jnp.ndarray:
    """One layer of a compiled segment program (static-geometry
    counterpart of :func:`_apply_local`)."""
    conv_t, k, s, pads, sl, chans = rec
    conv_t = ConvT(conv_t)
    if conv_t == ConvT.FC:
        seg = x.reshape(x.shape[0], x.shape[-1])
        return (seg @ w[:, chans[0]:chans[1]]).reshape(
            x.shape[0], 1, chans[1] - chans[0])
    if conv_t in (ConvT.ADD, ConvT.CONCAT):
        return x[:, :, chans[0]:chans[1]]
    pt, pb, pl_, pr = pads
    r0, r1, c0, c1 = sl
    xs = x[r0:r1, c0:c1, :]
    if conv_t in (ConvT.CONV, ConvT.POINTWISE):
        wsel = w[:, :, :, chans[0]:chans[1]]
        return _conv_region_p(conv_t, k, s, wsel, xs, ((pt, pb), (pl_, pr)))
    out = _conv_region_p(conv_t, k, s, w, xs, ((pt, pb), (pl_, pr)))
    return out[:, :, chans[0]:chans[1]]


def _apply_record_pallas(rec: _SegRec, w, x: jnp.ndarray) -> jnp.ndarray:
    """Pallas lowering of one segment-layer record: the local slice (halo
    rows included) goes to the shard kernel as-is with its per-side zero
    pads.  Raises :class:`UnsupportedGeometry` for records the kernels
    cannot lower (POOL, degenerate shard outputs) — the caller falls back
    to the XLA record path."""
    conv_t, k, s, pads, sl, chans = rec
    conv_t = ConvT(conv_t)
    interp = _pallas_interpret()
    if conv_t == ConvT.FC:
        seg = x.reshape(x.shape[0], x.shape[-1])
        out = matmul_tiled(seg, w[:, chans[0]:chans[1]], interpret=interp)
        return out.reshape(x.shape[0], 1, chans[1] - chans[0])
    if conv_t in (ConvT.ADD, ConvT.CONCAT):
        return x[:, :, chans[0]:chans[1]]
    if conv_t not in (ConvT.CONV, ConvT.POINTWISE, ConvT.DWCONV):
        raise UnsupportedGeometry(f"no pallas kernel for {conv_t.name}")
    pt, pb, pl_, pr = pads
    r0, r1, c0, c1 = sl
    xs = x[r0:r1, c0:c1, :]
    if conv_t == ConvT.DWCONV:
        out = conv2d_shard(xs, w, pads=(pt, pb, pl_, pr), stride=s,
                           depthwise=True, interpret=interp)
        return out[:, :, chans[0]:chans[1]]
    wsel = w[:, :, :, chans[0]:chans[1]]
    return conv2d_shard(xs, wsel, pads=(pt, pb, pl_, pr), stride=s,
                        interpret=interp)


def _apply_record_b(rec: _SegRec, w, x: jnp.ndarray,
                    backend: str) -> jnp.ndarray:
    """Backend dispatch for one record.  Geometry support is static (shapes
    are known at trace time), so the pallas->xla fallback resolves during
    tracing and costs nothing at run time."""
    if backend == "pallas":
        try:
            return _apply_record_pallas(rec, w, x)
        except UnsupportedGeometry:
            pass
    return _apply_record(rec, w, x)


@functools.lru_cache(maxsize=None)
def _compiled_segment(recs: Tuple[_SegRec, ...], backend: str = "xla"):
    """Jitted program for one (segment-cell signature, backend) pair.
    ``jax.jit`` adds its own shape/dtype guard under this entry, so one
    signature serves every input that shares the geometry."""
    def run(x, ws):
        for rec, w in zip(recs, ws):
            x = _apply_record_b(rec, w, x, backend)
        return x
    return jax.jit(run)


def segment_cache_info():
    """(hits, misses, ...) of the compiled-segment cache — repeated blocks
    and repeated `run_partitioned` calls should mostly hit."""
    return _compiled_segment.cache_info()


def clear_segment_cache() -> None:
    _compiled_segment.cache_clear()


def _run_branch(layers: Sequence[LayerSpec],
                weights: Sequence,
                steps: Sequence[Tuple[Scheme, Mode]],
                x: jnp.ndarray,
                owned: Optional[List[List[Rect]]],
                nodes: int,
                stats: ExecStats,
                jit_segments: bool = True,
                backend: str = "xla"
                ) -> Tuple[jnp.ndarray, List[List[Rect]]]:
    """Execute one chain of layers segment by segment.  ``x`` is the full
    input tensor at the branch entry; ``owned`` is the per-node layout it is
    distributed in (None = initial input, no comm accounting).  Returns the
    full output and its per-node layout at the final T boundary."""
    full = x
    for (a, b) in steps_segments(steps):
        scheme = steps[a][0]
        regs_b = exact_regions(layers[b], scheme, nodes)
        cell_out: List[Tuple[Rect, jnp.ndarray]] = []
        computed = 0
        for n, cells in enumerate(regs_b):
            for reg_b in cells:
                # backward-chain the needed region through the segment
                need, in_rect = backward_chain(layers, a, b, reg_b)
                (in_r, in_c, _) = in_rect
                # communication accounting: elems this node did not hold
                if owned is not None:
                    held = sum(_rect_elems(_rect_isect(in_rect, o))
                               for o in owned[n])
                    stats.bytes_received += DTYPE_BYTES * (
                        _rect_elems(in_rect) - held)
                node_x = full[in_r[0]:in_r[1], in_c[0]:in_c[1], :]
                for li in range(a, b):
                    computed += _rect_elems(need[li])
                if jit_segments:
                    recs = _segment_records(layers, a, b, need, in_rect)
                    node_x = _compiled_segment(recs, backend)(
                        node_x, tuple(weights[a:b + 1]))
                elif backend != "xla":
                    # eager non-XLA path: same per-record dispatch, no jit
                    recs = _segment_records(layers, a, b, need, in_rect)
                    for rec, w in zip(recs, weights[a:b + 1]):
                        node_x = _apply_record_b(rec, w, node_x, backend)
                else:
                    origin = (in_r[0], in_c[0])
                    for li in range(a, b + 1):
                        l = layers[li]
                        node_x = _apply_local(l, weights[li], node_x,
                                              origin, need[li])
                        origin = (need[li][0][0], need[li][1][0])
                cell_out.append((reg_b, node_x))
        # T boundary: reassemble ("synchronize")
        lb = layers[b]
        rebuilt = jnp.zeros((lb.out_h, lb.out_w, lb.out_c), full.dtype)
        for (r, c, ch), shard in cell_out:
            rebuilt = rebuilt.at[r[0]:r[1], c[0]:c[1],
                                 ch[0]:ch[1]].set(shard)
        stats.sync_points += 1
        stats.redundant_elems += float(computed)
        stats.compute_stages += 1
        owned = regs_b
        full = rebuilt
    assert owned is not None, "branch must contain at least one segment"
    return full, owned


def _merge_comm_bytes(l: LayerSpec, prods: Sequence[int],
                      prod_channels: Sequence[int],
                      owned_map: Dict[int, Optional[List[List[Rect]]]],
                      regs: List[List[Rect]]) -> float:
    """Bytes every node must receive to assemble its merge-output regions
    from the producers' shard layouts.  CONCAT maps output-channel windows
    back into each producer's channel range (``prod_channels`` includes the
    graph input's channels, keeping later windows aligned); ADD needs the
    same region of every input."""
    offsets: List[int] = []
    off = 0
    for c in prod_channels:
        offsets.append(off)
        off += c if l.conv_t == ConvT.CONCAT else 0
    total = 0.0
    for n, cells in enumerate(regs):
        for (rows, cols, chans) in cells:
            for j, pid in enumerate(prods):
                if l.conv_t == ConvT.CONCAT:
                    c0 = max(chans[0] - offsets[j], 0)
                    c1 = min(chans[1] - offsets[j], prod_channels[j])
                    if c1 <= c0:
                        continue
                    need: Rect = (rows, cols, (c0, c1))
                else:
                    need = (rows, cols, chans)
                owned = owned_map.get(pid)
                if owned is None:
                    continue   # graph input: pre-distributed, not counted
                held = sum(_rect_elems(_rect_isect(need, o))
                           for o in owned[n])
                total += DTYPE_BYTES * (_rect_elems(need) - held)
    return total


def run_partitioned(graph: ModelGraph, weights, x: jnp.ndarray, plan: Plan,
                    nodes: int,
                    jit_segments: bool = True,
                    backend: str = "xla",
                    executor: str = "local",
                    mesh=None,
                    instrument: bool = False,
                    overlap: bool = True,
                    stage_timeout_s: Optional[float] = None,
                    stage_retries: int = 0,
                    fallback: str = "raise"
                    ) -> Tuple[jnp.ndarray, ExecStats]:
    """Deprecated kwarg-sprawl entry point — use
    :class:`repro.runtime.session.Session` with
    :class:`repro.runtime.session.ExecConfig`.

    Equivalent to ``Session(graph, weights, plan, nodes,
    ExecConfig(backend=..., executor=..., ...), mesh=mesh).run(x)``;
    kept as a thin shim so existing callers keep working, at the cost of
    rebuilding the Session (and, for the mesh executor, re-deriving the
    mesh) on every call."""
    import warnings
    warnings.warn(
        "run_partitioned is deprecated; build a repro.runtime.session."
        "Session with an ExecConfig and call session.run(x)",
        DeprecationWarning, stacklevel=2)
    from repro.runtime.session import ExecConfig, Session
    cfg = ExecConfig(backend=backend, executor=executor,
                     jit_segments=jit_segments, instrument=instrument,
                     overlap=overlap, stage_timeout_s=stage_timeout_s,
                     stage_retries=stage_retries, fallback=fallback)
    return Session(graph, weights, plan, nodes, cfg, mesh=mesh).run(x)


def _run_partitioned_local(graph: ModelGraph, weights, x: jnp.ndarray,
                           plan: Plan, nodes: int,
                           jit_segments: bool = True,
                           backend: str = "xla"
                           ) -> Tuple[jnp.ndarray, ExecStats]:
    """Execute ``plan`` on ``nodes`` simulated devices in-process (the
    ``executor="local"`` path behind :class:`~repro.runtime.session.
    Session`).  ``jit_segments`` routes each segment cell through the
    compiled-program cache (repeated blocks compile once and reuse across
    calls); ``False`` keeps the historical eager path.  ``backend``
    selects the segment-layer lowering: ``"xla"`` (generic
    ``conv_general_dilated``) or ``"pallas"`` (shard kernels with
    automatic per-record XLA fallback); stats accounting is
    backend-independent by construction."""
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r} not in {BACKENDS}")
    stats = ExecStats()
    if graph.is_chain:
        plan.validate()
        if len(plan) != len(graph):
            raise ValueError("plan/graph length mismatch")
        full, _ = _run_branch(graph.layers, weights, plan.steps, x, None,
                              nodes, stats, jit_segments, backend)
        return full, stats

    plan.validate_for(graph)
    layers = graph.layers
    outs: Dict[int, jnp.ndarray] = {-1: x}
    owned_map: Dict[int, Optional[List[List[Rect]]]] = {-1: None}
    for br in graph.linearize():
        ids = list(br.ids)
        head = ids[0]
        prods = graph.producer_ids[head]
        if len(prods) >= 2:
            l_m = layers[head]
            q = plan.steps[head][0]
            merged = merge_tensors(l_m, [outs[p] for p in prods])
            regs = exact_regions(l_m, q, nodes)
            stats.sync_points += 1
            # the merge layer's T-singleton segment executes inside
            # merge_tensors — still one compute stage of the pipeline
            stats.compute_stages += 1
            stats.bytes_received += _merge_comm_bytes(
                l_m, prods,
                [layers[p].out_c if p >= 0 else layers[0].in_c
                 for p in prods],
                owned_map, regs)
            cur, owned = merged, regs
            rest = ids[1:]
        else:
            src = prods[0]
            cur, owned = outs[src], owned_map[src]
            rest = ids
        if rest:
            ls = [layers[i] for i in rest]
            ws = [weights[i] for i in rest]
            st = [plan.steps[i] for i in rest]
            cur, owned = _run_branch(ls, ws, st, cur, owned, nodes, stats,
                                     jit_segments, backend)
        outs[ids[-1]] = cur
        owned_map[ids[-1]] = owned
    return outs[len(graph) - 1], stats


def _apply_local(l: LayerSpec, w, x_local: jnp.ndarray,
                 origin: Tuple[int, int], out_rect: Rect) -> jnp.ndarray:
    """Compute ``out_rect`` of layer ``l`` from a local input slice whose
    [0,0] corresponds to absolute input coords ``origin``."""
    rows, cols, chans = out_rect
    if l.conv_t == ConvT.FC:
        seg = x_local.reshape(x_local.shape[0], x_local.shape[-1])
        # local rows already correspond to rows (1:1 chain)
        return (seg @ w[:, chans[0]:chans[1]]).reshape(
            x_local.shape[0], 1, chans[1] - chans[0])
    if l.conv_t in (ConvT.ADD, ConvT.CONCAT):
        return x_local[:, :, chans[0]:chans[1]]
    # needed (unclipped) input range for this output region
    nr = in_rows(l, rows, 0)
    nc = in_rows(l, cols, 1)
    pt = max(0, -nr[0])
    pb = max(0, nr[1] - l.in_h)
    pl_ = max(0, -nc[0])
    pr = max(0, nc[1] - l.in_w)
    r0 = max(0, nr[0]) - origin[0]
    r1 = min(l.in_h, nr[1]) - origin[0]
    c0 = max(0, nc[0]) - origin[1]
    c1 = min(l.in_w, nc[1]) - origin[1]
    assert r0 >= 0 and c0 >= 0 and r1 <= x_local.shape[0] \
        and c1 <= x_local.shape[1], (
            "local slice does not cover the needed region", l.name)
    xs = x_local[r0:r1, c0:c1, :]
    if l.conv_t in (ConvT.CONV, ConvT.POINTWISE):
        wsel = w[:, :, :, chans[0]:chans[1]]
        return _conv_region(l, wsel, xs, ((pt, pb), (pl_, pr)))
    out = _conv_region(l, w, xs, ((pt, pb), (pl_, pr)))
    return out[:, :, chans[0]:chans[1]]
