"""Serving layer: batched stage scaling, batch-size choice under a p99
bound, simulator-in-the-loop refinement, and the stage-count contract
between the plan, the simulator, and the engine."""
import numpy as np
import pytest

from repro.cluster import (asym_uplink, build_stages, choose_batch,
                           cluster_pipeline_frontier, cluster_plan_search,
                           homogeneous, mixed_fast_slow,
                           refine_with_simulator, serve_point, simulate,
                           sweep_serving)
from repro.configs.edge_models import EDGE_MODELS
from repro.core import Objective, plan_stage_counts
from repro.core.graph import ConvT, LayerSpec, chain


def small_chain():
    return chain("serve4", [
        LayerSpec("c0", ConvT.CONV, 24, 24, 3, 8, 3, 1, 1),
        LayerSpec("c1", ConvT.CONV, 24, 24, 8, 8, 3, 1, 1),
        LayerSpec("pw", ConvT.POINTWISE, 24, 24, 8, 16, 1, 1, 0),
        LayerSpec("c2", ConvT.CONV, 24, 24, 16, 8, 3, 1, 1),
    ])


def test_batch_scales_compute_linearly_but_not_message_latency():
    g = small_chain()
    cl = homogeneous(4)
    plan = cluster_plan_search(g, cl).plan
    s1 = build_stages(g, plan, cl, batch_size=1)
    s4 = build_stages(g, plan, cl, batch_size=4)
    assert len(s1) == len(s4)
    lat_s = cl.links[0].latency_us * 1e-6
    for a, b in zip(s1, s4):
        assert a.kind == b.kind
        da = np.asarray(a.durations)
        db = np.asarray(b.durations)
        if a.kind == "compute":
            assert np.allclose(db, 4.0 * da, rtol=1e-12)
        elif da.size and da.max() > 0.0:
            # bytes quadruple, per-message latency does not
            msgs = np.round((4.0 * da - db) / (3.0 * lat_s))
            assert np.all(4.0 * da - db >= -1e-15)
            assert np.allclose(db, 4.0 * da - msgs * 3.0 * lat_s,
                               rtol=1e-9)


def test_batch_size_validation():
    g = small_chain()
    cl = homogeneous(2)
    plan = cluster_plan_search(g, cl).plan
    with pytest.raises(ValueError):
        build_stages(g, plan, cl, batch_size=0)


def test_single_request_latency_independent_of_batching_accounting():
    """batch_size=1 must be the historical behavior bit for bit."""
    g = EDGE_MODELS["mobilenet"]()
    cl = mixed_fast_slow(4)
    plan = cluster_plan_search(g, cl).plan
    a = simulate(g, plan, cl, n_requests=4)
    b = simulate(g, plan, cl, n_requests=4, batch_size=1)
    assert a.latencies_s == b.latencies_s
    assert a.throughput_rps == b.throughput_rps


def test_serve_point_stability_and_p99_accounting():
    g = small_chain()
    cl = homogeneous(4)
    plan = cluster_plan_search(g, cl).plan
    cap = simulate(g, plan, cl, n_requests=16).throughput_rps
    easy = serve_point(g, plan, cl, arrival_rate_rps=cap * 0.5,
                       batch_size=1, p99_bound_s=10.0)
    assert easy.stable and easy.feasible
    assert easy.goodput_rps == pytest.approx(cap * 0.5)
    hot = serve_point(g, plan, cl, arrival_rate_rps=cap * 3.0,
                      batch_size=1, p99_bound_s=10.0, n_batches=16)
    assert not hot.stable and hot.goodput_rps == 0.0
    # batching adds the batch-fill wait to the tail
    b4 = serve_point(g, plan, cl, arrival_rate_rps=cap * 0.5,
                     batch_size=4, p99_bound_s=10.0)
    assert b4.p99_latency_s >= 3.0 / (cap * 0.5) - 1e-12


def test_choose_batch_maximizes_goodput_under_bound():
    g = small_chain()
    cl = homogeneous(4)
    plan = cluster_plan_search(g, cl).plan
    lat = cluster_plan_search(g, cl).cost
    cap = simulate(g, plan, cl, n_requests=16).throughput_rps
    best, pts = choose_batch(g, plan, cl, arrival_rate_rps=cap * 0.6,
                             p99_bound_s=lat * 20,
                             batch_sizes=(1, 2, 4))
    assert best.feasible
    assert best.goodput_rps == max(p.goodput_rps for p in pts)
    # impossible bound: nothing feasible, fallback reports zero goodput
    none_ok, pts2 = choose_batch(g, plan, cl, arrival_rate_rps=cap * 0.6,
                                 p99_bound_s=lat * 1e-3,
                                 batch_sizes=(1, 2))
    assert not none_ok.feasible and none_ok.goodput_rps == 0.0
    rows = sweep_serving(g, plan, cl, [cap * 0.4, cap * 0.8], lat * 20,
                         batch_sizes=(1, 2))
    assert len(rows) == 2 and all("per_batch" in r for r in rows)


def test_refinement_never_loses_to_unrefined_throughput_plan():
    g = EDGE_MODELS["inception"]()
    cl = mixed_fast_slow(8)
    fr = cluster_pipeline_frontier(g, cl)
    rr = refine_with_simulator(g, cl, n_requests=16, max_iters=4,
                               frontier=fr)
    base = cluster_plan_search(g, cl, objective=Objective.THROUGHPUT)
    base_rep = simulate(g, base.plan, cl, n_requests=16)
    assert rr.throughput_rps >= base_rep.throughput_rps * (1 - 1e-9)
    assert len(rr.steps) >= 1
    s0 = rr.steps[0]
    assert s0.beta == 1.0 and s0.alpha == 1.0
    # measured occupancies never exceed their analytic upper bounds
    for s in rr.steps:
        assert s.dev_occupancy_s <= s.compute_s * (1 + 1e-9)
        assert s.link_occupancy_s <= s.sync_s * (1 + 1e-9)


def test_stage_counts_contract_plan_vs_simulator():
    for model in ("mobilenet", "resnet18", "inception"):
        g = EDGE_MODELS[model]()
        cl = asym_uplink(4)
        for objective in (Objective.LATENCY, Objective.THROUGHPUT):
            plan = cluster_plan_search(g, cl, objective=objective).plan
            nc, ns = plan_stage_counts(g, plan)
            stages = build_stages(g, plan, cl)
            assert nc == sum(1 for s in stages if s.kind == "compute")
            assert ns == sum(1 for s in stages if s.kind == "sync")


def test_stage_counts_contract_engine():
    import jax

    from repro.runtime.engine import init_weights
    from repro.runtime.session import Session

    g = small_chain()
    cl = homogeneous(4)
    plan = cluster_plan_search(g, cl, objective=Objective.THROUGHPUT).plan
    nc, _ = plan_stage_counts(g, plan)
    w = init_weights(g, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (g.layers[0].in_h, g.layers[0].in_w,
                           g.layers[0].in_c))
    _, stats = Session(g, w, plan, 4).run(x)
    assert stats.compute_stages == nc
