"""Distributed paged KV cache for autoregressive decode.

Pages live on the devices that own the heads: each (layer, node) keeps its
own physical page pool holding exactly that node's kv heads — the node that
computes a head's attention is the node whose pool stores that head's K/V,
so decode steps touch no remote KV at all (only the tiny head-output
gather at the output projection crosses the interconnect).

A single logical→physical page table is shared by every pool: logical page
``i`` (token positions ``i*page_size .. (i+1)*page_size - 1``) maps to the
physical slot ``page_table[i]``.  Physical slots are assigned in a
deterministic *scrambled* order (seeded permutation) so every consumer of
the cache genuinely exercises the page-table indirection — a bug that
assumes contiguous physical layout fails loudly instead of passing by
accident.  The paged-KV layout follows the flashinfer/DeepSeek-MLA idiom:
fixed-capacity pools, append-only growth, gather-by-table reads.

Pool layout is ``[local_heads, n_pages, page_size, head_dim]`` — the
batch*head-major order :func:`repro.kernels.flash_decode_paged` streams
and the XLA gather path indexes without transposes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

__all__ = ["PagedKVCache"]


@dataclasses.dataclass(frozen=True)
class _PoolKey:
    layer: int
    node: int


class PagedKVCache:
    """Paged K/V pools for ``n_layers`` attention layers over ``nodes``
    devices.

    ``head_split[layer][node]`` is the number of kv heads node ``node``
    owns in ``layer`` (the planner's head-granular OutC split; replicated
    layers list the full head count on every node).  ``capacity`` is the
    maximum token count; storage is ``ceil(capacity / page_size)`` physical
    pages per pool, allocated up front.
    """

    def __init__(self, head_split: Sequence[Sequence[int]], head_dim: int,
                 page_size: int, capacity: int, *, seed: int = 0,
                 dtype=None):
        import jax.numpy as jnp
        if page_size < 1 or capacity < 1:
            raise ValueError(f"bad page geometry ps={page_size}, "
                             f"capacity={capacity}")
        self.head_split: Tuple[Tuple[int, ...], ...] = tuple(
            tuple(int(h) for h in per_node) for per_node in head_split)
        self.n_layers = len(self.head_split)
        self.nodes = len(self.head_split[0]) if self.n_layers else 0
        self.head_dim = int(head_dim)
        self.page_size = int(page_size)
        self.capacity = int(capacity)
        self.n_pages = -(-capacity // page_size)
        self.dtype = jnp.float32 if dtype is None else dtype
        # scrambled logical -> physical assignment (deterministic per seed)
        rng = np.random.default_rng(seed)
        self._table = np.asarray(rng.permutation(self.n_pages), np.int32)
        self._k: List[List] = []
        self._v: List[List] = []
        for per_node in self.head_split:
            if len(per_node) != self.nodes:
                raise ValueError("ragged head_split across layers")
            shape = lambda lh: (lh, self.n_pages, self.page_size,
                                self.head_dim)
            self._k.append([jnp.zeros(shape(lh), self.dtype)
                            for lh in per_node])
            self._v.append([jnp.zeros(shape(lh), self.dtype)
                            for lh in per_node])
        self.length = 0

    # ---- geometry ---------------------------------------------------------
    @property
    def page_table(self) -> np.ndarray:
        """Logical→physical page map, ``[n_pages]`` int32."""
        return self._table

    def slot(self, pos: int) -> Tuple[int, int]:
        """(physical_page, row) of token position ``pos``."""
        if not 0 <= pos < self.capacity:
            raise ValueError(f"position {pos} outside capacity "
                             f"{self.capacity}")
        return int(self._table[pos // self.page_size]), pos % self.page_size

    def bytes_per_node(self, node: int) -> int:
        """Pool bytes resident on ``node`` — proportional to the heads it
        owns, which is the whole point of head-owner page placement."""
        elems = sum(split[node] for split in self.head_split) \
            * self.n_pages * self.page_size * self.head_dim
        return 2 * elems * np.dtype(np.float32).itemsize  # K and V

    # ---- access -----------------------------------------------------------
    def append(self, layer: int, node: int, pos: int, k, v) -> None:
        """Write one token's K/V (``[local_heads, head_dim]``) for
        ``(layer, node)`` at position ``pos`` (functional jnp update)."""
        phys, row = self.slot(pos)
        self._k[layer][node] = self._k[layer][node].at[:, phys, row].set(k)
        self._v[layer][node] = self._v[layer][node].at[:, phys, row].set(v)

    def store(self, layer: int, node: int, k_pages, v_pages) -> None:
        """Replace a pool wholesale (executors that batch their updates
        inside a jitted step write the carried-through arrays back here)."""
        exp = self._k[layer][node].shape
        if tuple(k_pages.shape) != exp:
            raise ValueError(f"pool shape {k_pages.shape} != {exp}")
        self._k[layer][node] = k_pages
        self._v[layer][node] = v_pages

    def pages(self, layer: int, node: int):
        """(k_pages, v_pages) of one pool —
        ``[local_heads, n_pages, page_size, head_dim]``."""
        return self._k[layer][node], self._v[layer][node]

    def advance(self, n: int = 1) -> int:
        """Commit ``n`` appended positions; returns the new length."""
        if self.length + n > self.capacity:
            raise ValueError(f"cache overflow: {self.length}+{n} > "
                             f"capacity {self.capacity}")
        self.length += n
        return self.length

    def gather(self, layer: int, node: int):
        """Contiguous logical-order (K, V) ``[length, local_heads,
        head_dim]`` — debugging / conformance view (gathers by table)."""
        kp, vp = self.pages(layer, node)
        L = self.length
        pages = self._table[: -(-L // self.page_size)] if L else \
            self._table[:0]
        k = kp[:, pages].reshape(kp.shape[0], -1, self.head_dim)[:, :L]
        v = vp[:, pages].reshape(vp.shape[0], -1, self.head_dim)[:, :L]
        return k.transpose(1, 0, 2), v.transpose(1, 0, 2)
