"""Dynamic Partition Planner — Algorithm 1 (§3.3).

Reverse-order DP over T-states.  ``S[i][p]`` is the optimal remaining time
from layer ``i`` to the end, given layer ``i``'s input is exactly sharded in
layout ``p``.  NT runs appear only *inside* segments ``[i..b]`` that start and
end at T boundaries — exactly the paper's Key designs 1-3: an NT-prefixed
subsequence has indeterminate workload (footnote 3), so such states are never
evaluated on their own.

Pruning (the paper's "piecing together" list):
  1. reverse search never expands NT-start states (they exist only inside
     segment enumeration);
  2. suffix costs ``S[b+1][p']`` are reused across all segments ending at b;
  3. dynamic threshold — segment cost is monotone in segment length, so the
     backtrack stops as soon as the partial segment cost alone exceeds the
     incumbent (and when the halo swallows the whole shard, at which point
     redundant compute has degenerated into full replication).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from .cost import Testbed
from .estimator import CostEstimator
from .graph import ModelGraph, halo_growth
from .partition import ALL_SCHEMES, Mode, Scheme, min_shard_extent
from .plan import Plan

_INF = float("inf")


@dataclasses.dataclass
class SearchStats:
    i_calls: int = 0
    s_calls: int = 0
    states: int = 0
    pruned_threshold: int = 0
    pruned_halo: int = 0


@dataclasses.dataclass(frozen=True)
class SearchResult:
    plan: Plan
    cost: float
    stats: SearchStats


def plan_search(graph: ModelGraph, est: CostEstimator, tb: Testbed,
                schemes: Sequence[Scheme] = ALL_SCHEMES,
                max_segment: int = 32,
                allow_fusion: bool = True) -> SearchResult:
    """Run DPP.  ``allow_fusion=False`` restricts to all-T plans (the
    layerwise baseline); ``schemes`` restricted to one scheme with fusion on
    gives the fused-layer baseline."""
    layers = graph.layers
    n = len(layers)
    k = len(schemes)
    stats = SearchStats()

    S: List[List[float]] = [[_INF] * k for _ in range(n + 1)]
    # choice[i][pi] = (segment_end_b, next_scheme_index or -1)
    choice: List[List[Tuple[int, int]]] = [[(-1, -1)] * k for _ in range(n + 1)]

    for i in range(n - 1, -1, -1):
        for pi, p in enumerate(schemes):
            best, best_choice = _INF, (-1, -1)
            stats.states += 1
            seg_hi = min(i + max_segment, n) if allow_fusion else i + 1
            for b in range(i, seg_hi):
                if b > i and not p.spatial:
                    break  # OutC cannot fuse (NT undefined)
                halos = halo_growth(layers[i:b + 1], b - i)
                if b > i and 2 * halos[0] >= min_shard_extent(
                        layers[i], p, tb.nodes):
                    stats.pruned_halo += 1
                    break  # halo degenerated into replication
                segcost = 0.0
                for off, m in enumerate(range(i, b + 1)):
                    segcost += est.i_cost(layers[m], p, tb,
                                          extra_halo=halos[off] if b > i else 0)
                    stats.i_calls += 1
                if segcost >= best:
                    stats.pruned_threshold += 1
                    break  # dynamic threshold: monotone in b
                if b == n - 1:
                    stats.s_calls += 1
                    c = segcost + est.s_cost(layers[b], None, p, None, tb)
                    if c < best:
                        best, best_choice = c, (b, -1)
                else:
                    for qi, q in enumerate(schemes):
                        if S[b + 1][qi] == _INF:
                            continue
                        stats.s_calls += 1
                        c = (segcost
                             + est.s_cost(layers[b], layers[b + 1], p, q, tb)
                             + S[b + 1][qi])
                        if c < best:
                            best, best_choice = c, (b, qi)
            S[i][pi] = best
            choice[i][pi] = best_choice

    pi = min(range(k), key=lambda j: S[0][j])
    total = S[0][pi]
    steps: List[Tuple[Scheme, Mode]] = []
    i = 0
    while i < n:
        b, qi = choice[i][pi]
        p = schemes[pi]
        for m in range(i, b + 1):
            steps.append((p, Mode.NT if m < b else Mode.T))
        i = b + 1
        if qi >= 0:
            pi = qi
    return SearchResult(plan=Plan(tuple(steps)), cost=total, stats=stats)
