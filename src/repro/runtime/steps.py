"""Step functions lowered by the launcher/dry-run: train / prefill / decode."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.optim import adamw_update, cosine_schedule


def make_train_step(model: Model, *, peak_lr: float = 3e-4,
                    warmup: int = 100, total: int = 10_000,
                    accum: int = 1):
    """``accum > 1`` runs gradient accumulation over microbatches (scan):
    the global batch is split on its leading axis, cutting peak activation
    memory ~accum x at the cost of serializing the microbatches — the
    §Perf "fit" lever for pairs whose activations exceed HBM."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: model.loss(p, batch, remat=True))(params)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = grads_of(params, batch)
        else:
            # strided split (rows i::accum): every microbatch draws evenly
            # from every data shard, so the per-micro sharding layout is
            # identical to the full batch's
            micro = jax.tree.map(
                lambda a: a.reshape((a.shape[0] // accum, accum)
                                    + a.shape[1:]).swapaxes(0, 1), batch)

            def body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, g_acc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        lr = cosine_schedule(opt_state["step"], peak_lr=peak_lr,
                             warmup=warmup, total=total)
        params, opt_state = adamw_update(grads, opt_state, params, lr)
        return params, opt_state, {"loss": loss, "lr": lr}
    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tok, t):
        return model.decode_step(params, cache, tok, t)
    return decode_step
