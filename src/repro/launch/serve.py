"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Real batched KV-cache decoding on local devices (reduced configs on this
container), or ``--dry-run`` to lower/compile the production-mesh
decode step for any shape.
"""
import argparse
import sys
import time

from repro.obs.log import log


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun
        return dryrun.main(["--arch", args.arch, "--shape", args.shape])

    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.models.transformer import Model

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    if cfg.family == "vlm":
        cfg = dataclasses.replace(cfg, vision_tokens=0)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)
    cache = model.cache_init(B, capacity=cfg.attn_window or (P + args.gen))
    if cfg.family == "encdec":
        audio = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model)) * 0.02
        cache["xlayers"] = model.encode_cross(params, audio)

    step = jax.jit(model.decode_step)
    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = step(params, cache, prompts[:, t:t + 1],
                             jnp.int32(t))
    tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True)
    tp = time.time() - t0
    t0 = time.time()
    out = []
    for i in range(args.gen):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(P + i))
        tok = jnp.argmax(logits[:, -1], axis=-1, keepdims=True)
    jax.block_until_ready(tok)
    td = time.time() - t0
    log("serve.timing", arch=cfg.name, batch=B, prefill_ms=tp * 1e3,
        decode_ms_per_token=td * 1e3 / args.gen)
    assert bool(jnp.isfinite(logits).all())
    return 0


if __name__ == "__main__":
    sys.exit(main())
