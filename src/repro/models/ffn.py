"""Feed-forward blocks: dense MLP (SwiGLU / GELU) and capacity-based MoE.

The MoE dispatch is gather/scatter with a fixed per-expert capacity (GShard
style but without the quadratic one-hot dispatch einsum): token->slot
positions come from a cumulative count per expert; overflow tokens drop
(standard capacity-factor semantics).  Expert compute is three batched
einsums over an [E, C, d] buffer — MXU-friendly and shardable over an
expert-parallel axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init


def init_mlp(cfg, key, d_ff: Optional[int] = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    if cfg.act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"w_gate": dense_init(k1, cfg.d_model, d_ff, dt),
                "w_up": dense_init(k2, cfg.d_model, d_ff, dt),
                "w_down": dense_init(k3, d_ff, cfg.d_model, dt)}
    k1, k2 = jax.random.split(key, 2)
    return {"w_up": dense_init(k1, cfg.d_model, d_ff, dt),
            "b_up": jnp.zeros((d_ff,), dt),
            "w_down": dense_init(k2, d_ff, cfg.d_model, dt),
            "b_down": jnp.zeros((cfg.d_model,), dt)}


def mlp(cfg, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.act == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"] + p["b_up"]) @ p["w_down"] + p["b_down"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe(cfg, key) -> dict:
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    E, d, f = m.n_experts, cfg.d_model, m.d_ff_expert
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32)
                   * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32)
                 * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                   / jnp.sqrt(jnp.float32(f))).astype(dt),
    }
    if m.n_shared:
        p["shared"] = init_mlp(cfg, ks[4], d_ff=m.d_ff_expert * m.n_shared)
    return p


def moe(cfg, p: dict, x: jnp.ndarray,
        capacity: Optional[int] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B,S,d] -> (out, aux_loss).  Top-k routing with a fixed per-expert
    capacity, computed PER GROUP (group = batch row, GShard style): slot
    positions come from a cumulative count over each group's own tokens
    only, so the dispatch never synchronizes across data-parallel shards —
    the global-cumsum variant all-reduced a [T*K, E] counter matrix across
    the whole mesh (found and fixed in the §Perf collective hillclimb)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    G = B                       # groups = batch rows (data-shard aligned)
    xt = x.reshape(G, S, d)

    logits = (xt.astype(jnp.float32) @ p["router"])            # [G,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                        # [G,S,K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch style, global)
    me = probs.reshape(T, E).mean(0)                           # [E]
    ce = jnp.zeros((E,)).at[idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce) * m.router_aux_weight

    C = capacity or max(1, int(S * K * m.capacity_factor / E))
    # slot position of each (token, k) assignment inside (group, expert)
    flat_e = idx.reshape(G, S * K)                             # [G,S*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # [G,S*K,E]
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot             # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None],
                              axis=2)[..., 0]                  # [G,S*K]
    keep = pos < C
    # buffer layout [E, G*C, d]: slot = e*(G*C) + g*C + pos
    gidx = jnp.arange(G, dtype=jnp.int32)[:, None]
    slot = flat_e * (G * C) + gidx * C + jnp.minimum(pos, C - 1)

    buf = jnp.zeros((E * G * C, d), x.dtype)
    src = jnp.repeat(xt.reshape(G, S, d), K, axis=1)           # [G,S*K,d]
    buf = buf.at[jnp.where(keep, slot, E * G * C).reshape(-1)].add(
        src.reshape(-1, d), mode="drop")                       # drop overflow
    ebuf = buf.reshape(E, G * C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", ebuf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * G * C, d)

    gathered = y[jnp.minimum(slot, E * G * C - 1).reshape(-1)]  # [G*S*K,d]
    gathered = jnp.where(keep.reshape(-1)[:, None], gathered, 0.0)
    w = gate.reshape(-1)[:, None].astype(x.dtype)
    out = (gathered * w).reshape(T, K, d).sum(axis=1).reshape(B, S, d)

    if m.n_shared:
        out = out + mlp(cfg, p["shared"], x)
    return out, aux
