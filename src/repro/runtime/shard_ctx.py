"""Activation-sharding context: the TPU analogue of FlexPie's T boundaries.

Model code stays sharding-agnostic; the launcher installs a constraint
callback for the duration of tracing, and blocks call :func:`constrain` at
their boundaries.  Sequence-sharded activations (the InH scheme) vs
batch-only sharding (leaving the model axis to weights, the OutC scheme) is
exactly the per-class decision the FCO planner makes.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACT_FN: contextvars.ContextVar = contextvars.ContextVar(
    "repro_act_fn", default=None)


@contextlib.contextmanager
def activation_sharding(fn: Optional[Callable]):
    tok = _ACT_FN.set(fn)
    try:
        yield
    finally:
        _ACT_FN.reset(tok)


def constrain(x):
    fn = _ACT_FN.get()
    return fn(x) if fn is not None else x


def seq_shard_fn(mesh: Mesh, dp_axes, *, seq_axis: str = "model"):
    """Constraint callback: [B, S, d] -> B over data axes, S over ``model``
    when divisible (best-effort; skips non-conforming streams)."""
    dpn = 1
    for a in dp_axes:
        dpn *= mesh.shape[a]
    m = mesh.shape[seq_axis]

    def fn(x):
        if x.ndim != 3:
            return x
        b, s, _ = x.shape
        spec = [None, None, None]
        if b % dpn == 0 and b > 1:
            spec[0] = dp_axes
        if s % m == 0 and s > 1:
            spec[1] = seq_axis
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))
    return fn


def batch_shard_fn(mesh: Mesh, dp_axes):
    """Constraint callback: batch over data axes only (TP-style)."""
    dpn = 1
    for a in dp_axes:
        dpn *= mesh.shape[a]

    def fn(x):
        if x.ndim != 3:
            return x
        b = x.shape[0]
        spec = [dp_axes if (b % dpn == 0 and b > 1) else None] \
            + [None] * (x.ndim - 1)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))
    return fn
