"""Labeled counters / gauges / histograms with JSON snapshots.

A :class:`Metrics` registry keys every instrument by ``(name, sorted
label items)`` and renders keys Prometheus-style
(``name{k="v",k2="v2"}``) in :meth:`Metrics.snapshot`.  Histograms use
power-of-two buckets (``le_2^k``) plus count/sum/min/max — enough to
read convergence and cache-hit behaviour without a stats dependency.

Like tracing (``obs.trace``), collection is opt-in: the module-level
registry is ``None`` by default and the free functions (:func:`inc`,
:func:`gauge`, :func:`observe`) are no-ops until :func:`set_metrics`
installs one.  Hot paths may also accumulate plain ints locally and
push one batched :func:`inc` at the end of a phase.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Any, Dict, Optional, Tuple

_Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, Any]) -> _Key:
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


def _render(key: _Key) -> str:
    name, items = key
    if not items:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in items)
    return f"{name}{{{inner}}}"


class Metrics:
    """Thread-safe registry of labeled counters, gauges, histograms."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[_Key, float] = {}
        self._gauges: Dict[_Key, float] = {}
        self._hists: Dict[_Key, Dict[str, Any]] = {}

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0.0) + value

    def gauge(self, name: str, value: float, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            self._gauges[k] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        k = _key(name, labels)
        v = float(value)
        with self._lock:
            h = self._hists.get(k)
            if h is None:
                h = self._hists[k] = {"count": 0, "sum": 0.0,
                                      "min": math.inf, "max": -math.inf,
                                      "buckets": {}}
            h["count"] += 1
            h["sum"] += v
            h["min"] = min(h["min"], v)
            h["max"] = max(h["max"], v)
            # power-of-two bucket: smallest k with v <= 2^k
            exp = 0 if v <= 0 else math.ceil(math.log2(v)) if v > 0 else 0
            b = f"le_2^{exp}"
            h["buckets"][b] = h["buckets"].get(b, 0) + 1

    def counter_value(self, name: str, **labels) -> float:
        with self._lock:
            return self._counters.get(_key(name, labels), 0.0)

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        with self._lock:
            return self._gauges.get(_key(name, labels))

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready snapshot: ``{"counters": {...}, "gauges": {...},
        "histograms": {...}}`` with Prometheus-style keys."""
        with self._lock:
            counters = {_render(k): v for k, v in self._counters.items()}
            gauges = {_render(k): v for k, v in self._gauges.items()}
            hists = {}
            for k, h in self._hists.items():
                out = dict(h)
                out["buckets"] = dict(h["buckets"])
                if out["count"] == 0:
                    out["min"] = out["max"] = None
                hists[_render(k)] = out
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)
        return path


# ---------------------------------------------------------------------------
# global registry (None by default — collection is opt-in)
# ---------------------------------------------------------------------------

_METRICS: Optional[Metrics] = None


def get_metrics() -> Optional[Metrics]:
    return _METRICS


def set_metrics(m: Optional[Metrics]) -> Optional[Metrics]:
    global _METRICS
    _METRICS = m
    return m


def inc(name: str, value: float = 1.0, **labels) -> None:
    m = _METRICS
    if m is not None:
        m.inc(name, value, **labels)


def gauge(name: str, value: float, **labels) -> None:
    m = _METRICS
    if m is not None:
        m.gauge(name, value, **labels)


def observe(name: str, value: float, **labels) -> None:
    m = _METRICS
    if m is not None:
        m.observe(name, value, **labels)
