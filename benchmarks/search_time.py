"""§4 metric — DPP search time and estimator-call counts per benchmark
model, plus optimality confirmation vs exhaustive search on a small graph."""
from __future__ import annotations

import random

from repro.core import Testbed
from repro.core.dpp import plan_search
from repro.core.exhaustive import exhaustive_search
from repro.core.graph import ConvT, LayerSpec, chain
from repro.configs.edge_models import EDGE_MODELS

from .common import EST, emit, time_call


def run() -> None:
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    for model, fn in EDGE_MODELS.items():
        g = fn()
        us, res = time_call(lambda: plan_search(g, EST, tb))
        emit(f"search/{model}", us,
             f"layers={len(g)};i_calls={res.stats.i_calls};"
             f"s_calls={res.stats.s_calls};"
             f"pruned={res.stats.pruned_threshold + res.stats.pruned_halo}")

    # optimality check vs exhaustive on a 5-layer random graph
    rng = random.Random(0)
    layers = []
    h, c = 28, 32
    for i in range(5):
        layers.append(LayerSpec(f"l{i}", ConvT.CONV, h, h, c, c, 3, 1, 1))
    g = chain("opt5", layers)
    us_dp, dp = time_call(lambda: plan_search(g, EST, tb))
    us_ex, ex = time_call(lambda: exhaustive_search(g, EST, tb), repeats=1)
    emit("search/optimality-5layer", us_dp,
         f"dp={dp.cost * 1e3:.4f}ms;exhaustive={ex[1] * 1e3:.4f}ms;"
         f"match={abs(dp.cost - ex[1]) < 1e-12};"
         f"speedup_vs_exhaustive={us_ex / max(us_dp, 1e-9):.1f}x")


if __name__ == "__main__":
    run()
