"""Cost estimator (CE) interface — i-Estimator and s-Estimator (§3.2).

Two implementations:

* :class:`AnalyticEstimator` — wraps the closed-form testbed model
  (``core/cost.py``).  Used as the Theorem-1 oracle and as the label source
  for trace generation.
* :class:`GBDTEstimator` — the paper-faithful data-driven estimator: two
  from-scratch histogram GBDT regressors (``repro/gbdt``) trained on traces
  sampled from the simulator (``repro/sim/trace.py``).  Predicts log-time.

Feature expression (Fig. 4, extended with the planner's decision variables,
the DAG fan-in so the estimators see merge structure, and the ATTN head
count so they see head-granular OutC geometry):
``[InH, InW, InC, OutH, OutW, OutC, K, S, P, ConvT, FanIn, Heads,
bandwidth, topology]`` plus ``nodes, scheme, halo`` for i- and ``nodes,
src, dst, next_K, next_fan_in, next_conv_t`` for s-.

Heterogeneity-aware extension: both expressions optionally append the
:data:`HETERO_FEATURE_NAMES` per-cluster capability summary (min/mean/max
capability share after ``eff_derate``, busiest-link bandwidth ratio,
link-latency class).  The homogeneous columns are preserved as an **exact
prefix**, so forests trained on the historical 17/20-column layout keep
loading and predicting identically; hetero-trained forests are simply
wider (see ``repro.sim.trace`` for sampling and
``repro.cluster.ClusterGBDTEstimator`` for planner integration).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Protocol, Sequence, Tuple

import numpy as np

from .cost import (Testbed, compute_time_batch_s, compute_time_s,
                   sync_time_batch_s, sync_time_s)
from .graph import LayerSpec
from .partition import Scheme


class CostEstimator(Protocol):
    """Scalar estimator protocol — the minimum every estimator provides.

    Estimators may additionally implement :class:`BatchedCostEstimator`;
    consumers feature-test with ``hasattr(est, "i_cost_batch")`` and fall
    back to scalar-call paths otherwise (scalar-only estimators may depend
    on information outside the feature expression, e.g. layer names)."""

    def i_cost(self, layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int = 0) -> float: ...

    def s_cost(self, layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed) -> float: ...


class BatchedCostEstimator(CostEstimator, Protocol):
    """Batched extension: costs are determined by the feature expression
    alone, and whole query matrices evaluate in one call, bit-identical to
    the scalar protocol row for row."""

    def i_cost_batch(self, X: np.ndarray, tb: Testbed,
                     flop_factor: Optional[np.ndarray] = None
                     ) -> np.ndarray:
        """Vector i-Estimator over a stacked ``(n, 17)`` matrix of
        :func:`i_features` rows.  Row ``j`` must equal
        ``i_cost(layer_j, scheme_j, tb_j, halo_j)`` exactly.
        ``flop_factor`` carries ``extra_flop_factor`` per row for estimators
        that read the analytic physics (it is not a learned feature)."""
        ...

    def s_cost_batch(self, X: np.ndarray, tb: Testbed) -> np.ndarray:
        """Vector s-Estimator over stacked ``(n, 20)`` :func:`s_features`
        rows (``Dst = -1`` marks the final gather)."""
        ...


class AnalyticEstimator:
    """Oracle estimator: reads the simulated testbed physics directly."""

    def i_cost(self, layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int = 0) -> float:
        return compute_time_s(layer, scheme, tb, extra_halo=extra_halo)

    def s_cost(self, layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed) -> float:
        return sync_time_s(layer, nxt, src, dst, tb)

    def i_cost_batch(self, X: np.ndarray, tb: Testbed,
                     flop_factor: Optional[np.ndarray] = None
                     ) -> np.ndarray:
        return compute_time_batch_s(X, tb, flop_factor)

    def s_cost_batch(self, X: np.ndarray, tb: Testbed) -> np.ndarray:
        return sync_time_batch_s(X, tb)


# ---------------------------------------------------------------------------
# Feature extraction shared by trace generation and GBDT inference.
# ---------------------------------------------------------------------------

def i_features(layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int,
               hetero: Optional[Sequence[float]] = None) -> List[float]:
    """17-column i-feature row; ``hetero`` (a :func:`hetero_summary` list)
    appends the per-cluster capability columns after the exact homogeneous
    prefix."""
    row = [*layer.feature_vector(), tb.bandwidth_gbps, float(tb.topology),
           float(tb.nodes), float(scheme), float(extra_halo)]
    if hetero is not None:
        row.extend(hetero)
    return row


def s_features(layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed,
               hetero: Optional[Sequence[float]] = None) -> List[float]:
    row = [*layer.feature_vector(), tb.bandwidth_gbps, float(tb.topology),
           float(tb.nodes), float(src),
           -1.0 if dst is None else float(dst),
           0.0 if nxt is None else float(nxt.k),
           0.0 if nxt is None else float(nxt.fan_in),
           0.0 if nxt is None else float(nxt.conv_t)]
    if hetero is not None:
        row.extend(hetero)
    return row


I_FEATURE_NAMES = ["InH", "InW", "InC", "OutH", "OutW", "OutC", "K", "S", "P",
                   "ConvT", "FanIn", "Heads", "BW", "Topo", "Nodes", "Scheme",
                   "Halo"]
S_FEATURE_NAMES = ["InH", "InW", "InC", "OutH", "OutW", "OutC", "K", "S", "P",
                   "ConvT", "FanIn", "Heads", "BW", "Topo", "Nodes", "Src",
                   "Dst", "NextK", "NextFanIn", "NextConvT"]

#: per-cluster capability summary appended by the hetero-aware expression
HETERO_FEATURE_NAMES = ["CapMin", "CapMean", "CapMax", "LinkRatio",
                        "LatClass"]
N_HETERO_FEATURES = len(HETERO_FEATURE_NAMES)
I_FEATURE_NAMES_HETERO = I_FEATURE_NAMES + HETERO_FEATURE_NAMES
S_FEATURE_NAMES_HETERO = S_FEATURE_NAMES + HETERO_FEATURE_NAMES


def latency_class(latency_us: float) -> float:
    """Coarse link-latency bucket: 0 = on-board/switched (<= 15us),
    1 = LAN-grade (<= 75us), 2 = constrained uplink.  A discrete class
    (rather than the raw microseconds) keeps the learned trees from
    splitting on measurement jitter."""
    if latency_us <= 15.0:
        return 0.0
    if latency_us <= 75.0:
        return 1.0
    return 2.0


def hetero_summary(capability_weights: Sequence[float],
                   link_bandwidths_gbps: Sequence[float],
                   max_latency_us: float) -> List[float]:
    """Per-cluster capability summary columns (:data:`HETERO_FEATURE_NAMES`).

    ``capability_weights`` is ``gflops * eff_derate`` per device
    (``ClusterSpec.capability_weights``) — the summary carries each
    device's *share* of the total, so the columns are scale-free:
    a uniform cluster reads ``(1/n, 1/n, 1/n, 1.0, class)``.  Plain
    sequences keep ``core`` import-cycle free of ``repro.cluster``.
    """
    w = np.asarray(capability_weights, np.float64)
    if w.size == 0 or np.any(w <= 0.0):
        raise ValueError("capability weights must be positive")
    shares = w / w.sum()
    bws = np.asarray(link_bandwidths_gbps, np.float64)
    ratio = float(bws.min() / bws.max()) if bws.size else 1.0
    return [float(shares.min()), float(shares.mean()), float(shares.max()),
            ratio, latency_class(max_latency_us)]


def testbed_summary(tb: Testbed) -> List[float]:
    """:func:`hetero_summary` of the uniform cluster a ``Testbed``
    describes — what homogeneous trace rows carry in a hetero-width
    matrix."""
    share = 1.0 / tb.nodes
    return [share, share, share, 1.0, latency_class(tb.link_latency_us)]


class _LRUCache:
    """Bounded scalar-prediction cache (plain LRU on an ``OrderedDict``).

    The scalar estimator paths key on ``(layer, scheme, tb, ...)`` tuples;
    a long-lived serving process sees an unbounded stream of distinct
    testbeds/layers, so the cache must evict — the historical plain dicts
    grew forever."""

    __slots__ = ("maxsize", "hits", "misses", "_data")

    def __init__(self, maxsize: int):
        if maxsize < 1:
            raise ValueError(f"cache maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._data: "OrderedDict" = OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key) -> Optional[float]:
        hit = self._data.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key, value: float) -> None:
        self._data[key] = value
        if len(self._data) > self.maxsize:
            self._data.popitem(last=False)

    def clear(self) -> None:
        self._data.clear()


class GBDTEstimator:
    """Data-driven CE backed by two trained GBDT regressors (log-seconds).

    The scalar protocol memoizes per-query predictions in LRU caches
    bounded at ``cache_size`` entries each (the batched protocol never
    touches them); ``cache_info()`` mirrors
    ``cost_tables.PrefetchedEstimator``."""

    def __init__(self, i_model, s_model, cache_size: int = 4096):
        self.i_model = i_model
        self.s_model = s_model
        self._i_cache = _LRUCache(cache_size)
        self._s_cache = _LRUCache(cache_size)

    def cache_info(self) -> Tuple[int, int]:
        """(hits, misses) of the scalar lookup paths, both caches."""
        return (self._i_cache.hits + self._s_cache.hits,
                self._i_cache.misses + self._s_cache.misses)

    def clear_cache(self) -> None:
        self._i_cache.clear()
        self._s_cache.clear()

    def i_cost(self, layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int = 0) -> float:
        key = (layer, scheme, tb, extra_halo)
        hit = self._i_cache.get(key)
        if hit is None:
            x = np.asarray([i_features(layer, scheme, tb, extra_halo)],
                           dtype=np.float64)
            hit = float(np.exp(self.i_model.predict(x)[0]))
            self._i_cache.put(key, hit)
        return hit

    def s_cost(self, layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed) -> float:
        key = (layer,
               None if nxt is None else (nxt.k, nxt.fan_in, nxt.conv_t),
               src, dst, tb)
        hit = self._s_cache.get(key)
        if hit is None:
            x = np.asarray([s_features(layer, nxt, src, dst, tb)],
                           dtype=np.float64)
            hit = float(np.exp(self.s_model.predict(x)[0]))
            self._s_cache.put(key, hit)
        return hit

    def i_cost_batch(self, X: np.ndarray, tb: Testbed,
                     flop_factor: Optional[np.ndarray] = None
                     ) -> np.ndarray:
        """One forest pass for the whole matrix (``flop_factor`` is not part
        of the learned feature expression and is ignored, exactly as the
        scalar path ignores it)."""
        return np.exp(self.i_model.predict(np.asarray(X, np.float64)))

    def s_cost_batch(self, X: np.ndarray, tb: Testbed) -> np.ndarray:
        return np.exp(self.s_model.predict(np.asarray(X, np.float64)))
