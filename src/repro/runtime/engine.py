"""Distributed edge-inference engine: executes a FlexPie Plan on real
tensors, node by node, and verifies exact reassembly.

Each simulated edge node computes only from data it actually holds: the
engine backward-chains the receptive field from the node's exact output
shard at the segment end (T layer) through every NT-fused layer, slices
that input region once at the segment entry (counting the bytes the node
did not own — the measured communication), then runs the whole segment
locally.  This exercises the paper's core mechanics end to end: halo
growth, redundant computation, scheme-dependent re-layout.

Correctness contract (tested): for ANY valid plan, the reassembled output
is identical to the unpartitioned reference inference.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import ConvT, LayerSpec, ModelGraph
from repro.core.partition import Mode, Scheme, grid_dims, split_sizes
from repro.core.plan import Plan

Rect = Tuple[Tuple[int, int], Tuple[int, int], Tuple[int, int]]


# ---------------------------------------------------------------------------
# Reference (unpartitioned) inference
# ---------------------------------------------------------------------------

def init_weights(graph: ModelGraph, key) -> List[Optional[jnp.ndarray]]:
    ws: List[Optional[jnp.ndarray]] = []
    for l in graph.layers:
        if l.conv_t in (ConvT.CONV, ConvT.POINTWISE):
            key, k = jax.random.split(key)
            ws.append(jax.random.normal(k, (l.k, l.k, l.in_c, l.out_c),
                                        jnp.float32)
                      / np.sqrt(l.k * l.k * l.in_c))
        elif l.conv_t == ConvT.DWCONV:
            key, k = jax.random.split(key)
            ws.append(jax.random.normal(k, (l.k, l.k, 1, l.in_c), jnp.float32)
                      / np.sqrt(l.k * l.k))
        elif l.conv_t == ConvT.FC:
            key, k = jax.random.split(key)
            ws.append(jax.random.normal(k, (l.in_c, l.out_c), jnp.float32)
                      / np.sqrt(l.in_c))
        else:
            ws.append(None)
    return ws


def apply_layer(l: LayerSpec, w, x: jnp.ndarray) -> jnp.ndarray:
    """Full-tensor layer application. x: [H, W, C] (FC: [seq, 1, C])."""
    out = _conv_region(l, w, x, pads=((l.p, l.p), (l.p, l.p)))
    return out


def _conv_region(l: LayerSpec, w, x: jnp.ndarray, pads) -> jnp.ndarray:
    if l.conv_t in (ConvT.CONV, ConvT.POINTWISE):
        return jax.lax.conv_general_dilated(
            x[None], w, (l.s, l.s), list(pads),
            dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    if l.conv_t == ConvT.DWCONV:
        return jax.lax.conv_general_dilated(
            x[None], w, (l.s, l.s), list(pads),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=x.shape[-1])[0]
    if l.conv_t == ConvT.POOL:
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (l.k, l.k, 1), (l.s, l.s, 1),
            [tuple(pads[0]), tuple(pads[1]), (0, 0)])
    if l.conv_t == ConvT.FC:
        return (x.reshape(x.shape[0], x.shape[-1]) @ w).reshape(
            x.shape[0], 1, -1)
    if l.conv_t == ConvT.ADD:
        return x
    raise ValueError(l.conv_t)


def run_reference(graph: ModelGraph, weights, x: jnp.ndarray) -> jnp.ndarray:
    for l, w in zip(graph.layers, weights):
        x = apply_layer(l, w, x)
    return x


# ---------------------------------------------------------------------------
# Shard geometry
# ---------------------------------------------------------------------------

def _ranges(total: int, parts: int) -> List[Tuple[int, int]]:
    sizes = split_sizes(total, parts)
    out, a = [], 0
    for s in sizes:
        out.append((a, a + s))
        a += s
    return out


def exact_regions(l: LayerSpec, scheme: Scheme,
                  nodes: int) -> List[List[Rect]]:
    """Per-node exact (halo-free) output cells of layer ``l``.  One cell per
    node for the 1-D schemes; round-robin cell assignment for 2D-grid on
    non-square node counts (the paper's 3-node imbalance case)."""
    oh, ow, oc = l.out_h, l.out_w, l.out_c
    if scheme == Scheme.INH:
        return [[((r0, r1), (0, ow), (0, oc))]
                for r0, r1 in _ranges(oh, nodes)]
    if scheme == Scheme.INW:
        return [[((0, oh), (c0, c1), (0, oc))]
                for c0, c1 in _ranges(ow, nodes)]
    if scheme == Scheme.OUTC:
        return [[((0, oh), (0, ow), (k0, k1))]
                for k0, k1 in _ranges(oc, nodes)]
    if scheme == Scheme.GRID2D:
        gh, gw = grid_dims(nodes)
        cells = [((r0, r1), (c0, c1), (0, oc))
                 for r0, r1 in _ranges(oh, gh) for c0, c1 in _ranges(ow, gw)]
        per_node: List[List[Rect]] = [[] for _ in range(nodes)]
        for i, cell in enumerate(cells):
            per_node[i % nodes].append(cell)
        return per_node
    raise ValueError(scheme)


def in_rows(l: LayerSpec, out_r: Tuple[int, int], dim: int
            ) -> Tuple[int, int]:
    """Unclipped input range needed for an output range along H (dim=0,
    bound l.in_h) or W (dim=1, bound l.in_w).  FC/ADD are 1:1."""
    if l.conv_t in (ConvT.FC, ConvT.ADD):
        return out_r
    r0 = out_r[0] * l.s - l.p
    r1 = (out_r[1] - 1) * l.s - l.p + l.k
    return (r0, r1)


def _clip(r: Tuple[int, int], bound: int) -> Tuple[int, int]:
    return (max(0, r[0]), min(bound, r[1]))


# ---------------------------------------------------------------------------
# Plan execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExecStats:
    sync_points: int = 0
    bytes_received: float = 0.0      # across all nodes/boundaries (fp32)
    redundant_elems: float = 0.0     # halo outputs computed more than once


def _rect_elems(r: Rect) -> int:
    return max(0, r[0][1] - r[0][0]) * max(0, r[1][1] - r[1][0]) \
        * max(0, r[2][1] - r[2][0])


def _rect_isect(a: Rect, b: Rect) -> Rect:
    return tuple((max(x[0], y[0]), min(x[1], y[1]))
                 for x, y in zip(a, b))  # type: ignore[return-value]


def run_partitioned(graph: ModelGraph, weights, x: jnp.ndarray, plan: Plan,
                    nodes: int) -> Tuple[jnp.ndarray, ExecStats]:
    plan.validate()
    stats = ExecStats()
    layers = graph.layers
    full = x
    owned: Optional[List[List[Rect]]] = None  # per-node layout (prev sync)

    for (a, b) in plan.segments():
        scheme = plan.steps[a][0]
        l_in = layers[a]
        regs_b = exact_regions(layers[b], scheme, nodes)
        cell_out: List[Tuple[Rect, jnp.ndarray]] = []
        computed = 0
        for n, cells in enumerate(regs_b):
            for reg_b in cells:
                # backward-chain the needed region through the segment
                need: Dict[int, Rect] = {b: reg_b}
                rows, cols = reg_b[0], reg_b[1]
                for li in range(b, a, -1):
                    rows = _clip(in_rows(layers[li], rows, 0),
                                 layers[li].in_h)
                    cols = _clip(in_rows(layers[li], cols, 1),
                                 layers[li].in_w)
                    need[li - 1] = (rows, cols, (0, layers[li - 1].out_c))
                in_r = _clip(in_rows(l_in, need[a][0], 0), l_in.in_h)
                in_c = _clip(in_rows(l_in, need[a][1], 1), l_in.in_w)
                in_rect: Rect = (in_r, in_c, (0, l_in.in_c))
                # communication accounting: elems this node did not hold
                if owned is not None:
                    held = sum(_rect_elems(_rect_isect(in_rect, o))
                               for o in owned[n])
                    stats.bytes_received += 4.0 * (
                        _rect_elems(in_rect) - held)
                node_x = full[in_r[0]:in_r[1], in_c[0]:in_c[1], :]
                origin = (in_r[0], in_c[0])
                for li in range(a, b + 1):
                    l = layers[li]
                    node_x = _apply_local(l, weights[li], node_x, origin,
                                          need[li])
                    origin = (need[li][0][0], need[li][1][0])
                    computed += _rect_elems(need[li]) if li < b else 0
                cell_out.append((reg_b, node_x))
        # T boundary: reassemble ("synchronize")
        lb = layers[b]
        rebuilt = jnp.zeros((lb.out_h, lb.out_w, lb.out_c), full.dtype)
        for (r, c, ch), shard in cell_out:
            rebuilt = rebuilt.at[r[0]:r[1], c[0]:c[1],
                                 ch[0]:ch[1]].set(shard)
        stats.sync_points += 1
        stats.redundant_elems += float(computed)
        owned = regs_b
        full = rebuilt
    return full, stats


def _apply_local(l: LayerSpec, w, x_local: jnp.ndarray,
                 origin: Tuple[int, int], out_rect: Rect) -> jnp.ndarray:
    """Compute ``out_rect`` of layer ``l`` from a local input slice whose
    [0,0] corresponds to absolute input coords ``origin``."""
    rows, cols, chans = out_rect
    if l.conv_t == ConvT.FC:
        seg = x_local.reshape(x_local.shape[0], x_local.shape[-1])
        # local rows already correspond to rows (1:1 chain)
        return (seg @ w[:, chans[0]:chans[1]]).reshape(
            x_local.shape[0], 1, chans[1] - chans[0])
    if l.conv_t == ConvT.ADD:
        return x_local[:, :, chans[0]:chans[1]]
    # needed (unclipped) input range for this output region
    nr = in_rows(l, rows, 0)
    nc = in_rows(l, cols, 1)
    pt = max(0, -nr[0])
    pb = max(0, nr[1] - l.in_h)
    pl_ = max(0, -nc[0])
    pr = max(0, nc[1] - l.in_w)
    r0 = max(0, nr[0]) - origin[0]
    r1 = min(l.in_h, nr[1]) - origin[0]
    c0 = max(0, nc[0]) - origin[1]
    c1 = min(l.in_w, nc[1]) - origin[1]
    assert r0 >= 0 and c0 >= 0 and r1 <= x_local.shape[0] \
        and c1 <= x_local.shape[1], (
            "local slice does not cover the needed region", l.name)
    xs = x_local[r0:r1, c0:c1, :]
    if l.conv_t in (ConvT.CONV, ConvT.POINTWISE):
        wsel = w[:, :, :, chans[0]:chans[1]]
        return _conv_region(l, wsel, xs, ((pt, pb), (pl_, pr)))
    out = _conv_region(l, w, xs, ((pt, pb), (pl_, pr)))
    return out[:, :, chans[0]:chans[1]]
