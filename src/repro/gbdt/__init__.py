"""From-scratch histogram GBDT (XGBoost stand-in for the cost estimator)."""
from .gbdt import GBDTRegressor
from .tree import RegressionTree

__all__ = ["GBDTRegressor", "RegressionTree"]
