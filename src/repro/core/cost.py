"""Analytic cost model — the simulated testbed "physics".

On real hardware these times would be measured; here (no SRIO DSP cluster)
the analytic model is both (a) the ground truth the trace generator samples
from when training the GBDT estimators and (b) the oracle the Theorem-1
property tests compare DPP against.  The model captures the effects the paper
measures: straggler imbalance, scheme-dependent efficiency, per-message
latency, topology (ring / PS / mesh) and bandwidth.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from .graph import ConvT, LayerSpec
from .partition import (Mode, Scheme, boundary_bytes_same_scheme,
                        relayout_bytes, shard_work)


class Topology(enum.IntEnum):
    RING = 0
    PS = 1     # parameter-server (star)
    MESH = 2   # full bisection, direct point-to-point


@dataclasses.dataclass(frozen=True)
class Testbed:
    """Edge cluster description (Fig. 4 features 11-12 + node count)."""

    nodes: int = 4
    bandwidth_gbps: float = 5.0          # per-link, SRIO in the paper
    topology: Topology = Topology.RING
    device_gflops: float = 16.0          # TMS320C6678 ~16 GFLOP/s fp32
    link_latency_us: float = 10.0        # per message
    # scheme-dependent kernel efficiency: contiguous row splits vectorize
    # better on the DSP than column or channel splits.
    eff_inh: float = 0.90
    eff_inw: float = 0.80
    eff_outc: float = 0.85
    eff_grid: float = 0.82

    def efficiency(self, scheme: Scheme) -> float:
        return {Scheme.INH: self.eff_inh, Scheme.INW: self.eff_inw,
                Scheme.OUTC: self.eff_outc, Scheme.GRID2D: self.eff_grid}[scheme]

    def topo_factor(self) -> float:
        """Multiplier on bytes-on-busiest-link."""
        return {Topology.RING: 1.0, Topology.PS: 2.0, Topology.MESH: 0.7}[
            self.topology]

    def comm_time_s(self, bytes_busiest: float, n_messages: int = 2) -> float:
        if bytes_busiest <= 0.0:
            return 0.0
        bw = self.bandwidth_gbps * 1e9 / 8.0  # bytes/s
        return (bytes_busiest * self.topo_factor() / bw
                + n_messages * self.link_latency_us * 1e-6)


def compute_time_s(layer: LayerSpec, scheme: Scheme, tb: Testbed,
                   extra_halo: int = 0) -> float:
    """i-Estimator ground truth: straggler compute time of one layer."""
    work = shard_work(layer, scheme, tb.nodes, extra_halo=extra_halo)
    eff = tb.efficiency(scheme)
    # depthwise conv sustains lower utilization (low arithmetic intensity)
    if layer.conv_t == ConvT.DWCONV:
        eff *= 0.45
    elif layer.conv_t == ConvT.POOL:
        eff *= 0.60
    elif layer.conv_t in (ConvT.ADD, ConvT.CONCAT):
        eff *= 0.30
    return work.straggler_flops / (tb.device_gflops * 1e9 * eff)


def sync_time_s(layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
                dst: Optional[Scheme], tb: Testbed) -> float:
    """s-Estimator ground truth: time to make ``layer``'s output available in
    the layout the next layer's scheme requires (T-mode boundary).

    ``nxt=None`` means final layer: outputs are gathered to node 0.
    """
    if nxt is None or dst is None:
        total = layer.out_elems() * 4.0
        return tb.comm_time_s(total * (tb.nodes - 1) / tb.nodes,
                              n_messages=tb.nodes - 1)
    if src == dst and src.spatial:
        b = boundary_bytes_same_scheme(layer, nxt, src, tb.nodes)
        return tb.comm_time_s(b, n_messages=2 if b else 0)
    b = relayout_bytes(layer, src, dst, tb.nodes)
    halo = 0.0
    if dst.spatial:
        halo = boundary_bytes_same_scheme(layer, nxt, dst, tb.nodes)
    return tb.comm_time_s(b + halo, n_messages=2 * (tb.nodes - 1))
