"""FlexPie reproduction: flexible combinatorial partition planning and
distributed execution for edge inference.

The curated surface — plan, then run:

    from repro import (Testbed, plan_search, AnalyticEstimator,
                       Session, ExecConfig, init_weights)

    res = plan_search(graph, AnalyticEstimator(), Testbed(nodes=4))
    out, stats = Session(graph, weights, res.plan, 4,
                         ExecConfig(executor="mesh")).run(x)

Autoregressive serving:

    from repro import TransformerSpec, DecodeSession, plan_decode

    spec = TransformerSpec(n_layers=2, d_model=256, n_heads=8, d_ff=1024)
    plan = plan_decode(spec, kv_len=2048, nodes=4).plan
    session = DecodeSession(spec, weights, plan, 4)

Deeper layers (cost physics, GBDT estimators, cluster simulator, elastic
replanning, observability) stay importable from their subpackages:
``repro.core``, ``repro.cluster``, ``repro.runtime``, ``repro.kernels``,
``repro.obs``, ``repro.launch``, ``repro.configs``.
"""
from repro.core import (AnalyticEstimator, ConvT, LayerSpec, Mode,
                        ModelGraph, Objective, Plan, Scheme, SearchResult,
                        Testbed, Topology, chain, fixed_plan, plan_search)
from repro.cluster import (ClusterSpec, cluster_plan_search, homogeneous,
                           mixed_fast_slow)
from repro.runtime import (DecodeSession, ExecConfig, ExecStats,
                           PagedKVCache, Session, TransformerSpec,
                           decode_graph, greedy_decode, init_transformer,
                           init_weights, plan_decode, prefill_graph,
                           reference_decode, run_reference)

__all__ = [
    # planning
    "AnalyticEstimator", "ConvT", "LayerSpec", "Mode", "ModelGraph",
    "Objective", "Plan", "Scheme", "SearchResult", "Testbed", "Topology",
    "chain", "fixed_plan", "plan_search",
    # clusters
    "ClusterSpec", "cluster_plan_search", "homogeneous", "mixed_fast_slow",
    # execution
    "ExecConfig", "Session", "ExecStats", "init_weights", "run_reference",
    # autoregressive serving
    "DecodeSession", "TransformerSpec", "PagedKVCache", "decode_graph",
    "prefill_graph", "init_transformer", "reference_decode",
    "greedy_decode", "plan_decode",
]
