"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q/k/v: [B, H, S, hd] (same head count; GQA expansion happens in the
    wrapper).  Naive softmax attention with causal / sliding-window mask."""
    B, H, S, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)   # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, *, padding: int = 0,
               stride: int = 1) -> jnp.ndarray:
    """x: [H, W, Cin]; w: [K, K, Cin, Cout].  -> [Ho, Wo, Cout]."""
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=[(padding, padding)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out[0].astype(x.dtype)


def dwconv2d_ref(x: jnp.ndarray, w: jnp.ndarray, *, padding: int = 0,
                 stride: int = 1) -> jnp.ndarray:
    """Depthwise reference: x [H, W, C]; w [K, K, 1, C]."""
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=[(padding, padding)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1])
    return out[0].astype(x.dtype)


def conv2d_shard_ref(x: jnp.ndarray, w: jnp.ndarray, *,
                     pads: Tuple[int, int, int, int] = (0, 0, 0, 0),
                     stride: int = 1,
                     depthwise: bool = False) -> jnp.ndarray:
    """Shard-layout reference with per-side zero pads (the oracle for
    :func:`repro.kernels.conv2d.conv2d_shard`)."""
    pt, pb, pl_, pr = pads
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=[(pt, pb), (pl_, pr)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1] if depthwise else 1)
    return out[0].astype(x.dtype)


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: [M, Cin] @ w: [Cin, Cout] in f32 accumulation."""
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)
