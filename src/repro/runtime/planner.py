"""FlexPie FCO applied to the TPU mesh: choose a Strategy per block class.

The mapping (DESIGN.md §3): each block class of the architecture becomes one
"layer" of a proxy :class:`ModelGraph`; the mesh's model axis plays the edge
cluster ("nodes" = model-axis size, "bandwidth" = ICI, "device_gflops" = one
chip's MXU peak).  The scheme alphabet is restricted to

    INH   -> "sp"  (sequence-parallel activations, replicated weights)
    OUTC  -> "tp"  (tensor-parallel weights — heads / FFN / experts)

and the T/NT alternative corresponds to re-gathering activations at the
block boundary vs. leaving them sharded through norm/residual (redundant
small-op compute).  We then run the *same* ``core.plan_search`` DP used on
the edge side, with a TPU-roofline estimator implementing the
``CostEstimator`` protocol — the paper's machinery end-to-end, new physics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cost import Testbed
from repro.core.dpp import Objective, plan_search
from repro.core.graph import ConvT, LayerSpec, ModelGraph
from repro.core.partition import Scheme
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.runtime.shard_plan import Strategy

_SCHEMES = (Scheme.INH, Scheme.OUTC)   # sp, tp


class TpuRooflineEstimator:
    """i/s-cost oracle for the proxy graph: roofline terms on a v5e mesh.

    ``layer.in_h`` = tokens per data-shard, ``in_c/out_c`` = matmul dims.
    ``extra_flop_factor`` folds attention-score FLOPs.  Infeasible schemes
    (non-divisible TP) return +inf, the divisibility rule of shard_plan.
    """

    def __init__(self, model_axis: int, divisible: dict,
                 kv_dim: Optional[dict] = None):
        self.m = model_axis
        self.divisible = divisible   # layer name -> TP divisibility ok?
        # attention layers under SP must all-gather K/V over the model axis
        # (hillclimb C lesson: this is what made SP lose for MLA/DeepSeek)
        self.kv_dim = kv_dim or {}

    def i_cost(self, layer, scheme, tb, extra_halo: int = 0) -> float:
        flops = layer.flops()
        t_ici = 0.0
        if scheme == Scheme.OUTC:
            if not self.divisible.get(layer.name, True):
                return float("inf")
            shard_flops = flops / self.m
            weight_bytes = layer.weight_elems() * 2 / self.m
        else:  # INH: sequence-parallel — weights replicated on each chip
            shard_flops = flops / self.m
            weight_bytes = layer.weight_elems() * 2
            kv = self.kv_dim.get(layer.name, 0)
            if kv:
                # gather K and V (bf16) for the full sequence per chip
                t_ici = (2.0 * layer.in_h * kv * 2.0
                         * (self.m - 1) / self.m) / ICI_BW
        act_bytes = (layer.in_elems() + layer.out_elems()) * 2 / self.m
        t_compute = shard_flops / (PEAK_FLOPS_BF16 * 0.5)
        t_memory = (weight_bytes + act_bytes) / HBM_BW
        return max(t_compute, t_memory) + t_ici

    def s_cost(self, layer, nxt, src, dst, tb) -> float:
        """Boundary re-layout on the model axis (ICI ring)."""
        out_bytes = layer.out_elems() * 2
        if nxt is None:
            return 0.0
        if src == dst:
            if src == Scheme.OUTC:
                # TP partial sums -> all-reduce 2x(m-1)/m
                return 2 * out_bytes * (self.m - 1) / self.m / ICI_BW
            return 0.0   # SP -> SP: already aligned
        # layout change (all-gather then re-shard)
        return out_bytes * (self.m - 1) / self.m / ICI_BW * 2


def _proxy_graph(cfg, tokens_per_dp: int, model_axis: int):
    """One FC layer per block class + divisibility/kv tables."""
    d = cfg.d_model
    layers = []
    div = {}
    kv_dim = {}
    m = model_axis

    def fc(name, cin, cout, extra=1.0, tp_ok=True, kv=0):
        layers.append(LayerSpec(name, ConvT.FC, tokens_per_dp, 1,
                                cin, cout, extra_flop_factor=extra))
        div[name] = tp_ok
        if kv:
            kv_dim[name] = kv

    if cfg.family in ("dense", "vlm", "moe"):
        hd = cfg.hd
        if cfg.mla:
            qk = cfg.mla.qk_nope + cfg.mla.qk_rope
            fc("attn", d, cfg.n_heads * qk,
               extra=1.0 + cfg.mla.kv_lora / qk,
               tp_ok=(cfg.n_heads * qk) % m == 0,
               # expanded-prefill K/V are per-head: the SP gather is huge
               kv=cfg.n_heads * (qk + cfg.mla.v_head))
        else:
            fc("attn", d, cfg.n_heads * hd,
               extra=2.0,   # k/v/o projections + scores folded
               tp_ok=(cfg.n_heads * hd) % m == 0 and (cfg.n_kv * hd) % m == 0,
               kv=2 * cfg.n_kv * hd)
        if cfg.moe:
            mo = cfg.moe
            active = mo.top_k + mo.n_shared
            fc("ffn", d, mo.d_ff_expert * active, extra=3.0,
               tp_ok=mo.d_ff_expert % m == 0 or mo.n_experts % m == 0)
        else:
            fc("ffn", d, cfg.d_ff, extra=3.0 if cfg.act == "swiglu" else 2.0,
               tp_ok=cfg.d_ff % m == 0)
    elif cfg.family == "hybrid":
        din = cfg.ssm.expand * d
        fc("ssm", d, din, extra=3.0, tp_ok=din % m == 0)
        fc("attn", d, cfg.n_heads * cfg.hd, extra=2.0,
           tp_ok=(cfg.n_heads * cfg.hd) % m == 0, kv=2 * cfg.n_kv * cfg.hd)
        fc("ffn", d, cfg.d_ff, extra=3.0, tp_ok=cfg.d_ff % m == 0)
    elif cfg.family == "ssm":
        fc("ssm", d, 6 * d, extra=1.0, tp_ok=d % m == 0)
        fc("ffn", d, cfg.d_ff, extra=2.0, tp_ok=cfg.d_ff % m == 0)
    elif cfg.family == "encdec":
        fc("attn", d, 4 * d, extra=2.0,
           tp_ok=(cfg.n_heads * cfg.hd) % m == 0, kv=2 * cfg.n_kv * cfg.hd)
        fc("ffn", d, cfg.d_ff, extra=2.0, tp_ok=cfg.d_ff % m == 0)
    return (ModelGraph(name=cfg.name + "-proxy", layers=_chainify(layers)),
            div, kv_dim)


def _chainify(layers):
    """Force chain consistency (proxy layers all share in_h=tokens, w=1)."""
    fixed = []
    for i, l in enumerate(layers):
        if i == 0:
            fixed.append(l)
        else:
            prev = fixed[-1]
            fixed.append(dataclasses.replace(l, in_h=prev.out_h,
                                             in_w=prev.out_w,
                                             in_c=prev.out_c))
    return tuple(fixed)


def choose_strategy(cfg, mesh, mode: str,
                    use_planner: bool = True,
                    objective: Objective = Objective.LATENCY,
                    latency_bound_s: Optional[float] = None) -> Strategy:
    """Run the FCO planner over the proxy graph and map schemes back.

    ``objective`` threads the serving objective through to the DP:
    ``Objective.THROUGHPUT`` picks the block strategy that maximizes
    steady-state pipelined step rate (decode serving, where batches
    stream through the mesh and ICI collectives overlap the next batch's
    compute), ``P99_BOUNDED`` constrains it to a per-step latency bound.
    The TPU roofline estimator is scalar-only, so these run the
    scalar-provider frontier path of ``plan_search``."""
    m = mesh.shape["model"]
    dpn = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            dpn *= mesh.shape[a]

    # resident decode weights when the TP-sharded model fits comfortably
    param_bytes = _param_bytes_estimate(cfg)
    resident = mode != "train" and param_bytes / m < 6e9

    if not use_planner:
        return Strategy(decode_resident=resident)

    tokens = 4096 if mode == "train" else (32768 if mode == "prefill" else 1)
    graph, div, kv_dim = _proxy_graph(cfg, max(1, tokens), m)
    est = TpuRooflineEstimator(m, div, kv_dim)
    tb = Testbed(nodes=m, bandwidth_gbps=ICI_BW * 8 / 1e9)
    res = plan_search(graph, est, tb, schemes=_SCHEMES, allow_fusion=True,
                      objective=objective, latency_bound_s=latency_bound_s)

    by_name = {}
    for layer, (scheme, _mode) in zip(graph.layers, res.plan.steps):
        by_name[layer.name] = "tp" if scheme == Scheme.OUTC else "sp"

    moe_mode = "ep"
    if cfg.moe and cfg.moe.n_experts % m != 0:
        moe_mode = "tp"
    return Strategy(attn=by_name.get("attn", "sp"),
                    ffn=by_name.get("ffn", "tp"),
                    moe=moe_mode,
                    fsdp=True,
                    decode_resident=resident)


def _param_bytes_estimate(cfg) -> float:
    d, L = cfg.d_model, cfg.n_layers
    per = 0.0
    if cfg.family in ("dense", "vlm"):
        per = (2 * d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_kv * cfg.hd
               + 3 * d * cfg.d_ff)
    elif cfg.family == "moe":
        mo = cfg.moe
        per = 3 * d * mo.d_ff_expert * (mo.n_experts + mo.n_shared)
        if cfg.mla:
            mla = cfg.mla
            per += (d * mla.q_lora + d * mla.kv_lora
                    + mla.kv_lora * cfg.n_heads * 256)
    elif cfg.family == "ssm":
        per = 6 * d * d + 2 * d * cfg.d_ff
    elif cfg.family == "hybrid":
        per = 3 * d * cfg.ssm.expand * d
    elif cfg.family == "encdec":
        per = 2 * (4 * d * d + 2 * d * cfg.d_ff)
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return (emb + L * per) * 2.0    # bf16
