"""Data-driven FCO, exactly as the paper runs it: collect traces from the
(simulated) testbed, train the GBDT i-/s-Estimators, plan with DPP, and
compare the data-driven plan against the oracle optimum across bandwidths
and topologies.

Run:  PYTHONPATH=src python examples/plan_edge_cnn.py [--samples 20000]
"""
import argparse
import sys
import time

from repro.core import AnalyticEstimator, Testbed, Topology
from repro.core.dpp import plan_search
from repro.core.partition import Mode
from repro.core.plan import plan_cost
from repro.configs.edge_models import EDGE_MODELS
from repro.sim import TraceConfig, train_estimators


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=20_000,
                    help="traces per estimator (paper: 330K)")
    ap.add_argument("--trees", type=int, default=60)
    args = ap.parse_args()

    print(f"collecting {args.samples} traces and training the estimators...")
    t0 = time.time()
    est = train_estimators(TraceConfig(n_samples=args.samples),
                           gbdt_kwargs=dict(n_estimators=args.trees,
                                            max_depth=7))
    print(f"  trained in {time.time() - t0:.1f}s")

    oracle = AnalyticEstimator()
    worst = 0.0
    for model, fn in EDGE_MODELS.items():
        g = fn()
        for bw in (5.0, 1.0, 0.5):
            for topo in (Topology.RING, Topology.PS):
                tb = Testbed(nodes=4, bandwidth_gbps=bw, topology=topo)
                plan = plan_search(g, est, tb).plan
                nt = sum(1 for _, m in plan.steps if m == Mode.NT)
                true_cost = plan_cost(g, plan, oracle, tb)
                opt = plan_search(g, oracle, tb).cost
                gap = true_cost / opt - 1
                worst = max(worst, gap)
                print(f"  {model:10s} bw={bw:3.1f} {topo.name:4s} "
                      f"NT={nt:2d}  data-driven={true_cost * 1e3:7.2f}ms "
                      f"oracle-opt={opt * 1e3:7.2f}ms gap={gap * 100:5.1f}%")
    print(f"worst gap: {worst * 100:.1f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
