"""DAG IR: validation, branch decomposition, Theorem-1 on branched graphs,
and exact engine reassembly through fork/merge topologies."""
import random

import jax
import jax.numpy as jnp
import pytest

from repro.core import (ALL_SCHEMES, AnalyticEstimator, ConvT, LayerSpec,
                        Mode, ModelGraph, Scheme, Testbed, Topology, chain,
                        fixed_plan, plan_cost, plan_feasible, plan_search)
from repro.core.estimator import (I_FEATURE_NAMES, S_FEATURE_NAMES,
                                  i_features, s_features)
from repro.core.exhaustive import enumerate_dag_plans, exhaustive_search
from repro.core.plan import dag_plan_cost
from repro.runtime.engine import init_weights, run_reference
from repro.runtime.session import Session

EST = AnalyticEstimator()


def _resnet_block_dag(h=16):
    """conv -> [conv, conv] + identity skip -> ADD -> conv."""
    return ModelGraph(name="rb", layers=(
        LayerSpec("c0", ConvT.CONV, h, h, 3, 8, 3, 1, 1),
        LayerSpec("ba", ConvT.CONV, h, h, 8, 8, 3, 1, 1, inputs=("c0",)),
        LayerSpec("bb", ConvT.CONV, h, h, 8, 8, 3, 1, 1, inputs=("ba",)),
        LayerSpec("add", ConvT.ADD, h, h, 8, 8, inputs=("bb", "c0")),
        LayerSpec("c1", ConvT.CONV, h, h, 8, 8, 3, 1, 1),
    ))


def _inception_dag(h=16):
    """stem -> {1x1, 1x1->3x3, pool} -> CONCAT -> head."""
    return ModelGraph(name="inc", layers=(
        LayerSpec("stem", ConvT.CONV, h, h, 3, 8, 3, 1, 1),
        LayerSpec("b1", ConvT.POINTWISE, h, h, 8, 4, 1, 1, 0,
                  inputs=("stem",)),
        LayerSpec("b2a", ConvT.POINTWISE, h, h, 8, 4, 1, 1, 0,
                  inputs=("stem",)),
        LayerSpec("b2b", ConvT.CONV, h, h, 4, 8, 3, 1, 1, inputs=("b2a",)),
        LayerSpec("b3", ConvT.POOL, h, h, 8, 8, 3, 1, 1, inputs=("stem",)),
        LayerSpec("cat", ConvT.CONCAT, h, h, 20, 20,
                  inputs=("b1", "b2b", "b3")),
        LayerSpec("head", ConvT.CONV, h, h, 20, 8, 3, 1, 1),
    ))


DAGS = {"resnet_block": _resnet_block_dag, "inception": _inception_dag}


# ---------------------------------------------------------------------------
# IR structure & validation
# ---------------------------------------------------------------------------

def test_chain_graphs_stay_chains():
    g = chain("c", [
        LayerSpec("a", ConvT.CONV, 8, 8, 3, 4, 3, 1, 1),
        LayerSpec("b", ConvT.CONV, 8, 8, 4, 4, 3, 1, 1),
    ])
    assert g.is_chain
    assert [br.ids for br in g.linearize()] == [(0, 1)]
    assert g.producer_ids == ((-1,), (0,))


def test_linearize_resnet_block():
    g = _resnet_block_dag()
    assert not g.is_chain
    assert [br.ids for br in g.linearize()] == [(0,), (1, 2), (3, 4)]
    assert g.fan_out(0) == 2 and g.fan_in(3) == 2


def test_linearize_inception():
    g = _inception_dag()
    assert [br.ids for br in g.linearize()] == [(0,), (1,), (2, 3), (4,),
                                                (5, 6)]
    assert g.fan_in(5) == 3


def test_dag_validation_rejects_bad_shapes():
    with pytest.raises(ValueError):   # ADD input channel mismatch
        ModelGraph(name="bad", layers=(
            LayerSpec("a", ConvT.CONV, 8, 8, 3, 4, 3, 1, 1),
            LayerSpec("b", ConvT.CONV, 8, 8, 4, 8, 3, 1, 1, inputs=("a",)),
            LayerSpec("add", ConvT.ADD, 8, 8, 8, 8, inputs=("b", "a")),
        ))
    with pytest.raises(ValueError):   # CONCAT channel sum mismatch
        ModelGraph(name="bad", layers=(
            LayerSpec("a", ConvT.CONV, 8, 8, 3, 4, 3, 1, 1),
            LayerSpec("b", ConvT.CONV, 8, 8, 4, 4, 3, 1, 1, inputs=("a",)),
            LayerSpec("cat", ConvT.CONCAT, 8, 8, 12, 12, inputs=("b", "a")),
        ))
    with pytest.raises(ValueError):   # unknown producer
        ModelGraph(name="bad", layers=(
            LayerSpec("a", ConvT.CONV, 8, 8, 3, 4, 3, 1, 1),
            LayerSpec("b", ConvT.CONV, 8, 8, 4, 4, 3, 1, 1, inputs=("zz",)),
        ))
    with pytest.raises(ValueError):   # fan-in >= 2 on a non-merge layer
        ModelGraph(name="bad", layers=(
            LayerSpec("a", ConvT.CONV, 8, 8, 3, 4, 3, 1, 1),
            LayerSpec("b", ConvT.CONV, 8, 8, 4, 4, 3, 1, 1, inputs=("a",)),
            LayerSpec("c", ConvT.CONV, 8, 8, 4, 4, 3, 1, 1,
                      inputs=("a", "b")),
        ))


def test_merge_consuming_graph_input_validates_and_runs():
    """@input is a first-class producer: its shape (layer 0's input) counts
    in merge validation, and the engine executes the two-tower exactly."""
    from repro.core import GRAPH_INPUT
    with pytest.raises(ValueError):   # 8 + 3 input channels != declared 8
        ModelGraph(name="bad", layers=(
            LayerSpec("c0", ConvT.CONV, 8, 8, 3, 8, 3, 1, 1),
            LayerSpec("cat", ConvT.CONCAT, 8, 8, 8, 8,
                      inputs=("c0", GRAPH_INPUT)),
        ))
    g = ModelGraph(name="tower", layers=(
        LayerSpec("c0", ConvT.CONV, 8, 8, 3, 8, 3, 1, 1),
        LayerSpec("cat", ConvT.CONCAT, 8, 8, 11, 11,
                  inputs=("c0", GRAPH_INPUT)),
        LayerSpec("head", ConvT.CONV, 8, 8, 11, 4, 3, 1, 1),
    ))
    key = jax.random.PRNGKey(3)
    ws = init_weights(g, key)
    x = jax.random.normal(key, (8, 8, 3))
    ref = run_reference(g, ws, x)
    for scheme in ALL_SCHEMES:
        out, _ = Session(g, ws, fixed_plan(g, scheme), 3).run(x)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_dag_plan_validation_forces_junction_sync():
    g = _resnet_block_dag()
    steps = [(Scheme.INH, Mode.T)] * len(g)
    steps[0] = (Scheme.INH, Mode.NT)   # fork layer fused -> invalid
    from repro.core.plan import Plan
    with pytest.raises(ValueError):
        Plan(tuple(steps)).validate_for(g)


# ---------------------------------------------------------------------------
# Theorem 1 extended to DAGs: DPP == exhaustive oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", sorted(DAGS))
@pytest.mark.parametrize("seed", range(4))
def test_dag_dpp_matches_exhaustive(model, seed):
    rng = random.Random(seed)
    g = DAGS[model]()
    tb = Testbed(nodes=rng.choice([3, 4, 5]),
                 bandwidth_gbps=rng.choice([0.5, 1.0, 5.0]),
                 topology=Topology(rng.randint(0, 2)))
    _, best = exhaustive_search(g, EST, tb)
    res = plan_search(g, EST, tb)
    assert res.cost == pytest.approx(best, rel=1e-12)
    # the returned plan's independently-evaluated cost equals the DP value
    assert plan_cost(g, res.plan, EST, tb) == pytest.approx(res.cost,
                                                            rel=1e-9)
    assert plan_feasible(g, res.plan, tb.nodes)


@pytest.mark.parametrize("model", list(DAGS))
@pytest.mark.parametrize("nodes", [3, 4, 5])
def test_dag_batched_search_bit_matches_reference(model, nodes):
    """Batched DAG composition returns the scalar reference's exact plan
    and cost on the branched configs."""
    from repro.core import plan_search_reference
    g = DAGS[model]()
    tb = Testbed(nodes=nodes, bandwidth_gbps=1.0)
    res = plan_search(g, EST, tb)
    ref = plan_search_reference(g, EST, tb)
    assert res.plan == ref.plan
    assert res.cost == ref.cost


def test_dag_cost_reduces_to_chain_cost():
    """On a single-branch graph the DAG semantics equal the chain ones."""
    layers = (
        LayerSpec("a", ConvT.CONV, 16, 16, 3, 8, 3, 1, 1),
        LayerSpec("b", ConvT.DWCONV, 16, 16, 8, 8, 3, 1, 1),
        LayerSpec("c", ConvT.POINTWISE, 16, 16, 8, 16, 1, 1, 0),
    )
    g = chain("c3", layers)
    tb = Testbed(nodes=4)
    for plan in [fixed_plan(g, s) for s in ALL_SCHEMES]:
        assert dag_plan_cost(g, plan, EST, tb) == pytest.approx(
            plan_cost(g, plan, EST, tb), rel=1e-12)


def test_dag_flexpie_dominates_fixed_schemes():
    for model in sorted(DAGS):
        g = DAGS[model]()
        tb = Testbed(nodes=4, bandwidth_gbps=1.0)
        flex = plan_search(g, EST, tb).cost
        for s in ALL_SCHEMES:
            assert flex <= plan_cost(g, fixed_plan(g, s), EST, tb) + 1e-12


# ---------------------------------------------------------------------------
# Engine: exact reassembly through branches
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=sorted(DAGS))
def dag_setup(request):
    g = DAGS[request.param]()
    key = jax.random.PRNGKey(0)
    ws = init_weights(g, key)
    x = jax.random.normal(key, (16, 16, 3))
    return g, ws, x, run_reference(g, ws, x)


@pytest.mark.parametrize("nodes", [3, 4, 5])
@pytest.mark.parametrize("scheme", list(ALL_SCHEMES))
def test_dag_fixed_schemes_exact(dag_setup, nodes, scheme):
    g, ws, x, ref = dag_setup
    out, _ = Session(g, ws, fixed_plan(g, scheme), nodes).run(x)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


@pytest.mark.parametrize("nodes", [3, 4])
@pytest.mark.parametrize("bw", [0.5, 5.0])
def test_dag_flexpie_plans_exact(dag_setup, nodes, bw):
    g, ws, x, ref = dag_setup
    plan = plan_search(g, EST, Testbed(nodes=nodes, bandwidth_gbps=bw)).plan
    out, stats = Session(g, ws, plan, nodes).run(x)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
    assert stats.sync_points >= len(g.linearize())


def test_dag_random_valid_plans_exact(dag_setup):
    """Theorem-1 reassembly property: EVERY valid branched plan is exact."""
    g, ws, x, ref = dag_setup
    rng = random.Random(0)
    plans = [p for p in enumerate_dag_plans(g) if plan_feasible(g, p, 4)]
    rng.shuffle(plans)
    for plan in plans[:12]:
        out, _ = Session(g, ws, plan, 4).run(x)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_dag_add_actually_adds():
    """The residual edge is real: zeroing the skip branch changes output."""
    g = _resnet_block_dag()
    key = jax.random.PRNGKey(1)
    ws = init_weights(g, key)
    x = jax.random.normal(key, (16, 16, 3))
    ref = run_reference(g, ws, x)
    # same layers with the skip deliberately dropped: must differ
    with pytest.raises(ValueError):
        chain("rb_chain", g.layers)   # silent edge-stripping is rejected
    g_chain = chain("rb_chain", g.layers, drop_edges=True)
    ref_chain = run_reference(g_chain, ws, x)
    assert float(jnp.max(jnp.abs(ref - ref_chain))) > 1e-3


def test_resnet18_slice_executes_exactly():
    """A real branched benchmark prefix stays exact under the planner."""
    from repro.configs.edge_models import resnet18
    g_full = resnet18(width=32)
    ids = range(0, 8)   # conv1, maxpool, b0(a,b,+), b1(a,b,+)
    sub = ModelGraph(name="r18_prefix",
                     layers=tuple(g_full.layers[i] for i in ids))
    key = jax.random.PRNGKey(2)
    ws = init_weights(sub, key)
    x = jax.random.normal(key, (32, 32, 3))
    ref = run_reference(sub, ws, x)
    plan = plan_search(sub, EST, Testbed(nodes=4, bandwidth_gbps=0.5)).plan
    out, _ = Session(sub, ws, plan, 4).run(x)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


# ---------------------------------------------------------------------------
# Feature expression (satellite: docstring/feature-dim contract)
# ---------------------------------------------------------------------------

def test_feature_vector_matches_estimator_names():
    l = LayerSpec("add", ConvT.ADD, 8, 8, 4, 4, inputs=("a", "b", "c"))
    tb = Testbed()
    assert len(i_features(l, Scheme.INH, tb, 0)) == len(I_FEATURE_NAMES)
    assert len(s_features(l, l, Scheme.INH, Scheme.INW, tb)) == \
        len(S_FEATURE_NAMES)
    # fan-in is a real feature: merge structure is visible to the GBDTs
    fi = I_FEATURE_NAMES.index("FanIn")
    assert i_features(l, Scheme.INH, tb, 0)[fi] == 3.0
    l1 = LayerSpec("conv", ConvT.CONV, 8, 8, 4, 4, 3, 1, 1)
    assert i_features(l1, Scheme.INH, tb, 0)[fi] == 1.0
