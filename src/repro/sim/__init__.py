"""Edge-testbed simulator: the stand-in for the paper's SRIO DSP cluster."""
from .trace import (TraceConfig, generate_i_traces, generate_s_traces,
                    train_estimators)

__all__ = ["TraceConfig", "generate_i_traces", "generate_s_traces",
           "train_estimators"]
