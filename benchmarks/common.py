"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable, Tuple

from repro.core import AnalyticEstimator

EST = AnalyticEstimator()


def time_call(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out   # us


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def trace_dir_arg(argv):
    """Parse an optional ``--trace-dir PATH`` flag (shared by run.py and
    the mesh/churn bench CLIs).  Returns None when absent."""
    if "--trace-dir" not in argv:
        return None
    i = argv.index("--trace-dir")
    if i + 1 >= len(argv) or argv[i + 1].startswith("-"):
        raise SystemExit("--trace-dir requires a PATH argument")
    return argv[i + 1]


def json_arg(argv, default: str = "BENCH_search.json"):
    """Parse an optional ``--json [PATH]`` flag (shared by run.py and
    search_time's CLI).  Returns None when absent, ``default`` when the
    flag has no value (or the next token is another flag)."""
    if "--json" not in argv:
        return None
    i = argv.index("--json")
    if i + 1 < len(argv) and not argv[i + 1].startswith("-"):
        return argv[i + 1]
    return default
