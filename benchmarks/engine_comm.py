"""Engine-measured communication vs the paper's qualitative claims: OutC
gathers the whole map (Fig. 1c); NT fusion trades compute for comm (§2.3)."""
from __future__ import annotations

import jax

from repro.core import Testbed, chain
from repro.core.dpp import plan_search
from repro.core.partition import Scheme
from repro.core.plan import fixed_plan
from repro.configs.edge_models import mobilenet_v1
from repro.runtime.engine import init_weights, run_reference
from repro.runtime.session import Session

from .common import EST, emit, time_call


def run() -> None:
    g_full = mobilenet_v1(width=56)
    g = chain("mb56_prefix", g_full.layers[:9])
    key = jax.random.PRNGKey(0)
    ws = init_weights(g, key)
    x = jax.random.normal(key, (56, 56, 3))
    ref = run_reference(g, ws, x)

    plans = {
        "inh": fixed_plan(g, Scheme.INH),
        "outc": fixed_plan(g, Scheme.OUTC),
        "grid2d": fixed_plan(g, Scheme.GRID2D),
        "flexpie": plan_search(g, EST, Testbed(nodes=4,
                                               bandwidth_gbps=0.5)).plan,
    }
    import jax.numpy as jnp
    for name, plan in plans.items():
        us, (out, stats) = time_call(
            lambda plan=plan: Session(g, ws, plan, 4).run(x), repeats=1)
        exact = float(jnp.max(jnp.abs(out - ref))) < 1e-4
        emit(f"engine/{name}", us,
             f"recv_KB={stats.bytes_received / 1e3:.1f};"
             f"sync_points={stats.sync_points};exact={exact}")


if __name__ == "__main__":
    run()
